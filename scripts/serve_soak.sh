#!/usr/bin/env bash
# Serve-soak: run the long-lived serving runtime (DESIGN.md §8) with ≥ 4
# workers and mixed Release+Lp tenants for a fixed job count, then assert
# from the emitted metrics JSON that
#   1. the drain was clean (process exited 0, all admitted jobs completed,
#      none failed), and
#   2. no tenant's spent ε exceeds the per-tenant cap.
# The same check runs in CI (.github/workflows/ci.yml, serve-soak job).
#
#   ./scripts/serve_soak.sh [JOBS] [WORKERS] [TENANTS] [EPS_PER_TENANT]
set -euo pipefail
source "$(dirname "$0")/smoke_lib.sh"
smoke_cd_root

JOBS="${1:-30}"
WORKERS="${2:-4}"
TENANTS="${3:-3}"
EPS_CAP="${4:-6.0}"
OUT="${SOAK_METRICS_OUT:-soak_metrics.json}"

# `timeout` bounds the run so a drain deadlock fails the gate instead of
# hanging it.
timeout 900 cargo run --release -- serve --daemon \
    "--jobs=$JOBS" "--workers=$WORKERS" "--tenants=$TENANTS" \
    "--eps-per-tenant=$EPS_CAP" --queue-depth=8 --policy=block \
    "--metrics-out=$OUT"

smoke_assert_clean_drain "$OUT"
smoke_assert_caps "$OUT" "$EPS_CAP"

python3 - "$OUT" "$EPS_CAP" <<'EOF'
import json, sys

metrics = json.load(open(sys.argv[1]))
counters = metrics["counters"]

timings = metrics["timings"]
assert "latency_release" in timings and "latency_lp" in timings, (
    "soak must exercise both job kinds: " f"{sorted(timings)}"
)
spent = {k: v for k, v in metrics["gauges"].items()
         if k.startswith("tenant_") and k.endswith("_eps_spent")}
print(f"soak OK: {counters['jobs_completed']} jobs completed, "
      f"{counters.get('jobs_denied_budget', 0)} denied at admission, "
      f"{len(spent)} tenants all within cap {sys.argv[2]}")
EOF
