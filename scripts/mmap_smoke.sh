#!/usr/bin/env bash
# Larger-than-RAM paging smoke (DESIGN.md §12), runnable locally and in CI:
#
#   ./scripts/mmap_smoke.sh [STORE_DIR]
#
# Exercises the zero-copy restore path across processes:
#
#   1. serve a batch against a fresh artifact store — every index is a
#      cold build and is persisted as a page-aligned v3 artifact;
#   2. serve the same batch again under a 1 MiB heap budget — every index
#      must come back from the store (store_hit > 0, store_miss == 0) and
#      every promotion must page through the mmap pager rather than the
#      copying decode path (store_mmap_restore > 0, store_decode_restore
#      == 0), with the L1 byte gauge published for the budget to act on.
#
# The decode==0 assertion is safe on the Linux CI runners: the mapped
# restore only falls back to a heap decode where mmap is unavailable.
set -euo pipefail
source "$(dirname "$0")/smoke_lib.sh"
smoke_cd_root

STORE="${1:-/tmp/fastmwem-mmap-smoke}"
rm -rf "$STORE"

smoke_build

echo "== 1. cold serve: build and persist paged artifacts =="
cargo run --release -- serve --jobs=8 --workers=2 --workloads=4 --store-dir="$STORE"

echo "== 2. budget-constrained serve: restore by paging, never by decoding =="
out=$(cargo run --release -- serve --jobs=8 --workers=2 --workloads=4 \
    --store-dir="$STORE" --heap-budget-mb=1)
echo "$out"

smoke_out_counter_pos "$out" store_hit \
    "restarted serve must restore indices from the store"
smoke_out_counter_zero "$out" store_miss \
    "restarted serve must rebuild zero indices"
smoke_out_counter_pos "$out" store_mmap_restore \
    "budget-constrained restores must page via mmap"
smoke_out_counter_zero "$out" store_decode_restore \
    "budget-constrained restores must never heap-decode"
echo "$out" | grep -q '"index_cache_bytes":' \
    || { echo "FAIL: serve must publish the index_cache_bytes gauge"; exit 1; }

echo "mmap smoke passed"
