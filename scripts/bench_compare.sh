#!/usr/bin/env bash
# Perf-regression gate, runnable locally and in CI:
#
#   ./scripts/bench_compare.sh                 # compare existing JSON artifacts
#   ./scripts/bench_compare.sh --run           # regenerate them first (quick mode)
#
# Compares the fresh bench artifacts (BENCH_hot_paths.json +
# BENCH_serving.json) against the committed BENCH_baseline.json and exits
# nonzero if any tracked warm-path metric regressed beyond the tolerance.
# Tracked metrics include the dynamic-workload axis
# `dynamic.patch_over_rebuild` (incrementally patching 1% of a workload's
# rows vs a full index rebuild; the baseline bound enforces the >= 5x
# acceptance bar — DESIGN.md §9). The comparison itself is
# `repro bench-compare` (rust/src/main.rs), so the gate has no dependency
# beyond cargo.
set -euo pipefail
cd "$(dirname "$0")/.."

if [ "${1:-}" = "--run" ]; then
    echo "== regenerating bench artifacts (quick mode) =="
    cargo bench --bench hot_paths -- --quick --json=BENCH_hot_paths.json
    cargo bench --bench serving -- --quick --json=BENCH_serving.json
fi

for f in BENCH_hot_paths.json BENCH_serving.json; do
    if [ ! -f "$f" ]; then
        echo "missing $f — run './scripts/bench_compare.sh --run' to generate it" >&2
        exit 1
    fi
done

echo "== perf-regression gate: fresh benches vs BENCH_baseline.json =="
cargo run --release --quiet -- bench-compare \
    --baseline=BENCH_baseline.json \
    --fresh=BENCH_hot_paths.json,BENCH_serving.json
