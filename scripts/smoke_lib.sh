# Shared harness for the smoke/soak scripts (sourced, never executed):
# repo-root discovery, release builds, wire-daemon spawn / wait-for-listen,
# and the metrics-JSON assertions every gate repeats. Used by
# serve_soak.sh, dynamic_smoke.sh, mmap_smoke.sh, wire_soak.sh and
# multiproc_smoke.sh so the five gates speak one dialect and a harness
# fix lands everywhere at once.
#
# Conventions: callers run `set -euo pipefail` themselves; helpers print a
# "FAIL: ..." line and return nonzero instead of exiting, so callers keep
# control of cleanup.

# cd to the repository root (the scripts all live in scripts/).
smoke_cd_root() {
    cd "$(dirname "${BASH_SOURCE[1]}")/.."
}

# Build the release binary once; SMOKE_SKIP_BUILD=1 skips (CI builds in a
# prior step and the smokes must not pay it twice).
smoke_build() {
    if [ "${SMOKE_SKIP_BUILD:-0}" != "1" ]; then
        cargo build --release
    fi
}

# smoke_spawn_daemon LOG ARGS... — start a bounded wire daemon in the
# background, stdout+stderr to LOG, and leave its pid in
# SMOKE_DAEMON_PID (not echoed: command substitution would orphan the
# daemon into a subshell and break the caller's `wait`). `timeout`
# bounds the run so a drain deadlock fails the gate instead of hanging it.
smoke_spawn_daemon() {
    local log="$1"; shift
    timeout "${SMOKE_TIMEOUT:-900}" ./target/release/repro serve --daemon \
        "$@" > "$log" 2>&1 &
    SMOKE_DAEMON_PID=$!
}

# smoke_wait_listen LOG — poll LOG for the daemon's listen line and echo
# the bound address; fails (with the log) if it never appears.
smoke_wait_listen() {
    local log="$1" addr=""
    for _ in $(seq 1 "${SMOKE_LISTEN_TRIES:-150}"); do
        addr=$(grep -m1 -oE 'wire: listening on [0-9.]+:[0-9]+' "$log" \
            | awk '{print $4}' || true)
        [ -n "$addr" ] && { echo "$addr"; return 0; }
        sleep 0.2
    done
    echo "FAIL: daemon never reported its listen address (log: $log)" >&2
    cat "$log" >&2
    return 1
}

# smoke_counter FILE NAME — a counter's value from a metrics JSON dump
# (0 when absent, matching the Metrics counter semantics).
smoke_counter() {
    python3 - "$1" "$2" <<'EOF'
import json, sys
print(json.load(open(sys.argv[1])).get("counters", {}).get(sys.argv[2], 0))
EOF
}

# smoke_assert_clean_drain FILE — the drain contract every daemon gate
# shares: zero failed jobs and every admitted job completed.
smoke_assert_clean_drain() {
    python3 - "$1" <<'EOF'
import json, sys
c = json.load(open(sys.argv[1]))["counters"]
assert c.get("jobs_failed", 0) == 0, f"failed jobs: {c}"
assert c["jobs_completed"] == c["jobs_admitted"], (
    "clean drain must complete every admitted job: " f"{c}")
EOF
}

# smoke_assert_caps FILE CAP — no tenant's spent ε exceeds the cap, and
# more than one tenant actually ran.
smoke_assert_caps() {
    python3 - "$1" "$2" <<'EOF'
import json, sys
m = json.load(open(sys.argv[1]))
cap = float(sys.argv[2])
g = m["gauges"]
assert g["tenant_eps_cap"] == cap
spent = {k: v for k, v in g.items()
         if k.startswith("tenant_") and k.endswith("_eps_spent")}
assert len(spent) >= 2, f"expected multiple tenants, got {spent}"
over = {k: v for k, v in spent.items() if v > cap + 1e-9}
assert not over, f"tenants over their cap: {over}"
EOF
}

# smoke_out_counter_pos OUT NAME — assert a serve run's stdout metrics
# JSON shows counter NAME > 0.
smoke_out_counter_pos() {
    echo "$1" | grep -Eq "\"$2\":[1-9]" \
        || { echo "FAIL: expected $2 > 0 — $3"; return 1; }
}

# smoke_out_counter_zero OUT NAME — assert counter NAME == 0.
smoke_out_counter_zero() {
    echo "$1" | grep -Eq "\"$2\":0[,}]" \
        || { echo "FAIL: expected $2 == 0 — $3"; return 1; }
}
