#!/usr/bin/env bash
# Wire-soak (DESIGN.md §11): boot the serving daemon behind its HTTP front
# end, drive it with N concurrent keep-alive connections of mixed-tenant
# traffic, and assert the wire contract end to end:
#   1. every response is a 200 whose body is BYTE-IDENTICAL to the
#      in-process oracle (`repro job --body=...` for the same spec),
#   2. request p99 stays under a bound,
#   3. `POST /v1/shutdown` drains cleanly (process exits 0, every admitted
#      job completed, no failures, no connections left open),
#   4. zero parse errors, and no tenant spends past its ε cap.
# The same check runs in CI (.github/workflows/ci.yml, wire-soak job),
# which uploads the metrics JSON as an artifact.
#
#   ./scripts/wire_soak.sh [CONNS] [REQS_PER_CONN] [EPS_PER_TENANT] [P99_MS]
set -euo pipefail
source "$(dirname "$0")/smoke_lib.sh"
smoke_cd_root

CONNS="${1:-16}"
REQS="${2:-3}"
EPS_CAP="${3:-6.0}"
P99_MS="${4:-15000}"
OUT="${WIRE_METRICS_OUT:-wire_metrics.json}"
LOG="${WIRE_LOG:-wire_soak.log}"
ORACLE_DIR="${WIRE_ORACLE_DIR:-wire_oracle}"

smoke_build

# The fixed spec set: one release + one lp per tenant, seeds pinned. Every
# wire response is compared byte-for-byte against the in-process oracle
# for its spec, so the soak checks determinism, not just availability.
mkdir -p "$ORACLE_DIR"
BODIES_FILE="$ORACLE_DIR/bodies.tsv"
: > "$BODIES_FILE"
i=0
for tenant in 0 1 2 3; do
    rel="{\"kind\":\"release\",\"u\":128,\"m\":400,\"n\":400,\"t\":100,\"eps\":0.25,\"index\":\"hnsw\",\"workload\":$tenant,\"seed\":$((100 + tenant))}"
    lp="{\"kind\":\"lp\",\"m\":600,\"d\":10,\"t\":100,\"eps\":0.25,\"mode\":\"hnsw\",\"seed\":$((200 + tenant))}"
    for body in "$rel" "$lp"; do
        oracle="$ORACLE_DIR/spec_$i.txt"
        ./target/release/repro job "--body=$body" "--tenant=$tenant" > "$oracle"
        printf '%s\t%s\t%s\n' "$tenant" "$body" "$oracle" >> "$BODIES_FILE"
        i=$((i + 1))
    done
done

# Boot the daemon on an ephemeral port and wait for its listen line.
smoke_spawn_daemon "$LOG" --listen=127.0.0.1:0 \
    --workers=4 --queue-depth=16 --policy=block "--eps-per-tenant=$EPS_CAP" \
    "--conn-workers=$CONNS" --tenants=4 "--metrics-out=$OUT"
DAEMON=$SMOKE_DAEMON_PID

if ! ADDR=$(smoke_wait_listen "$LOG"); then
    kill "$DAEMON" 2>/dev/null || true
    exit 1
fi
echo "soaking $ADDR with $CONNS conns x $REQS requests"

python3 - "$ADDR" "$CONNS" "$REQS" "$P99_MS" "$BODIES_FILE" <<'EOF'
import http.client, sys, threading, time

addr, conns, reqs, p99_ms = sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), float(sys.argv[4])
host, port = addr.rsplit(":", 1)
specs = []  # (tenant, body, expected_bytes)
for line in open(sys.argv[5]):
    tenant, body, oracle = line.rstrip("\n").split("\t")
    specs.append((tenant, body, open(oracle, "rb").read().rstrip(b"\n")))

latencies, failures, lock = [], [], threading.Lock()

def drive(thread_id):
    try:
        c = http.client.HTTPConnection(host, int(port), timeout=300)
        for r in range(reqs):
            tenant, body, expected = specs[(thread_id + r) % len(specs)]
            t0 = time.monotonic()
            c.request("POST", "/v1/jobs", body=body,
                      headers={"Authorization": f"Bearer tenant-{tenant}"})
            resp = c.getresponse()
            got = resp.read()  # http.client de-frames chunked bodies
            dt = (time.monotonic() - t0) * 1e3
            with lock:
                latencies.append(dt)
                if resp.status != 200:
                    failures.append(f"conn {thread_id} req {r}: status {resp.status}: {got[:200]!r}")
                elif got != expected:
                    failures.append(
                        f"conn {thread_id} req {r}: wire bytes differ from oracle "
                        f"(wire {len(got)}B vs oracle {len(expected)}B) for {body[:80]}")
        c.close()
    except Exception as e:  # noqa: BLE001 - any transport failure fails the soak
        with lock:
            failures.append(f"conn {thread_id}: {type(e).__name__}: {e}")

threads = [threading.Thread(target=drive, args=(t,)) for t in range(conns)]
for t in threads: t.start()
for t in threads: t.join()

assert not failures, "soak failures:\n  " + "\n  ".join(failures)
assert len(latencies) == conns * reqs
latencies.sort()
p99 = latencies[int(0.99 * (len(latencies) - 1))]
assert p99 <= p99_ms, f"p99 {p99:.1f}ms exceeds the {p99_ms:.0f}ms bound"

# Graceful teardown over the wire.
c = http.client.HTTPConnection(host, int(port), timeout=60)
c.request("POST", "/v1/shutdown", headers={"Authorization": "Bearer tenant-0"})
assert c.getresponse().status == 200
print(f"drove {len(latencies)} requests: p50 {latencies[len(latencies)//2]:.1f}ms, "
      f"p99 {p99:.1f}ms (bound {p99_ms:.0f}ms), byte-identity held for all")
EOF

# The shutdown was posted by the driver; a clean drain must exit 0.
wait "$DAEMON"
echo "daemon drained cleanly"
tail -n 12 "$LOG"

smoke_assert_clean_drain "$OUT"
smoke_assert_caps "$OUT" "$EPS_CAP"

python3 - "$OUT" "$EPS_CAP" "$CONNS" "$REQS" <<'EOF'
import json, sys

metrics = json.load(open(sys.argv[1]))
cap, conns, reqs = float(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4])
counters = metrics["counters"]
gauges = metrics["gauges"]

assert counters.get("parse_errors", 0) == 0, f"parse errors on valid traffic: {counters}"
assert counters["http_200"] >= conns * reqs, f"missing successes: {counters}"
assert counters.get("http_400", 0) == 0 and counters.get("http_401", 0) == 0, (
    "valid authenticated traffic must never 4xx: " f"{counters}"
)
assert gauges.get("conns_open", 0) == 0, f"connections left open: {gauges}"

spent = {k: v for k, v in gauges.items()
         if k.startswith("tenant_") and k.endswith("_eps_spent")}

timings = metrics["timings"]
assert "wire_request" in timings, f"wire latency series missing: {sorted(timings)}"
assert "latency_release" in timings and "latency_lp" in timings, (
    "soak must exercise both job kinds: " f"{sorted(timings)}"
)
print(f"wire soak OK: {counters['jobs_completed']} jobs over "
      f"{counters['conns_accepted']} conns, {counters['bytes_out']} bytes out, "
      f"{len(spent)} tenants all within cap {cap}, zero parse errors")
EOF
