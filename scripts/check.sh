#!/usr/bin/env bash
# Repository gate: build, test, and documentation health in one command.
#
#   ./scripts/check.sh
#
# Steps:
#   1. cargo build --release            — the serving binary and library
#   2. cargo build --release --benches  — the harness-less bench binaries
#   3. cargo test -q                    — unit + integration tests (tier-1)
#   4. cargo doc --no-deps              — with rustdoc warnings denied, so
#      doc regressions (broken intra-doc links, bare URLs, malformed HTML)
#      fail fast. The crate carries #![warn(missing_docs)]; new public API
#      without docs shows up as warnings in steps 1-3.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --benches =="
cargo build --release --benches

echo "== cargo test -q =="
cargo test -q

echo "== cargo doc --no-deps (rustdoc warnings denied) =="
RUSTDOCFLAGS="-D rustdoc::broken-intra-doc-links -D rustdoc::invalid-html-tags -D rustdoc::bare-urls" \
    cargo doc --no-deps -q

echo "All checks passed."
