#!/usr/bin/env bash
# Repository gate: build, lint, test, and documentation health in one
# command — the same sequence `.github/workflows/ci.yml` runs on every
# push/PR.
#
#   ./scripts/check.sh
#
# Steps:
#   1. cargo build --release            — the serving binary and library
#   2. cargo build --release --benches  — the harness-less bench binaries
#   3. cargo fmt --check                — formatting is canonical rustfmt
#   4. cargo clippy --all-targets       — lints denied (-D warnings)
#   5. cargo test -q                    — unit + integration tests (tier-1)
#   6. cargo doc --no-deps              — with rustdoc warnings denied, so
#      doc regressions (broken intra-doc links, bare URLs, malformed HTML)
#      fail fast. The crate carries #![warn(missing_docs)]; new public API
#      without docs shows up as warnings in steps 1-2.
#
# Steps 3-4 need the rustfmt/clippy components; minimal local toolchains
# without them get a loud skip. In CI (CI=true) a missing component is a
# hard failure instead — otherwise the gate could go green without ever
# linting, and the skip would hide it.
set -euo pipefail
cd "$(dirname "$0")/.."

# A lint step whose tool is missing is a skip locally, a failure in CI.
missing_component() {
    local name="$1"
    if [ "${CI:-}" = "true" ]; then
        echo "== FAIL: $name component not installed, but CI=true requires it =="
        exit 1
    fi
    echo "== SKIP $name (component not installed) =="
}

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --benches =="
cargo build --release --benches

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --all -- --check
else
    missing_component "cargo fmt (rustfmt)"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy --all-targets (-D warnings) =="
    cargo clippy --all-targets -- -D warnings
else
    missing_component "cargo clippy"
fi

echo "== cargo test -q =="
cargo test -q

echo "== cargo doc --no-deps (rustdoc warnings denied) =="
RUSTDOCFLAGS="-D rustdoc::broken-intra-doc-links -D rustdoc::invalid-html-tags -D rustdoc::bare-urls" \
    cargo doc --no-deps -q

echo "All checks passed."
