#!/usr/bin/env bash
# Convex-loss release smoke (DESIGN.md §14), runnable locally and in CI:
#
#   ./scripts/convex_smoke.sh [STORE_DIR]
#
# Proves the query-class seam end to end on the serving path:
#
#   1. serve a batch of convex-lsq release jobs against a fresh artifact
#      store — every index over the embedded loss vectors is a cold build
#      and is persisted under a class-tagged workload fingerprint;
#   2. serve the same batch again — every index must come back from the
#      store (store_hit > 0, store_miss == 0), proving the class-salted
#      fingerprints round-trip through the tiered store;
#   3. a logistic-loss batch against the same store must make its own
#      fingerprints (no cross-class cache aliasing) yet still drain clean.
set -euo pipefail
source "$(dirname "$0")/smoke_lib.sh"
smoke_cd_root

STORE="${1:-/tmp/fastmwem-convex-smoke}"
rm -rf "$STORE"

smoke_build

echo "== 1. cold serve: build and persist convex-lsq class artifacts =="
cargo run --release -- serve --jobs=8 --workers=2 --workloads=4 \
    --class=convex-lsq --store-dir="$STORE"

echo "== 2. warm serve: class-tagged fingerprints must hit the store =="
out=$(cargo run --release -- serve --jobs=8 --workers=2 --workloads=4 \
    --class=convex-lsq --store-dir="$STORE")
echo "$out"

smoke_out_counter_pos "$out" store_hit \
    "restarted convex serve must restore indices from the store"
smoke_out_counter_zero "$out" store_miss \
    "restarted convex serve must rebuild zero indices"

echo "== 3. logistic class on the same store: no cross-class aliasing =="
out=$(cargo run --release -- serve --jobs=4 --workers=2 --workloads=2 \
    --class=convex-logistic --store-dir="$STORE")
echo "$out"

# A different class over the same workload ids must MISS (distinct
# fingerprints) — a hit here would mean logistic jobs served lsq indices.
smoke_out_counter_pos "$out" store_miss \
    "a new query class must not alias another class's artifacts"

echo "convex smoke passed"
