#!/usr/bin/env bash
# Dynamic-workload smoke (DESIGN.md §9), runnable locally and in CI:
#
#   ./scripts/dynamic_smoke.sh [STORE_DIR]
#
# Exercises the full update path across processes:
#   1. serve a batch against an artifact store (cold builds, snapshots
#      persisted at generation 0);
#   2. evolve one workload with `repro update-workload` (a compact delta
#      artifact lands next to the snapshots);
#   3. serve the same batch again: the untouched workloads restore from
#      their snapshots and the updated workload is patched forward from
#      snapshot + delta — store_hit > 0, zero cold rebuilds, at least one
#      patched promotion, and zero stale-generation serves.
set -euo pipefail
source "$(dirname "$0")/smoke_lib.sh"
smoke_cd_root

STORE="${1:-/tmp/fastmwem-dynamic-smoke}"
rm -rf "$STORE"

smoke_build

echo "== 1. cold serve: build + persist generation 0 =="
cargo run --release -- serve --jobs=8 --workers=2 --workloads=4 --store-dir="$STORE"

echo "== 2. evolve workload 0 (zero-eps update, delta artifact) =="
out_update=$(cargo run --release -- update-workload --workload=0 \
    --m=400 --u=256 --n=500 --insert=4 --tombstone=2 --store-dir="$STORE")
echo "$out_update"
echo "$out_update" | grep -q "generation 1" \
    || { echo "FAIL: update must report generation 1"; exit 1; }

echo "== 3. warm serve: restore + patch forward, never serve stale =="
out=$(cargo run --release -- serve --jobs=8 --workers=2 --workloads=4 --store-dir="$STORE")
echo "$out"

smoke_out_counter_pos "$out" store_hit \
    "restarted serve must restore indices"
smoke_out_counter_zero "$out" store_miss \
    "restarted serve must build zero indices"
smoke_out_counter_pos "$out" index_cache_patched \
    "the updated workload must be patched forward"
smoke_out_counter_zero "$out" stale_generation_serves \
    "a stale generation must never be served"

echo "dynamic-workload smoke passed"
