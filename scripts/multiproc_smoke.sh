#!/usr/bin/env bash
# Multi-process smoke (DESIGN.md §13): boot TWO wire daemons over ONE
# artifact store directory, drive a tenant-partitioned job mix at the
# fleet (tenant t -> daemon t % 2, the examples/router.rs partitioning),
# and assert the coordination contract from the merged metrics:
#   1. exactly one build per workload fingerprint fleet-wide — the sum of
#      `store_miss` across processes equals the number of distinct
#      workloads, and at least one process waited on a peer's build lease
#      (`lease_waited > 0`) because the mix opens with the SAME heavy
#      workload landing on both daemons at once;
#   2. a workload update committed by one process is adopted by the other
#      before it serves (`peer_invalidations > 0` fleet-wide and
#      `stale_generation_serves == 0` in EVERY process);
#   3. the fleet outruns a single daemon serving the identical mix
#      (aggregate throughput strictly above the one-process baseline);
#   4. both daemons drain cleanly on `POST /v1/shutdown` (exit 0, every
#      admitted job completed, none failed).
# The same check runs in CI (.github/workflows/ci.yml, multiproc-smoke
# job), which uploads the logs and metrics JSON on failure.
#
#   ./scripts/multiproc_smoke.sh [EPS_PER_TENANT]
set -euo pipefail
source "$(dirname "$0")/smoke_lib.sh"
smoke_cd_root

EPS_CAP="${1:-6.0}"
SCRATCH="${MULTIPROC_SCRATCH:-/tmp/fastmwem-multiproc-smoke}"
rm -rf "$SCRATCH"
mkdir -p "$SCRATCH"

smoke_build

# Drive the fixed mix at a fleet: tenants hash across the given addresses
# (tenant t -> addrs[t % N]), so one address gets the whole mix and two
# addresses split it — identical work either way, which is what makes the
# throughput comparison fair. Writes the drive's wall-clock seconds to
# ELAPSED_FILE.
drive_mix() {
    python3 - "$@" <<'EOF'
import http.client, sys, threading, time

elapsed_file, addrs = sys.argv[1], sys.argv[2:]
failures, lock = [], threading.Lock()

def post(addr, tenant, body):
    try:
        host, port = addr.rsplit(":", 1)
        c = http.client.HTTPConnection(host, int(port), timeout=600)
        c.request("POST", "/v1/jobs", body=body,
                  headers={"Authorization": f"Bearer tenant-{tenant}"})
        r = c.getresponse()
        data = r.read()
        c.close()
        if r.status != 200:
            raise AssertionError(f"status {r.status}: {data[:200]!r}")
    except Exception as e:  # noqa: BLE001 - any failure fails the smoke
        with lock:
            failures.append(f"tenant {tenant} -> {addr}: {e}")

def route(tenant):
    return addrs[tenant % len(addrs)]

def run_all(threads):
    for t in threads: t.start()
    for t in threads: t.join()
    assert not failures, "drive failures:\n  " + "\n  ".join(failures)

HEAVY = ('{"kind":"release","u":128,"m":1200,"n":400,"t":60,"eps":0.25,'
         '"index":"hnsw","workload":9,"seed":1}')
def rel(w, seed):
    return ('{"kind":"release","u":64,"m":300,"n":400,"t":50,"eps":0.25,'
            f'"index":"hnsw","workload":{w},"seed":{seed}}}')
UPDATE = '{"kind":"update","workload":0,"u":64,"m":300,"n":400,"insert":4,"tombstone":2}'

t0 = time.monotonic()

# 1. The SAME heavy workload lands everywhere at once: a shared cold miss
# that the build lease must collapse to one build fleet-wide.
run_all([threading.Thread(target=post, args=(route(t), t, HEAVY))
         for t in (0, 1)])

# 2. Four tenants sweep four workloads (16 jobs, the throughput body).
def sweep(tenant, seed_base):
    for w in range(4):
        post(route(tenant), tenant, rel(w, seed_base + tenant))
run_all([threading.Thread(target=sweep, args=(t, 10)) for t in range(4)])

# 3. One tenant evolves workload 0 from its side of the fleet...
post(route(0), 0, UPDATE)

# 4. ...and every tenant's next release of it — on BOTH daemons — must
# answer the new generation.
run_all([threading.Thread(target=post, args=(route(t), t, rel(0, 100 + t)))
         for t in range(4)])

elapsed = time.monotonic() - t0
open(elapsed_file, "w").write(f"{elapsed:.3f}")
print(f"  drove 23 jobs across {len(addrs)} daemon(s) in {elapsed:.1f}s")
EOF
}

post_shutdown() {
    python3 - "$1" <<'EOF'
import http.client, sys
host, port = sys.argv[1].rsplit(":", 1)
c = http.client.HTTPConnection(host, int(port), timeout=60)
c.request("POST", "/v1/shutdown", headers={"Authorization": "Bearer tenant-0"})
assert c.getresponse().status == 200
EOF
}

DAEMON_ARGS=(--workers=2 --queue-depth=16 --policy=block --tenants=4
    "--eps-per-tenant=$EPS_CAP" --conn-workers=8 --listen=127.0.0.1:0)

echo "== 1. baseline: ONE daemon serves the whole mix =="
smoke_spawn_daemon "$SCRATCH/base.log" "${DAEMON_ARGS[@]}" \
    --store-dir="$SCRATCH/base_store" "--metrics-out=$SCRATCH/base.json"
BASE_PID=$SMOKE_DAEMON_PID
BASE_ADDR=$(smoke_wait_listen "$SCRATCH/base.log") \
    || { kill "$BASE_PID" 2>/dev/null || true; exit 1; }
drive_mix "$SCRATCH/base_elapsed" "$BASE_ADDR"
post_shutdown "$BASE_ADDR"
wait "$BASE_PID"
smoke_assert_clean_drain "$SCRATCH/base.json"

echo "== 2. fleet: TWO daemons share one store, tenants partitioned =="
smoke_spawn_daemon "$SCRATCH/proc0.log" "${DAEMON_ARGS[@]}" \
    --store-dir="$SCRATCH/shared_store" "--metrics-out=$SCRATCH/proc0.json"
PID0=$SMOKE_DAEMON_PID
smoke_spawn_daemon "$SCRATCH/proc1.log" "${DAEMON_ARGS[@]}" \
    --store-dir="$SCRATCH/shared_store" "--metrics-out=$SCRATCH/proc1.json"
PID1=$SMOKE_DAEMON_PID
ADDR0=$(smoke_wait_listen "$SCRATCH/proc0.log") \
    || { kill "$PID0" "$PID1" 2>/dev/null || true; exit 1; }
ADDR1=$(smoke_wait_listen "$SCRATCH/proc1.log") \
    || { kill "$PID0" "$PID1" 2>/dev/null || true; exit 1; }
drive_mix "$SCRATCH/multi_elapsed" "$ADDR0" "$ADDR1"

# Clean drain on every process: shutdown over the wire, exit status 0.
post_shutdown "$ADDR0"
post_shutdown "$ADDR1"
wait "$PID0"
wait "$PID1"
smoke_assert_clean_drain "$SCRATCH/proc0.json"
smoke_assert_clean_drain "$SCRATCH/proc1.json"

echo "== 3. merged-metrics coordination contract =="
python3 - "$SCRATCH" <<'EOF'
import json, sys

scratch = sys.argv[1]
procs = [json.load(open(f"{scratch}/proc{i}.json"))["counters"] for i in (0, 1)]
base = json.load(open(f"{scratch}/base.json"))["counters"]
tot = lambda name: sum(c.get(name, 0) for c in procs)

# The mix touches 5 distinct workload fingerprints (workloads 0-3 + the
# heavy contended one). Exactly one process built each: every other
# lookup promoted a peer's committed artifact or hit L1.
DISTINCT = 5
assert base.get("store_miss", 0) == DISTINCT, f"baseline builds: {base}"
assert tot("store_miss") == DISTINCT, (
    f"fleet must build once per workload, not per process: "
    f"{[c.get('store_miss', 0) for c in procs]}")
assert tot("lease_waited") > 0, (
    "the shared cold miss must make one process wait on the peer's build "
    f"lease: {[c.get('lease_waited', 0) for c in procs]}")
assert tot("lease_acquired") == tot("store_miss"), (
    f"every build runs under a lease: {[c.get('lease_acquired', 0) for c in procs]}")

# The update committed by one process reached the other before it served.
assert tot("peer_invalidations") > 0, (
    f"the peer never adopted the update: {procs}")
for i, c in enumerate(procs):
    assert c.get("stale_generation_serves", 0) == 0, (
        f"proc{i} served a stale generation: {c}")
    assert "lease_takeovers" in c, f"proc{i} lease counters not materialized: {c}"

# Same 23-job mix, so throughput compares as inverse wall-clock.
base_s = float(open(f"{scratch}/base_elapsed").read())
multi_s = float(open(f"{scratch}/multi_elapsed").read())
assert multi_s < base_s, (
    f"two daemons must outrun one on the same mix: "
    f"fleet {multi_s:.1f}s vs single {base_s:.1f}s")

print(f"multiproc smoke OK: {tot('jobs_completed')} jobs over 2 procs, "
      f"{tot('store_miss')} builds for {DISTINCT} workloads "
      f"({tot('lease_waited')} lease waits, "
      f"{tot('peer_invalidations')} peer invalidations), "
      f"fleet {base_s / multi_s:.2f}x faster than one process")
EOF

echo "multiproc smoke passed"
