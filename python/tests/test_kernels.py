"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes (block-aligned and clamped), value ranges, and
signs; the allclose tolerances reflect f32 accumulation differences only.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import absdot, dot, make_matvec, mwu_update
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- absdot --


@pytest.mark.parametrize(
    "m,u",
    [(1, 1), (4, 8), (256, 512), (512, 1024), (300, 500), (1024, 37)],
)
def test_absdot_matches_ref(m, u):
    r = _rng(m * 1000 + u)
    q = r.uniform(0, 1, size=(m, u)).astype(np.float32)
    d = r.uniform(-1, 1, size=(u,)).astype(np.float32)
    got = absdot(jnp.asarray(q), jnp.asarray(d))
    want = ref.absdot_ref(jnp.asarray(q), jnp.asarray(d))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,u", [(4, 8), (256, 512), (128, 1024)])
def test_dot_matches_ref_signed(m, u):
    r = _rng(7 * m + u)
    q = r.normal(size=(m, u)).astype(np.float32)
    d = r.normal(size=(u,)).astype(np.float32)
    got = dot(jnp.asarray(q), jnp.asarray(d))
    want = ref.dot_ref(jnp.asarray(q), jnp.asarray(d))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_absdot_nonaligned_shapes_fall_back_to_divisor_blocks():
    # 300 rows with bm=256: block clamps to the largest divisor (150).
    mv = make_matvec(absolute=True, bm=256, bu=512)
    q = np.ones((300, 512), np.float32)
    d = np.full((512,), -0.5, np.float32)
    got = mv(jnp.asarray(q), jnp.asarray(d))
    np.testing.assert_allclose(got, np.abs(q @ d), rtol=1e-5)


def test_absdot_zero_padding_rows_score_zero():
    r = _rng(3)
    q = np.zeros((8, 16), np.float32)
    q[:5] = r.uniform(0, 1, size=(5, 16))
    d = r.normal(size=(16,)).astype(np.float32)
    got = np.asarray(absdot(jnp.asarray(q), jnp.asarray(d)))
    assert np.all(got[5:] == 0.0)


@settings(max_examples=25, deadline=None)
@given(
    mb=st.integers(1, 6),
    ub=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_absdot_hypothesis_sweep(mb, ub, seed, scale):
    m, u = mb * 64, ub * 64
    mv = make_matvec(absolute=True, bm=64, bu=64)
    r = _rng(seed)
    q = (r.uniform(0, 1, size=(m, u)) * scale).astype(np.float32)
    d = r.normal(size=(u,)).astype(np.float32)
    got = mv(jnp.asarray(q), jnp.asarray(d))
    want = ref.absdot_ref(jnp.asarray(q), jnp.asarray(d))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale)


# ------------------------------------------------------------------- mwu --


@pytest.mark.parametrize("u,s", [(8, -0.5), (512, 0.3), (1024, -1.0), (2048, 0.0)])
def test_mwu_update_matches_ref(u, s):
    r = _rng(u)
    w = r.uniform(0, 1, size=(u,)).astype(np.float32)
    c = r.uniform(0, 1, size=(u,)).astype(np.float32)
    w_new, psums = mwu_update(jnp.asarray(w), jnp.asarray(c), jnp.float32(s))
    want_w, want_z = ref.mwu_update_ref(jnp.asarray(w), jnp.asarray(c), s)
    np.testing.assert_allclose(w_new, want_w, rtol=1e-5)
    np.testing.assert_allclose(jnp.sum(psums), want_z, rtol=1e-5)


def test_mwu_zero_tail_stays_zero():
    w = np.zeros((1024,), np.float32)
    w[:100] = 0.5
    c = np.ones((1024,), np.float32)
    w_new, psums = mwu_update(jnp.asarray(w), jnp.asarray(c), jnp.float32(-0.7))
    w_new = np.asarray(w_new)
    assert np.all(w_new[100:] == 0.0)
    np.testing.assert_allclose(
        float(jnp.sum(psums)), float(np.sum(w_new)), rtol=1e-6
    )


@settings(max_examples=25, deadline=None)
@given(
    ub=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
    s=st.floats(-2.0, 2.0),
)
def test_mwu_hypothesis_sweep(ub, seed, s):
    u = ub * 128
    r = _rng(seed)
    w = r.uniform(1e-6, 1, size=(u,)).astype(np.float32)
    c = r.uniform(0, 1, size=(u,)).astype(np.float32)
    w_new, psums = mwu_update(jnp.asarray(w), jnp.asarray(c), jnp.float32(s))
    want_w, want_z = ref.mwu_update_ref(jnp.asarray(w), jnp.asarray(c), np.float32(s))
    np.testing.assert_allclose(w_new, want_w, rtol=1e-4)
    np.testing.assert_allclose(float(jnp.sum(psums)), float(want_z), rtol=1e-4)
