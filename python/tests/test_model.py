"""L2 semantics: model graphs vs numpy references and MWEM invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _rng(seed=0):
    return np.random.default_rng(seed)


def test_scores_fn_is_absdot():
    r = _rng(1)
    q = r.uniform(0, 1, size=(256, 512)).astype(np.float32)
    d = r.normal(size=(512,)).astype(np.float32)
    (got,) = model.scores_fn(jnp.asarray(q), jnp.asarray(d))
    np.testing.assert_allclose(got, np.abs(q @ d), rtol=1e-5, atol=1e-5)


def test_mwu_update_fn_normalizes():
    r = _rng(2)
    w = r.uniform(0.1, 1, size=(1024,)).astype(np.float32)
    c = r.uniform(0, 1, size=(1024,)).astype(np.float32)
    w_new, p_new = model.mwu_update_fn(
        jnp.asarray(w), jnp.asarray(c), jnp.float32(-0.4)
    )
    np.testing.assert_allclose(float(jnp.sum(p_new)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(w_new), w * np.exp(-0.4 * c), rtol=1e-5
    )


def test_mwem_step_fn_matches_numpy_reference():
    r = _rng(3)
    m, u = 256, 512
    q = (r.uniform(0, 1, size=(m, u)) < 0.25).astype(np.float32)
    h = r.uniform(0, 1, size=(u,)).astype(np.float32)
    h /= h.sum()
    w = np.ones((u,), np.float32)
    i_t, noise, s_scale = 17, 0.01, 0.5

    w_new, p_new, scores = model.mwem_step_fn(
        jnp.asarray(w),
        jnp.asarray(q),
        jnp.asarray(h),
        jnp.asarray(q[i_t]),
        jnp.float32(noise),
        jnp.float32(s_scale),
    )

    # numpy reference
    p = w / w.sum()
    m_t = q[i_t] @ h + noise
    s = s_scale * (m_t - q[i_t] @ p)
    w_want = w * np.exp(s * q[i_t])
    p_want = w_want / w_want.sum()
    scores_want = np.abs(q @ (h - p_want))

    np.testing.assert_allclose(np.asarray(w_new), w_want, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(p_new), p_want, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(scores), scores_want, rtol=1e-4, atol=1e-5)


def test_mwem_step_reduces_selected_query_error():
    """One classic-MWEM step against the worst query must shrink its error."""
    r = _rng(4)
    m, u = 256, 512
    q = (r.uniform(0, 1, size=(m, u)) < 0.25).astype(np.float32)
    h = r.uniform(0, 1, size=(u,)).astype(np.float32)
    h /= h.sum()
    w = np.ones((u,), np.float32)
    p0 = w / w.sum()
    errs = np.abs(q @ (h - p0))
    i_t = int(np.argmax(errs))
    _, p_new, scores = model.mwem_step_fn(
        jnp.asarray(w),
        jnp.asarray(q),
        jnp.asarray(h),
        jnp.asarray(q[i_t]),
        jnp.float32(0.0),
        jnp.float32(0.5),
    )
    assert float(np.asarray(scores)[i_t]) < float(errs[i_t])


def test_ref_step_consistency():
    """ref.mwem_step_ref agrees with the fused model step."""
    r = _rng(5)
    m, u = 256, 512
    q = r.uniform(0, 1, size=(m, u)).astype(np.float32)
    h = r.uniform(0, 1, size=(u,)).astype(np.float32)
    w = r.uniform(0.5, 1.5, size=(u,)).astype(np.float32)
    i_t = 9
    m_t = float(q[i_t] @ h) + 0.02
    w_ref, p_ref = ref.mwem_step_ref(
        jnp.asarray(w), jnp.asarray(q[i_t]), m_t, 0.5
    )
    w_got, p_got, _ = model.mwem_step_fn(
        jnp.asarray(w),
        jnp.asarray(q),
        jnp.asarray(h),
        jnp.asarray(q[i_t]),
        jnp.float32(0.02),
        jnp.float32(0.5),
    )
    np.testing.assert_allclose(np.asarray(w_got), np.asarray(w_ref), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(p_got), np.asarray(p_ref), rtol=1e-5)
