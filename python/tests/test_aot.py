"""AOT artifact emission: manifest structure + HLO text sanity."""

import json
import pathlib

import pytest

from compile import aot


@pytest.fixture(scope="module")
def small_build(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(out, "small")
    return out, manifest


def test_manifest_written(small_build):
    out, manifest = small_build
    on_disk = json.loads((out / "manifest.json").read_text())
    assert on_disk == manifest
    assert on_disk["version"] == 1
    names = {e["name"] for e in on_disk["entries"]}
    assert names == {"scores_m256_u512", "dot_m256_d32", "mwu_u512", "step_m256_u512"}


def test_hlo_text_files_exist_and_parse_shapes(small_build):
    out, manifest = small_build
    for e in manifest["entries"]:
        text = (out / e["file"]).read_text()
        assert text.startswith("HloModule"), e["name"]
        assert "ENTRY" in text
        # every input shape should appear as a parameter type in the text
        for inp in e["inputs"]:
            if inp["shape"]:
                dims = ",".join(str(d) for d in inp["shape"])
                assert f"[{dims}]" in text, (e["name"], inp)


def test_entry_io_arity(small_build):
    _, manifest = small_build
    by_name = {e["name"]: e for e in manifest["entries"]}
    assert len(by_name["step_m256_u512"]["inputs"]) == 6
    assert len(by_name["step_m256_u512"]["outputs"]) == 3
    assert len(by_name["mwu_u512"]["outputs"]) == 2
