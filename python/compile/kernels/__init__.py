"""Layer-1 Pallas kernels for Fast-MWEM (interpret=True lowering)."""
from .absdot import absdot, dot, make_matvec
from .mwu import mwu_update

__all__ = ["absdot", "dot", "make_matvec", "mwu_update"]
