"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

pytest (python/tests/) asserts allclose between these and the kernels across
a hypothesis-driven sweep of shapes and inputs; the same expressions are
re-implemented in Rust tests to validate the runtime end of the bridge.
"""

import jax.numpy as jnp


def absdot_ref(q, d):
    return jnp.abs(q.astype(jnp.float32) @ d.astype(jnp.float32))


def dot_ref(q, d):
    return q.astype(jnp.float32) @ d.astype(jnp.float32)


def mwu_update_ref(w, c, s):
    w_new = w * jnp.exp(s * c)
    return w_new, jnp.sum(w_new)


def normalize_ref(w):
    return w / jnp.sum(w)


def mwem_step_ref(w, q_sel, m_t, s_scale):
    """One classic-MWEM iteration given the already-selected query row.

    s = s_scale * (m_t - <q_sel, p>) where p = normalize(w); the caller
    chooses s_scale (1/2 for Hardt et al.; s_scale=-eta with m_t chosen so
    that m_t - <q,p> = 1 degenerates to the paper's Alg-1 rule).
    """
    p = normalize_ref(w)
    s = s_scale * (m_t - q_sel @ p)
    w_new, z = mwu_update_ref(w, q_sel, s)
    p_new = w_new / z
    return w_new, p_new
