"""Pallas kernel for the multiplicative-weights update.

Computes ``w' = w * exp(s * c)`` elementwise plus per-block partial sums, so
the surrounding L2 graph can normalize with a single tree-reduce over
``num_blocks`` partials instead of re-reading the full ``w'`` vector.

``s`` is a scalar carrying the whole update rule, chosen by the Rust
coordinator per iteration:
  * paper rule   (Alg 1/2):  s = -eta
  * classic MWEM (Hardt et al. 2012): s = (m_t - <q, p>) / 2
so one artifact serves both update rules.

TPU mapping: 1-D grid over U-tiles; each step holds (BU,) slices of w and c
in VMEM (~8 KiB at BU=1024), exp on the VPU, one local reduction per block.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BU = 1024


def _mwu_kernel(s_ref, w_ref, c_ref, w_out_ref, psum_ref):
    s = s_ref[0]
    w_new = w_ref[...] * jnp.exp(s * c_ref[...])
    w_out_ref[...] = w_new
    psum_ref[0] = jnp.sum(w_new)


def mwu_update(w: jax.Array, c: jax.Array, s: jax.Array):
    """Return ``(w', partial_sums)`` with ``w' = w * exp(s*c)``.

    ``partial_sums`` has one entry per U-tile; ``sum(partial_sums)`` is the
    normalizer for the synthetic distribution ``p = w' / sum(w')``.
    """
    (u,) = w.shape
    bu = min(DEFAULT_BU, u)
    if u % bu:
        raise ValueError(f"domain size {u} not divisible by block {bu}")
    grid = (u // bu,)
    s_arr = jnp.reshape(s.astype(jnp.float32), (1,))
    return pl.pallas_call(
        _mwu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bu,), lambda i: (i,)),
            pl.BlockSpec((bu,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bu,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((u,), jnp.float32),
            jax.ShapeDtypeStruct((grid[0],), jnp.float32),
        ],
        interpret=True,
    )(s_arr, w, c)
