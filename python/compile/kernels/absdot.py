"""Tiled (abs-)matvec Pallas kernel: scores = |Q @ d| (or signed Q @ d).

This is the dense hot-spot of MWEM's exponential mechanism: scoring every
candidate (query / LP constraint) against the evolving difference vector
``d = h - p`` (linear queries) or ``x' = x̃ ∘ -1`` (LPs).

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * grid = (M/BM, U/BU); each step streams one (BM, BU) tile of Q from HBM
    into VMEM while a (BU,) slice of d stays resident.
  * the contraction (BM,BU)x(BU,) targets the MXU; partial sums accumulate
    in the output block, which is revisited across the U-tile axis (its
    index map ignores ``j``) — the canonical Pallas accumulation pattern.
  * |.| is applied once on the final U-tile, avoiding a second pass.

VMEM footprint per step (f32): BM*BU + BU + BM floats. With the default
BM=256, BU=512 that is ~0.5 MiB, comfortably inside a 4 MiB/core budget and
leaving room for double-buffering the Q tile stream.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256
DEFAULT_BU = 512


def _matvec_kernel(q_ref, d_ref, o_ref, *, absolute: bool):
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    # (BM, BU) @ (BU,) -> (BM,) partial contraction for this U-tile.
    partial = jnp.dot(q_ref[...], d_ref[...], preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        o_ref[...] = o_ref[...] + partial

    if absolute:

        @pl.when(j == nj - 1)
        def _abs():
            o_ref[...] = jnp.abs(o_ref[...])


def _fit_block(dim: int, block: int) -> int:
    """Largest divisor of ``dim`` that is ≤ ``block``.

    The AOT shape grid is block-aligned so this is a no-op there; odd test
    shapes fall back to a smaller (possibly degenerate) tile instead of
    failing to lower.
    """
    b = min(block, dim)
    while dim % b:
        b -= 1
    return b


def _block_sizes(m: int, u: int, bm: int, bu: int) -> tuple[int, int]:
    return _fit_block(m, bm), _fit_block(u, bu)


def make_matvec(absolute: bool, bm: int = DEFAULT_BM, bu: int = DEFAULT_BU):
    """Build a pallas matvec ``f(Q[m,u], d[u]) -> scores[m]``.

    ``absolute=True`` yields |Q·d| (linear-query EM scores); ``False`` the
    signed product (LP constraint scores). Shapes must be multiples of the
    (clamped) block sizes; the AOT shape grid guarantees this and the Rust
    runtime pads to the grid.
    """

    def matvec(q: jax.Array, d: jax.Array) -> jax.Array:
        m, u = q.shape
        bm_, bu_ = _block_sizes(m, u, bm, bu)
        if m % bm_ or u % bu_:
            raise ValueError(f"shape ({m},{u}) not divisible by blocks ({bm_},{bu_})")
        grid = (m // bm_, u // bu_)
        kernel = functools.partial(_matvec_kernel, absolute=absolute)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm_, bu_), lambda i, j: (i, j)),
                pl.BlockSpec((bu_,), lambda i, j: (j,)),
            ],
            out_specs=pl.BlockSpec((bm_,), lambda i, j: (i,)),
            out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
            interpret=True,  # CPU-PJRT execution; TPU would emit Mosaic.
        )(q, d)

    return matvec


absdot = make_matvec(absolute=True)
dot = make_matvec(absolute=False)
