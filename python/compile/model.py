"""Layer-2 JAX compute graphs for Fast-MWEM, built on the L1 Pallas kernels.

Each public function here is a pure jax function that ``aot.py`` lowers once
to HLO text for the Rust runtime. Privacy-critical randomness (Gumbel,
Laplace, binomial tail) deliberately does NOT live here — the Rust
coordinator samples it and passes noise in as plain inputs, so the artifacts
are deterministic functions.
"""

import jax.numpy as jnp

from .kernels import absdot, dot, mwu_update


def scores_fn(q, d):
    """EM scores for linear queries: ``|Q @ d|`` with ``d = h - p``.

    Padding contract: rows of Q beyond the true m are zero → score 0; the
    Rust side masks them out before sampling.
    """
    return (absdot(q, d),)


def dot_scores_fn(k, x):
    """Signed scores for LP constraints: ``K @ x`` (K rows are A_i ∘ b_i)."""
    return (dot(k, x),)


def mwu_update_fn(w, c, s):
    """Multiplicative update + normalize: ``w' = w·exp(s·c)``, ``p' = w'/Σw'``.

    ``s`` is a scalar chosen by the coordinator (−η for the paper rule,
    (m_t − ⟨q,p⟩)/2 for classic MWEM). Zero-padded tail entries of ``w``
    stay zero and do not perturb the normalizer.
    """
    w_new, psums = mwu_update(w, c, s)
    z = jnp.sum(psums)
    return w_new, w_new / z


def mwem_step_fn(w, q, h, q_sel, noise, s_scale):
    """One fused classic-MWEM iteration (Hardt et al. 2012 update).

    Inputs:
      w[U]      current (unnormalized) weights
      q[M,U]    full query matrix (device-resident across calls)
      h[U]      private histogram
      q_sel[U]  the query row selected by the (Rust-side) exponential
                mechanism. Passed as a vector, not an index: a gather with
                an i32 operand crashes the xla_extension 0.5.1 text path
                ("Unhandled primitive type"), and the O(U) host transfer is
                already on the coordinator's per-round budget.
      noise     Laplace noise for the measurement, sampled in Rust
      s_scale   update scale (1/2 for classic MWEM)

    Returns (w', p', scores') where scores' = |Q (h − p')| feeds the next
    selection round on the flat/exact path.
    """
    z = jnp.sum(w)
    p = w / z
    m_t = jnp.dot(q_sel, h) + noise
    s = s_scale * (m_t - jnp.dot(q_sel, p))
    w_new, psums = mwu_update(w, q_sel, s)
    z_new = jnp.sum(psums)
    p_new = w_new / z_new
    new_scores = absdot(q, h - p_new)
    return w_new, p_new, new_scores
