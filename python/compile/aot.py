"""AOT-lower the L2 graphs to HLO *text* artifacts + a manifest.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
        python -m compile.aot --out-dir /tmp/a --grid small   # test grid

The Rust runtime discovers artifacts through ``manifest.json``; every entry
records the function, shapes, dtypes and output arity so the loader can
pick the smallest artifact that fits a request and pad inputs accordingly.
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (M, U) grid for query-scoring artifacts; (U,) grid for MWU updates;
# (M, D) grid for LP constraint scoring. Kept deliberately small: each
# shape is one compiled executable held by the Rust runtime.
GRIDS = {
    "default": {
        "scores": [(1024, 1024), (8192, 4096)],
        "step": [(1024, 1024), (8192, 4096)],
        "mwu": [1024, 4096],
        "dot": [(1024, 32), (8192, 32)],
    },
    "small": {
        "scores": [(256, 512)],
        "step": [(256, 512)],
        "mwu": [512],
        "dot": [(256, 32)],
    },
}

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _entry(name, fn, in_specs, out_specs, out_dir):
    lowered = jax.jit(fn).lower(*[_spec(s, d) for s, d in in_specs])
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    (out_dir / fname).write_text(text)
    return {
        "name": name,
        "file": fname,
        "inputs": [{"shape": list(s), "dtype": str(jnp.dtype(d))} for s, d in in_specs],
        "outputs": [{"shape": list(s), "dtype": str(jnp.dtype(d))} for s, d in out_specs],
    }


def build(out_dir: pathlib.Path, grid_name: str) -> dict:
    grid = GRIDS[grid_name]
    out_dir.mkdir(parents=True, exist_ok=True)
    entries = []

    for m, u in grid["scores"]:
        entries.append(
            _entry(
                f"scores_m{m}_u{u}",
                model.scores_fn,
                [((m, u), F32), ((u,), F32)],
                [((m,), F32)],
                out_dir,
            )
        )

    for m, d in grid["dot"]:
        entries.append(
            _entry(
                f"dot_m{m}_d{d}",
                model.dot_scores_fn,
                [((m, d), F32), ((d,), F32)],
                [((m,), F32)],
                out_dir,
            )
        )

    for u in grid["mwu"]:
        entries.append(
            _entry(
                f"mwu_u{u}",
                model.mwu_update_fn,
                [((u,), F32), ((u,), F32), ((), F32)],
                [((u,), F32), ((u,), F32)],
                out_dir,
            )
        )

    for m, u in grid["step"]:
        entries.append(
            _entry(
                f"step_m{m}_u{u}",
                model.mwem_step_fn,
                [
                    ((u,), F32),
                    ((m, u), F32),
                    ((u,), F32),
                    ((u,), F32),
                    ((), F32),
                    ((), F32),
                ],
                [((u,), F32), ((u,), F32), ((m,), F32)],
                out_dir,
            )
        )

    manifest = {"version": 1, "grid": grid_name, "entries": entries}
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--grid", default="default", choices=sorted(GRIDS))
    args = ap.parse_args()
    manifest = build(pathlib.Path(args.out_dir), args.grid)
    total = len(manifest["entries"])
    print(f"wrote {total} artifacts + manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
