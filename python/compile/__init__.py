"""Build-time compile path: JAX/Pallas → HLO text artifacts.

Nothing in this package is imported at runtime; the Rust coordinator only
consumes ``artifacts/*.hlo.txt`` + ``artifacts/manifest.json`` produced by
``python -m compile.aot``.
"""
