//! Wire front-end demo (DESIGN.md §11): the serving runtime behind real
//! sockets. Starts a [`WireServer`] on a loopback port with three dev
//! tenants and a deliberately tiny queue under [`QueuePolicy::Reject`],
//! then exercises the protocol end to end with [`WireClient`]s:
//!
//!   * authenticated `POST /v1/jobs` with flat JSON specs, outcomes
//!     streamed back as chunked responses (watch the chunk counts)
//!   * a malformed body and a body-supplied `tenant` — both answered 400
//!     before anything touches the ε ledger
//!   * an unknown token (401) and an over-cap tenant (403)
//!   * a burst that overflows the queue — 429 plus `Retry-After`, honored
//!     by the client, after which the retry succeeds
//!
//! Run:  cargo run --release --example wire

use fast_mwem::server::{
    QueuePolicy, Server, ServerConfig, WireClient, WireConfig, WireServer,
};

fn main() {
    let server = Server::start(ServerConfig {
        workers: 2,
        queue_depth: 2, // tiny on purpose: the burst below must overflow
        policy: QueuePolicy::Reject,
        eps_per_tenant: Some(3.0),
        cache_capacity: 4,
        store_dir: None,
        ..Default::default()
    });
    let wire = WireServer::start(server, &WireConfig { tenants: 3, ..WireConfig::default() })
        .expect("bind loopback");
    let addr = wire.local_addr().to_string();
    println!("wire daemon on {addr} (dev tokens tenant-0..2)\n");

    let mut c = WireClient::connect(&addr).expect("connect");

    // A release job: the averaged synthetic histogram streams back chunked.
    let r = c
        .post_job("tenant-0", r#"{"kind":"release","u":512,"m":800,"t":300,"seed":1}"#)
        .expect("release");
    println!(
        "release: {} (job {}, {} chunks, {} body bytes)",
        r.status,
        r.header("x-job-id").unwrap_or("?"),
        r.chunks,
        r.body.len()
    );

    // An LP job on the same keep-alive connection.
    let r = c
        .post_job("tenant-0", r#"{"kind":"lp","m":4000,"d":16,"t":300,"seed":2}"#)
        .expect("lp");
    println!("lp:      {} ({} chunks, {} body bytes)", r.status, r.chunks, r.body.len());

    // Refusals spend nothing: malformed JSON, a spec trying to name its
    // own tenant, and a token nobody issued.
    for (what, token, body) in [
        ("truncated body", "tenant-0", r#"{"kind":"release","#),
        ("tenant in body", "tenant-0", r#"{"kind":"release","tenant":1}"#),
        ("unknown token", "intruder", r#"{"kind":"release"}"#),
    ] {
        let r = c.post_job(token, body).expect(what);
        println!("{what}: {} — {}", r.status, r.body_str().trim_end());
    }

    // Tenant 2 asks for more ε than its cap: 403 at admission.
    for i in 0..4 {
        let body = format!(r#"{{"kind":"release","eps":1.0,"t":100,"seed":{i}}}"#);
        let r = c.post_job("tenant-2", &body).expect("capped job");
        if r.status != 200 {
            println!("tenant-2 job {i}: {} — {}", r.status, r.body_str().trim_end());
        }
    }

    // Overflow the 2-deep Reject queue from concurrent connections; shed
    // requests answer 429 with a Retry-After the client honors.
    println!("\nburst of 8 concurrent jobs into a 2-deep Reject queue:");
    let shed = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let addr = &addr;
                s.spawn(move || {
                    let mut c = WireClient::connect(addr).expect("connect");
                    // small eps so the whole burst fits tenant-1's cap —
                    // this demo is about queue shedding, not admission
                    let body =
                        format!(r#"{{"kind":"lp","m":2000,"t":200,"eps":0.1,"seed":{i}}}"#);
                    let r = c.post_job("tenant-1", &body).expect("burst job");
                    if r.status != 429 {
                        return 0usize;
                    }
                    let wait: u64 =
                        r.header("retry-after").and_then(|v| v.parse().ok()).unwrap_or(1);
                    std::thread::sleep(std::time::Duration::from_secs(wait));
                    let retry = c.post_job("tenant-1", &body).expect("retry");
                    println!("  job {i}: 429, retried after {wait}s -> {}", retry.status);
                    1usize
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("burst thread")).sum::<usize>()
    });
    println!("  {shed} of 8 were shed and retried");

    // Graceful teardown over the wire.
    let r = c.request("POST", "/v1/shutdown", Some("tenant-0"), None).expect("shutdown");
    println!("\nshutdown: {} — {}", r.status, r.body_str().trim_end());
    wire.wait_for_shutdown();
    let metrics = wire.drain();
    println!(
        "drained: {} requests over {} conns, {} bytes out, {} parse errors, \
         {} shed (429), {} denied (403)",
        metrics.counter("requests"),
        metrics.counter("conns_accepted"),
        metrics.counter("bytes_out"),
        metrics.counter("parse_errors"),
        metrics.counter("http_429"),
        metrics.counter("http_403"),
    );
}
