//! Private linear programming demo (§4): solve a scalar-private feasibility
//! LP with every selection mode, and a constraint-private packing LP with
//! the dense-MWU dual solver.
//!
//! Run:  cargo run --release --example private_lp

use fast_mwem::lp::{run_dense, run_scalar, DenseLpConfig, ScalarLpConfig, SelectionMode};
use fast_mwem::lp::dense::violated_constraints;
use fast_mwem::mips::IndexKind;
use fast_mwem::util::rng::Rng;
use fast_mwem::workloads::{random_feasibility_lp, random_packing_lp};

fn main() {
    // ---- scalar-private feasibility LP (Algorithm 3) -----------------------
    let (m, d, t) = (20_000usize, 20usize, 1_000usize);
    let mut rng = Rng::new(3);
    let lp = random_feasibility_lp(&mut rng, m, d, 0.6);
    println!("scalar-private LP: m={m} d={d} T={t} (Δ∞=0.1, ε=1)");
    println!(
        "  {:<12} {:>14} {:>12} {:>12} {:>10}",
        "mode", "max violation", "select/iter", "work/iter", "build"
    );

    for (name, mode) in [
        ("exhaustive", SelectionMode::Exhaustive),
        ("lazy-flat", SelectionMode::Lazy(IndexKind::Flat)),
        ("lazy-ivf", SelectionMode::Lazy(IndexKind::Ivf)),
        ("lazy-hnsw", SelectionMode::Lazy(IndexKind::Hnsw)),
        ("lazy-hnsw-x4", SelectionMode::LazySharded(IndexKind::Hnsw, 4)),
    ] {
        let cfg = ScalarLpConfig {
            t,
            eps: 1.0,
            delta: 1e-3,
            delta_inf: 0.1,
            mode,
            seed: 17,
            log_every: 0,
        };
        let res = run_scalar(&cfg, &lp);
        println!(
            "  {:<12} {:>+14.4} {:>10.1}µs {:>12.0} {:>9.2}s",
            name,
            lp.max_violation(&res.x),
            res.avg_select_time.as_secs_f64() * 1e6,
            res.avg_select_work,
            res.index_build_time.as_secs_f64(),
        );
    }

    // ---- constraint-private packing LP via dense MWU (§4.2) ---------------
    let (m2, d2, t2, s) = (2_000usize, 24usize, 400usize, 100usize);
    let mut rng = Rng::new(4);
    let plp = random_packing_lp(&mut rng, m2, d2);
    println!("\nconstraint-private packing LP (dense MWU): m={m2} d={d2} T={t2} s={s}");
    for (name, mode) in [
        ("exhaustive", SelectionMode::Exhaustive),
        ("lazy-hnsw", SelectionMode::Lazy(IndexKind::Hnsw)),
    ] {
        let cfg = DenseLpConfig {
            t: t2,
            eps: 2.0,
            delta: 1e-3,
            s,
            mode,
            seed: 23,
        };
        let res = run_dense(&cfg, &plp);
        let cx: f64 = res.x.iter().zip(&plp.c).map(|(&x, &c)| (x * c) as f64).sum();
        println!(
            "  {:<12} c·x̄ = {:.4} (OPT {:.4}), violated(α=0.5) {}/{}  work/iter {:.0}",
            name,
            cx,
            plp.opt,
            violated_constraints(&plp, &res.x, 0.5),
            m2,
            res.avg_select_work,
        );
    }
}
