//! Tenant-partitioned router over a multi-daemon fleet (DESIGN.md §13):
//! N wire daemons share ONE artifact store directory, and a thin HTTP
//! router in front hash-partitions tenants across them. The store is the
//! only coordination between the daemons — build leases make a shared
//! cold miss build once fleet-wide, and the manifest watch propagates
//! workload updates committed by one daemon to the others before they
//! can serve a stale generation.
//!
//! The demo starts two in-process daemons on one scratch store, routes
//! four tenants' traffic through the partitioner, evolves a workload
//! from one side of the fleet, and then reads both daemons' metrics to
//! show: one build per workload fleet-wide (`store_hit` on the daemon
//! that did not build), `stale_generation_serves == 0` on both, and the
//! peer invalidation the router's partitioning made necessary.
//!
//! Run:  cargo run --release --example router
//!
//! `scripts/multiproc_smoke.sh` drives the same topology across real
//! process boundaries in CI.

use fast_mwem::server::{
    QueuePolicy, Server, ServerConfig, WireClient, WireConfig, WireServer,
};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};

/// FNV-1a over the bearer token: the router's partition function. Stable
/// across restarts and router replicas — a tenant always lands on the
/// same daemon, so per-tenant queue ordering is preserved fleet-wide.
fn partition(token: &str, backends: usize) -> usize {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in token.bytes() {
        h = (h ^ u64::from(b)).wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % backends as u64) as usize
}

/// One relayed request: parse the head far enough to route (method, path,
/// bearer token, content-length), re-issue it to the chosen backend with
/// a [`WireClient`], and write the backend's answer back with
/// Content-Length framing. Returns false when the client closed.
fn relay(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    backends: &[String],
) -> std::io::Result<bool> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(false); // client hung up between requests
    }
    let mut parts = line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next()) {
        (Some(m), Some(p)) => (m.to_string(), p.to_string()),
        _ => return Ok(false),
    };
    let (mut token, mut content_len) = (None, 0usize);
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 || h.trim().is_empty() {
            break;
        }
        let Some((name, value)) = h.split_once(':') else { continue };
        let (name, value) = (name.trim().to_ascii_lowercase(), value.trim());
        if name == "authorization" {
            token = value.strip_prefix("Bearer ").map(str::to_string);
        } else if name == "content-length" {
            content_len = value.parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body)?;
    let body = String::from_utf8_lossy(&body).into_owned();

    // Route on the token; tokenless probes (health checks) go to backend
    // 0 — they are tenant-free, any daemon can answer.
    let chosen = token.as_deref().map_or(0, |t| partition(t, backends.len()));
    let r = WireClient::connect(&backends[chosen])?.request(
        &method,
        &path,
        token.as_deref(),
        if content_len > 0 { Some(&body) } else { None },
    )?;
    let content_type = r.header("content-type").unwrap_or("application/json");
    write!(
        writer,
        "HTTP/1.1 {} relayed\r\ncontent-type: {}\r\nx-backend: {}\r\n\
         content-length: {}\r\n\r\n",
        r.status,
        content_type,
        chosen,
        r.body.len()
    )?;
    writer.write_all(&r.body)?;
    writer.flush()?;
    Ok(true)
}

fn spawn_router(backends: Vec<String>) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind router");
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for conn in listener.incoming().flatten() {
            let backends = backends.clone();
            std::thread::spawn(move || {
                conn.set_nodelay(true).ok();
                let mut writer = match conn.try_clone() {
                    Ok(w) => w,
                    Err(_) => return,
                };
                let mut reader = BufReader::new(conn);
                while matches!(relay(&mut reader, &mut writer, &backends), Ok(true)) {}
            });
        }
    });
    addr
}

fn main() {
    // One shared store dir — the fleet's entire coordination substrate.
    let store = std::env::temp_dir()
        .join(format!("fastmwem-router-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&store);

    let daemon = || {
        let server = Server::start(ServerConfig {
            workers: 2,
            queue_depth: 16,
            policy: QueuePolicy::Block,
            eps_per_tenant: None,
            cache_capacity: 4,
            store_dir: Some(store.clone()),
            ..Default::default()
        });
        WireServer::start(server, &WireConfig { tenants: 4, ..WireConfig::default() })
            .expect("bind daemon")
    };
    let daemons = [daemon(), daemon()];
    let backends: Vec<String> =
        daemons.iter().map(|d| d.local_addr().to_string()).collect();
    let router = spawn_router(backends.clone());
    println!("router on {router} over daemons {backends:?} sharing {store:?}\n");

    // Four tenants hit ONE workload through the router. Tenants split
    // across both daemons, yet the fleet builds the index once: the
    // second daemon's cold miss finds the first's committed artifact.
    let spec = |seed: usize| {
        format!(r#"{{"kind":"release","u":64,"m":120,"t":40,"workload":7,"seed":{seed}}}"#)
    };
    for tenant in 0..4u64 {
        let token = format!("tenant-{tenant}");
        let mut c = WireClient::connect(&router).expect("connect router");
        let r = c.post_job(&token, &spec(tenant as usize)).expect("job");
        println!(
            "  {token} -> daemon {} ({}, {} body bytes)",
            r.header("x-backend").unwrap_or("?"),
            r.status,
            r.body.len()
        );
    }

    // One tenant evolves the workload; every tenant's next release — on
    // BOTH daemons — must answer the new generation (the manifest watch
    // carries the update across the process boundary).
    let mut c = WireClient::connect(&router).expect("connect router");
    let r = c
        .post_job("tenant-0", r#"{"kind":"update","workload":7,"insert":4,"tombstone":2}"#)
        .expect("update");
    println!("\n  tenant-0 update -> daemon {} ({})", r.header("x-backend").unwrap_or("?"), r.status);
    for tenant in 0..4u64 {
        let token = format!("tenant-{tenant}");
        let r = WireClient::connect(&router)
            .expect("connect router")
            .post_job(&token, &spec(100 + tenant as usize))
            .expect("job");
        println!("  {token} -> daemon {} ({})", r.header("x-backend").unwrap_or("?"), r.status);
    }

    // Drain the fleet and read the coordination counters.
    println!();
    for (i, d) in daemons.into_iter().enumerate() {
        d.shutdown();
        d.wait_for_shutdown();
        let m = d.drain();
        println!(
            "daemon {i}: store_miss {} (built), store_hit {} (reused a peer's build), \
             peer_invalidations {}, stale_generation_serves {}",
            m.counter("store_miss"),
            m.counter("store_hit"),
            m.counter("peer_invalidations"),
            m.counter("stale_generation_serves"),
        );
    }
    let _ = std::fs::remove_dir_all(&store);
}
