//! End-to-end quickstart — the full stack on a real workload.
//!
//! Reproduces the paper's headline result in miniature:
//!   1. generate the §5.1 workload (Gaussian histogram, binary queries);
//!   2. run classic MWEM with the dense steps executing through the
//!      runtime-dispatched SIMD kernel layer ([`CpuBackend`]);
//!   3. run Fast-MWEM with the from-scratch HNSW index;
//!   4. print the error trajectory ("loss curve") and the per-iteration
//!      selection cost of both, demonstrating equal utility at Θ(√m) work.
//!
//! Run:  cargo run --release --example quickstart
//! Force a specific kernel arm with FAST_MWEM_KERNELS=scalar|avx2|neon.
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use fast_mwem::mips::IndexKind;
use fast_mwem::mwem::{run_classic, run_fast, FastMwemConfig, MwemBackend, MwemConfig};
use fast_mwem::runtime::{kernels, CpuBackend};
use fast_mwem::util::rng::Rng;
use fast_mwem::workloads::{binary_queries, gaussian_histogram};

fn main() -> anyhow::Result<()> {
    // ---- workload (paper §5.1, scaled down for a quick run) --------------
    let (u, m, n, t) = (1024usize, 1000usize, 500usize, 400usize);
    let eps = 1.0;
    let delta = 1e-3;
    let mut rng = Rng::new(7);
    let h = gaussian_histogram(&mut rng, u, n);
    let q = binary_queries(&mut rng, m, u);
    let p0 = vec![1.0 / u as f32; u];
    println!("workload: U={u} m={m} n={n} T={t} (ε={eps}, δ={delta})");
    println!("kernels : {} dispatch", kernels::active().arm);
    println!("initial max query error: {:.4}\n", q.max_error(h.probs(), &p0));

    let mut cfg = MwemConfig::paper(t, u, eps, delta, 1234);
    cfg.log_every = t / 8;

    // ---- classic MWEM through the dispatched kernel layer -----------------
    println!("[1/3] classic MWEM, dense ops on the dispatched kernels...");
    let mut cpu = CpuBackend::new();
    let classic = run_classic(&cfg, &q, &h, &mut cpu);
    println!("      ({} kernel-backend calls)", cpu.calls);

    // ---- Fast-MWEM with HNSW ----------------------------------------------
    println!("[2/3] Fast-MWEM (lazy EM over from-scratch HNSW)...");
    let mut fast_cpu = CpuBackend::new();
    let backend: &mut dyn MwemBackend = &mut fast_cpu;
    let fast = run_fast(&FastMwemConfig::new(cfg, IndexKind::Hnsw), &q, &h, backend);

    // ---- report -------------------------------------------------------------
    println!("\n[3/3] error trajectory (max query error of running average p̂):");
    println!("  iter    classic     fast-hnsw");
    for (c, f) in classic.stats.iter().zip(fast.result.stats.iter()) {
        println!(
            "  {:>5}   {:.4}      {:.4}",
            c.iter, c.max_error_avg, f.max_error_avg
        );
    }

    let e_classic = q.max_error(h.probs(), &classic.p_avg);
    let e_fast = q.max_error(h.probs(), &fast.result.p_avg);
    println!("\nfinal error    : classic {e_classic:.4} | fast-hnsw {e_fast:.4}");
    println!(
        "selection cost : classic {:.0} score-evals/iter | fast {:.0} ({:.1}x less, √m = {:.0})",
        classic.avg_select_work,
        fast.result.avg_select_work,
        classic.avg_select_work / fast.result.avg_select_work,
        (m as f64).sqrt()
    );
    println!(
        "selection time : classic {:.1}µs/iter | fast {:.1}µs/iter",
        classic.avg_select_time.as_secs_f64() * 1e6,
        fast.result.avg_select_time.as_secs_f64() * 1e6
    );
    println!(
        "privacy spent  : classic ε={:.3} | fast ε={:.3} (budget ε={eps})",
        classic.privacy_spent.0, fast.result.privacy_spent.0
    );
    Ok(())
}
