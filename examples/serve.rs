//! Coordinator demo: a batch of private-analysis jobs through the
//! leader/worker pool with a global privacy cap.
//!
//! Run:  cargo run --release --example serve

use fast_mwem::coordinator::{
    Coordinator, CoordinatorConfig, JobSpec, LpJobSpec, ReleaseJobSpec,
};
use fast_mwem::lp::SelectionMode;
use fast_mwem::mips::IndexKind;

fn main() {
    let mut coord = Coordinator::start(CoordinatorConfig {
        workers: 4,
        eps_cap: Some(10.0), // global privacy budget across accepted jobs
    });

    let mut submitted = 0;
    let mut rejected = 0;
    for i in 0..12 {
        let spec = if i % 3 == 2 {
            JobSpec::Lp(LpJobSpec {
                m: 4_000,
                d: 16,
                t: 300,
                eps: 1.0,
                delta: 1e-3,
                delta_inf: 0.1,
                mode: SelectionMode::Lazy(IndexKind::Hnsw),
                seed: i,
            })
        } else {
            JobSpec::Release(ReleaseJobSpec {
                u: 512,
                m: 800,
                n: 500,
                t: 300,
                eps: 1.0,
                delta: 1e-3,
                index: Some(if i % 3 == 0 { IndexKind::Hnsw } else { IndexKind::Ivf }),
                // every other release job exercises the sharded lazy EM
                shards: if i % 2 == 0 { 4 } else { 1 },
                seed: i,
            })
        };
        match coord.submit(spec) {
            Ok(id) => {
                submitted += 1;
                println!("submitted job {id}");
            }
            Err(e) => {
                rejected += 1;
                println!("rejected: {e}");
            }
        }
    }

    let (results, metrics) = coord.finish();
    println!("\n{submitted} accepted, {rejected} rejected by the budget manager\n");
    let mut total_eps = 0.0;
    for r in &results {
        match &r.outcome {
            Ok(o) => {
                total_eps += o.eps_spent;
                println!(
                    "job {:>2} [{:<7}] quality {:.4}  ε {:.3}  work/iter {:>7.0}  {:>7.1}ms",
                    r.job_id,
                    r.kind,
                    o.quality,
                    o.eps_spent,
                    o.avg_select_work,
                    o.total_time.as_secs_f64() * 1e3,
                );
            }
            Err(e) => println!("job {:>2} FAILED: {e}", r.job_id),
        }
    }
    println!("\ntotal ε spent: {total_eps:.2} (cap 10.0)");
    println!("metrics: {}", metrics.to_json());
}
