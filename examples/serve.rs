//! Serving-runtime demo (DESIGN.md §8 + §9): a long-lived server with a
//! bounded MPMC queue, persistent workers over the warm-index cache, and
//! per-tenant privacy-budget admission — every job reserves its ε against
//! its tenant's cap *before* running, denied jobs spend nothing, failures
//! refund. Two tenant threads submit mixed Release+Lp traffic
//! concurrently; tenant 0 additionally evolves workload 0 mid-stream with
//! a zero-ε `WorkloadUpdate`, so later releases answer the patched
//! generation (watch the `index_cache_patched` counter). The graceful
//! drain reports per-kind latency p50/p95/p99 and each tenant's spend.
//!
//! Run:  cargo run --release --example serve
//!
//! Pass a directory to persist built indices (DESIGN.md §7) and run the
//! example twice — the second run restores every index from disk instead
//! of rebuilding (watch the `store_hit` counter); the persisted delta
//! artifacts restore the workload generations too:
//!
//!   cargo run --release --example serve -- /tmp/fastmwem-store
//!   cargo run --release --example serve -- /tmp/fastmwem-store

use fast_mwem::coordinator::{JobSpec, LpJobSpec, ReleaseJobSpec, WorkloadUpdateSpec};
use fast_mwem::lp::SelectionMode;
use fast_mwem::mips::IndexKind;
use fast_mwem::server::{QueuePolicy, Server, ServerConfig, SubmitError};

/// One tenant's mixed request stream: repeated-workload releases (warm
/// after the first build) interleaved with LP solves; tenant 0's fourth
/// slot evolves workload 0 in place — a dynamic-workload update riding the
/// same queue as the release traffic.
fn spec_for(tenant: u64, i: u64) -> JobSpec {
    if tenant == 0 && i == 3 {
        return JobSpec::Update(WorkloadUpdateSpec {
            workload: 0,
            u: 512,
            m: 800,
            n: 500,
            insert: 8,    // analysts added a handful of queries...
            tombstone: 4, // ...and retired a few others
            tenant,
        });
    }
    if i % 3 == 2 {
        JobSpec::Lp(LpJobSpec {
            m: 4_000,
            d: 16,
            t: 300,
            eps: 1.0,
            delta: 1e-3,
            delta_inf: 0.1,
            mode: SelectionMode::Lazy(IndexKind::Hnsw),
            tenant,
            seed: tenant * 100 + i,
        })
    } else {
        JobSpec::Release(ReleaseJobSpec {
            u: 512,
            m: 800,
            n: 500,
            t: 300,
            eps: 1.0,
            delta: 1e-3,
            index: Some(IndexKind::Hnsw),
            shards: 1,
            class: fast_mwem::workloads::QueryClassKind::Linear,
            workload: i % 2, // two repeated workloads -> cache hits
            tenant,
            seed: tenant * 100 + i,
        })
    }
}

fn main() {
    let store_dir = std::env::args().nth(1).map(std::path::PathBuf::from);
    if let Some(dir) = &store_dir {
        println!("persisting built indices to {dir:?}\n");
    }
    let server = Server::start(ServerConfig {
        workers: 4,
        queue_depth: 16,
        policy: QueuePolicy::Block, // lossless backpressure
        eps_per_tenant: Some(5.0),  // each tenant's privacy budget
        cache_capacity: 8,          // warm-index cache (DESIGN.md §6)
        store_dir,                  // artifact store (DESIGN.md §7)
        ..Default::default()        // mmap pager on, heap budget unlimited
    });

    // Two tenants submit concurrently — the MPMC request path. Tenant 1
    // asks for more than its cap allows; the overflow is denied at
    // admission and spends zero ε.
    std::thread::scope(|s| {
        for tenant in 0..2u64 {
            let server = &server;
            s.spawn(move || {
                let asks = if tenant == 1 { 8 } else { 5 };
                let mut tickets = Vec::new();
                for i in 0..asks {
                    match server.submit(spec_for(tenant, i)) {
                        Ok(t) => tickets.push(t),
                        Err(SubmitError::Budget(e)) => println!("denied: {e}"),
                        Err(e) => println!("refused: {e}"),
                    }
                }
                for t in tickets {
                    let r = t.wait();
                    match r.outcome {
                        Ok(o) => println!(
                            "tenant {tenant} job {:>2} [{:<7}] quality {:.4}  \
                             eps {:.3}  {:>7.1}ms",
                            r.job_id,
                            r.kind,
                            o.quality,
                            o.eps_spent,
                            o.total_time.as_secs_f64() * 1e3,
                        ),
                        Err(e) => println!("tenant {tenant} job {} FAILED: {e}", r.job_id),
                    }
                }
            });
        }
    });

    let spends = server.tenant_spend();
    let metrics = server.drain();
    println!();
    for t in &spends {
        println!(
            "tenant {}: spent eps {:.2} of cap 5.0 ({} admitted, {} denied)",
            t.tenant, t.spent, t.admitted_jobs, t.denied_jobs
        );
    }
    for series in ["latency_release", "latency_lp", "queue_wait"] {
        if let Some(t) = metrics.timing_summary(series) {
            println!(
                "{series:<16} n={:<3} p50 {:>7.1}ms  p95 {:>7.1}ms  p99 {:>7.1}ms",
                t.count,
                t.p50 * 1e3,
                t.p95 * 1e3,
                t.p99 * 1e3
            );
        }
    }
    println!(
        "index cache: {} hits / {} misses, {} patched forward across generations, \
         ~{}ms of index builds skipped",
        metrics.counter("index_cache_hit"),
        metrics.counter("index_cache_miss"),
        metrics.counter("index_cache_patched"),
        metrics.counter("index_build_saved_ms"),
    );
    if metrics.gauge("store_artifacts").is_some() {
        println!(
            "artifact store: {} restored from disk, {} built cold, {} artifacts + {} \
             workload deltas persisted",
            metrics.counter("store_hit"),
            metrics.counter("store_miss"),
            metrics.gauge("store_artifacts").unwrap_or(0.0),
            metrics.gauge("store_deltas").unwrap_or(0.0),
        );
    }
    println!("metrics: {}", metrics.to_json());
}
