//! Coordinator demo: a batch of private-analysis jobs through the
//! leader/worker pool with a global privacy cap and warm-index serving —
//! release jobs repeat a couple of workloads, so after the first build per
//! workload the cache hands every later job a shared pre-built index.
//!
//! Run:  cargo run --release --example serve
//!
//! Pass a directory to persist built indices (DESIGN.md §7) and run the
//! example twice — the second run restores every index from disk instead
//! of rebuilding (watch the `store_hit` counter):
//!
//!   cargo run --release --example serve -- /tmp/fastmwem-store
//!   cargo run --release --example serve -- /tmp/fastmwem-store

use fast_mwem::coordinator::{
    Coordinator, CoordinatorConfig, JobSpec, LpJobSpec, ReleaseJobSpec,
};
use fast_mwem::lp::SelectionMode;
use fast_mwem::mips::IndexKind;

fn main() {
    let store_dir = std::env::args().nth(1).map(std::path::PathBuf::from);
    if let Some(dir) = &store_dir {
        println!("persisting built indices to {dir:?}\n");
    }
    let mut coord = Coordinator::start(CoordinatorConfig {
        workers: 4,
        eps_cap: Some(10.0), // global privacy budget across accepted jobs
        cache_capacity: 8,   // warm-index cache (DESIGN.md §6)
        store_dir,           // artifact store (DESIGN.md §7)
    });

    let mut submitted = 0;
    let mut rejected = 0;
    for i in 0..12 {
        let spec = if i % 3 == 2 {
            JobSpec::Lp(LpJobSpec {
                m: 4_000,
                d: 16,
                t: 300,
                eps: 1.0,
                delta: 1e-3,
                delta_inf: 0.1,
                mode: SelectionMode::Lazy(IndexKind::Hnsw),
                seed: i,
            })
        } else {
            // Two workloads repeated across the batch — serving-shaped
            // traffic. The index kind and shard count ride on the workload
            // id so repeats share one cache entry; only the mechanism seed
            // is fresh per job.
            let wl = i % 3;
            JobSpec::Release(ReleaseJobSpec {
                u: 512,
                m: 800,
                n: 500,
                t: 300,
                eps: 1.0,
                delta: 1e-3,
                index: Some(if wl == 0 { IndexKind::Hnsw } else { IndexKind::Ivf }),
                shards: if wl == 1 { 4 } else { 1 },
                workload: wl,
                seed: i,
            })
        };
        match coord.submit(spec) {
            Ok(id) => {
                submitted += 1;
                println!("submitted job {id}");
            }
            Err(e) => {
                rejected += 1;
                println!("rejected: {e}");
            }
        }
    }

    let (results, metrics) = coord.finish();
    println!("\n{submitted} accepted, {rejected} rejected by the budget manager\n");
    let mut total_eps = 0.0;
    for r in &results {
        match &r.outcome {
            Ok(o) => {
                total_eps += o.eps_spent;
                println!(
                    "job {:>2} [{:<7}] quality {:.4}  ε {:.3}  work/iter {:>7.0}  {:>7.1}ms",
                    r.job_id,
                    r.kind,
                    o.quality,
                    o.eps_spent,
                    o.avg_select_work,
                    o.total_time.as_secs_f64() * 1e3,
                );
            }
            Err(e) => println!("job {:>2} FAILED: {e}", r.job_id),
        }
    }
    println!("\ntotal ε spent: {total_eps:.2} (cap 10.0)");
    println!(
        "index cache: {} hits / {} misses, ~{}ms of index builds skipped",
        metrics.counter("index_cache_hit"),
        metrics.counter("index_cache_miss"),
        metrics.counter("index_build_saved_ms"),
    );
    if metrics.gauge("store_artifacts").is_some() {
        println!(
            "artifact store: {} restored from disk, {} built cold, {} artifacts persisted",
            metrics.counter("store_hit"),
            metrics.counter("store_miss"),
            metrics.gauge("store_artifacts").unwrap_or(0.0),
        );
    }
    println!("metrics: {}", metrics.to_json());
}
