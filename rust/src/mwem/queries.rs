//! Linear query sets: `Q ∈ [0,1]^{m×U}`, one row per query (§3.1).

use crate::mips::VectorSet;
use crate::runtime::kernels;
use crate::util::math::dot;

/// A set of m linear queries, one row of Q per query.
#[derive(Clone, Debug)]
pub struct QuerySet {
    vs: VectorSet,
}

impl QuerySet {
    /// Wrap an m × U query matrix.
    pub fn new(vs: VectorSet) -> Self {
        QuerySet { vs }
    }

    /// Number of queries m.
    pub fn m(&self) -> usize {
        self.vs.len()
    }

    /// Domain size U.
    pub fn u(&self) -> usize {
        self.vs.dim()
    }

    /// Row of query i.
    pub fn query(&self, i: usize) -> &[f32] {
        self.vs.row(i)
    }

    /// The full query matrix (the k-MIPS dataset of Fast-MWEM).
    pub fn vectors(&self) -> &VectorSet {
        &self.vs
    }

    /// True answer of query i on distribution `dist`: ⟨q_i, dist⟩.
    pub fn answer(&self, i: usize, dist: &[f32]) -> f64 {
        dot(self.vs.row(i), dist) as f64
    }

    /// `|Q·d|` for all queries — the exhaustive EM score vector. Runs on
    /// the dispatched [`kernels::dot`] (bit-identical to the scalar
    /// reference on every arm).
    pub fn abs_scores(&self, d: &[f32]) -> Vec<f32> {
        self.vs.rows().map(|row| kernels::dot(row, d).abs()).collect()
    }

    /// Max error of a synthetic distribution: ‖Q(h − p)‖∞ (Equation 1).
    /// Evaluation-only — never called on the private path.
    pub fn max_error(&self, h: &[f32], p: &[f32]) -> f64 {
        let d: Vec<f32> = h.iter().zip(p.iter()).map(|(&a, &b)| a - b).collect();
        self.abs_scores(&d).iter().fold(0.0f64, |acc, &s| acc.max(s as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qs() -> QuerySet {
        // 2 queries over a domain of 3
        QuerySet::new(VectorSet::new(vec![1.0, 0.0, 0.0, 0.0, 1.0, 1.0], 2, 3))
    }

    #[test]
    fn answers_are_inner_products() {
        let q = qs();
        let dist = [0.5f32, 0.25, 0.25];
        assert!((q.answer(0, &dist) - 0.5).abs() < 1e-9);
        assert!((q.answer(1, &dist) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn max_error_is_linf() {
        let q = qs();
        let h = [1.0f32, 0.0, 0.0];
        let p = [0.0f32, 1.0, 0.0];
        // q0 error = |1-0| = 1; q1 error = |0-1| = 1
        assert!((q.max_error(&h, &p) - 1.0).abs() < 1e-6);
        let p2 = [0.9f32, 0.1, 0.0];
        assert!((q.max_error(&h, &p2) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn abs_scores_match_manual() {
        let q = qs();
        let d = [0.2f32, -0.3, 0.1];
        let s = q.abs_scores(&d);
        assert!((s[0] - 0.2).abs() < 1e-6);
        assert!((s[1] - 0.2).abs() < 1e-6);
    }
}
