//! Normalized histogram representation of a dataset (§3.1).

/// `h_x = |{i : x_i = x}| / n` over a finite domain of size U.
#[derive(Clone, Debug)]
pub struct Histogram {
    probs: Vec<f32>,
    /// Number of underlying records (drives EM sensitivity 1/n).
    n: usize,
}

impl Histogram {
    /// Build from raw domain-element samples.
    pub fn from_samples(samples: &[usize], u: usize) -> Self {
        let mut counts = vec![0u64; u];
        for &s in samples {
            assert!(s < u, "sample {s} outside domain [0,{u})");
            counts[s] += 1;
        }
        Self::from_counts(&counts)
    }

    /// Build from per-element counts (panics on an all-zero histogram).
    pub fn from_counts(counts: &[u64]) -> Self {
        let n: u64 = counts.iter().sum();
        assert!(n > 0, "empty histogram");
        let probs = counts.iter().map(|&c| c as f32 / n as f32).collect();
        Histogram { probs, n: n as usize }
    }

    /// Uniform distribution with a nominal record count.
    pub fn uniform(u: usize, n: usize) -> Self {
        Histogram { probs: vec![1.0 / u as f32; u], n }
    }

    /// The normalized distribution h (length U, sums to 1).
    pub fn probs(&self) -> &[f32] {
        &self.probs
    }

    /// Domain size U.
    pub fn domain_size(&self) -> usize {
        self.probs.len()
    }

    /// Number of records — EM score sensitivity is 1/n.
    pub fn record_count(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_samples_normalizes() {
        let h = Histogram::from_samples(&[0, 0, 1, 3], 4);
        assert_eq!(h.probs(), &[0.5, 0.25, 0.0, 0.25]);
        assert_eq!(h.record_count(), 4);
        assert_eq!(h.domain_size(), 4);
    }

    #[test]
    #[should_panic]
    fn out_of_domain_sample_panics() {
        Histogram::from_samples(&[5], 4);
    }

    #[test]
    fn uniform_sums_to_one() {
        let h = Histogram::uniform(10, 100);
        assert!((h.probs().iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }
}
