//! Fast-MWEM (Algorithm 2): MWEM with the lazy exponential mechanism.
//!
//! Identical MWU loop to Algorithm 1; the only change is the selection
//! oracle — `LazyEM` backed by a k-MIPS index over the query vectors —
//! which drops the per-round selection cost from Θ(m·U) to Θ(√m·U)
//! expected (Theorem 3.3).

use super::classic::{measured_update, IterStat, MwemConfig, MwemResult};
use super::{Histogram, MwemBackend, MwuState, QuerySet};
use crate::dp::Accountant;
use crate::lazy::{LazyEm, LazySample, ScoreTransform, ShardSet, ShardedLazyEm};
use crate::mips::{build_index, IndexKind, MipsIndex};
use crate::mwem::classic::UpdateRule;
use crate::util::rng::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Configuration for Fast-MWEM (Algorithm 2).
#[derive(Clone, Debug)]
pub struct FastMwemConfig {
    /// The shared MWEM parameters (rounds, budget, update rule, seed).
    pub base: MwemConfig,
    /// Which k-MIPS index backs the lazy mechanism.
    pub index: IndexKind,
    /// Top-k size. Defaults to ⌈√m⌉ per the paper, or ⌈√(m/S)⌉ per shard
    /// when sharded. NOTE: an explicit value is applied *per shard* when
    /// `shards > 1` (total retrieval S·k) — leave `None` for sweeps that
    /// compare shard counts.
    pub k: Option<usize>,
    /// Algorithm 6's margin reduction `c` (0 = Algorithms 4/5 behaviour).
    pub margin_slack: f64,
    /// Number of lazy-EM shards (≤ 1 → one monolithic index; > 1 →
    /// [`ShardedLazyEm`] with parallel per-shard index builds, DESIGN.md §5).
    pub shards: usize,
    /// Pool width for per-draw shard searches (0 → one worker per shard).
    /// Only meaningful with `parallel_shard_select`.
    pub shard_workers: usize,
    /// Fan each draw's S shard searches onto pool threads instead of
    /// running them inline (bit-identical results either way).
    pub parallel_shard_select: bool,
}

impl FastMwemConfig {
    /// Fast-MWEM with a single monolithic index of the given kind.
    pub fn new(base: MwemConfig, index: IndexKind) -> Self {
        FastMwemConfig {
            base,
            index,
            k: None,
            margin_slack: 0.0,
            shards: 1,
            shard_workers: 0,
            parallel_shard_select: false,
        }
    }

    /// Split the lazy EM across `shards` per-shard indices (clamped ≥ 1).
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    /// Apply a full `[sharding]` config section (shard count plus the
    /// select-time parallelism knobs).
    pub fn with_sharding(mut self, sharding: crate::config::ShardingConfig) -> Self {
        self.shards = sharding.shards.max(1);
        self.shard_workers = sharding.workers;
        self.parallel_shard_select = sharding.parallel_select;
        self
    }
}

/// Extra diagnostics specific to the lazy mechanism.
#[derive(Debug, Default, Clone)]
pub struct LazyDiagnostics {
    /// Per-round C (tail sample count) — Figure 6's subject.
    pub tail_counts: Vec<usize>,
    /// Per-round margin B.
    pub margins: Vec<f64>,
    /// Index build time.
    pub build_time: Duration,
}

/// Everything [`run_fast`] returns: the MWEM result plus lazy diagnostics.
pub struct FastMwemOutput {
    /// The standard MWEM outputs (shared shape with the classic runner).
    pub result: MwemResult,
    /// Diagnostics specific to the lazy mechanism.
    pub lazy: LazyDiagnostics,
}

/// Run Algorithm 2. The index (or, with `cfg.shards > 1`, one index per
/// shard, built in parallel on the coordinator pool) is built once — the
/// paper's preprocessing — and queried every round with the evolving
/// difference vector d = h − p.
pub fn run_fast(
    cfg: &FastMwemConfig,
    q: &QuerySet,
    h: &Histogram,
    backend: &mut dyn MwemBackend,
) -> FastMwemOutput {
    let build_started = Instant::now();
    if cfg.shards > 1 {
        let mut em = ShardedLazyEm::build(
            cfg.index,
            q.vectors(),
            cfg.shards,
            ScoreTransform::Abs,
            cfg.base.seed ^ 0x5EED,
        )
        .with_margin_slack(cfg.margin_slack)
        .with_parallel_select(cfg.parallel_shard_select);
        if cfg.shard_workers > 0 {
            em = em.with_workers(cfg.shard_workers);
        }
        if let Some(k) = cfg.k {
            em = em.with_k(k);
        }
        let build_time = build_started.elapsed();
        return run_fast_loop(cfg, q, h, backend, build_time, |rng, d, eps, sens| {
            em.select(rng, d, eps, sens)
        });
    }
    let index = build_index(cfg.index, q.vectors().clone(), cfg.base.seed ^ 0x5EED);
    let build_time = build_started.elapsed();
    run_fast_with_index(cfg, q, h, backend, index.as_ref(), build_time)
}

/// Same as [`run_fast`] but with a caller-supplied (pre-built) monolithic
/// index, so benchmark sweeps — and, via the coordinator's
/// [`crate::coordinator::IndexCache`], repeated serving jobs on one
/// workload — can amortize index construction across runs. Ignores
/// `cfg.shards`.
pub fn run_fast_with_index(
    cfg: &FastMwemConfig,
    q: &QuerySet,
    h: &Histogram,
    backend: &mut dyn MwemBackend,
    index: &dyn MipsIndex,
    build_time: Duration,
) -> FastMwemOutput {
    let mut em = LazyEm::new(index, q.vectors(), ScoreTransform::Abs)
        .with_margin_slack(cfg.margin_slack);
    if let Some(k) = cfg.k {
        em = em.with_k(k);
    }
    run_fast_loop(cfg, q, h, backend, build_time, |rng, d, eps, sens| {
        em.select(rng, d, eps, sens)
    })
}

/// Sharded sibling of [`run_fast_with_index`]: run Algorithm 2 over a
/// caller-supplied, `Arc`-shared [`ShardSet`], so warm-index serving skips
/// the per-job shard builds. With the same build seed the result is
/// bit-identical to [`run_fast`]'s inline sharded path. Ignores
/// `cfg.index` and `cfg.shards` in favor of the set's own geometry; the
/// set must have been built over `q.vectors()` (asserted).
pub fn run_fast_with_shard_set(
    cfg: &FastMwemConfig,
    q: &QuerySet,
    h: &Histogram,
    backend: &mut dyn MwemBackend,
    set: &Arc<ShardSet>,
    build_time: Duration,
) -> FastMwemOutput {
    let mut em = ShardedLazyEm::with_shard_set(Arc::clone(set), q.vectors(), ScoreTransform::Abs)
        .with_margin_slack(cfg.margin_slack)
        .with_parallel_select(cfg.parallel_shard_select);
    if cfg.shard_workers > 0 {
        em = em.with_workers(cfg.shard_workers);
    }
    if let Some(k) = cfg.k {
        em = em.with_k(k);
    }
    run_fast_loop(cfg, q, h, backend, build_time, |rng, d, eps, sens| {
        em.select(rng, d, eps, sens)
    })
}

/// The shared Algorithm 2 MWU loop, generic over the selection oracle —
/// the only piece that differs between the monolithic and sharded paths.
fn run_fast_loop(
    cfg: &FastMwemConfig,
    q: &QuerySet,
    h: &Histogram,
    backend: &mut dyn MwemBackend,
    build_time: Duration,
    mut select: impl FnMut(&mut Rng, &[f32], f64, f64) -> LazySample,
) -> FastMwemOutput {
    let mut rng = Rng::new(cfg.base.seed);
    let mut state = MwuState::new(q.u());
    let mut accountant = Accountant::new(cfg.base.delta);
    let eps0 = cfg.base.eps0();
    let sens = 1.0 / h.record_count() as f64;
    let eps_em = match cfg.base.update {
        UpdateRule::Paper { .. } => eps0,
        UpdateRule::Hardt => eps0 / 2.0,
    };

    let mut stats = Vec::new();
    let mut lazy = LazyDiagnostics { build_time, ..Default::default() };
    let started = Instant::now();
    let mut select_total = Duration::ZERO;
    let mut work_total = 0usize;

    for t in 0..cfg.base.t {
        let d: Vec<f32> =
            h.probs().iter().zip(state.p.iter()).map(|(&a, &b)| a - b).collect();

        let sel_started = Instant::now();
        let sample = select(&mut rng, &d, eps_em, sens);
        let sel_time = sel_started.elapsed();
        select_total += sel_time;
        work_total += sample.work;
        accountant.record(eps0, 0.0);
        lazy.tail_counts.push(sample.tail_count);
        lazy.margins.push(sample.b);

        let i_t = sample.index;
        let s = measured_update(&mut rng, cfg.base.update, q, h, &state, i_t, eps0);
        let c = q.query(i_t).to_vec();
        state.update(backend, &c, s);

        if cfg.base.log_every > 0 && (t + 1) % cfg.base.log_every == 0 {
            stats.push(IterStat {
                iter: t + 1,
                max_error_avg: q.max_error(h.probs(), &state.p_avg()),
                max_error_cur: q.max_error(h.probs(), &state.p),
                selected: i_t,
                selection_work: sample.work,
                selection_time: sel_time,
            });
        }
    }

    let total_time = started.elapsed();
    let t = cfg.base.t.max(1);
    FastMwemOutput {
        result: MwemResult {
            p_avg: state.p_avg(),
            p_final: state.p,
            stats,
            total_time,
            avg_select_time: select_total / t as u32,
            avg_select_work: work_total as f64 / t as f64,
            eps0,
            privacy_spent: accountant.best_total(),
        },
        lazy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mwem::NativeBackend;
    use crate::util::rng::Rng;
    use crate::workloads::linear_queries::{binary_queries, gaussian_histogram};

    fn workload(u: usize, m: usize, seed: u64) -> (Histogram, QuerySet) {
        let mut rng = Rng::new(seed);
        let h = gaussian_histogram(&mut rng, u, 500);
        let q = binary_queries(&mut rng, m, u);
        (h, q)
    }

    #[test]
    fn fast_flat_matches_classic_error_closely() {
        // Figure 2's claim: Fast-MWEM(flat) ≈ MWEM in error.
        let (h, q) = workload(128, 80, 1);
        let mut cfg = MwemConfig::paper(400, 128, 1.0, 1e-3, 11);
        cfg.log_every = 400;
        let classic = crate::mwem::run_classic(&cfg, &q, &h, &mut NativeBackend);
        let fast = run_fast(
            &FastMwemConfig::new(cfg, IndexKind::Flat),
            &q,
            &h,
            &mut NativeBackend,
        );
        let e_classic = classic.stats.last().unwrap().max_error_avg;
        let e_fast = fast.result.stats.last().unwrap().max_error_avg;
        assert!(
            (e_classic - e_fast).abs() < 0.1,
            "classic {e_classic} fast {e_fast}"
        );
    }

    #[test]
    fn fast_selection_work_is_sublinear() {
        let (h, q) = workload(64, 2_500, 2);
        let mut cfg = MwemConfig::paper(30, 64, 1.0, 1e-3, 5);
        cfg.log_every = 0;
        let fast = run_fast(
            &FastMwemConfig::new(cfg, IndexKind::Flat),
            &q,
            &h,
            &mut NativeBackend,
        );
        // √2500 = 50; expected work ≈ k + C ≤ a small multiple of √m
        assert!(
            fast.result.avg_select_work < 8.0 * 50.0,
            "avg work {}",
            fast.result.avg_select_work
        );
    }

    #[test]
    fn hnsw_index_converges_too() {
        let (h, q) = workload(96, 400, 3);
        let mut cfg = MwemConfig::paper(200, 96, 1.0, 1e-3, 13);
        cfg.log_every = 200;
        let fast = run_fast(
            &FastMwemConfig::new(cfg, IndexKind::Hnsw),
            &q,
            &h,
            &mut NativeBackend,
        );
        let p0 = vec![1.0 / 96.0f32; 96];
        let initial = q.max_error(h.probs(), &p0);
        let e = fast.result.stats.last().unwrap().max_error_avg;
        assert!(e < initial, "initial {initial} fast-hnsw {e}");
    }

    /// The sharded mechanism is exact (max-stability), so Fast-MWEM with
    /// S=4 shards must land at the same error as the monolithic run.
    #[test]
    fn sharded_matches_monolithic_error_closely() {
        let (h, q) = workload(128, 80, 1);
        let mut cfg = MwemConfig::paper(400, 128, 1.0, 1e-3, 11);
        cfg.log_every = 400;
        let mono = run_fast(
            &FastMwemConfig::new(cfg.clone(), IndexKind::Flat),
            &q,
            &h,
            &mut NativeBackend,
        );
        let sharded = run_fast(
            &FastMwemConfig::new(cfg, IndexKind::Flat).with_shards(4),
            &q,
            &h,
            &mut NativeBackend,
        );
        let e_mono = mono.result.stats.last().unwrap().max_error_avg;
        let e_sharded = sharded.result.stats.last().unwrap().max_error_avg;
        assert!(
            (e_mono - e_sharded).abs() < 0.1,
            "monolithic {e_mono} sharded {e_sharded}"
        );
        assert_eq!(sharded.lazy.tail_counts.len(), 400);
    }

    /// Warm serving is bit-exact: a pre-built `Arc<ShardSet>` with the same
    /// build seed reproduces the inline sharded run exactly.
    #[test]
    fn prebuilt_shard_set_matches_inline_build() {
        let (h, q) = workload(64, 120, 6);
        let cfg = MwemConfig::paper(60, 64, 1.0, 1e-3, 23);
        let fcfg = FastMwemConfig::new(cfg, IndexKind::Flat).with_shards(3);
        let inline = run_fast(&fcfg, &q, &h, &mut NativeBackend);

        let set = Arc::new(ShardSet::build(
            IndexKind::Flat,
            q.vectors(),
            3,
            fcfg.base.seed ^ 0x5EED,
        ));
        let warm =
            run_fast_with_shard_set(&fcfg, &q, &h, &mut NativeBackend, &set, Duration::ZERO);
        assert_eq!(inline.result.p_avg, warm.result.p_avg);
        assert_eq!(inline.result.avg_select_work, warm.result.avg_select_work);
        assert_eq!(warm.lazy.build_time, Duration::ZERO);
    }

    #[test]
    fn diagnostics_are_recorded() {
        let (h, q) = workload(32, 100, 4);
        let cfg = MwemConfig::paper(10, 32, 1.0, 1e-3, 17);
        let fast = run_fast(
            &FastMwemConfig::new(cfg, IndexKind::Ivf),
            &q,
            &h,
            &mut NativeBackend,
        );
        assert_eq!(fast.lazy.tail_counts.len(), 10);
        assert_eq!(fast.lazy.margins.len(), 10);
    }
}
