//! Fast-MWEM (Algorithm 2): MWEM with the lazy exponential mechanism.
//!
//! Identical MWU loop to Algorithm 1; the only change is the selection
//! oracle — `LazyEM` backed by a k-MIPS index over the query vectors —
//! which drops the per-round selection cost from Θ(m·U) to Θ(√m·U)
//! expected (Theorem 3.3). Since the engine refactor (DESIGN.md §14) the
//! loop lives in [`MwemEngine`]; this module builds the lazy/sharded
//! [`SelectionOracle`] and runs [`crate::workloads::LinearQueries`]
//! through it.

use super::classic::{MwemConfig, MwemResult};
use super::engine::{EngineReport, MwemEngine, SelectionOracle};
use super::{Histogram, MwemBackend, QuerySet};
use crate::lazy::{LazyEm, ScoreTransform, ShardSet, ShardedLazyEm};
use crate::mips::{build_index, IndexKind, MipsIndex};
use crate::workloads::LinearQueries;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Configuration for Fast-MWEM (Algorithm 2).
#[derive(Clone, Debug)]
pub struct FastMwemConfig {
    /// The shared MWEM parameters (rounds, budget, update rule, seed).
    pub base: MwemConfig,
    /// Which k-MIPS index backs the lazy mechanism.
    pub index: IndexKind,
    /// Total top-k retrieval budget per round, across all shards. Defaults
    /// (`None`) to ⌈√m⌉ per the paper, or ⌈√(m/S)⌉ per shard when sharded.
    /// An explicit value is split evenly over shards (⌈k/S⌉ each), so the
    /// retrieval budget no longer silently scales with the shard count.
    pub k: Option<usize>,
    /// Algorithm 6's margin reduction `c` (0 = Algorithms 4/5 behaviour).
    pub margin_slack: f64,
    /// Number of lazy-EM shards (≤ 1 → one monolithic index; > 1 →
    /// [`ShardedLazyEm`] with parallel per-shard index builds, DESIGN.md §5).
    pub shards: usize,
    /// Pool width for per-draw shard searches (0 → one worker per shard).
    /// Only meaningful with `parallel_shard_select`.
    pub shard_workers: usize,
    /// Fan each draw's S shard searches onto pool threads instead of
    /// running them inline (bit-identical results either way).
    pub parallel_shard_select: bool,
}

impl FastMwemConfig {
    /// Fast-MWEM with a single monolithic index of the given kind.
    pub fn new(base: MwemConfig, index: IndexKind) -> Self {
        FastMwemConfig {
            base,
            index,
            k: None,
            margin_slack: 0.0,
            shards: 1,
            shard_workers: 0,
            parallel_shard_select: false,
        }
    }

    /// Split the lazy EM across `shards` per-shard indices (clamped ≥ 1).
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    /// Apply a full `[sharding]` config section (shard count plus the
    /// select-time parallelism knobs).
    pub fn with_sharding(mut self, sharding: crate::config::ShardingConfig) -> Self {
        self.shards = sharding.shards.max(1);
        self.shard_workers = sharding.workers;
        self.parallel_shard_select = sharding.parallel_select;
        self
    }

    /// Set the *total* per-round retrieval budget (clamped ≥ 1); shards
    /// split it evenly. Sweeps comparing shard counts at fixed k now hold
    /// total work constant.
    pub fn with_total_k(mut self, k: usize) -> Self {
        self.k = Some(k.max(1));
        self
    }

    /// Pre-refactor semantics: `k` retrieved from *each* shard (total S·k).
    #[deprecated(
        note = "FastMwemConfig::k is now a total across shards; use with_total_k"
    )]
    pub fn with_per_shard_k(mut self, k: usize) -> Self {
        self.k = Some(k.max(1).saturating_mul(self.shards.max(1)));
        self
    }

    /// The per-shard retrieval budget implied by the total `k` for a run
    /// over `shards` shards: ⌈k/S⌉, `None` when `k` is defaulted.
    pub fn per_shard_k_for(&self, shards: usize) -> Option<usize> {
        self.k.map(|k| {
            let s = shards.max(1);
            k.div_ceil(s).max(1)
        })
    }
}

/// Extra diagnostics specific to the lazy mechanism.
#[derive(Debug, Default, Clone)]
pub struct LazyDiagnostics {
    /// Per-round C (tail sample count) — Figure 6's subject.
    pub tail_counts: Vec<usize>,
    /// Per-round margin B.
    pub margins: Vec<f64>,
    /// Index build time.
    pub build_time: Duration,
}

/// Everything [`run_fast`] returns: the MWEM result plus lazy diagnostics.
pub struct FastMwemOutput {
    /// The standard MWEM outputs (shared shape with the classic runner).
    pub result: MwemResult,
    /// Diagnostics specific to the lazy mechanism.
    pub lazy: LazyDiagnostics,
}

/// Run Algorithm 2. The index (or, with `cfg.shards > 1`, one index per
/// shard, built in parallel on the coordinator pool) is built once — the
/// paper's preprocessing — and queried every round with the evolving
/// difference vector d = h − p.
pub fn run_fast(
    cfg: &FastMwemConfig,
    q: &QuerySet,
    h: &Histogram,
    backend: &mut dyn MwemBackend,
) -> FastMwemOutput {
    let build_started = Instant::now();
    if cfg.shards > 1 {
        let mut em = ShardedLazyEm::build(
            cfg.index,
            q.vectors(),
            cfg.shards,
            ScoreTransform::Abs,
            cfg.base.seed ^ 0x5EED,
        )
        .with_margin_slack(cfg.margin_slack)
        .with_parallel_select(cfg.parallel_shard_select);
        if cfg.shard_workers > 0 {
            em = em.with_workers(cfg.shard_workers);
        }
        if let Some(k) = cfg.per_shard_k_for(cfg.shards) {
            em = em.with_k(k);
        }
        let build_time = build_started.elapsed();
        return run_engine(cfg, q, h, backend, SelectionOracle::Sharded(em), build_time);
    }
    let index = build_index(cfg.index, q.vectors().clone(), cfg.base.seed ^ 0x5EED);
    let build_time = build_started.elapsed();
    run_fast_with_index(cfg, q, h, backend, index.as_ref(), build_time)
}

/// Same as [`run_fast`] but with a caller-supplied (pre-built) monolithic
/// index, so benchmark sweeps — and, via the coordinator's
/// [`crate::coordinator::IndexCache`], repeated serving jobs on one
/// workload — can amortize index construction across runs. Ignores
/// `cfg.shards`.
pub fn run_fast_with_index(
    cfg: &FastMwemConfig,
    q: &QuerySet,
    h: &Histogram,
    backend: &mut dyn MwemBackend,
    index: &dyn MipsIndex,
    build_time: Duration,
) -> FastMwemOutput {
    let mut em = LazyEm::new(index, q.vectors(), ScoreTransform::Abs)
        .with_margin_slack(cfg.margin_slack);
    if let Some(k) = cfg.per_shard_k_for(1) {
        em = em.with_k(k);
    }
    run_engine(cfg, q, h, backend, SelectionOracle::Lazy(em), build_time)
}

/// Sharded sibling of [`run_fast_with_index`]: run Algorithm 2 over a
/// caller-supplied, `Arc`-shared [`ShardSet`], so warm-index serving skips
/// the per-job shard builds. With the same build seed the result is
/// bit-identical to [`run_fast`]'s inline sharded path. Ignores
/// `cfg.index` and `cfg.shards` in favor of the set's own geometry; the
/// set must have been built over `q.vectors()` (asserted).
pub fn run_fast_with_shard_set(
    cfg: &FastMwemConfig,
    q: &QuerySet,
    h: &Histogram,
    backend: &mut dyn MwemBackend,
    set: &Arc<ShardSet>,
    build_time: Duration,
) -> FastMwemOutput {
    let mut em = ShardedLazyEm::with_shard_set(Arc::clone(set), q.vectors(), ScoreTransform::Abs)
        .with_margin_slack(cfg.margin_slack)
        .with_parallel_select(cfg.parallel_shard_select);
    if cfg.shard_workers > 0 {
        em = em.with_workers(cfg.shard_workers);
    }
    if let Some(k) = cfg.per_shard_k_for(em.num_shards()) {
        em = em.with_k(k);
    }
    run_engine(cfg, q, h, backend, SelectionOracle::Sharded(em), build_time)
}

/// The shared Algorithm 2 shell: drive [`LinearQueries`] through the
/// engine with the prepared lazy oracle, then split the report into the
/// MWEM result and the lazy diagnostics.
fn run_engine(
    cfg: &FastMwemConfig,
    q: &QuerySet,
    h: &Histogram,
    backend: &mut dyn MwemBackend,
    oracle: SelectionOracle<'_>,
    build_time: Duration,
) -> FastMwemOutput {
    let eps0 = cfg.base.eps0();
    let mut class = LinearQueries::new(q, h, backend, cfg.base.update, cfg.base.log_every);
    let report: EngineReport = MwemEngine::new(oracle, cfg.base.t, eps0, cfg.base.seed)
        .with_accounting(cfg.base.delta)
        .run(&mut class);
    let result = class.into_result(&report);
    FastMwemOutput {
        result,
        lazy: LazyDiagnostics {
            tail_counts: report.tail_counts,
            margins: report.margins,
            build_time,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mwem::NativeBackend;
    use crate::util::rng::Rng;
    use crate::workloads::linear_queries::{binary_queries, gaussian_histogram};

    fn workload(u: usize, m: usize, seed: u64) -> (Histogram, QuerySet) {
        let mut rng = Rng::new(seed);
        let h = gaussian_histogram(&mut rng, u, 500);
        let q = binary_queries(&mut rng, m, u);
        (h, q)
    }

    #[test]
    fn fast_flat_matches_classic_error_closely() {
        // Figure 2's claim: Fast-MWEM(flat) ≈ MWEM in error.
        let (h, q) = workload(128, 80, 1);
        let mut cfg = MwemConfig::paper(400, 128, 1.0, 1e-3, 11);
        cfg.log_every = 400;
        let classic = crate::mwem::run_classic(&cfg, &q, &h, &mut NativeBackend);
        let fast = run_fast(
            &FastMwemConfig::new(cfg, IndexKind::Flat),
            &q,
            &h,
            &mut NativeBackend,
        );
        let e_classic = classic.stats.last().unwrap().max_error_avg;
        let e_fast = fast.result.stats.last().unwrap().max_error_avg;
        assert!(
            (e_classic - e_fast).abs() < 0.1,
            "classic {e_classic} fast {e_fast}"
        );
    }

    #[test]
    fn fast_selection_work_is_sublinear() {
        let (h, q) = workload(64, 2_500, 2);
        let mut cfg = MwemConfig::paper(30, 64, 1.0, 1e-3, 5);
        cfg.log_every = 0;
        let fast = run_fast(
            &FastMwemConfig::new(cfg, IndexKind::Flat),
            &q,
            &h,
            &mut NativeBackend,
        );
        // √2500 = 50; expected work ≈ k + C ≤ a small multiple of √m
        assert!(
            fast.result.avg_select_work < 8.0 * 50.0,
            "avg work {}",
            fast.result.avg_select_work
        );
    }

    #[test]
    fn hnsw_index_converges_too() {
        let (h, q) = workload(96, 400, 3);
        let mut cfg = MwemConfig::paper(200, 96, 1.0, 1e-3, 13);
        cfg.log_every = 200;
        let fast = run_fast(
            &FastMwemConfig::new(cfg, IndexKind::Hnsw),
            &q,
            &h,
            &mut NativeBackend,
        );
        let p0 = vec![1.0 / 96.0f32; 96];
        let initial = q.max_error(h.probs(), &p0);
        let e = fast.result.stats.last().unwrap().max_error_avg;
        assert!(e < initial, "initial {initial} fast-hnsw {e}");
    }

    /// The sharded mechanism is exact (max-stability), so Fast-MWEM with
    /// S=4 shards must land at the same error as the monolithic run.
    #[test]
    fn sharded_matches_monolithic_error_closely() {
        let (h, q) = workload(128, 80, 1);
        let mut cfg = MwemConfig::paper(400, 128, 1.0, 1e-3, 11);
        cfg.log_every = 400;
        let mono = run_fast(
            &FastMwemConfig::new(cfg.clone(), IndexKind::Flat),
            &q,
            &h,
            &mut NativeBackend,
        );
        let sharded = run_fast(
            &FastMwemConfig::new(cfg, IndexKind::Flat).with_shards(4),
            &q,
            &h,
            &mut NativeBackend,
        );
        let e_mono = mono.result.stats.last().unwrap().max_error_avg;
        let e_sharded = sharded.result.stats.last().unwrap().max_error_avg;
        assert!(
            (e_mono - e_sharded).abs() < 0.1,
            "monolithic {e_mono} sharded {e_sharded}"
        );
        assert_eq!(sharded.lazy.tail_counts.len(), 400);
    }

    /// Warm serving is bit-exact: a pre-built `Arc<ShardSet>` with the same
    /// build seed reproduces the inline sharded run exactly.
    #[test]
    fn prebuilt_shard_set_matches_inline_build() {
        let (h, q) = workload(64, 120, 6);
        let cfg = MwemConfig::paper(60, 64, 1.0, 1e-3, 23);
        let fcfg = FastMwemConfig::new(cfg, IndexKind::Flat).with_shards(3);
        let inline = run_fast(&fcfg, &q, &h, &mut NativeBackend);

        let set = Arc::new(ShardSet::build(
            IndexKind::Flat,
            q.vectors(),
            3,
            fcfg.base.seed ^ 0x5EED,
        ));
        let warm =
            run_fast_with_shard_set(&fcfg, &q, &h, &mut NativeBackend, &set, Duration::ZERO);
        assert_eq!(inline.result.p_avg, warm.result.p_avg);
        assert_eq!(inline.result.avg_select_work, warm.result.avg_select_work);
        assert_eq!(warm.lazy.build_time, Duration::ZERO);
    }

    #[test]
    fn diagnostics_are_recorded() {
        let (h, q) = workload(32, 100, 4);
        let cfg = MwemConfig::paper(10, 32, 1.0, 1e-3, 17);
        let fast = run_fast(
            &FastMwemConfig::new(cfg, IndexKind::Ivf),
            &q,
            &h,
            &mut NativeBackend,
        );
        assert_eq!(fast.lazy.tail_counts.len(), 10);
        assert_eq!(fast.lazy.margins.len(), 10);
    }

    /// The k-footgun fix: an explicit `k` is a *total* retrieval budget.
    /// Every round retrieves `work − tail_count = Σ_shards k_shard` exact
    /// top-k candidates, so with k=12 both S=1 and S=4 must charge 12 —
    /// pre-fix, S=4 charged S·k = 48.
    #[test]
    fn explicit_k_is_total_across_shard_counts() {
        let (h, q) = workload(32, 40, 8);
        let mut base = MwemConfig::paper(12, 32, 1.0, 1e-3, 19);
        base.log_every = 1;
        for shards in [1usize, 4] {
            let fcfg = FastMwemConfig::new(base.clone(), IndexKind::Flat)
                .with_shards(shards)
                .with_total_k(12);
            let out = run_fast(&fcfg, &q, &h, &mut NativeBackend);
            assert_eq!(out.result.stats.len(), 12);
            for (stat, &tail) in out.result.stats.iter().zip(out.lazy.tail_counts.iter()) {
                assert_eq!(
                    stat.selection_work - tail,
                    12,
                    "S={shards}: retrieval must be 12 total, got {} (tail {tail})",
                    stat.selection_work - tail
                );
            }
        }
    }

    /// The deprecation shim preserves the old per-shard meaning: k per
    /// shard × S shards total.
    #[test]
    #[allow(deprecated)]
    fn per_shard_shim_keeps_old_totals() {
        let (h, q) = workload(32, 40, 8);
        let mut base = MwemConfig::paper(6, 32, 1.0, 1e-3, 19);
        base.log_every = 1;
        let fcfg = FastMwemConfig::new(base, IndexKind::Flat)
            .with_shards(4)
            .with_per_shard_k(3);
        assert_eq!(fcfg.k, Some(12));
        assert_eq!(fcfg.per_shard_k_for(4), Some(3));
        let out = run_fast(&fcfg, &q, &h, &mut NativeBackend);
        for (stat, &tail) in out.result.stats.iter().zip(out.lazy.tail_counts.iter()) {
            assert_eq!(stat.selection_work - tail, 12);
        }
    }
}
