//! Classic MWEM (Algorithm 1): exhaustive exponential mechanism per round.
//!
//! Since the engine refactor (DESIGN.md §14) the loop itself lives in
//! [`MwemEngine`]; this module keeps the config/result types, the shared
//! [`measured_update`] step, and [`run_classic`] as the exhaustive-oracle
//! shell over [`crate::workloads::LinearQueries`].

use super::engine::{MwemEngine, SelectionOracle};
use super::{Histogram, MwemBackend, MwuState, QuerySet};
use crate::dp::accountant::per_step_epsilon;
use crate::util::math::dot;
use crate::util::rng::Rng;
use crate::workloads::LinearQueries;
use std::time::Duration;

/// Multiplicative-update rule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UpdateRule {
    /// Algorithm 1's simplified rule `w ← w·e^{−η·q}`, with the error sign
    /// restored (the paper's experiments implicitly need it for the error
    /// to decrease): s = −η·sgn(⟨q,p⟩ − ⟨q,h⟩). Uses the exact sign, as in
    /// the paper's presentation, which omits a private measurement step.
    Paper { eta: f64 },
    /// Hardt–Ligett–McSherry (2012) classic MWEM: the round budget is split
    /// between EM selection and a Laplace measurement m_t of ⟨q,h⟩; the
    /// update is w ← w·exp(q·(m_t − ⟨q,p⟩)/2). Fully private end to end.
    Hardt,
}

/// Configuration shared by classic MWEM and Fast-MWEM.
#[derive(Clone, Debug)]
pub struct MwemConfig {
    /// Number of MWU rounds T.
    pub t: usize,
    /// Total privacy budget ε.
    pub eps: f64,
    /// Total privacy budget δ.
    pub delta: f64,
    /// Multiplicative-update rule (paper-simplified or Hardt et al.).
    pub update: UpdateRule,
    /// Mechanism seed.
    pub seed: u64,
    /// Evaluate ‖Q(h−p̂)‖∞ every `log_every` rounds (0 = never; evaluation
    /// is non-private and O(mU), so runtime benches disable it).
    pub log_every: usize,
}

impl MwemConfig {
    /// Paper defaults: T rounds with η = √(ln U / T).
    pub fn paper(t: usize, u: usize, eps: f64, delta: f64, seed: u64) -> Self {
        let eta = ((u as f64).ln() / t as f64).sqrt();
        MwemConfig { t, eps, delta, update: UpdateRule::Paper { eta }, seed, log_every: 0 }
    }

    /// Per-round ε₀ from the advanced-composition budget split (Alg 1/2).
    pub fn eps0(&self) -> f64 {
        per_step_epsilon(self.eps, self.delta, self.t as u64, 1.0)
    }
}

/// Per-logged-round statistics.
#[derive(Clone, Debug)]
pub struct IterStat {
    /// Round number (1-based).
    pub iter: usize,
    /// ‖Q(h − p̄)‖∞ of the running average p̄ (NaN if not evaluated).
    pub max_error_avg: f64,
    /// ‖Q(h − p⁽ᵗ⁾)‖∞ of the current iterate.
    pub max_error_cur: f64,
    /// Candidate selected by the mechanism this round.
    pub selected: usize,
    /// Score evaluations charged to selection (m for classic, k+C for lazy).
    pub selection_work: usize,
    /// Wall-clock of this round's selection.
    pub selection_time: Duration,
}

/// Output of [`run_classic`] / the `result` half of Fast-MWEM's output.
#[derive(Debug)]
pub struct MwemResult {
    /// Averaged synthetic distribution p̂ (the paper's output).
    pub p_avg: Vec<f32>,
    /// Final iterate p⁽ᵀ⁾.
    pub p_final: Vec<f32>,
    /// Per-logged-round statistics (empty when `log_every` = 0).
    pub stats: Vec<IterStat>,
    /// End-to-end solve wall-clock.
    pub total_time: Duration,
    /// Mean selection time per round.
    pub avg_select_time: Duration,
    /// Mean selection work (score evaluations) per round.
    pub avg_select_work: f64,
    pub eps0: f64,
    /// Composed privacy spend as tracked by the accountant.
    pub privacy_spent: (f64, f64),
}

/// Shared per-round post-selection step: (optionally) measure the selected
/// query's answer and apply the multiplicative update. Returns (s, c).
pub(crate) fn measured_update(
    rng: &mut Rng,
    rule: UpdateRule,
    q: &QuerySet,
    h: &Histogram,
    state: &MwuState,
    i_t: usize,
    eps0: f64,
) -> f32 {
    let q_row = q.query(i_t);
    match rule {
        UpdateRule::Paper { eta } => {
            let err = dot(q_row, h.probs()) as f64 - dot(q_row, &state.p) as f64;
            (-(eta) * (-err).signum()) as f32 // s = −η·sgn(⟨q,p⟩−⟨q,h⟩) = +η·sgn(err)
        }
        UpdateRule::Hardt => {
            let sens = 1.0 / h.record_count() as f64;
            // Clip the noisy measurement to the query's range [0,1] (as in
            // Hardt et al.'s implementation) — unbounded Laplace noise at
            // small ε·n would otherwise blow up the multiplicative update.
            let m_t = (dot(q_row, h.probs()) as f64 + rng.laplace(sens / (eps0 / 2.0)))
                .clamp(0.0, 1.0);
            let s = (m_t - dot(q_row, &state.p) as f64) / 2.0;
            s as f32
        }
    }
}

/// Run Algorithm 1. `backend` supplies the dense numeric ops.
pub fn run_classic(
    cfg: &MwemConfig,
    q: &QuerySet,
    h: &Histogram,
    backend: &mut dyn MwemBackend,
) -> MwemResult {
    let eps0 = cfg.eps0();
    let mut class = LinearQueries::new(q, h, backend, cfg.update, cfg.log_every);
    let report = MwemEngine::new(SelectionOracle::Exhaustive, cfg.t, eps0, cfg.seed)
        .with_accounting(cfg.delta)
        .run(&mut class);
    class.into_result(&report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mips::VectorSet;
    use crate::mwem::NativeBackend;
    use crate::workloads::linear_queries::{gaussian_histogram, binary_queries};

    #[test]
    fn error_decreases_on_easy_instance() {
        let u = 128;
        let mut rng = Rng::new(1);
        let h = gaussian_histogram(&mut rng, u, 500);
        let q = binary_queries(&mut rng, 60, u);
        let mut cfg = MwemConfig::paper(300, u, 1.0, 1e-3, 7);
        cfg.log_every = 50;
        let res = run_classic(&cfg, &q, &h, &mut NativeBackend);

        let p0 = vec![1.0 / u as f32; u];
        let initial = q.max_error(h.probs(), &p0);
        let last = res.stats.last().unwrap();
        assert!(
            last.max_error_avg < initial * 0.8,
            "initial {initial} final {}",
            last.max_error_avg
        );
        assert!((res.p_avg.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    }

    #[test]
    fn hardt_rule_also_converges() {
        let u = 128;
        let mut rng = Rng::new(2);
        let h = gaussian_histogram(&mut rng, u, 2_000);
        let q = binary_queries(&mut rng, 60, u);
        let mut cfg = MwemConfig::paper(300, u, 2.0, 1e-3, 8);
        cfg.update = UpdateRule::Hardt;
        cfg.log_every = 300;
        let res = run_classic(&cfg, &q, &h, &mut NativeBackend);
        let p0 = vec![1.0 / u as f32; u];
        let initial = q.max_error(h.probs(), &p0);
        assert!(res.stats.last().unwrap().max_error_avg < initial);
    }

    /// Regression: tiny ε·n with the Hardt rule must not blow up the
    /// weights (unclipped Laplace noise once drove w → inf → NaN scores →
    /// an unbounded geometric-skip loop in the lazy tail sampler).
    #[test]
    fn hardt_rule_stays_finite_under_huge_noise() {
        let u = 64;
        let mut rng = Rng::new(3);
        let h = gaussian_histogram(&mut rng, u, 30); // n=30 → large noise scale
        let q = binary_queries(&mut rng, 40, u);
        let mut cfg = MwemConfig::paper(800, u, 1.0, 1e-3, 9);
        cfg.update = UpdateRule::Hardt;
        let res = run_classic(&cfg, &q, &h, &mut NativeBackend);
        assert!(res.p_avg.iter().all(|x| x.is_finite()));
        assert!(res.p_final.iter().all(|x| x.is_finite()));
        assert!((res.p_final.iter().sum::<f32>() - 1.0).abs() < 1e-3);
    }

    /// MWU weights are rebased each round — no drift over long horizons.
    #[test]
    fn weights_stay_bounded_over_many_rounds() {
        let u = 32;
        let mut rng = Rng::new(4);
        let h = gaussian_histogram(&mut rng, u, 500);
        let q = binary_queries(&mut rng, 30, u);
        let cfg = MwemConfig::paper(5_000, u, 1.0, 1e-3, 11);
        let res = run_classic(&cfg, &q, &h, &mut NativeBackend);
        assert!(res.p_final.iter().all(|x| x.is_finite() && *x >= 0.0));
    }

    #[test]
    fn accountant_tracks_t_rounds() {
        let u = 16;
        let h = Histogram::uniform(u, 100);
        let q = QuerySet::new(VectorSet::new(vec![0.5; 8 * u], 8, u));
        let cfg = MwemConfig::paper(25, u, 1.0, 1e-3, 3);
        let res = run_classic(&cfg, &q, &h, &mut NativeBackend);
        let (eps_spent, _) = res.privacy_spent;
        assert!(eps_spent > 0.0);
        // 25 rounds at eps0 each, basic-composed upper bound
        assert!(eps_spent <= 25.0 * res.eps0 + 1e-9);
    }
}
