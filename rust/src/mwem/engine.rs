//! The generic private-mechanism engine (DESIGN.md §14).
//!
//! Every private MWU loop in the repo — classic MWEM, Fast-MWEM's
//! lazy/sharded variants, the scalar-private LP and the dense packing-LP
//! solver — runs the same per-round skeleton:
//!
//! 1. ask the query class for the round's query vector,
//! 2. select a candidate through the selection oracle (exhaustive
//!    exponential mechanism, lazy Gumbel top-k, or sharded lazy Gumbel),
//! 3. record the round's ε₀ with the accountant (when one is attached),
//! 4. apply the class's measured multiplicative update,
//! 5. hand the round's observation back for per-round statistics.
//!
//! [`MwemEngine`] owns exactly that skeleton, plus the RNG and the
//! timers; everything mechanism-specific lives behind
//! [`QueryClass`](crate::workloads::QueryClass). The engine reproduces
//! the pre-refactor loops draw-for-draw: selection noise first, then any
//! measurement noise, nothing else touches the RNG
//! (`tests/engine_equivalence.rs` pins this bit-for-bit).

use crate::dp::Accountant;
use crate::lazy::{LazyEm, ShardedLazyEm};
use crate::util::rng::Rng;
use crate::workloads::{QueryClass, RoundObservation};
use std::time::{Duration, Instant};

/// How the engine privately selects a candidate each round.
pub enum SelectionOracle<'a> {
    /// Score every candidate exactly, then run the exponential mechanism
    /// over the full score vector (work = m per round).
    Exhaustive,
    /// Lazy Gumbel top-k over one k-MIPS index.
    Lazy(LazyEm<'a>),
    /// Exact-by-max-stability sharded lazy Gumbel selection.
    Sharded(ShardedLazyEm<'a>),
}

/// What one engine run produced, besides the class's own state: totals
/// for the timing/work columns of every result struct, lazy-oracle
/// diagnostics, and the accounted privacy spend.
#[derive(Clone, Debug)]
pub struct EngineReport {
    /// Rounds executed.
    pub rounds: usize,
    /// Per-round budget the run was configured with.
    pub eps0: f64,
    /// Wall-clock of the whole loop.
    pub total_time: Duration,
    /// Summed selection wall-clock across rounds.
    pub select_total: Duration,
    /// Summed selection work (score evaluations) across rounds.
    pub work_total: usize,
    /// Per-round lazy tail-candidate counts (empty for exhaustive runs).
    pub tail_counts: Vec<usize>,
    /// Per-round lazy threshold margins `b` (empty for exhaustive runs).
    pub margins: Vec<f64>,
    /// `(ε, δ)` actually spent per the accountant's best composition
    /// bound, or `(0, 0)` when the run carried no accountant.
    pub privacy_spent: (f64, f64),
}

/// The shared per-round driver. Construct with the oracle and schedule,
/// optionally attach accounting, then [`run`](MwemEngine::run) a
/// [`QueryClass`](crate::workloads::QueryClass) through it.
pub struct MwemEngine<'a> {
    oracle: SelectionOracle<'a>,
    rounds: usize,
    eps0: f64,
    seed: u64,
    accountant_delta: Option<f64>,
}

impl<'a> MwemEngine<'a> {
    /// An engine running `rounds` rounds at per-round budget `eps0`,
    /// drawing all noise from `Rng::new(seed)`.
    pub fn new(oracle: SelectionOracle<'a>, rounds: usize, eps0: f64, seed: u64) -> Self {
        MwemEngine { oracle, rounds, eps0, seed, accountant_delta: None }
    }

    /// Attach an [`Accountant`] with composition slack `delta`; each round
    /// records `(eps0, 0)` and the report carries
    /// [`Accountant::best_total`]. LP runs leave this off (their results
    /// report ε₀ only, as before the engine).
    pub fn with_accounting(mut self, delta: f64) -> Self {
        self.accountant_delta = Some(delta);
        self
    }

    /// Drive `class` through the full loop and return the run's totals.
    pub fn run(self, class: &mut dyn QueryClass) -> EngineReport {
        let MwemEngine { oracle, rounds, eps0, seed, accountant_delta } = self;
        let mut rng = Rng::new(seed);
        let mut accountant = accountant_delta.map(Accountant::new);
        let sens = class.sensitivity();
        let eps_sel = class.selection_epsilon(eps0);

        let started = Instant::now();
        let mut select_total = Duration::ZERO;
        let mut work_total = 0usize;
        let mut tail_counts = Vec::new();
        let mut margins = Vec::new();

        for t in 0..rounds {
            let query = class.query_vector();

            let sel_started = Instant::now();
            let (selected, work) = match &oracle {
                SelectionOracle::Exhaustive => {
                    let scores = class.exhaustive_scores(&query);
                    let work = scores.len();
                    let i =
                        crate::dp::exponential_mechanism(&mut rng, &scores, eps_sel, sens);
                    (i, work)
                }
                SelectionOracle::Lazy(em) => {
                    let sample = em.select(&mut rng, &query, eps_sel, sens);
                    tail_counts.push(sample.tail_count);
                    margins.push(sample.b);
                    (sample.index, sample.work)
                }
                SelectionOracle::Sharded(em) => {
                    let sample = em.select(&mut rng, &query, eps_sel, sens);
                    tail_counts.push(sample.tail_count);
                    margins.push(sample.b);
                    (sample.index, sample.work)
                }
            };
            let selection_time = sel_started.elapsed();
            select_total += selection_time;
            work_total += work;

            if let Some(a) = accountant.as_mut() {
                a.record(eps0, 0.0);
            }

            class.update(&mut rng, selected, eps0);
            class.observe_round(&RoundObservation {
                iter: t + 1,
                selected,
                work,
                selection_time,
            });
        }

        EngineReport {
            rounds,
            eps0,
            total_time: started.elapsed(),
            select_total,
            work_total,
            tail_counts,
            margins,
            privacy_spent: accountant.map(|a| a.best_total()).unwrap_or((0.0, 0.0)),
        }
    }
}
