//! Private linear query release: classic MWEM (Algorithm 1) and Fast-MWEM
//! (Algorithm 2).
//!
//! Both algorithms share the MWU state ([`MwuState`]) and differ only in
//! how the exponential-mechanism "adversary" is implemented: an exhaustive
//! O(m) scan (classic) vs the Θ(√m) [`crate::lazy::LazyEm`] (fast).
//!
//! The dense numeric steps (score matvec, multiplicative update) go through
//! the [`MwemBackend`] trait; both implementations here route the hot loops
//! to the runtime-dispatched SIMD kernels ([`crate::runtime::kernels`]).
//!
//! Since the engine refactor (DESIGN.md §14) both entry points — and the
//! private LP solvers in [`crate::lp`] — are thin shells over one shared
//! per-round driver, [`MwemEngine`], parameterized by
//! [`crate::workloads::QueryClass`].

pub mod classic;
pub mod engine;
pub mod fast;
pub mod histogram;
pub mod queries;

pub use classic::{run_classic, IterStat, MwemConfig, MwemResult, UpdateRule};
pub use engine::{EngineReport, MwemEngine, SelectionOracle};
pub use fast::{
    run_fast, run_fast_with_index, run_fast_with_shard_set, FastMwemConfig, FastMwemOutput,
    LazyDiagnostics,
};
pub use histogram::Histogram;
pub use queries::QuerySet;

use crate::util::math::normalize_l1;

/// Pluggable dense-compute backend for MWEM's two hot numeric steps.
pub trait MwemBackend {
    /// `|Q · d|` for all m queries.
    fn abs_scores(&mut self, q: &QuerySet, d: &[f32]) -> Vec<f32>;

    /// `w ← w · exp(s·c)`; returns the normalized distribution p = w/‖w‖₁.
    fn mwu_update(&mut self, w: &mut [f32], c: &[f32], s: f32) -> Vec<f32>;
}

/// Stateless in-process backend; the dense loops run on the dispatched
/// kernels ([`crate::runtime::kernels`]). [`crate::runtime::CpuBackend`] is
/// the same computation plus call accounting.
pub struct NativeBackend;

impl MwemBackend for NativeBackend {
    fn abs_scores(&mut self, q: &QuerySet, d: &[f32]) -> Vec<f32> {
        q.abs_scores(d)
    }

    fn mwu_update(&mut self, w: &mut [f32], c: &[f32], s: f32) -> Vec<f32> {
        crate::runtime::kernels::exp_mul(w, c, s);
        let mut p = w.to_vec();
        normalize_l1(&mut p);
        p
    }
}

/// Multiplicative-weights state shared by classic and fast MWEM.
pub struct MwuState {
    /// Unnormalized weights over the domain.
    pub w: Vec<f32>,
    /// Current synthetic distribution p = w/‖w‖₁.
    pub p: Vec<f32>,
    /// Running sum of p across iterations (for the averaged output p̂).
    pub p_sum: Vec<f64>,
    /// Number of updates applied so far.
    pub iters: usize,
}

impl MwuState {
    /// Uniform initial state over a domain of size `u`.
    pub fn new(u: usize) -> Self {
        MwuState {
            w: vec![1.0; u],
            p: vec![1.0 / u as f32; u],
            p_sum: vec![0.0; u],
            iters: 0,
        }
    }

    /// Apply one multiplicative update through `backend` and accumulate the
    /// running average.
    pub fn update(&mut self, backend: &mut dyn MwemBackend, c: &[f32], s: f32) {
        self.p = backend.mwu_update(&mut self.w, c, s);
        // Rebase the weights onto the normalized distribution (MWU only
        // depends on weight ratios): over 10⁴+ rounds the raw products
        // would drift to f32 overflow/underflow.
        let u = self.w.len() as f32;
        for (wi, &pi) in self.w.iter_mut().zip(self.p.iter()) {
            *wi = pi * u;
        }
        for (acc, &pi) in self.p_sum.iter_mut().zip(self.p.iter()) {
            *acc += pi as f64;
        }
        self.iters += 1;
    }

    /// The averaged synthetic distribution p̂ = (1/T)Σₜ p⁽ᵗ⁾.
    pub fn p_avg(&self) -> Vec<f32> {
        if self.iters == 0 {
            return self.p.clone();
        }
        let inv = 1.0 / self.iters as f64;
        self.p_sum.iter().map(|&x| (x * inv) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mwu_state_updates_and_averages() {
        let mut st = MwuState::new(4);
        let mut be = NativeBackend;
        st.update(&mut be, &[1.0, 0.0, 0.0, 0.0], -1.0);
        assert!((st.p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(st.p[0] < st.p[1]); // coordinate 0 was down-weighted
        let avg = st.p_avg();
        assert!((avg.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn empty_state_avg_is_uniform() {
        let st = MwuState::new(5);
        let avg = st.p_avg();
        for &x in &avg {
            assert!((x - 0.2).abs() < 1e-6);
        }
    }
}
