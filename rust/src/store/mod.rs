//! Persistent artifact store: content-addressed k-MIPS index snapshots on
//! disk, so warm serving survives a coordinator restart (DESIGN.md §7).
//!
//! PR 2's [`crate::coordinator::IndexCache`] amortizes the Θ(m·d)+ index
//! build *within* one process; this subsystem makes the amortization
//! durable. Built indices (and sharded [`crate::lazy::ShardSet`]s) are
//! sealed into versioned, checksummed artifact files ([`mod@format`]),
//! cataloged by an atomically-rewritten JSON manifest ([`manifest`]), and
//! served
//! through a two-tier cache ([`tiered::TieredIndexCache`]): L1 = the
//! in-memory LRU, L2 = this store. A restarted coordinator pointed at the
//! same `--store-dir` decodes yesterday's index instead of rebuilding it.
//!
//! Trust and privacy: artifacts hold only *public* workload structure —
//! the query matrix and its derived search structure — exactly what the
//! in-memory cache already shares across jobs (see the privacy note in
//! `coordinator/cache.rs`). No histogram, iterate, accountant state or
//! mechanism randomness is ever written. The checksum defends against
//! corruption, not adversaries: the store directory has the same trust
//! level as the process itself.
//!
//! Failure philosophy: the store is an accelerator, never a correctness
//! dependency. Every read-side failure (missing file, truncation, bad
//! checksum, wrong version, stale manifest) is counted, logged, and
//! answered by falling back to a rebuild.

pub mod format;
pub mod lease;
pub mod manifest;
pub mod pager;
pub mod tiered;

pub use format::StoreError;
pub use lease::{Acquire, Lease, LeaseError, LeaseSettings};
pub use manifest::{DeltaEntry, Manifest, ManifestEntry, MANIFEST_FILE};
pub use pager::{HeapBudget, PagerSettings};
pub use tiered::{TieredEvent, TieredIndexCache};

use crate::coordinator::cache::{CachedIndex, WorkloadKey};
use crate::mips::WorkloadDelta;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Lifetime statistics of a [`DiskStore`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Artifacts currently cataloged.
    pub artifacts: usize,
    /// Workload-delta artifacts currently cataloged (DESIGN.md §9).
    pub deltas: usize,
    /// Loads that decoded an artifact successfully.
    pub hits: u64,
    /// Loads that found no artifact for the key.
    pub misses: u64,
    /// Loads that found an artifact but failed to decode it (counted in
    /// addition to a miss; the stale catalog entry is dropped).
    pub load_failures: u64,
    /// Successful loads served by mapping the artifact and borrowing its
    /// sections (DESIGN.md §12) — zero heap for the row data.
    pub mmap_restores: u64,
    /// Successful loads that decoded the artifact into heap — the pager
    /// was disabled, or mapping failed on this platform.
    pub decode_restores: u64,
    /// Artifacts written.
    pub writes: u64,
    /// Total artifact bytes written (excluding manifest rewrites).
    pub bytes_written: u64,
    /// Manifest re-reads triggered by a peer process changing the file
    /// (DESIGN.md §13). The watch itself is one `stat` per poll; this
    /// counts only the polls that found a new (mtime, len) stamp and paid
    /// for a parse — the O(1)-poll regression test pins it at zero across
    /// unchanged polls.
    pub manifest_reloads: u64,
    /// Total wall-clock spent decoding artifacts on successful loads.
    pub promote_time: Duration,
}

/// Write `bytes` to `path` atomically: write and fsync `<path>.tmp` in
/// the same directory, then rename it over `path` — a reader (or a crash,
/// even mid-rename) sees the old complete file or the new one, never a
/// torn write. The fsync before the rename matters: without it a
/// journaled rename can land before the data blocks, leaving an empty
/// file at the final name after power loss. Shared by the artifact and
/// manifest write paths.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write;
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    let mut f = std::fs::File::create(&tmp)
        .with_context(|| format!("creating temp file {tmp:?}"))?;
    f.write_all(bytes).with_context(|| format!("writing temp file {tmp:?}"))?;
    f.sync_all().with_context(|| format!("syncing temp file {tmp:?}"))?;
    drop(f);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {tmp:?} over {path:?}"))?;
    Ok(())
}

/// (mtime, len) identity of the manifest file as last read or written by
/// this process — the O(1) cross-process change detector (DESIGN.md §13):
/// one `stat` per poll, a full reload + parse only when the stamp moves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct FileStamp {
    mtime: std::time::SystemTime,
    len: u64,
}

fn stamp(path: &Path) -> Option<FileStamp> {
    let md = std::fs::metadata(path).ok()?;
    Some(FileStamp { mtime: md.modified().ok()?, len: md.len() })
}

struct DiskInner {
    manifest: Manifest,
    stats: StoreStats,
    /// Stamp of the manifest file backing `manifest`; `None` when the
    /// file does not exist (fresh store) or the stamp was unreadable.
    seen: Option<FileStamp>,
}

/// A content-addressed artifact store rooted at one directory: artifact
/// files named by [`Manifest::artifact_id`] plus a `manifest.json`
/// catalog. Thread-safe; artifact reads, decodes and artifact-file
/// writes run outside the interior lock, while catalog/stat updates —
/// including the (small) manifest rewrite that keeps the catalog
/// consistent — are serialized under it.
pub struct DiskStore {
    dir: PathBuf,
    pager: PagerSettings,
    inner: Mutex<DiskInner>,
}

impl DiskStore {
    /// Open (creating if needed) the store directory and load its
    /// manifest, restoring artifacts with the default [`PagerSettings`]
    /// (mmap paging on, eager section verification on). A corrupt
    /// manifest degrades to empty — the artifacts are self-describing, so
    /// the catalog repopulates as jobs re-save.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        Self::open_with(dir, PagerSettings::default())
    }

    /// Open the store with explicit pager settings (the `[pager]` config
    /// section).
    pub fn open_with(dir: impl AsRef<Path>, pager: PagerSettings) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating store directory {dir:?}"))?;
        let manifest_path = dir.join(MANIFEST_FILE);
        let seen = stamp(&manifest_path);
        let manifest = Manifest::load_or_empty(manifest_path);
        Ok(DiskStore {
            dir,
            pager,
            inner: Mutex::new(DiskInner { manifest, stats: StoreStats::default(), seen }),
        })
    }

    /// The store's root directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// How this store restores artifacts.
    pub fn pager_settings(&self) -> PagerSettings {
        self.pager
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> StoreStats {
        let g = self.inner.lock().unwrap();
        StoreStats { artifacts: g.manifest.len(), deltas: g.manifest.delta_count(), ..g.stats }
    }

    /// True when an artifact for `key` is cataloged (no I/O).
    pub fn contains(&self, key: &WorkloadKey) -> bool {
        self.inner.lock().unwrap().manifest.get(key).is_some()
    }

    /// Poll the manifest file for changes committed by peer processes
    /// sharing this directory (DESIGN.md §13). One `stat`; only when the
    /// (mtime, len) stamp differs from the last read/write by this
    /// process is the catalog re-read and adopted. Returns `true` when
    /// the in-memory catalog actually changed — the signal the tiered
    /// cache and registry use to invalidate before serving.
    pub fn refresh(&self) -> bool {
        let mut g = self.inner.lock().unwrap();
        self.refresh_locked(&mut g)
    }

    /// The manifest change counter as currently known to this process.
    pub fn manifest_counter(&self) -> u64 {
        self.inner.lock().unwrap().manifest.counter()
    }

    /// Newest cataloged delta generation of `fingerprint`'s family (no
    /// I/O) — compared against a [`crate::workloads::WorkloadRegistry`]'s
    /// generation to detect updates committed by peer processes.
    pub fn max_delta_generation(&self, fingerprint: u128) -> u64 {
        let g = self.inner.lock().unwrap();
        g.manifest
            .iter_deltas()
            .filter(|d| d.fingerprint == fingerprint)
            .map(|d| d.generation)
            .max()
            .unwrap_or(0)
    }

    fn refresh_locked(&self, g: &mut DiskInner) -> bool {
        let path = self.dir.join(MANIFEST_FILE);
        // Stamp before read, so a write racing between the two leaves us
        // with an old stamp over new content — the next poll re-reads
        // (spurious but safe), rather than a new stamp over old content,
        // which would mask the change forever.
        let now = stamp(&path);
        if now == g.seen {
            return false;
        }
        match Manifest::load(&path) {
            Ok(m) => {
                let changed = m != g.manifest;
                g.manifest = m;
                g.seen = now;
                g.stats.manifest_reloads += 1;
                changed
            }
            Err(e) => {
                // A torn or corrupt concurrent write: keep our catalog
                // and our stamp, so the next poll retries the read once
                // the writer's rename lands.
                eprintln!(
                    "warning: ignoring concurrently-modified store manifest in {:?}: {e:#}",
                    self.dir
                );
                false
            }
        }
    }

    /// Commit the in-memory catalog: bump the change counter past
    /// whatever was merged from disk, write atomically, and re-stamp so
    /// our own write does not read back as a peer change.
    fn commit_locked(&self, g: &mut DiskInner) -> Result<()> {
        let path = self.dir.join(MANIFEST_FILE);
        g.manifest.bump_counter(0);
        g.manifest.save(&path)?;
        g.seen = stamp(&path);
        Ok(())
    }

    /// Load the artifact for `key` — by mmap paging when the pager is
    /// enabled (decode-into-heap only as the platform fallback), plain
    /// decode otherwise. Returns the restored entry, the build cost
    /// recorded at save time (what a promotion saves), and the restore
    /// wall-clock (what it cost instead). Any corruption — unreadable
    /// file, bad envelope, checksum mismatch, malformed payload — returns
    /// `None` after dropping the stale catalog entry; the caller rebuilds.
    pub fn load(&self, key: &WorkloadKey) -> Option<(CachedIndex, Duration, Duration)> {
        let entry = {
            let mut g = self.inner.lock().unwrap();
            match g.manifest.get(key).cloned() {
                Some(e) => e,
                None => {
                    g.stats.misses += 1;
                    return None;
                }
            }
        };
        let path = self.dir.join(&entry.file);
        let t0 = Instant::now();
        let restored: Result<(CachedIndex, bool), String> = if self.pager.enabled {
            match pager::mmap_artifact(&path, key, self.pager.verify) {
                Ok(value) => Ok((value, true)),
                // the artifact itself is bad — decoding the same bytes
                // would fail identically, so fall through to the drop path
                Err(pager::PagerFailure::Artifact(e)) => Err(e.to_string()),
                // mapping is unavailable (platform, syscall, endianness):
                // the copying decode path restores the same entry
                Err(pager::PagerFailure::Map(_)) => std::fs::read(&path)
                    .map_err(|e| e.to_string())
                    .and_then(|bytes| {
                        format::decode_artifact(&bytes, key).map_err(|e| e.to_string())
                    })
                    .map(|value| (value, false)),
            }
        } else {
            std::fs::read(&path)
                .map_err(|e| e.to_string())
                .and_then(|bytes| {
                    format::decode_artifact(&bytes, key).map_err(|e| e.to_string())
                })
                .map(|value| (value, false))
        };
        match restored {
            Ok((value, mmapped)) => {
                let took = t0.elapsed();
                let mut g = self.inner.lock().unwrap();
                g.stats.hits += 1;
                g.stats.promote_time += took;
                if mmapped {
                    g.stats.mmap_restores += 1;
                } else {
                    g.stats.decode_restores += 1;
                }
                Some((value, Duration::from_micros(entry.build_us), took))
            }
            Err(e) => {
                eprintln!(
                    "warning: dropping unusable index artifact {path:?}: {e} \
                     (falling back to rebuild)"
                );
                // reclaim the dead file too — content addressing would
                // otherwise never overwrite it for a non-recurring key
                let _ = std::fs::remove_file(&path);
                let mut g = self.inner.lock().unwrap();
                g.stats.misses += 1;
                g.stats.load_failures += 1;
                self.refresh_locked(&mut g);
                if g.manifest.remove(key).is_some() {
                    let _ = self.commit_locked(&mut g);
                }
                None
            }
        }
    }

    /// Seal `value` into an artifact for `key`: write the file via
    /// temp-then-rename, then atomically rewrite the manifest. Returns the
    /// artifact size in bytes.
    ///
    /// Writing a snapshot is also the *compaction* step of the dynamic
    /// workload policy (DESIGN.md §9): snapshots of the same family (same
    /// fingerprint, kind, shards) at older generations are superseded —
    /// their catalog entries and files are removed. Delta artifacts are
    /// retained: they are tiny, and the full chain is what reconstructs
    /// the effective workload (and the registry's generation state) after
    /// a restart.
    ///
    /// Multi-process safety (DESIGN.md §13): the catalog commit merges
    /// with whatever peers wrote since our last read, so a concurrent
    /// save never erases another process's entries; an artifact a peer
    /// already cataloged for this exact key is left alone (builds are
    /// deterministic per key, ours adds nothing); and supersession only
    /// ever removes *strictly older* generations of the family, so a
    /// build that lost a lease race cannot clobber a newer artifact.
    pub fn save(
        &self,
        key: &WorkloadKey,
        value: &CachedIndex,
        build_time: Duration,
    ) -> Result<u64> {
        let id = Manifest::artifact_id(key);
        let file = format!("{id}.idx");
        let path = self.dir.join(&file);
        {
            let mut g = self.inner.lock().unwrap();
            self.refresh_locked(&mut g);
            if let Some(existing) = g.manifest.get(key) {
                return Ok(existing.bytes);
            }
        }
        let bytes = format::encode_artifact(key, value);
        write_atomic(&path, &bytes)
            .with_context(|| format!("persisting artifact {file}"))?;

        let entry = ManifestEntry {
            file,
            kind: key.kind,
            shards: key.shards,
            fingerprint: key.fingerprint,
            generation: key.generation,
            bytes: bytes.len() as u64,
            build_us: build_time.as_micros() as u64,
        };
        let superseded = {
            let mut g = self.inner.lock().unwrap();
            self.refresh_locked(&mut g);
            g.manifest.insert(key, entry);
            let superseded = g.manifest.remove_superseded_snapshots(key);
            self.commit_locked(&mut g)?;
            g.stats.writes += 1;
            g.stats.bytes_written += bytes.len() as u64;
            superseded
        };
        for old in superseded {
            let _ = std::fs::remove_file(self.dir.join(&old.file));
        }
        Ok(bytes.len() as u64)
    }

    /// Persist one workload delta as a compact artifact (DESIGN.md §9).
    /// Idempotent: a delta already cataloged for `(fingerprint,
    /// generation)` is left untouched (deltas are deterministic per
    /// generation, so re-deriving the same bytes would be wasted I/O).
    /// Returns the artifact size in bytes.
    pub fn save_delta(
        &self,
        fingerprint: u128,
        generation: u64,
        delta: &WorkloadDelta,
    ) -> Result<u64> {
        {
            let mut g = self.inner.lock().unwrap();
            self.refresh_locked(&mut g);
            if let Some(existing) = g.manifest.get_delta(fingerprint, generation) {
                return Ok(existing.bytes);
            }
        }
        let id = Manifest::delta_id(fingerprint, generation);
        let file = format!("{id}.delta");
        let path = self.dir.join(&file);
        let bytes = format::encode_delta_artifact(fingerprint, generation, delta);
        write_atomic(&path, &bytes)
            .with_context(|| format!("persisting delta artifact {file}"))?;

        let entry = DeltaEntry {
            file,
            fingerprint,
            generation,
            bytes: bytes.len() as u64,
        };
        let mut g = self.inner.lock().unwrap();
        self.refresh_locked(&mut g);
        g.manifest.insert_delta(entry);
        self.commit_locked(&mut g)?;
        g.stats.writes += 1;
        g.stats.bytes_written += bytes.len() as u64;
        Ok(bytes.len() as u64)
    }

    /// Load the delta chain taking `fingerprint` from generation
    /// `from` (exclusive) to `to` (inclusive). Returns `None` if any link
    /// is missing or unreadable — the caller falls back to a fresh build;
    /// unreadable links are dropped from the catalog like bad snapshots.
    pub fn load_deltas(
        &self,
        fingerprint: u128,
        from: u64,
        to: u64,
    ) -> Option<Vec<Arc<WorkloadDelta>>> {
        let mut chain = Vec::with_capacity(to.saturating_sub(from) as usize);
        for generation in from + 1..=to {
            let entry = {
                let g = self.inner.lock().unwrap();
                g.manifest.get_delta(fingerprint, generation).cloned()?
            };
            let path = self.dir.join(&entry.file);
            let decoded = std::fs::read(&path)
                .map_err(|e| e.to_string())
                .and_then(|bytes| {
                    format::decode_delta_artifact(&bytes).map_err(|e| e.to_string())
                });
            match decoded {
                Ok((fp, produced, delta)) if fp == fingerprint && produced == generation => {
                    chain.push(Arc::new(delta));
                }
                other => {
                    let why = match other {
                        Ok(_) => "delta describes a different workload/generation".to_string(),
                        Err(e) => e,
                    };
                    eprintln!(
                        "warning: dropping unusable delta artifact {path:?}: {why} \
                         (falling back to rebuild)"
                    );
                    let _ = std::fs::remove_file(&path);
                    let mut g = self.inner.lock().unwrap();
                    g.stats.load_failures += 1;
                    self.refresh_locked(&mut g);
                    if g.manifest.remove_delta(fingerprint, generation).is_some() {
                        let _ = self.commit_locked(&mut g);
                    }
                    return None;
                }
            }
        }
        Some(chain)
    }

    /// The newest cataloged snapshot of `key`'s family at a generation
    /// ≤ `key.generation`, decoded: `(found generation, entry, recorded
    /// build, decode wall-clock)`. An exact-generation snapshot serves
    /// directly; an older one is the base the caller patches forward.
    /// Failures behave like [`DiskStore::load`]: drop the catalog entry,
    /// return `None`, rebuild.
    pub fn load_latest(
        &self,
        key: &WorkloadKey,
    ) -> Option<(u64, CachedIndex, Duration, Duration)> {
        let found = {
            let mut g = self.inner.lock().unwrap();
            match g.manifest.latest_snapshot(key).map(|(generation, _)| generation) {
                Some(generation) => generation,
                None => {
                    g.stats.misses += 1;
                    return None;
                }
            }
        };
        self.load(&key.at_generation(found))
            .map(|(value, build, took)| (found, value, build, took))
    }

    /// Generation of the newest cataloged snapshot of `key`'s family at or
    /// below `key.generation` (no I/O) — the compaction-due check.
    pub fn latest_snapshot_generation(&self, key: &WorkloadKey) -> Option<u64> {
        let g = self.inner.lock().unwrap();
        g.manifest.latest_snapshot(key).map(|(generation, _)| generation)
    }

    /// Every cataloged delta chain, grouped by family fingerprint and
    /// decoded, each chain contiguous from generation 1 (a gap truncates
    /// the chain at the last contiguous link, with a warning). Used to
    /// restore a [`crate::workloads::WorkloadRegistry`] after a restart.
    pub fn delta_chains(&self) -> Vec<(u128, Vec<Arc<WorkloadDelta>>)> {
        let families: Vec<(u128, u64)> = {
            let g = self.inner.lock().unwrap();
            let mut max_gen: std::collections::BTreeMap<u128, u64> =
                std::collections::BTreeMap::new();
            for d in g.manifest.iter_deltas() {
                let e = max_gen.entry(d.fingerprint).or_insert(0);
                *e = (*e).max(d.generation);
            }
            max_gen.into_iter().collect()
        };
        let mut chains = Vec::with_capacity(families.len());
        for (fingerprint, top) in families {
            // walk 1..=top but stop at the first missing/unreadable link
            let mut chain = Vec::new();
            for generation in 1..=top {
                match self.load_deltas(fingerprint, generation - 1, generation) {
                    Some(mut link) => chain.append(&mut link),
                    None => {
                        eprintln!(
                            "warning: delta chain for {fingerprint:032x} breaks at \
                             generation {generation}; restoring the prefix"
                        );
                        break;
                    }
                }
            }
            if !chain.is_empty() {
                chains.push((fingerprint, chain));
            }
        }
        chains
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mips::{build_index, IndexKind, VectorSet};
    use crate::util::rng::Rng;

    fn random_set(n: usize, d: usize, seed: u64) -> VectorSet {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        VectorSet::new(data, n, d)
    }

    fn scratch_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("fastmwem-store-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn save_load_round_trip_updates_stats() {
        let dir = scratch_dir("roundtrip");
        let store = DiskStore::open(&dir).unwrap();
        let vs = random_set(50, 4, 1);
        let key = WorkloadKey { fingerprint: 5, kind: IndexKind::Flat, shards: 1, generation: 0 };
        let value = CachedIndex::Mono(build_index(IndexKind::Flat, vs, 1));

        assert!(store.load(&key).is_none(), "empty store must miss");
        let bytes = store.save(&key, &value, Duration::from_millis(20)).unwrap();
        assert!(bytes > 0);
        assert!(store.contains(&key));

        let (restored, recorded_build, decode_time) = store.load(&key).unwrap();
        assert_eq!(recorded_build, Duration::from_millis(20));
        assert!(decode_time > Duration::ZERO);
        match restored {
            CachedIndex::Mono(i) => assert_eq!((i.len(), i.dim()), (50, 4)),
            _ => panic!("mono in, mono out"),
        }

        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.writes, s.artifacts), (1, 1, 1, 1));
        assert_eq!(s.bytes_written, bytes);
        assert_eq!(s.load_failures, 0);
        #[cfg(unix)]
        assert_eq!(
            (s.mmap_restores, s.decode_restores),
            (1, 0),
            "with the pager on, a restore maps instead of decoding"
        );

        // a second process (fresh DiskStore) sees the same artifact
        let store2 = DiskStore::open(&dir).unwrap();
        assert!(store2.load(&key).is_some(), "artifacts must survive reopen");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Delta persistence + generation-aware restore: a snapshot at g0 plus
    /// the delta chain reconstructs the family state; compaction (a newer
    /// snapshot) supersedes the old file but keeps the deltas.
    #[test]
    fn delta_chain_persists_and_snapshot_compaction_prunes() {
        let dir = scratch_dir("deltas");
        let store = DiskStore::open(&dir).unwrap();
        let fp = 0xABCu128;
        let key = WorkloadKey { fingerprint: fp, kind: IndexKind::Flat, shards: 1, generation: 0 };
        let vs = random_set(30, 3, 7);
        let g0 = CachedIndex::Mono(build_index(IndexKind::Flat, vs.clone(), 1));
        store.save(&key, &g0, Duration::from_millis(2)).unwrap();

        // two deltas: g1 tombstones a row, g2 inserts one
        let d1 = WorkloadDelta::new(crate::mips::VectorSet::zeros(0, 3), vec![4]);
        let d2 = WorkloadDelta::new(random_set(1, 3, 8), vec![]);
        store.save_delta(fp, 1, &d1).unwrap();
        let delta_bytes = store.save_delta(fp, 2, &d2).unwrap();
        assert_eq!(store.save_delta(fp, 2, &d2).unwrap(), delta_bytes, "idempotent");
        assert_eq!(store.stats().deltas, 2);

        // the chain loads contiguously; a gap returns None
        let chain = store.load_deltas(fp, 0, 2).unwrap();
        assert_eq!(chain.len(), 2);
        assert_eq!(chain[0].tombstoned, vec![4]);
        assert!(store.load_deltas(fp, 0, 3).is_none(), "gap at g3");

        // generation-aware restore: request g2, find the g0 snapshot
        let (found, _, build, _) = store.load_latest(&key.at_generation(2)).unwrap();
        assert_eq!(found, 0);
        assert_eq!(build, Duration::from_millis(2));

        // compaction: a g2 snapshot supersedes g0 (file + entry) but the
        // deltas survive — they reconstruct the workload after restarts
        let patched = CachedIndex::Mono(build_index(IndexKind::Flat, vs, 2));
        store.save(&key.at_generation(2), &patched, Duration::from_millis(3)).unwrap();
        let s = store.stats();
        assert_eq!((s.artifacts, s.deltas), (1, 2));
        assert!(!dir.join(format!("{}.idx", Manifest::artifact_id(&key))).exists());
        let (found, _, _, _) = store.load_latest(&key.at_generation(2)).unwrap();
        assert_eq!(found, 2, "exact-generation snapshot now serves");

        // restart: the registry-restore scan sees the full chain
        let store2 = DiskStore::open(&dir).unwrap();
        let chains = store2.delta_chains();
        assert_eq!(chains.len(), 1);
        assert_eq!(chains[0].0, fp);
        assert_eq!(chains[0].1.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pager_disabled_store_restores_by_decoding() {
        let dir = scratch_dir("pager-off");
        let store =
            DiskStore::open_with(&dir, PagerSettings { enabled: false, verify: true }).unwrap();
        let key = WorkloadKey { fingerprint: 9, kind: IndexKind::Flat, shards: 1, generation: 0 };
        let value = CachedIndex::Mono(build_index(IndexKind::Flat, random_set(20, 3, 4), 1));
        store.save(&key, &value, Duration::ZERO).unwrap();
        assert!(store.load(&key).is_some());
        let s = store.stats();
        assert_eq!((s.mmap_restores, s.decode_restores), (0, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Two processes (modeled as two `DiskStore` handles) sharing one
    /// directory (DESIGN.md §13): commits merge instead of erasing each
    /// other, the change watch is one `stat` per poll (a parse only when
    /// the stamp moves), and counters stay strictly increasing across
    /// writers.
    #[test]
    fn peer_commits_merge_and_unchanged_polls_never_reparse() {
        let dir = scratch_dir("peers");
        let a = DiskStore::open(&dir).unwrap();
        let b = DiskStore::open(&dir).unwrap();
        let key_a = WorkloadKey { fingerprint: 1, kind: IndexKind::Flat, shards: 1, generation: 0 };
        let key_b = WorkloadKey { fingerprint: 2, kind: IndexKind::Flat, shards: 1, generation: 0 };
        let value = CachedIndex::Mono(build_index(IndexKind::Flat, random_set(20, 3, 1), 1));

        a.save(&key_a, &value, Duration::ZERO).unwrap();
        assert!(b.refresh(), "A's commit must show up on B's next poll");
        assert!(b.contains(&key_a));
        assert_eq!(b.stats().manifest_reloads, 1);
        // O(1) watch: polls with an unchanged stamp never re-read the file
        for _ in 0..100 {
            assert!(!b.refresh());
        }
        assert_eq!(b.stats().manifest_reloads, 1);
        // our own commits re-stamp, so they don't read back as changes
        b.save(&key_b, &value, Duration::ZERO).unwrap();
        assert!(!b.refresh());

        // merge-before-write: B's commit must not erase A's entry
        assert!(b.contains(&key_a) && b.contains(&key_b));
        assert!(a.refresh());
        assert!(a.contains(&key_b), "B's commit must show up on A's next poll");
        assert_eq!(a.manifest_counter(), 2, "two commits, strictly increasing counter");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A builder that lost a lease race and saves late (DESIGN.md §13):
    /// re-saving an already-cataloged key is skipped (no duplicate
    /// write), and saving an *older* generation never clobbers the newer
    /// snapshot a peer committed meanwhile — supersession is strictly
    /// one-directional.
    #[test]
    fn losing_builder_never_clobbers_newer_generation() {
        let dir = scratch_dir("no-clobber");
        let winner = DiskStore::open(&dir).unwrap();
        let loser = DiskStore::open(&dir).unwrap();
        let fam = WorkloadKey { fingerprint: 7, kind: IndexKind::Flat, shards: 1, generation: 0 };
        let v0 = CachedIndex::Mono(build_index(IndexKind::Flat, random_set(20, 3, 2), 1));
        let v1 = CachedIndex::Mono(build_index(IndexKind::Flat, random_set(21, 3, 3), 1));

        // the winner has already advanced the family to generation 1
        winner.save(&fam.at_generation(1), &v1, Duration::ZERO).unwrap();
        let g1_file = dir.join(format!("{}.idx", Manifest::artifact_id(&fam.at_generation(1))));
        assert!(g1_file.exists());

        // the loser finishes its stale generation-0 build and saves late
        loser.save(&fam, &v0, Duration::ZERO).unwrap();
        assert!(g1_file.exists(), "an older-generation save must not remove the newer file");
        assert!(loser.contains(&fam.at_generation(1)), "…nor its catalog entry");
        let (found, _, _, _) = loser.load_latest(&fam.at_generation(1)).unwrap();
        assert_eq!(found, 1, "the newer snapshot still serves");

        // duplicate save of an already-cataloged key is skipped entirely
        let writes_before = loser.stats().writes;
        loser.save(&fam.at_generation(1), &v1, Duration::ZERO).unwrap();
        assert_eq!(loser.stats().writes, writes_before, "peer-won keys are not rewritten");

        // a *newer* save still supersedes: the winner compacts to g2
        winner.refresh();
        winner.save(&fam.at_generation(2), &v1, Duration::ZERO).unwrap();
        assert!(!g1_file.exists(), "forward supersession still prunes old snapshots");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_artifact_is_dropped_and_misses() {
        let dir = scratch_dir("corrupt");
        let store = DiskStore::open(&dir).unwrap();
        let key = WorkloadKey { fingerprint: 6, kind: IndexKind::Flat, shards: 1, generation: 0 };
        let value = CachedIndex::Mono(build_index(IndexKind::Flat, random_set(30, 3, 2), 1));
        store.save(&key, &value, Duration::ZERO).unwrap();

        // truncate the artifact behind the store's back
        let file = dir.join(format!("{}.idx", Manifest::artifact_id(&key)));
        let bytes = std::fs::read(&file).unwrap();
        std::fs::write(&file, &bytes[..bytes.len() / 2]).unwrap();

        assert!(store.load(&key).is_none(), "corrupt artifact must miss, not panic");
        let s = store.stats();
        assert_eq!(s.load_failures, 1);
        assert!(!store.contains(&key), "stale catalog entry must be dropped");

        // the drop is persistent: a reopened store does not re-try the file
        let store2 = DiskStore::open(&dir).unwrap();
        assert!(!store2.contains(&key));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
