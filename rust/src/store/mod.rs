//! Persistent artifact store: content-addressed k-MIPS index snapshots on
//! disk, so warm serving survives a coordinator restart (DESIGN.md §7).
//!
//! PR 2's [`crate::coordinator::IndexCache`] amortizes the Θ(m·d)+ index
//! build *within* one process; this subsystem makes the amortization
//! durable. Built indices (and sharded [`crate::lazy::ShardSet`]s) are
//! sealed into versioned, checksummed artifact files ([`mod@format`]),
//! cataloged by an atomically-rewritten JSON manifest ([`manifest`]), and
//! served
//! through a two-tier cache ([`tiered::TieredIndexCache`]): L1 = the
//! in-memory LRU, L2 = this store. A restarted coordinator pointed at the
//! same `--store-dir` decodes yesterday's index instead of rebuilding it.
//!
//! Trust and privacy: artifacts hold only *public* workload structure —
//! the query matrix and its derived search structure — exactly what the
//! in-memory cache already shares across jobs (see the privacy note in
//! `coordinator/cache.rs`). No histogram, iterate, accountant state or
//! mechanism randomness is ever written. The checksum defends against
//! corruption, not adversaries: the store directory has the same trust
//! level as the process itself.
//!
//! Failure philosophy: the store is an accelerator, never a correctness
//! dependency. Every read-side failure (missing file, truncation, bad
//! checksum, wrong version, stale manifest) is counted, logged, and
//! answered by falling back to a rebuild.

pub mod format;
pub mod manifest;
pub mod tiered;

pub use format::StoreError;
pub use manifest::{Manifest, ManifestEntry, MANIFEST_FILE};
pub use tiered::{TieredEvent, TieredIndexCache};

use crate::coordinator::cache::{CachedIndex, WorkloadKey};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Lifetime statistics of a [`DiskStore`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Artifacts currently cataloged.
    pub artifacts: usize,
    /// Loads that decoded an artifact successfully.
    pub hits: u64,
    /// Loads that found no artifact for the key.
    pub misses: u64,
    /// Loads that found an artifact but failed to decode it (counted in
    /// addition to a miss; the stale catalog entry is dropped).
    pub load_failures: u64,
    /// Artifacts written.
    pub writes: u64,
    /// Total artifact bytes written (excluding manifest rewrites).
    pub bytes_written: u64,
    /// Total wall-clock spent decoding artifacts on successful loads.
    pub promote_time: Duration,
}

/// Write `bytes` to `path` atomically: write and fsync `<path>.tmp` in
/// the same directory, then rename it over `path` — a reader (or a crash,
/// even mid-rename) sees the old complete file or the new one, never a
/// torn write. The fsync before the rename matters: without it a
/// journaled rename can land before the data blocks, leaving an empty
/// file at the final name after power loss. Shared by the artifact and
/// manifest write paths.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write;
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    let mut f = std::fs::File::create(&tmp)
        .with_context(|| format!("creating temp file {tmp:?}"))?;
    f.write_all(bytes).with_context(|| format!("writing temp file {tmp:?}"))?;
    f.sync_all().with_context(|| format!("syncing temp file {tmp:?}"))?;
    drop(f);
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {tmp:?} over {path:?}"))?;
    Ok(())
}

struct DiskInner {
    manifest: Manifest,
    stats: StoreStats,
}

/// A content-addressed artifact store rooted at one directory: artifact
/// files named by [`Manifest::artifact_id`] plus a `manifest.json`
/// catalog. Thread-safe; artifact reads, decodes and artifact-file
/// writes run outside the interior lock, while catalog/stat updates —
/// including the (small) manifest rewrite that keeps the catalog
/// consistent — are serialized under it.
pub struct DiskStore {
    dir: PathBuf,
    inner: Mutex<DiskInner>,
}

impl DiskStore {
    /// Open (creating if needed) the store directory and load its
    /// manifest. A corrupt manifest degrades to empty — the artifacts are
    /// self-describing, so the catalog repopulates as jobs re-save.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating store directory {dir:?}"))?;
        let manifest = Manifest::load_or_empty(dir.join(MANIFEST_FILE));
        Ok(DiskStore {
            dir,
            inner: Mutex::new(DiskInner { manifest, stats: StoreStats::default() }),
        })
    }

    /// The store's root directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Statistics snapshot.
    pub fn stats(&self) -> StoreStats {
        let g = self.inner.lock().unwrap();
        StoreStats { artifacts: g.manifest.len(), ..g.stats }
    }

    /// True when an artifact for `key` is cataloged (no I/O).
    pub fn contains(&self, key: &WorkloadKey) -> bool {
        self.inner.lock().unwrap().manifest.get(key).is_some()
    }

    /// Load and decode the artifact for `key`. Returns the restored entry,
    /// the build cost recorded at save time (what a promotion saves), and
    /// the decode wall-clock (what it cost instead). Any failure — no
    /// catalog entry, unreadable file, bad envelope, malformed payload —
    /// returns `None` after dropping the stale catalog entry; the caller
    /// rebuilds.
    pub fn load(&self, key: &WorkloadKey) -> Option<(CachedIndex, Duration, Duration)> {
        let entry = {
            let mut g = self.inner.lock().unwrap();
            match g.manifest.get(key).cloned() {
                Some(e) => e,
                None => {
                    g.stats.misses += 1;
                    return None;
                }
            }
        };
        let path = self.dir.join(&entry.file);
        let t0 = Instant::now();
        let decoded = std::fs::read(&path)
            .map_err(|e| e.to_string())
            .and_then(|bytes| {
                format::decode_artifact(&bytes, key).map_err(|e| e.to_string())
            });
        match decoded {
            Ok(value) => {
                let took = t0.elapsed();
                let mut g = self.inner.lock().unwrap();
                g.stats.hits += 1;
                g.stats.promote_time += took;
                Some((value, Duration::from_micros(entry.build_us), took))
            }
            Err(e) => {
                eprintln!(
                    "warning: dropping unusable index artifact {path:?}: {e} \
                     (falling back to rebuild)"
                );
                // reclaim the dead file too — content addressing would
                // otherwise never overwrite it for a non-recurring key
                let _ = std::fs::remove_file(&path);
                let manifest_path = self.dir.join(MANIFEST_FILE);
                let mut g = self.inner.lock().unwrap();
                g.stats.misses += 1;
                g.stats.load_failures += 1;
                if g.manifest.remove(key).is_some() {
                    let _ = g.manifest.save(&manifest_path);
                }
                None
            }
        }
    }

    /// Seal `value` into an artifact for `key`: write the file via
    /// temp-then-rename, then atomically rewrite the manifest. Returns the
    /// artifact size in bytes.
    pub fn save(
        &self,
        key: &WorkloadKey,
        value: &CachedIndex,
        build_time: Duration,
    ) -> Result<u64> {
        let id = Manifest::artifact_id(key);
        let file = format!("{id}.idx");
        let path = self.dir.join(&file);
        let bytes = format::encode_artifact(key, value);
        write_atomic(&path, &bytes)
            .with_context(|| format!("persisting artifact {file}"))?;

        let manifest_path = self.dir.join(MANIFEST_FILE);
        let entry = ManifestEntry {
            file,
            kind: key.kind,
            shards: key.shards,
            bytes: bytes.len() as u64,
            build_us: build_time.as_micros() as u64,
        };
        let mut g = self.inner.lock().unwrap();
        g.manifest.insert(key, entry);
        g.manifest.save(&manifest_path)?;
        g.stats.writes += 1;
        g.stats.bytes_written += bytes.len() as u64;
        Ok(bytes.len() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mips::{build_index, IndexKind, VectorSet};
    use crate::util::rng::Rng;

    fn random_set(n: usize, d: usize, seed: u64) -> VectorSet {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        VectorSet::new(data, n, d)
    }

    fn scratch_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("fastmwem-store-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn save_load_round_trip_updates_stats() {
        let dir = scratch_dir("roundtrip");
        let store = DiskStore::open(&dir).unwrap();
        let vs = random_set(50, 4, 1);
        let key = WorkloadKey { fingerprint: 5, kind: IndexKind::Flat, shards: 1 };
        let value = CachedIndex::Mono(build_index(IndexKind::Flat, vs, 1));

        assert!(store.load(&key).is_none(), "empty store must miss");
        let bytes = store.save(&key, &value, Duration::from_millis(20)).unwrap();
        assert!(bytes > 0);
        assert!(store.contains(&key));

        let (restored, recorded_build, decode_time) = store.load(&key).unwrap();
        assert_eq!(recorded_build, Duration::from_millis(20));
        assert!(decode_time > Duration::ZERO);
        match restored {
            CachedIndex::Mono(i) => assert_eq!((i.len(), i.dim()), (50, 4)),
            _ => panic!("mono in, mono out"),
        }

        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.writes, s.artifacts), (1, 1, 1, 1));
        assert_eq!(s.bytes_written, bytes);
        assert_eq!(s.load_failures, 0);

        // a second process (fresh DiskStore) sees the same artifact
        let store2 = DiskStore::open(&dir).unwrap();
        assert!(store2.load(&key).is_some(), "artifacts must survive reopen");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_artifact_is_dropped_and_misses() {
        let dir = scratch_dir("corrupt");
        let store = DiskStore::open(&dir).unwrap();
        let key = WorkloadKey { fingerprint: 6, kind: IndexKind::Flat, shards: 1 };
        let value = CachedIndex::Mono(build_index(IndexKind::Flat, random_set(30, 3, 2), 1));
        store.save(&key, &value, Duration::ZERO).unwrap();

        // truncate the artifact behind the store's back
        let file = dir.join(format!("{}.idx", Manifest::artifact_id(&key)));
        let bytes = std::fs::read(&file).unwrap();
        std::fs::write(&file, &bytes[..bytes.len() / 2]).unwrap();

        assert!(store.load(&key).is_none(), "corrupt artifact must miss, not panic");
        let s = store.stats();
        assert_eq!(s.load_failures, 1);
        assert!(!store.contains(&key), "stale catalog entry must be dropped");

        // the drop is persistent: a reopened store does not re-try the file
        let store2 = DiskStore::open(&dir).unwrap();
        assert!(!store2.contains(&key));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
