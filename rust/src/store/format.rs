//! On-disk artifact format: a versioned, checksummed envelope around one
//! index snapshot (DESIGN.md §7, §12).
//!
//! Version 3 splits an artifact into a small **meta** stream (index
//! structure: lists, links, quantized codes — everything the decoder
//! walks) and zero or more page-aligned **sections** holding raw blocked
//! f32 row data. The section layout on disk is exactly the in-memory
//! blocked layout of [`crate::mips::VectorSet`], so a mapped file can be
//! borrowed as vector storage with zero copies (`store::pager`).
//!
//! ```text
//! offset  size  field
//! 0       8     magic  "FMWEMIDX"
//! 8       4     format version (u32 LE, currently 3)
//! 12      16    WorkloadKey.fingerprint (u128 LE)
//! 28      1     WorkloadKey.kind tag (IndexKind::tag)
//! 29      8     WorkloadKey.shards (u64 LE)
//! 37      8     WorkloadKey.generation (u64 LE)
//! 45      8     meta payload length (u64 LE)
//! 53      16    FNV-128 meta checksum (u128 LE)
//! 69      8     section count (u64 LE)
//! 77      ..    section table — 40 bytes per entry:
//!                 offset u64 (from file start, multiple of 4096)
//!                 rows u64, dim u64
//!                 FNV-128 section checksum u128
//! ..      ..    meta payload — a mips/lazy snapshot (see `encode_payload`)
//! ..      ..    zero padding to the first section offset
//! ..      ..    sections: rows × row_stride(dim) f32s each, LE, blocked,
//!               page-aligned, in table order, back to back (page-padded)
//! ```
//!
//! Dynamic workloads (DESIGN.md §9) add a second artifact species: compact
//! **delta artifacts** ([`encode_delta_artifact`]) carrying one
//! [`crate::mips::WorkloadDelta`] under their own magic `"FMWEMDLT"`, keyed by the
//! workload family fingerprint plus the generation the delta produces.
//! Deltas are small and short-lived, so their vector payloads stay inline
//! (no sections) and their header keeps the v2 shape.
//!
//! The header carries the full [`WorkloadKey`] so an artifact is
//! self-describing: [`decode_artifact`] refuses to hand back an index for
//! a key other than the one the caller asked for, even if a file was
//! renamed or the content-addressed name collided. Every failure mode —
//! bad magic, unknown version, truncation, checksum mismatch, misaligned
//! or overlapping sections, malformed payload — is a typed [`StoreError`],
//! never a panic, so the tiered cache can always fall back to a rebuild.
//!
//! Integrity: the envelope checksum covers the meta stream (including any
//! quantized code payloads, which always encode inline); each section
//! carries its own checksum in the table. A flipped bit in the table
//! itself either breaks a structural invariant (alignment, bounds,
//! ordering) or makes the named section fail its checksum — both end in a
//! typed error and a rebuild, never a silently wrong index.
//!
//! The codec is hand-rolled on the vendored-offline discipline (DESIGN.md
//! §3 — no serde/bincode) and endianness-pinned (everything
//! little-endian), so artifacts are portable across hosts; only the
//! zero-copy *borrow* of a mapped section is gated to little-endian hosts
//! (`VectorSet::borrowed`), with the copying decode path as the portable
//! fallback.

use crate::coordinator::cache::{CachedIndex, WorkloadKey};
use crate::lazy::ShardSet;
use crate::mips::snapshot::{self, SectionBuf, SnapshotReader, SnapshotWriter};
use crate::mips::{row_stride, IndexKind, SnapshotCodec, SnapshotError, VectorSet};
use crate::util::mmap::PAGE_SIZE;
use std::fmt;
use std::sync::Arc;

/// First bytes of every index-snapshot artifact file.
pub const MAGIC: [u8; 8] = *b"FMWEMIDX";

/// First bytes of every workload-delta artifact file (DESIGN.md §9).
pub const DELTA_MAGIC: [u8; 8] = *b"FMWEMDLT";

/// Current artifact format version. Bump on any layout change; old
/// versions are rejected (and rebuilt), never reinterpreted. Version 3
/// moved bulk vector data out of the payload stream into page-aligned
/// sections so restores can borrow a mapped file instead of decoding.
pub const FORMAT_VERSION: u32 = 3;

/// Fixed header size in bytes: everything before the section count.
pub const HEADER_LEN: usize = 8 + 4 + 16 + 1 + 8 + 8 + 8 + 16;

/// Bytes per section-table entry: offset, rows, dim, checksum.
pub const SECTION_DESC_LEN: usize = 8 + 8 + 8 + 16;

/// Alignment of every section offset — one OS page, so a mapped section
/// can be handed to the kernels without copying.
pub const SECTION_ALIGN: usize = PAGE_SIZE;

/// Why an artifact failed to decode.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StoreError {
    /// The file does not start with [`MAGIC`].
    BadMagic,
    /// The file's format version is not [`FORMAT_VERSION`].
    BadVersion(u32),
    /// The file ended before the declared structure did.
    Truncated,
    /// A meta or section checksum does not match — bit rot or a torn
    /// write.
    ChecksumMismatch,
    /// The artifact is valid but describes a different [`WorkloadKey`]
    /// than the one requested.
    KeyMismatch,
    /// The envelope was intact but the snapshot payload inside was not.
    Snapshot(SnapshotError),
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::BadMagic => write!(f, "not an index artifact (bad magic)"),
            StoreError::BadVersion(v) => {
                write!(f, "unsupported artifact format version {v} (expected {FORMAT_VERSION})")
            }
            StoreError::Truncated => write!(f, "artifact truncated"),
            StoreError::ChecksumMismatch => write!(f, "artifact checksum mismatch"),
            StoreError::KeyMismatch => write!(f, "artifact describes a different workload key"),
            StoreError::Snapshot(e) => write!(f, "artifact payload: {e}"),
        }
    }
}

impl std::error::Error for StoreError {}

impl From<SnapshotError> for StoreError {
    fn from(e: SnapshotError) -> Self {
        StoreError::Snapshot(e)
    }
}

fn structural(msg: impl Into<String>) -> StoreError {
    StoreError::Snapshot(SnapshotError::Malformed(msg.into()))
}

/// FNV-128 over a byte slice: two independent FNV-1a passes (different
/// offset bases; the second consumes bit-rotated bytes), concatenated —
/// the same construction `fingerprint_vectors` uses for workload content.
/// Detects corruption; it is not cryptographic and the store is not an
/// integrity boundary against adversarial files (same trust model as the
/// in-memory cache).
pub fn fnv128(bytes: &[u8]) -> u128 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h1 = 0xcbf2_9ce4_8422_2325u64;
    let mut h2 = 0x6c62_272e_07bb_0142u64;
    for &b in bytes {
        h1 = (h1 ^ u64::from(b)).wrapping_mul(PRIME);
        h2 = (h2 ^ u64::from(b.rotate_left(3))).wrapping_mul(PRIME);
    }
    ((h1 as u128) << 64) | h2 as u128
}

/// One section-table entry, as validated by [`open_artifact`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SectionDesc {
    /// Byte offset of the section from the start of the file; always a
    /// multiple of [`SECTION_ALIGN`].
    pub offset: usize,
    /// Rows in the section.
    pub rows: usize,
    /// Logical dimension (on-disk stride is `row_stride(dim)`).
    pub dim: usize,
    /// FNV-128 over the section's `rows × row_stride(dim) × 4` bytes.
    pub checksum: u128,
}

impl SectionDesc {
    /// Section length in bytes (validated non-overflowing at open time).
    pub fn byte_len(&self) -> usize {
        self.rows * row_stride(self.dim) * 4
    }
}

/// A validated artifact, opened in place: the embedded key, the meta
/// stream, and the section table. Structural invariants (bounds,
/// alignment, ordering, meta checksum) have been checked; section
/// *checksums* have not — call [`verify_sections`] (the decode path always
/// does; the mmap pager does unless `pager.verify` is off).
pub struct ArtifactView<'a> {
    /// The workload key the artifact claims to serve.
    pub key: WorkloadKey,
    /// The meta payload (index structure, quantized codes, section refs).
    pub meta: &'a [u8],
    /// Section descriptors in table order.
    pub sections: Vec<SectionDesc>,
}

/// Encode one cache entry as a paged snapshot: a one-byte mono/sharded
/// tag plus the nested index snapshot in the returned meta stream, bulk
/// vector data spilled to the returned sections.
pub fn encode_payload(value: &CachedIndex) -> (Vec<u8>, Vec<SectionBuf>) {
    let mut meta = Vec::new();
    let mut sections = Vec::new();
    let mut w = SnapshotWriter::paged(&mut meta, &mut sections);
    match value {
        CachedIndex::Mono(index) => {
            w.u8(0);
            snapshot::encode_index(index.as_ref(), &mut w);
        }
        CachedIndex::Sharded(set) => {
            w.u8(1);
            set.encode(&mut w);
        }
    }
    (meta, sections)
}

/// Decode a meta payload produced by [`encode_payload`] against its
/// pre-restored sections (owned copies on the decode path, mmap-borrowed
/// on the pager path). Consumes the whole meta buffer and every section —
/// leftovers of either kind are corruption.
pub fn decode_payload(
    meta: &[u8],
    sections: Vec<VectorSet>,
) -> Result<CachedIndex, StoreError> {
    let mut r = SnapshotReader::with_sections(meta, sections);
    let value = match r.u8()? {
        0 => CachedIndex::Mono(snapshot::decode_index(&mut r)?),
        1 => CachedIndex::Sharded(Arc::new(ShardSet::decode(&mut r)?)),
        tag => return Err(structural(format!("unknown cache entry tag {tag}"))),
    };
    if !r.is_exhausted() {
        return Err(structural(format!("{} trailing bytes after payload", r.remaining())));
    }
    if !r.all_sections_consumed() {
        return Err(structural("payload left artifact sections unreferenced"));
    }
    Ok(value)
}

/// Seal `value` into a complete artifact file image for `key`: header,
/// section table, meta payload, then each section zero-padded out to a
/// page boundary.
pub fn encode_artifact(key: &WorkloadKey, value: &CachedIndex) -> Vec<u8> {
    let (meta, sections) = encode_payload(value);

    // lay the sections out page-aligned after the prefix
    let prefix_len = HEADER_LEN + 8 + sections.len() * SECTION_DESC_LEN + meta.len();
    let mut offsets = Vec::with_capacity(sections.len());
    let mut cursor = prefix_len;
    for sec in &sections {
        let offset = cursor.next_multiple_of(SECTION_ALIGN);
        offsets.push(offset);
        cursor = offset + sec.bytes.len();
    }

    let mut out = Vec::with_capacity(cursor);
    out.extend_from_slice(&MAGIC);
    snapshot::put_u32(&mut out, FORMAT_VERSION);
    snapshot::put_u128(&mut out, key.fingerprint);
    snapshot::put_u8(&mut out, key.kind.tag());
    snapshot::put_u64(&mut out, key.shards as u64);
    snapshot::put_u64(&mut out, key.generation);
    snapshot::put_u64(&mut out, meta.len() as u64);
    snapshot::put_u128(&mut out, fnv128(&meta));
    snapshot::put_u64(&mut out, sections.len() as u64);
    for (sec, &offset) in sections.iter().zip(&offsets) {
        snapshot::put_u64(&mut out, offset as u64);
        snapshot::put_u64(&mut out, sec.rows as u64);
        snapshot::put_u64(&mut out, sec.dim as u64);
        snapshot::put_u128(&mut out, fnv128(&sec.bytes));
    }
    out.extend_from_slice(&meta);
    for (sec, &offset) in sections.iter().zip(&offsets) {
        out.resize(offset, 0);
        out.extend_from_slice(&sec.bytes);
    }
    out
}

/// Open an artifact image in place: verify magic, version, bounds, the
/// meta checksum and every structural section invariant (page alignment,
/// non-overlap, ascending order, exact file length), and return the
/// validated [`ArtifactView`]. Section payload checksums are *not*
/// verified here — see [`verify_sections`].
pub fn open_artifact(bytes: &[u8]) -> Result<ArtifactView<'_>, StoreError> {
    let fixed = HEADER_LEN + 8;
    if bytes.len() < fixed {
        return if bytes.len() >= MAGIC.len() && bytes[..MAGIC.len()] != MAGIC {
            Err(StoreError::BadMagic)
        } else {
            Err(StoreError::Truncated)
        };
    }
    if bytes[..MAGIC.len()] != MAGIC {
        return Err(StoreError::BadMagic);
    }
    let mut r = SnapshotReader::new(&bytes[MAGIC.len()..fixed]);
    let version = r.u32()?;
    if version != FORMAT_VERSION {
        return Err(StoreError::BadVersion(version));
    }
    let fingerprint = r.u128()?;
    let kind_tag = r.u8()?;
    let shards = r.u64()?;
    let generation = r.u64()?;
    let meta_len = r.u64()?;
    let meta_checksum = r.u128()?;
    let section_count = r.u64()?;
    let kind = IndexKind::from_tag(kind_tag).ok_or(StoreError::KeyMismatch)?;

    // section table bounds
    let table_bytes = (section_count as usize)
        .checked_mul(SECTION_DESC_LEN)
        .filter(|&t| section_count <= usize::MAX as u64 && t <= bytes.len() - fixed)
        .ok_or(StoreError::Truncated)?;
    let meta_start = fixed + table_bytes;
    let meta_end = meta_start
        .checked_add(meta_len as usize)
        .filter(|&e| meta_len <= usize::MAX as u64 && e <= bytes.len())
        .ok_or(StoreError::Truncated)?;
    let meta = &bytes[meta_start..meta_end];
    if fnv128(meta) != meta_checksum {
        return Err(StoreError::ChecksumMismatch);
    }

    let mut tr = SnapshotReader::new(&bytes[fixed..meta_start]);
    let mut sections = Vec::with_capacity(section_count as usize);
    let mut prev_end = meta_end;
    for i in 0..section_count {
        let offset = tr.u64_as_usize()?;
        let rows = tr.u64_as_usize()?;
        let dim = tr.u64_as_usize()?;
        let checksum = tr.u128()?;
        if rows == 0 || dim == 0 {
            return Err(structural(format!("section {i} is empty ({rows}×{dim})")));
        }
        if offset % SECTION_ALIGN != 0 {
            return Err(structural(format!("section {i} offset {offset} not page-aligned")));
        }
        let len = rows
            .checked_mul(row_stride(dim))
            .and_then(|f| f.checked_mul(4))
            .ok_or_else(|| structural(format!("section {i} size overflows")))?;
        if offset < prev_end {
            return Err(structural(format!(
                "section {i} at {offset} overlaps preceding bytes (end {prev_end})"
            )));
        }
        let Some(end) = offset.checked_add(len).filter(|&e| e <= bytes.len()) else {
            return Err(StoreError::Truncated);
        };
        prev_end = end;
        sections.push(SectionDesc { offset, rows, dim, checksum });
    }
    // the file must end exactly where the structure does — bytes past the
    // last section (or past the meta, with no sections) are corruption
    if bytes.len() != prev_end {
        return Err(structural(format!(
            "{} bytes past the end of the artifact structure",
            bytes.len() - prev_end
        )));
    }

    let key = WorkloadKey { fingerprint, kind, shards: shards as usize, generation };
    Ok(ArtifactView { key, meta, sections })
}

/// The raw bytes of one section (bounds were validated at open time).
pub fn section_slice<'a>(bytes: &'a [u8], desc: &SectionDesc) -> &'a [u8] {
    &bytes[desc.offset..desc.offset + desc.byte_len()]
}

/// Verify every section's checksum against the table. The decode path
/// always runs this; the mmap pager runs it eagerly at open time unless
/// `pager.verify` is disabled (DESIGN.md §12 — verification walks every
/// page once, which trades the lazy page-in win for earlier corruption
/// detection).
pub fn verify_sections(bytes: &[u8], view: &ArtifactView<'_>) -> Result<(), StoreError> {
    for desc in &view.sections {
        if fnv128(section_slice(bytes, desc)) != desc.checksum {
            return Err(StoreError::ChecksumMismatch);
        }
    }
    Ok(())
}

/// Copy every section out of the file image into owned, heap-backed
/// [`VectorSet`]s (the portable decode-restore path).
pub fn owned_sections(bytes: &[u8], view: &ArtifactView<'_>) -> Vec<VectorSet> {
    view.sections
        .iter()
        .map(|desc| {
            let stride = row_stride(desc.dim);
            let raw = section_slice(bytes, desc);
            let mut vals = Vec::with_capacity(desc.rows * desc.dim);
            for row in 0..desc.rows {
                let start = row * stride * 4;
                for c in raw[start..start + desc.dim * 4].chunks_exact(4) {
                    vals.push(f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())));
                }
            }
            VectorSet::new(vals, desc.rows, desc.dim)
        })
        .collect()
}

/// Decode a complete artifact for `expect`: open the envelope, refuse a
/// key mismatch, verify every section checksum, copy the sections into
/// heap storage and decode the payload.
pub fn decode_artifact(bytes: &[u8], expect: &WorkloadKey) -> Result<CachedIndex, StoreError> {
    let view = open_artifact(bytes)?;
    if view.key != *expect {
        return Err(StoreError::KeyMismatch);
    }
    verify_sections(bytes, &view)?;
    let sections = owned_sections(bytes, &view);
    decode_payload(view.meta, sections)
}

/// Fixed delta-artifact header size: magic, version, fingerprint,
/// generation, payload length, checksum.
pub const DELTA_HEADER_LEN: usize = 8 + 4 + 16 + 8 + 8 + 16;

/// Seal one workload delta into a complete delta-artifact file image:
/// header (magic, version, family fingerprint, produced generation,
/// length, checksum) + the delta snapshot payload. Deltas keep their
/// vectors inline — they are small, short-lived, and compacted away.
pub fn encode_delta_artifact(
    fingerprint: u128,
    generation: u64,
    delta: &crate::mips::WorkloadDelta,
) -> Vec<u8> {
    let mut payload = Vec::new();
    delta.encode(&mut SnapshotWriter::inline(&mut payload));
    let mut out = Vec::with_capacity(DELTA_HEADER_LEN + payload.len());
    out.extend_from_slice(&DELTA_MAGIC);
    snapshot::put_u32(&mut out, FORMAT_VERSION);
    snapshot::put_u128(&mut out, fingerprint);
    snapshot::put_u64(&mut out, generation);
    snapshot::put_u64(&mut out, payload.len() as u64);
    snapshot::put_u128(&mut out, fnv128(&payload));
    out.extend_from_slice(&payload);
    out
}

/// Open and decode a delta artifact, verifying magic, version, length and
/// checksum. Returns the family fingerprint, the generation the delta
/// produces, and the delta itself.
pub fn decode_delta_artifact(
    bytes: &[u8],
) -> Result<(u128, u64, crate::mips::WorkloadDelta), StoreError> {
    if bytes.len() < DELTA_HEADER_LEN {
        return if bytes.len() >= DELTA_MAGIC.len() && bytes[..DELTA_MAGIC.len()] != DELTA_MAGIC {
            Err(StoreError::BadMagic)
        } else {
            Err(StoreError::Truncated)
        };
    }
    if bytes[..DELTA_MAGIC.len()] != DELTA_MAGIC {
        return Err(StoreError::BadMagic);
    }
    let mut r = SnapshotReader::new(&bytes[DELTA_MAGIC.len()..DELTA_HEADER_LEN]);
    let version = r.u32()?;
    if version != FORMAT_VERSION {
        return Err(StoreError::BadVersion(version));
    }
    let fingerprint = r.u128()?;
    let generation = r.u64()?;
    let payload_len = r.u64()?;
    let checksum = r.u128()?;

    let payload = &bytes[DELTA_HEADER_LEN..];
    if payload.len() as u64 != payload_len {
        return Err(StoreError::Truncated);
    }
    if fnv128(payload) != checksum {
        return Err(StoreError::ChecksumMismatch);
    }
    let mut pr = SnapshotReader::new(payload);
    let delta = crate::mips::WorkloadDelta::decode(&mut pr)?;
    if !pr.is_exhausted() {
        return Err(StoreError::Snapshot(SnapshotError::Malformed(format!(
            "{} trailing bytes after delta payload",
            pr.remaining()
        ))));
    }
    Ok((fingerprint, generation, delta))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mips::{build_index, VectorSet};
    use crate::util::rng::Rng;

    fn random_set(n: usize, d: usize, seed: u64) -> VectorSet {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        VectorSet::new(data, n, d)
    }

    fn mono_key() -> WorkloadKey {
        WorkloadKey { fingerprint: 0xABCD_EF01, kind: IndexKind::Flat, shards: 1, generation: 0 }
    }

    fn mono_value() -> CachedIndex {
        CachedIndex::Mono(build_index(IndexKind::Flat, random_set(40, 4, 1), 1))
    }

    #[test]
    fn fnv128_separates_nearby_buffers() {
        assert_ne!(fnv128(b"abc"), fnv128(b"abd"));
        assert_ne!(fnv128(b""), fnv128(b"\0"));
        assert_eq!(fnv128(b"same"), fnv128(b"same"));
    }

    #[test]
    fn artifact_round_trips_mono_and_sharded() {
        let vs = random_set(60, 5, 2);
        let cases = vec![
            (mono_key(), mono_value()),
            (
                WorkloadKey { fingerprint: 7, kind: IndexKind::Ivf, shards: 3, generation: 4 },
                CachedIndex::Sharded(Arc::new(ShardSet::build(IndexKind::Ivf, &vs, 3, 5))),
            ),
        ];
        for (key, value) in cases {
            let bytes = encode_artifact(&key, &value);
            let view = open_artifact(&bytes).unwrap();
            assert_eq!(view.key, key);
            assert!(!view.sections.is_empty(), "vector data must be paged out");
            for desc in &view.sections {
                assert_eq!(desc.offset % SECTION_ALIGN, 0);
            }
            verify_sections(&bytes, &view).unwrap();
            let restored = decode_artifact(&bytes, &key).unwrap();
            match (&value, &restored) {
                (CachedIndex::Mono(a), CachedIndex::Mono(b)) => {
                    assert_eq!(a.len(), b.len());
                    assert_eq!(a.kind(), b.kind());
                }
                (CachedIndex::Sharded(a), CachedIndex::Sharded(b)) => {
                    assert_eq!(a.len(), b.len());
                    assert_eq!(a.bounds(), b.bounds());
                    assert_eq!(a.kind(), b.kind());
                }
                _ => panic!("mono/sharded shape changed through the codec"),
            }
        }
    }

    #[test]
    fn wrong_key_is_refused() {
        let bytes = encode_artifact(&mono_key(), &mono_value());
        let other = WorkloadKey { fingerprint: 999, ..mono_key() };
        assert!(matches!(decode_artifact(&bytes, &other), Err(StoreError::KeyMismatch)));
        // a different generation of the same family is also a mismatch —
        // serving an older generation as the requested one would be a
        // stale serve
        let stale = mono_key().at_generation(3);
        assert!(matches!(decode_artifact(&bytes, &stale), Err(StoreError::KeyMismatch)));
    }

    #[test]
    fn delta_artifact_round_trips_and_rejects_corruption() {
        use crate::mips::{VectorSet as Vs, WorkloadDelta};
        let delta = WorkloadDelta::new(
            Vs::new(vec![0.5, -1.0, 2.0, 0.0], 2, 2),
            vec![4, 1],
        );
        let bytes = encode_delta_artifact(0xFEED, 3, &delta);
        let (fp, generation, back) = decode_delta_artifact(&bytes).unwrap();
        assert_eq!((fp, generation), (0xFEED, 3));
        assert_eq!(back.tombstoned, vec![1, 4]);
        assert_eq!(back.inserted.len(), 2);
        assert_eq!(back.inserted.row(0), &[0.5, -1.0]);

        // bad magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert_eq!(decode_delta_artifact(&bad).unwrap_err(), StoreError::BadMagic);
        // flipped payload byte -> checksum mismatch
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        assert_eq!(decode_delta_artifact(&bad).unwrap_err(), StoreError::ChecksumMismatch);
        // truncation at every prefix must error, never panic
        for cut in [0, 6, DELTA_HEADER_LEN - 1, bytes.len() - 1] {
            assert!(decode_delta_artifact(&bytes[..cut]).is_err());
        }
    }

    #[test]
    fn corruption_modes_are_typed_errors_not_panics() {
        let key = mono_key();
        let good = encode_artifact(&key, &mono_value());

        // bad magic
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(decode_artifact(&bad, &key), Err(StoreError::BadMagic)));

        // wrong version
        let mut bad = good.clone();
        bad[8] = 99;
        assert!(matches!(decode_artifact(&bad, &key), Err(StoreError::BadVersion(99))));

        // a v2 artifact (version field only) is rejected, not reinterpreted
        let mut v2 = good.clone();
        v2[8] = 2;
        assert!(matches!(decode_artifact(&v2, &key), Err(StoreError::BadVersion(2))));

        // truncation at every prefix length must error, never panic
        for cut in [0, 4, HEADER_LEN - 1, HEADER_LEN + 3, good.len() - 1] {
            assert!(
                decode_artifact(&good[..cut], &key).is_err(),
                "prefix of {cut} bytes must be rejected"
            );
        }

        // flipped last byte lands in the final section -> checksum mismatch
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        assert!(matches!(decode_artifact(&bad, &key), Err(StoreError::ChecksumMismatch)));

        // flipped meta byte -> meta checksum mismatch
        let meta_start = {
            let view = open_artifact(&good).unwrap();
            HEADER_LEN + 8 + view.sections.len() * SECTION_DESC_LEN
        };
        let mut bad = good.clone();
        bad[meta_start] ^= 0x01;
        assert!(matches!(decode_artifact(&bad, &key), Err(StoreError::ChecksumMismatch)));

        // trailing garbage past the last section is structural corruption
        let mut bad = good.clone();
        bad.push(0);
        assert!(decode_artifact(&bad, &key).is_err());
    }

    #[test]
    fn section_table_violations_are_rejected() {
        let key = mono_key();
        let good = encode_artifact(&key, &mono_value());
        let table_start = HEADER_LEN + 8;

        // misaligned offset: add 1 to the first section offset
        let mut bad = good.clone();
        let raw: [u8; 8] = bad[table_start..table_start + 8].try_into().unwrap();
        let offset = u64::from_le_bytes(raw);
        bad[table_start..table_start + 8].copy_from_slice(&(offset + 1).to_le_bytes());
        assert!(matches!(open_artifact(&bad), Err(StoreError::Snapshot(_))));

        // offset pointing before the meta end overlaps the prefix
        let mut bad = good.clone();
        bad[table_start..table_start + 8].copy_from_slice(&0u64.to_le_bytes());
        assert!(open_artifact(&bad).is_err());

        // offset past the file end is truncation
        let mut bad = good.clone();
        let huge =
            (good.len() as u64).next_multiple_of(SECTION_ALIGN as u64) + SECTION_ALIGN as u64;
        bad[table_start..table_start + 8].copy_from_slice(&huge.to_le_bytes());
        assert!(open_artifact(&bad).is_err());

        // absurd section count cannot allocate or scan past the file
        let mut bad = good.clone();
        bad[HEADER_LEN..HEADER_LEN + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(open_artifact(&bad), Err(StoreError::Truncated)));

        // zero-row section geometry is malformed
        let mut bad = good.clone();
        bad[table_start + 8..table_start + 16].copy_from_slice(&0u64.to_le_bytes());
        assert!(open_artifact(&bad).is_err());
    }

    #[test]
    fn decode_payload_refuses_orphaned_sections() {
        // a meta stream that never references its section is a layout
        // mismatch, not a silent leak
        let key = mono_key();
        let bytes = encode_artifact(&key, &mono_value());
        let view = open_artifact(&bytes).unwrap();
        let mut sections = owned_sections(&bytes, &view);
        sections.push(VectorSet::new(vec![0.0; 8], 2, 4));
        assert!(decode_payload(view.meta, sections).is_err());
    }
}
