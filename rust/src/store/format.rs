//! On-disk artifact format: a versioned, checksummed envelope around one
//! index snapshot (DESIGN.md §7).
//!
//! ```text
//! offset  size  field
//! 0       8     magic  "FMWEMIDX"
//! 8       4     format version (u32 LE, currently 2)
//! 12      16    WorkloadKey.fingerprint (u128 LE)
//! 28      1     WorkloadKey.kind tag (IndexKind::tag)
//! 29      8     WorkloadKey.shards (u64 LE)
//! 37      8     WorkloadKey.generation (u64 LE)
//! 45      8     payload length (u64 LE)
//! 53      16    FNV-128 payload checksum (u128 LE)
//! 69      ..    payload — a mips/lazy snapshot (see `encode_payload`)
//! ```
//!
//! Dynamic workloads (DESIGN.md §9) add a second artifact species: compact
//! **delta artifacts** ([`encode_delta_artifact`]) carrying one
//! [`crate::mips::WorkloadDelta`] under their own magic `"FMWEMDLT"`, keyed by the
//! workload family fingerprint plus the generation the delta produces. A
//! restore at generation g decodes the newest snapshot at g′ ≤ g and
//! replays the deltas g′+1..=g.
//!
//! The header carries the full [`WorkloadKey`] so an artifact is
//! self-describing: [`decode_artifact`] refuses to hand back an index for
//! a key other than the one the caller asked for, even if a file was
//! renamed or the content-addressed name collided. Every failure mode —
//! bad magic, unknown version, truncation, checksum mismatch, malformed
//! payload — is a typed [`StoreError`], never a panic, so the tiered
//! cache can always fall back to a rebuild.
//!
//! The codec is hand-rolled on the vendored-offline discipline (DESIGN.md
//! §3 — no serde/bincode) and endianness-pinned (everything
//! little-endian), so artifacts are portable across hosts.

use crate::coordinator::cache::{CachedIndex, WorkloadKey};
use crate::lazy::ShardSet;
use crate::mips::snapshot::{self, SnapshotReader};
use crate::mips::{IndexKind, SnapshotCodec, SnapshotError};
use std::fmt;
use std::sync::Arc;

/// First bytes of every index-snapshot artifact file.
pub const MAGIC: [u8; 8] = *b"FMWEMIDX";

/// First bytes of every workload-delta artifact file (DESIGN.md §9).
pub const DELTA_MAGIC: [u8; 8] = *b"FMWEMDLT";

/// Current artifact format version. Bump on any layout change; old
/// versions are rejected (and rebuilt), never reinterpreted. Version 2
/// added the workload generation to the envelope key and the tombstone
/// state to the index payloads.
pub const FORMAT_VERSION: u32 = 2;

/// Fixed header size in bytes (everything before the payload).
pub const HEADER_LEN: usize = 8 + 4 + 16 + 1 + 8 + 8 + 8 + 16;

/// Why an artifact failed to decode.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StoreError {
    /// The file does not start with [`MAGIC`].
    BadMagic,
    /// The file's format version is not [`FORMAT_VERSION`].
    BadVersion(u32),
    /// The file ended before the declared structure did.
    Truncated,
    /// The payload checksum does not match — bit rot or a torn write.
    ChecksumMismatch,
    /// The artifact is valid but describes a different [`WorkloadKey`]
    /// than the one requested.
    KeyMismatch,
    /// The envelope was intact but the snapshot payload inside was not.
    Snapshot(SnapshotError),
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::BadMagic => write!(f, "not an index artifact (bad magic)"),
            StoreError::BadVersion(v) => {
                write!(f, "unsupported artifact format version {v} (expected {FORMAT_VERSION})")
            }
            StoreError::Truncated => write!(f, "artifact truncated"),
            StoreError::ChecksumMismatch => write!(f, "artifact payload checksum mismatch"),
            StoreError::KeyMismatch => write!(f, "artifact describes a different workload key"),
            StoreError::Snapshot(e) => write!(f, "artifact payload: {e}"),
        }
    }
}

impl std::error::Error for StoreError {}

impl From<SnapshotError> for StoreError {
    fn from(e: SnapshotError) -> Self {
        StoreError::Snapshot(e)
    }
}

/// FNV-128 over a byte slice: two independent FNV-1a passes (different
/// offset bases; the second consumes bit-rotated bytes), concatenated —
/// the same construction `fingerprint_vectors` uses for workload content.
/// Detects corruption; it is not cryptographic and the store is not an
/// integrity boundary against adversarial files (same trust model as the
/// in-memory cache).
pub fn fnv128(bytes: &[u8]) -> u128 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h1 = 0xcbf2_9ce4_8422_2325u64;
    let mut h2 = 0x6c62_272e_07bb_0142u64;
    for &b in bytes {
        h1 = (h1 ^ u64::from(b)).wrapping_mul(PRIME);
        h2 = (h2 ^ u64::from(b.rotate_left(3))).wrapping_mul(PRIME);
    }
    ((h1 as u128) << 64) | h2 as u128
}

/// Encode one cache entry as a snapshot payload (no envelope): a one-byte
/// mono/sharded tag, then the nested index snapshot.
pub fn encode_payload(value: &CachedIndex) -> Vec<u8> {
    let mut out = Vec::new();
    match value {
        CachedIndex::Mono(index) => {
            snapshot::put_u8(&mut out, 0);
            snapshot::encode_index(index.as_ref(), &mut out);
        }
        CachedIndex::Sharded(set) => {
            snapshot::put_u8(&mut out, 1);
            set.encode(&mut out);
        }
    }
    out
}

/// Decode a payload produced by [`encode_payload`], consuming the whole
/// buffer (trailing bytes are treated as corruption).
pub fn decode_payload(payload: &[u8]) -> Result<CachedIndex, StoreError> {
    let mut r = SnapshotReader::new(payload);
    let value = match r.u8()? {
        0 => CachedIndex::Mono(snapshot::decode_index(&mut r)?),
        1 => CachedIndex::Sharded(Arc::new(ShardSet::decode(&mut r)?)),
        tag => {
            return Err(StoreError::Snapshot(SnapshotError::Malformed(format!(
                "unknown cache entry tag {tag}"
            ))))
        }
    };
    if !r.is_exhausted() {
        return Err(StoreError::Snapshot(SnapshotError::Malformed(format!(
            "{} trailing bytes after payload",
            r.remaining()
        ))));
    }
    Ok(value)
}

/// Seal `value` into a complete artifact file image for `key`:
/// header (magic, version, key, length, checksum) + payload.
pub fn encode_artifact(key: &WorkloadKey, value: &CachedIndex) -> Vec<u8> {
    let payload = encode_payload(value);
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    snapshot::put_u32(&mut out, FORMAT_VERSION);
    snapshot::put_u128(&mut out, key.fingerprint);
    snapshot::put_u8(&mut out, key.kind.tag());
    snapshot::put_u64(&mut out, key.shards as u64);
    snapshot::put_u64(&mut out, key.generation);
    snapshot::put_u64(&mut out, payload.len() as u64);
    snapshot::put_u128(&mut out, fnv128(&payload));
    out.extend_from_slice(&payload);
    out
}

/// Open an artifact image: verify magic, version, length and checksum,
/// and return the embedded [`WorkloadKey`] plus the payload slice.
pub fn open_artifact(bytes: &[u8]) -> Result<(WorkloadKey, &[u8]), StoreError> {
    if bytes.len() < HEADER_LEN {
        return if bytes.len() >= MAGIC.len() && bytes[..MAGIC.len()] != MAGIC {
            Err(StoreError::BadMagic)
        } else {
            Err(StoreError::Truncated)
        };
    }
    if bytes[..MAGIC.len()] != MAGIC {
        return Err(StoreError::BadMagic);
    }
    let mut r = SnapshotReader::new(&bytes[MAGIC.len()..HEADER_LEN]);
    let version = r.u32()?;
    if version != FORMAT_VERSION {
        return Err(StoreError::BadVersion(version));
    }
    let fingerprint = r.u128()?;
    let kind_tag = r.u8()?;
    let shards = r.u64()?;
    let generation = r.u64()?;
    let payload_len = r.u64()?;
    let checksum = r.u128()?;

    let kind = IndexKind::from_tag(kind_tag).ok_or(StoreError::KeyMismatch)?;
    let payload = &bytes[HEADER_LEN..];
    if payload.len() as u64 != payload_len {
        return Err(StoreError::Truncated);
    }
    if fnv128(payload) != checksum {
        return Err(StoreError::ChecksumMismatch);
    }
    let key = WorkloadKey { fingerprint, kind, shards: shards as usize, generation };
    Ok((key, payload))
}

/// Decode a complete artifact for `expect`: open the envelope, refuse a
/// key mismatch, then decode the payload.
pub fn decode_artifact(bytes: &[u8], expect: &WorkloadKey) -> Result<CachedIndex, StoreError> {
    let (key, payload) = open_artifact(bytes)?;
    if key != *expect {
        return Err(StoreError::KeyMismatch);
    }
    decode_payload(payload)
}

/// Fixed delta-artifact header size: magic, version, fingerprint,
/// generation, payload length, checksum.
pub const DELTA_HEADER_LEN: usize = 8 + 4 + 16 + 8 + 8 + 16;

/// Seal one workload delta into a complete delta-artifact file image:
/// header (magic, version, family fingerprint, produced generation,
/// length, checksum) + the delta snapshot payload.
pub fn encode_delta_artifact(
    fingerprint: u128,
    generation: u64,
    delta: &crate::mips::WorkloadDelta,
) -> Vec<u8> {
    let mut payload = Vec::new();
    delta.encode(&mut payload);
    let mut out = Vec::with_capacity(DELTA_HEADER_LEN + payload.len());
    out.extend_from_slice(&DELTA_MAGIC);
    snapshot::put_u32(&mut out, FORMAT_VERSION);
    snapshot::put_u128(&mut out, fingerprint);
    snapshot::put_u64(&mut out, generation);
    snapshot::put_u64(&mut out, payload.len() as u64);
    snapshot::put_u128(&mut out, fnv128(&payload));
    out.extend_from_slice(&payload);
    out
}

/// Open and decode a delta artifact, verifying magic, version, length and
/// checksum. Returns the family fingerprint, the generation the delta
/// produces, and the delta itself.
pub fn decode_delta_artifact(
    bytes: &[u8],
) -> Result<(u128, u64, crate::mips::WorkloadDelta), StoreError> {
    if bytes.len() < DELTA_HEADER_LEN {
        return if bytes.len() >= DELTA_MAGIC.len() && bytes[..DELTA_MAGIC.len()] != DELTA_MAGIC {
            Err(StoreError::BadMagic)
        } else {
            Err(StoreError::Truncated)
        };
    }
    if bytes[..DELTA_MAGIC.len()] != DELTA_MAGIC {
        return Err(StoreError::BadMagic);
    }
    let mut r = SnapshotReader::new(&bytes[DELTA_MAGIC.len()..DELTA_HEADER_LEN]);
    let version = r.u32()?;
    if version != FORMAT_VERSION {
        return Err(StoreError::BadVersion(version));
    }
    let fingerprint = r.u128()?;
    let generation = r.u64()?;
    let payload_len = r.u64()?;
    let checksum = r.u128()?;

    let payload = &bytes[DELTA_HEADER_LEN..];
    if payload.len() as u64 != payload_len {
        return Err(StoreError::Truncated);
    }
    if fnv128(payload) != checksum {
        return Err(StoreError::ChecksumMismatch);
    }
    let mut pr = SnapshotReader::new(payload);
    let delta = crate::mips::WorkloadDelta::decode(&mut pr)?;
    if !pr.is_exhausted() {
        return Err(StoreError::Snapshot(SnapshotError::Malformed(format!(
            "{} trailing bytes after delta payload",
            pr.remaining()
        ))));
    }
    Ok((fingerprint, generation, delta))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mips::{build_index, VectorSet};
    use crate::util::rng::Rng;

    fn random_set(n: usize, d: usize, seed: u64) -> VectorSet {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        VectorSet::new(data, n, d)
    }

    fn mono_key() -> WorkloadKey {
        WorkloadKey { fingerprint: 0xABCD_EF01, kind: IndexKind::Flat, shards: 1, generation: 0 }
    }

    fn mono_value() -> CachedIndex {
        CachedIndex::Mono(build_index(IndexKind::Flat, random_set(40, 4, 1), 1))
    }

    #[test]
    fn fnv128_separates_nearby_buffers() {
        assert_ne!(fnv128(b"abc"), fnv128(b"abd"));
        assert_ne!(fnv128(b""), fnv128(b"\0"));
        assert_eq!(fnv128(b"same"), fnv128(b"same"));
    }

    #[test]
    fn artifact_round_trips_mono_and_sharded() {
        let vs = random_set(60, 5, 2);
        let cases = vec![
            (mono_key(), mono_value()),
            (
                WorkloadKey { fingerprint: 7, kind: IndexKind::Ivf, shards: 3, generation: 4 },
                CachedIndex::Sharded(Arc::new(ShardSet::build(IndexKind::Ivf, &vs, 3, 5))),
            ),
        ];
        for (key, value) in cases {
            let bytes = encode_artifact(&key, &value);
            let (got_key, _) = open_artifact(&bytes).unwrap();
            assert_eq!(got_key, key);
            let restored = decode_artifact(&bytes, &key).unwrap();
            match (&value, &restored) {
                (CachedIndex::Mono(a), CachedIndex::Mono(b)) => {
                    assert_eq!(a.len(), b.len());
                    assert_eq!(a.kind(), b.kind());
                }
                (CachedIndex::Sharded(a), CachedIndex::Sharded(b)) => {
                    assert_eq!(a.len(), b.len());
                    assert_eq!(a.bounds(), b.bounds());
                    assert_eq!(a.kind(), b.kind());
                }
                _ => panic!("mono/sharded shape changed through the codec"),
            }
        }
    }

    #[test]
    fn wrong_key_is_refused() {
        let bytes = encode_artifact(&mono_key(), &mono_value());
        let other = WorkloadKey { fingerprint: 999, ..mono_key() };
        assert_eq!(decode_artifact(&bytes, &other), Err(StoreError::KeyMismatch));
        // a different generation of the same family is also a mismatch —
        // serving an older generation as the requested one would be a
        // stale serve
        let stale = mono_key().at_generation(3);
        assert_eq!(decode_artifact(&bytes, &stale), Err(StoreError::KeyMismatch));
    }

    #[test]
    fn delta_artifact_round_trips_and_rejects_corruption() {
        use crate::mips::{VectorSet as Vs, WorkloadDelta};
        let delta = WorkloadDelta::new(
            Vs::new(vec![0.5, -1.0, 2.0, 0.0], 2, 2),
            vec![4, 1],
        );
        let bytes = encode_delta_artifact(0xFEED, 3, &delta);
        let (fp, generation, back) = decode_delta_artifact(&bytes).unwrap();
        assert_eq!((fp, generation), (0xFEED, 3));
        assert_eq!(back.tombstoned, vec![1, 4]);
        assert_eq!(back.inserted.len(), 2);
        assert_eq!(back.inserted.row(0), &[0.5, -1.0]);

        // bad magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert_eq!(decode_delta_artifact(&bad).unwrap_err(), StoreError::BadMagic);
        // flipped payload byte -> checksum mismatch
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        assert_eq!(decode_delta_artifact(&bad).unwrap_err(), StoreError::ChecksumMismatch);
        // truncation at every prefix must error, never panic
        for cut in [0, 6, DELTA_HEADER_LEN - 1, bytes.len() - 1] {
            assert!(decode_delta_artifact(&bytes[..cut]).is_err());
        }
    }

    #[test]
    fn corruption_modes_are_typed_errors_not_panics() {
        let key = mono_key();
        let good = encode_artifact(&key, &mono_value());

        // bad magic
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert_eq!(decode_artifact(&bad, &key), Err(StoreError::BadMagic));

        // wrong version
        let mut bad = good.clone();
        bad[8] = 99;
        assert_eq!(decode_artifact(&bad, &key), Err(StoreError::BadVersion(99)));

        // truncation at every prefix length must error, never panic
        for cut in [0, 4, HEADER_LEN - 1, HEADER_LEN + 3, good.len() - 1] {
            assert!(
                decode_artifact(&good[..cut], &key).is_err(),
                "prefix of {cut} bytes must be rejected"
            );
        }

        // flipped payload byte -> checksum mismatch
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        assert_eq!(decode_artifact(&bad, &key), Err(StoreError::ChecksumMismatch));

        // trailing garbage changes the length -> truncated
        let mut bad = good.clone();
        bad.push(0);
        assert_eq!(decode_artifact(&bad, &key), Err(StoreError::Truncated));
    }
}
