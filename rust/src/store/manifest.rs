//! Store manifest: a small JSON catalog of the artifacts in a store
//! directory, written atomically (DESIGN.md §7).
//!
//! The manifest is pure acceleration — the artifact files are
//! self-describing (`format.rs` headers), so a lost or corrupted manifest
//! only costs cold rebuilds, never correctness. That is why the load path
//! is tolerant ([`Manifest::load_or_empty`]) while the *write* path is
//! strict: every save rewrites the whole document to a temp file in the
//! same directory and renames it over the old one, so a crash mid-write
//! leaves either the previous complete manifest or a stray `.tmp` that is
//! simply ignored — never a half-written catalog that parses into lies.
//!
//! Serialization reuses the vendored-offline [`crate::util::json`]
//! reader/writer (no serde_json — DESIGN.md §3).

use crate::coordinator::cache::WorkloadKey;
use crate::mips::IndexKind;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// File name of the manifest inside a store directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// Manifest schema version (bumped on incompatible layout changes).
/// Version 2 added the workload generation to snapshot entries plus the
/// delta catalog (DESIGN.md §9); version 3 added the change `counter`
/// that backs cross-process generation watches (DESIGN.md §13). Older
/// manifests degrade to empty and their orphaned artifacts are rebuilt
/// under the current ids.
pub const MANIFEST_VERSION: u64 = 3;

/// One cataloged snapshot artifact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Artifact file name, relative to the store directory.
    pub file: String,
    /// Index implementation inside the artifact.
    pub kind: IndexKind,
    /// Shard count (1 = monolithic index).
    pub shards: usize,
    /// Workload family fingerprint — duplicated from the artifact id so
    /// the generation-aware lookup can scan a family without parsing ids.
    pub fingerprint: u128,
    /// Workload generation this snapshot serves.
    pub generation: u64,
    /// Artifact file size in bytes.
    pub bytes: u64,
    /// Build cost of the snapshotted index, in microseconds — restored
    /// into the L1 cache entry so promoted indices meter the same
    /// "build time saved" a same-process hit would (µs so sub-ms builds
    /// are not zeroed away, matching the metrics pipeline's precision).
    pub build_us: u64,
}

/// One cataloged workload-delta artifact (DESIGN.md §9).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DeltaEntry {
    /// Delta file name, relative to the store directory.
    pub file: String,
    /// Workload family fingerprint.
    pub fingerprint: u128,
    /// The generation this delta produces (applied to generation − 1).
    pub generation: u64,
    /// Delta file size in bytes.
    pub bytes: u64,
}

/// The artifact catalog: artifact id → [`ManifestEntry`], plus the delta
/// chain per workload family.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Manifest {
    entries: BTreeMap<String, ManifestEntry>,
    deltas: BTreeMap<String, DeltaEntry>,
    /// Monotone change counter, bumped on every catalog commit
    /// (DESIGN.md §13). Peer processes sharing the store directory watch
    /// the manifest file's (mtime, len) stamp and use this counter to
    /// tell a real catalog change from an equal-length rewrite — the
    /// cheap cross-process invalidation signal behind
    /// `peer_invalidations`.
    counter: u64,
}

impl Manifest {
    /// An empty manifest.
    pub fn new() -> Self {
        Self::default()
    }

    /// Content-addressed artifact id for a key:
    /// `<fingerprint:032x>-<kind>-s<shards>-g<generation>` — stable across
    /// processes, filesystem-safe, and unique per [`WorkloadKey`].
    pub fn artifact_id(key: &WorkloadKey) -> String {
        format!(
            "{:032x}-{}-s{}-g{}",
            key.fingerprint, key.kind, key.shards, key.generation
        )
    }

    /// Content-addressed delta-artifact id: `<fingerprint:032x>-g<gen>`.
    /// Deltas are per workload *family* (one delta serves every index
    /// kind/shard variant of the workload), so the id carries no
    /// kind/shards component.
    pub fn delta_id(fingerprint: u128, generation: u64) -> String {
        format!("{fingerprint:032x}-g{generation}")
    }

    /// Entry for `key`, if cataloged.
    pub fn get(&self, key: &WorkloadKey) -> Option<&ManifestEntry> {
        self.entries.get(&Self::artifact_id(key))
    }

    /// Insert (or replace) the entry for `key`.
    pub fn insert(&mut self, key: &WorkloadKey, entry: ManifestEntry) {
        self.entries.insert(Self::artifact_id(key), entry);
    }

    /// Drop the entry for `key` (a stale/corrupt artifact), if present.
    pub fn remove(&mut self, key: &WorkloadKey) -> Option<ManifestEntry> {
        self.entries.remove(&Self::artifact_id(key))
    }

    /// Number of cataloged artifacts.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// The catalog change counter (see the field docs).
    pub fn counter(&self) -> u64 {
        self.counter
    }

    /// Advance the change counter past `floor` (normally the counter of
    /// the on-disk document this save will replace, so concurrent writers
    /// that both merged from disk still produce strictly increasing
    /// counters).
    pub fn bump_counter(&mut self, floor: u64) {
        self.counter = self.counter.max(floor) + 1;
    }

    /// True when nothing is cataloged.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Iterate `(artifact id, entry)` in sorted id order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &ManifestEntry)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// The newest cataloged snapshot of `key`'s workload family (same
    /// fingerprint, kind, shards) at a generation ≤ `key.generation`, for
    /// the generation-aware restore path: an exact-generation snapshot is
    /// served directly, an older one is patched forward with the delta
    /// chain. Returns the snapshot's generation and entry.
    pub fn latest_snapshot(&self, key: &WorkloadKey) -> Option<(u64, &ManifestEntry)> {
        self.entries
            .values()
            .filter(|e| {
                e.fingerprint == key.fingerprint
                    && e.kind == key.kind
                    && e.shards == key.shards
                    && e.generation <= key.generation
            })
            .max_by_key(|e| e.generation)
            .map(|e| (e.generation, e))
    }

    /// Snapshot entries of `key`'s family strictly below `key.generation`
    /// — the entries a compaction supersedes. Returns the removed entries
    /// so the caller can delete their files.
    pub fn remove_superseded_snapshots(&mut self, key: &WorkloadKey) -> Vec<ManifestEntry> {
        let ids: Vec<String> = self
            .entries
            .iter()
            .filter(|(_, e)| {
                e.fingerprint == key.fingerprint
                    && e.kind == key.kind
                    && e.shards == key.shards
                    && e.generation < key.generation
            })
            .map(|(id, _)| id.clone())
            .collect();
        ids.iter().filter_map(|id| self.entries.remove(id)).collect()
    }

    /// Insert (or replace) the delta entry producing `generation` of the
    /// `fingerprint` family.
    pub fn insert_delta(&mut self, entry: DeltaEntry) {
        self.deltas
            .insert(Self::delta_id(entry.fingerprint, entry.generation), entry);
    }

    /// The cataloged delta producing `generation` of `fingerprint`, if any.
    pub fn get_delta(&self, fingerprint: u128, generation: u64) -> Option<&DeltaEntry> {
        self.deltas.get(&Self::delta_id(fingerprint, generation))
    }

    /// Drop a cataloged delta (an unreadable file), if present.
    pub fn remove_delta(&mut self, fingerprint: u128, generation: u64) -> Option<DeltaEntry> {
        self.deltas.remove(&Self::delta_id(fingerprint, generation))
    }

    /// Every cataloged delta, in sorted id order.
    pub fn iter_deltas(&self) -> impl Iterator<Item = &DeltaEntry> {
        self.deltas.values()
    }

    /// Number of cataloged deltas.
    pub fn delta_count(&self) -> usize {
        self.deltas.len()
    }

    /// Serialize to the manifest JSON document. Fingerprints are hex
    /// strings (128 bits do not fit a JSON number losslessly).
    pub fn to_json(&self) -> Json {
        let artifacts: BTreeMap<String, Json> = self
            .entries
            .iter()
            .map(|(id, e)| {
                let mut obj = BTreeMap::new();
                obj.insert("file".to_string(), Json::Str(e.file.clone()));
                obj.insert("kind".to_string(), Json::Str(e.kind.to_string()));
                obj.insert("shards".to_string(), Json::Num(e.shards as f64));
                obj.insert(
                    "fingerprint".to_string(),
                    Json::Str(format!("{:032x}", e.fingerprint)),
                );
                obj.insert("generation".to_string(), Json::Num(e.generation as f64));
                obj.insert("bytes".to_string(), Json::Num(e.bytes as f64));
                obj.insert("build_us".to_string(), Json::Num(e.build_us as f64));
                (id.clone(), Json::Obj(obj))
            })
            .collect();
        let deltas: BTreeMap<String, Json> = self
            .deltas
            .iter()
            .map(|(id, e)| {
                let mut obj = BTreeMap::new();
                obj.insert("file".to_string(), Json::Str(e.file.clone()));
                obj.insert(
                    "fingerprint".to_string(),
                    Json::Str(format!("{:032x}", e.fingerprint)),
                );
                obj.insert("generation".to_string(), Json::Num(e.generation as f64));
                obj.insert("bytes".to_string(), Json::Num(e.bytes as f64));
                (id.clone(), Json::Obj(obj))
            })
            .collect();
        let mut doc = BTreeMap::new();
        doc.insert("version".to_string(), Json::Num(MANIFEST_VERSION as f64));
        doc.insert("counter".to_string(), Json::Num(self.counter as f64));
        doc.insert("artifacts".to_string(), Json::Obj(artifacts));
        doc.insert("deltas".to_string(), Json::Obj(deltas));
        Json::Obj(doc)
    }

    /// Parse a manifest document (strict: any missing or mistyped field
    /// is an error — the tolerant entry point is [`Manifest::load_or_empty`]).
    pub fn from_json(doc: &Json) -> Result<Self> {
        let version = doc
            .get("version")
            .and_then(Json::as_u64)
            .context("manifest: missing version")?;
        anyhow::ensure!(
            version == MANIFEST_VERSION,
            "manifest: unsupported version {version} (expected {MANIFEST_VERSION})"
        );
        // Absent on hand-rolled documents; 0 is a valid starting point —
        // the watch compares file stamps first, the counter is a tiebreak.
        let counter = doc.get("counter").and_then(Json::as_u64).unwrap_or(0);
        let artifacts = match doc.get("artifacts") {
            Some(Json::Obj(m)) => m,
            _ => anyhow::bail!("manifest: missing artifacts object"),
        };
        // Only bare file names inside the store directory are legal: the
        // artifact loader joins this onto the store root and, on a failed
        // decode, *deletes* the resolved path — a manifest must never be
        // able to point that at an arbitrary file.
        let bare_file = |id: &str, e: &Json| -> Result<String> {
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .with_context(|| format!("manifest entry {id}: missing file"))?
                .to_string();
            anyhow::ensure!(
                !file.is_empty()
                    && !file.contains('/')
                    && !file.contains('\\')
                    && file != ".."
                    && file != ".",
                "manifest entry {id}: file {file:?} is not a bare file name"
            );
            Ok(file)
        };
        let hex_fp = |id: &str, e: &Json| -> Result<u128> {
            let s = e
                .get("fingerprint")
                .and_then(Json::as_str)
                .with_context(|| format!("manifest entry {id}: missing fingerprint"))?;
            u128::from_str_radix(s, 16)
                .with_context(|| format!("manifest entry {id}: bad fingerprint {s:?}"))
        };
        let mut entries = BTreeMap::new();
        for (id, e) in artifacts {
            let field = |name: &str| -> Result<u64> {
                e.get(name)
                    .and_then(Json::as_u64)
                    .with_context(|| format!("manifest entry {id}: missing {name}"))
            };
            let kind: IndexKind = e
                .get("kind")
                .and_then(Json::as_str)
                .with_context(|| format!("manifest entry {id}: missing kind"))?
                .parse()
                .map_err(|err: String| anyhow::anyhow!("manifest entry {id}: {err}"))?;
            entries.insert(
                id.clone(),
                ManifestEntry {
                    file: bare_file(id, e)?,
                    kind,
                    shards: field("shards")? as usize,
                    fingerprint: hex_fp(id, e)?,
                    generation: field("generation")?,
                    bytes: field("bytes")?,
                    build_us: field("build_us")?,
                },
            );
        }
        let mut deltas = BTreeMap::new();
        if let Some(Json::Obj(m)) = doc.get("deltas") {
            for (id, e) in m {
                let field = |name: &str| -> Result<u64> {
                    e.get(name)
                        .and_then(Json::as_u64)
                        .with_context(|| format!("manifest delta {id}: missing {name}"))
                };
                deltas.insert(
                    id.clone(),
                    DeltaEntry {
                        file: bare_file(id, e)?,
                        fingerprint: hex_fp(id, e)?,
                        generation: field("generation")?,
                        bytes: field("bytes")?,
                    },
                );
            }
        }
        Ok(Manifest { entries, deltas, counter })
    }

    /// Load a manifest from disk, strictly: a missing file is an empty
    /// manifest, but unreadable or unparsable content is an error.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        if !path.exists() {
            return Ok(Manifest::new());
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?}"))?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing manifest {path:?}: {e}"))?;
        Self::from_json(&doc)
    }

    /// Load a manifest, degrading to empty on any failure (with a warning
    /// on stderr). The artifacts themselves are self-describing, so the
    /// worst case of a lost manifest is cold rebuilds that repopulate it.
    pub fn load_or_empty(path: impl AsRef<Path>) -> Self {
        match Self::load(path.as_ref()) {
            Ok(m) => m,
            Err(e) => {
                eprintln!(
                    "warning: ignoring unreadable store manifest {:?}: {e:#}",
                    path.as_ref()
                );
                Manifest::new()
            }
        }
    }

    /// Write the manifest atomically (via [`super::write_atomic`]:
    /// temp-then-rename, so readers see the old complete document or the
    /// new one, never a torn write).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        super::write_atomic(path.as_ref(), self.to_json().to_string().as_bytes())
            .context("writing store manifest")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(fp: u128, kind: IndexKind, shards: usize) -> WorkloadKey {
        WorkloadKey { fingerprint: fp, kind, shards, generation: 0 }
    }

    fn entry(file: &str, kind: IndexKind, shards: usize) -> ManifestEntry {
        entry_at(file, kind, shards, 0, 0)
    }

    fn entry_at(
        file: &str,
        kind: IndexKind,
        shards: usize,
        fp: u128,
        generation: u64,
    ) -> ManifestEntry {
        ManifestEntry {
            file: file.to_string(),
            kind,
            shards,
            fingerprint: fp,
            generation,
            bytes: 123,
            build_us: 7,
        }
    }

    fn tmp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("fastmwem-manifest-{}-{name}", std::process::id()))
    }

    #[test]
    fn artifact_ids_are_unique_per_key_component() {
        let base = key(42, IndexKind::Flat, 1);
        let ids: Vec<String> = [
            base,
            key(43, IndexKind::Flat, 1),
            key(42, IndexKind::Ivf, 1),
            key(42, IndexKind::Flat, 2),
        ]
        .iter()
        .map(Manifest::artifact_id)
        .collect();
        for i in 0..ids.len() {
            for j in i + 1..ids.len() {
                assert_ne!(ids[i], ids[j]);
            }
        }
        assert!(ids[0].contains("flat"));
        assert_ne!(
            Manifest::artifact_id(&base),
            Manifest::artifact_id(&base.at_generation(3)),
            "generations get distinct artifact ids"
        );
    }

    /// The generation-aware restore scan: newest family snapshot at or
    /// below the requested generation; compaction removes the superseded
    /// ones; the delta catalog round-trips.
    #[test]
    fn latest_snapshot_and_delta_catalog() {
        let mut m = Manifest::new();
        let fam = key(0x2a, IndexKind::Flat, 1);
        m.insert(&fam, entry_at("g0.idx", IndexKind::Flat, 1, 0x2a, 0));
        m.insert(&fam.at_generation(2), entry_at("g2.idx", IndexKind::Flat, 1, 0x2a, 2));
        // different kind: not the same family
        m.insert(
            &key(0x2a, IndexKind::Ivf, 1).at_generation(3),
            entry_at("ivf.idx", IndexKind::Ivf, 1, 0x2a, 3),
        );

        let (g, e) = m.latest_snapshot(&fam.at_generation(5)).unwrap();
        assert_eq!((g, e.file.as_str()), (2, "g2.idx"));
        let (g, e) = m.latest_snapshot(&fam.at_generation(1)).unwrap();
        assert_eq!((g, e.file.as_str()), (0, "g0.idx"));
        assert!(m.latest_snapshot(&key(0x2b, IndexKind::Flat, 1)).is_none());

        for gen in [1u64, 2] {
            m.insert_delta(DeltaEntry {
                file: format!("d{gen}.delta"),
                fingerprint: 0x2a,
                generation: gen,
                bytes: 9,
            });
        }
        assert_eq!(m.delta_count(), 2);
        assert_eq!(m.get_delta(0x2a, 1).unwrap().file, "d1.delta");
        assert!(m.get_delta(0x2a, 3).is_none());

        // compaction at generation 5 removes the older family snapshots
        // (both of them), leaving the other-kind snapshot alone
        m.insert(&fam.at_generation(5), entry_at("g5.idx", IndexKind::Flat, 1, 0x2a, 5));
        let removed = m.remove_superseded_snapshots(&fam.at_generation(5));
        let mut files: Vec<&str> = removed.iter().map(|e| e.file.as_str()).collect();
        files.sort_unstable();
        assert_eq!(files, vec!["g0.idx", "g2.idx"]);
        assert_eq!(m.len(), 2, "g5 + the ivf snapshot survive");

        // the full catalog (snapshots + deltas) round-trips through JSON
        let mut back =
            Manifest::from_json(&Json::parse(&m.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.remove_delta(0x2a, 1).unwrap().bytes, 9);
    }

    #[test]
    fn json_round_trips() {
        let mut m = Manifest::new();
        m.insert(&key(1, IndexKind::Hnsw, 1), entry("a.idx", IndexKind::Hnsw, 1));
        m.insert(&key(2, IndexKind::Ivf, 4), entry("b.idx", IndexKind::Ivf, 4));
        m.bump_counter(0);
        m.bump_counter(0);
        let doc = m.to_json();
        let back = Manifest::from_json(&Json::parse(&doc.to_string()).unwrap()).unwrap();
        assert_eq!(m, back);
        assert_eq!(back.len(), 2);
        assert_eq!(back.counter(), 2, "the change counter round-trips");
        assert_eq!(back.get(&key(1, IndexKind::Hnsw, 1)).unwrap().file, "a.idx");
    }

    /// The change counter behind cross-process watches (DESIGN.md §13):
    /// strictly monotone, and `bump_counter(floor)` jumps past a larger
    /// on-disk counter so concurrent merge-then-save writers never emit a
    /// repeated value.
    #[test]
    fn change_counter_is_monotone_and_floors() {
        let mut m = Manifest::new();
        assert_eq!(m.counter(), 0);
        m.bump_counter(0);
        assert_eq!(m.counter(), 1);
        m.bump_counter(7); // a peer committed counter=7 meanwhile
        assert_eq!(m.counter(), 8);
        m.bump_counter(3); // stale floor never rewinds
        assert_eq!(m.counter(), 9);
        // absent counter parses as 0 (hand-rolled v3 document)
        let doc = Json::parse("{\"version\":3,\"artifacts\":{},\"deltas\":{}}").unwrap();
        assert_eq!(Manifest::from_json(&doc).unwrap().counter(), 0);
    }

    #[test]
    fn save_is_atomic_and_partial_tmp_is_ignored() {
        let path = tmp_path("atomic");
        let _ = std::fs::remove_file(&path);

        let mut m = Manifest::new();
        m.insert(&key(9, IndexKind::Flat, 1), entry("c.idx", IndexKind::Flat, 1));
        m.save(&path).unwrap();

        // simulate a crash mid-write of the *next* save: a partial temp
        // file next to a complete manifest
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        std::fs::write(std::path::PathBuf::from(tmp.clone()), "{\"version\":1,\"arti").unwrap();

        let loaded = Manifest::load(&path).unwrap();
        assert_eq!(loaded, m, "partial .tmp must not affect the real manifest");

        // a later successful save replaces the manifest and the stale tmp
        m.insert(&key(10, IndexKind::Ivf, 2), entry("d.idx", IndexKind::Ivf, 2));
        m.save(&path).unwrap();
        assert_eq!(Manifest::load(&path).unwrap().len(), 2);

        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(std::path::PathBuf::from(tmp));
    }

    #[test]
    fn corrupt_manifest_degrades_to_empty_not_panic() {
        let path = tmp_path("corrupt");
        std::fs::write(&path, "{\"version\":1,\"artifacts\":{\"x\":{\"file\"").unwrap();
        assert!(Manifest::load(&path).is_err(), "strict load must report corruption");
        assert!(Manifest::load_or_empty(&path).is_empty(), "tolerant load degrades");

        // wrong versions (including the retired v1/v2) are rejected strictly
        std::fs::write(&path, "{\"version\":99,\"artifacts\":{}}").unwrap();
        assert!(Manifest::load(&path).is_err());
        std::fs::write(&path, "{\"version\":1,\"artifacts\":{}}").unwrap();
        assert!(Manifest::load(&path).is_err(), "v1 manifests are not reinterpreted");
        std::fs::write(&path, "{\"version\":2,\"artifacts\":{},\"deltas\":{}}").unwrap();
        assert!(Manifest::load(&path).is_err(), "v2 manifests are not reinterpreted");

        // a file field that escapes the store directory is rejected — the
        // loader deletes the resolved path on decode failure, so a
        // traversal here would be an arbitrary-file delete
        for bad in ["/etc/hosts", "../escape.idx", "a/b.idx", "..", ""] {
            std::fs::write(
                &path,
                format!(
                    "{{\"version\":3,\"artifacts\":{{\"x\":{{\"file\":{},\
                     \"kind\":\"flat\",\"shards\":1,\"fingerprint\":\"2a\",\
                     \"generation\":0,\"bytes\":1,\"build_us\":1}}}},\"deltas\":{{}}}}",
                    Json::Str(bad.to_string())
                ),
            )
            .unwrap();
            assert!(Manifest::load(&path).is_err(), "file {bad:?} must be rejected");
        }
        // the same traversal guard covers the delta catalog
        std::fs::write(
            &path,
            "{\"version\":3,\"artifacts\":{},\"deltas\":{\"x\":{\"file\":\"../d\",\
             \"fingerprint\":\"2a\",\"generation\":1,\"bytes\":1}}}",
        )
        .unwrap();
        assert!(Manifest::load(&path).is_err(), "delta traversal must be rejected");

        let _ = std::fs::remove_file(&path);

        // missing file is an empty manifest, not an error
        assert!(Manifest::load(tmp_path("never-written")).unwrap().is_empty());
    }
}
