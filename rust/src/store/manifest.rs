//! Store manifest: a small JSON catalog of the artifacts in a store
//! directory, written atomically (DESIGN.md §7).
//!
//! The manifest is pure acceleration — the artifact files are
//! self-describing (`format.rs` headers), so a lost or corrupted manifest
//! only costs cold rebuilds, never correctness. That is why the load path
//! is tolerant ([`Manifest::load_or_empty`]) while the *write* path is
//! strict: every save rewrites the whole document to a temp file in the
//! same directory and renames it over the old one, so a crash mid-write
//! leaves either the previous complete manifest or a stray `.tmp` that is
//! simply ignored — never a half-written catalog that parses into lies.
//!
//! Serialization reuses the vendored-offline [`crate::util::json`]
//! reader/writer (no serde_json — DESIGN.md §3).

use crate::coordinator::cache::WorkloadKey;
use crate::mips::IndexKind;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// File name of the manifest inside a store directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// Manifest schema version (bumped on incompatible layout changes).
pub const MANIFEST_VERSION: u64 = 1;

/// One cataloged artifact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Artifact file name, relative to the store directory.
    pub file: String,
    /// Index implementation inside the artifact.
    pub kind: IndexKind,
    /// Shard count (1 = monolithic index).
    pub shards: usize,
    /// Artifact file size in bytes.
    pub bytes: u64,
    /// Build cost of the snapshotted index, in microseconds — restored
    /// into the L1 cache entry so promoted indices meter the same
    /// "build time saved" a same-process hit would (µs so sub-ms builds
    /// are not zeroed away, matching the metrics pipeline's precision).
    pub build_us: u64,
}

/// The artifact catalog: artifact id → [`ManifestEntry`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Manifest {
    entries: BTreeMap<String, ManifestEntry>,
}

impl Manifest {
    /// An empty manifest.
    pub fn new() -> Self {
        Self::default()
    }

    /// Content-addressed artifact id for a key:
    /// `<fingerprint:032x>-<kind>-s<shards>` — stable across processes,
    /// filesystem-safe, and unique per [`WorkloadKey`].
    pub fn artifact_id(key: &WorkloadKey) -> String {
        format!("{:032x}-{}-s{}", key.fingerprint, key.kind, key.shards)
    }

    /// Entry for `key`, if cataloged.
    pub fn get(&self, key: &WorkloadKey) -> Option<&ManifestEntry> {
        self.entries.get(&Self::artifact_id(key))
    }

    /// Insert (or replace) the entry for `key`.
    pub fn insert(&mut self, key: &WorkloadKey, entry: ManifestEntry) {
        self.entries.insert(Self::artifact_id(key), entry);
    }

    /// Drop the entry for `key` (a stale/corrupt artifact), if present.
    pub fn remove(&mut self, key: &WorkloadKey) -> Option<ManifestEntry> {
        self.entries.remove(&Self::artifact_id(key))
    }

    /// Number of cataloged artifacts.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is cataloged.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Iterate `(artifact id, entry)` in sorted id order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &ManifestEntry)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Serialize to the manifest JSON document.
    pub fn to_json(&self) -> Json {
        let artifacts: BTreeMap<String, Json> = self
            .entries
            .iter()
            .map(|(id, e)| {
                let mut obj = BTreeMap::new();
                obj.insert("file".to_string(), Json::Str(e.file.clone()));
                obj.insert("kind".to_string(), Json::Str(e.kind.to_string()));
                obj.insert("shards".to_string(), Json::Num(e.shards as f64));
                obj.insert("bytes".to_string(), Json::Num(e.bytes as f64));
                obj.insert("build_us".to_string(), Json::Num(e.build_us as f64));
                (id.clone(), Json::Obj(obj))
            })
            .collect();
        let mut doc = BTreeMap::new();
        doc.insert("version".to_string(), Json::Num(MANIFEST_VERSION as f64));
        doc.insert("artifacts".to_string(), Json::Obj(artifacts));
        Json::Obj(doc)
    }

    /// Parse a manifest document (strict: any missing or mistyped field
    /// is an error — the tolerant entry point is [`Manifest::load_or_empty`]).
    pub fn from_json(doc: &Json) -> Result<Self> {
        let version = doc
            .get("version")
            .and_then(Json::as_u64)
            .context("manifest: missing version")?;
        anyhow::ensure!(
            version == MANIFEST_VERSION,
            "manifest: unsupported version {version} (expected {MANIFEST_VERSION})"
        );
        let artifacts = match doc.get("artifacts") {
            Some(Json::Obj(m)) => m,
            _ => anyhow::bail!("manifest: missing artifacts object"),
        };
        let mut entries = BTreeMap::new();
        for (id, e) in artifacts {
            let field = |name: &str| -> Result<u64> {
                e.get(name)
                    .and_then(Json::as_u64)
                    .with_context(|| format!("manifest entry {id}: missing {name}"))
            };
            let kind: IndexKind = e
                .get("kind")
                .and_then(Json::as_str)
                .with_context(|| format!("manifest entry {id}: missing kind"))?
                .parse()
                .map_err(|err: String| anyhow::anyhow!("manifest entry {id}: {err}"))?;
            let file = e
                .get("file")
                .and_then(Json::as_str)
                .with_context(|| format!("manifest entry {id}: missing file"))?
                .to_string();
            // Only bare file names inside the store directory are legal:
            // the artifact loader joins this onto the store root and, on a
            // failed decode, *deletes* the resolved path — a manifest must
            // never be able to point that at an arbitrary file.
            anyhow::ensure!(
                !file.is_empty()
                    && !file.contains('/')
                    && !file.contains('\\')
                    && file != ".."
                    && file != ".",
                "manifest entry {id}: file {file:?} is not a bare file name"
            );
            entries.insert(
                id.clone(),
                ManifestEntry {
                    file,
                    kind,
                    shards: field("shards")? as usize,
                    bytes: field("bytes")?,
                    build_us: field("build_us")?,
                },
            );
        }
        Ok(Manifest { entries })
    }

    /// Load a manifest from disk, strictly: a missing file is an empty
    /// manifest, but unreadable or unparsable content is an error.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        if !path.exists() {
            return Ok(Manifest::new());
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?}"))?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing manifest {path:?}: {e}"))?;
        Self::from_json(&doc)
    }

    /// Load a manifest, degrading to empty on any failure (with a warning
    /// on stderr). The artifacts themselves are self-describing, so the
    /// worst case of a lost manifest is cold rebuilds that repopulate it.
    pub fn load_or_empty(path: impl AsRef<Path>) -> Self {
        match Self::load(path.as_ref()) {
            Ok(m) => m,
            Err(e) => {
                eprintln!(
                    "warning: ignoring unreadable store manifest {:?}: {e:#}",
                    path.as_ref()
                );
                Manifest::new()
            }
        }
    }

    /// Write the manifest atomically (via [`super::write_atomic`]:
    /// temp-then-rename, so readers see the old complete document or the
    /// new one, never a torn write).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        super::write_atomic(path.as_ref(), self.to_json().to_string().as_bytes())
            .context("writing store manifest")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(fp: u128, kind: IndexKind, shards: usize) -> WorkloadKey {
        WorkloadKey { fingerprint: fp, kind, shards }
    }

    fn entry(file: &str, kind: IndexKind, shards: usize) -> ManifestEntry {
        ManifestEntry { file: file.to_string(), kind, shards, bytes: 123, build_us: 7 }
    }

    fn tmp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("fastmwem-manifest-{}-{name}", std::process::id()))
    }

    #[test]
    fn artifact_ids_are_unique_per_key_component() {
        let base = key(42, IndexKind::Flat, 1);
        let ids: Vec<String> = [
            base,
            key(43, IndexKind::Flat, 1),
            key(42, IndexKind::Ivf, 1),
            key(42, IndexKind::Flat, 2),
        ]
        .iter()
        .map(Manifest::artifact_id)
        .collect();
        for i in 0..ids.len() {
            for j in i + 1..ids.len() {
                assert_ne!(ids[i], ids[j]);
            }
        }
        assert!(ids[0].contains("flat"));
    }

    #[test]
    fn json_round_trips() {
        let mut m = Manifest::new();
        m.insert(&key(1, IndexKind::Hnsw, 1), entry("a.idx", IndexKind::Hnsw, 1));
        m.insert(&key(2, IndexKind::Ivf, 4), entry("b.idx", IndexKind::Ivf, 4));
        let doc = m.to_json();
        let back = Manifest::from_json(&Json::parse(&doc.to_string()).unwrap()).unwrap();
        assert_eq!(m, back);
        assert_eq!(back.len(), 2);
        assert_eq!(back.get(&key(1, IndexKind::Hnsw, 1)).unwrap().file, "a.idx");
    }

    #[test]
    fn save_is_atomic_and_partial_tmp_is_ignored() {
        let path = tmp_path("atomic");
        let _ = std::fs::remove_file(&path);

        let mut m = Manifest::new();
        m.insert(&key(9, IndexKind::Flat, 1), entry("c.idx", IndexKind::Flat, 1));
        m.save(&path).unwrap();

        // simulate a crash mid-write of the *next* save: a partial temp
        // file next to a complete manifest
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        std::fs::write(std::path::PathBuf::from(tmp.clone()), "{\"version\":1,\"arti").unwrap();

        let loaded = Manifest::load(&path).unwrap();
        assert_eq!(loaded, m, "partial .tmp must not affect the real manifest");

        // a later successful save replaces the manifest and the stale tmp
        m.insert(&key(10, IndexKind::Ivf, 2), entry("d.idx", IndexKind::Ivf, 2));
        m.save(&path).unwrap();
        assert_eq!(Manifest::load(&path).unwrap().len(), 2);

        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(std::path::PathBuf::from(tmp));
    }

    #[test]
    fn corrupt_manifest_degrades_to_empty_not_panic() {
        let path = tmp_path("corrupt");
        std::fs::write(&path, "{\"version\":1,\"artifacts\":{\"x\":{\"file\"").unwrap();
        assert!(Manifest::load(&path).is_err(), "strict load must report corruption");
        assert!(Manifest::load_or_empty(&path).is_empty(), "tolerant load degrades");

        // wrong version is also rejected strictly
        std::fs::write(&path, "{\"version\":99,\"artifacts\":{}}").unwrap();
        assert!(Manifest::load(&path).is_err());

        // a file field that escapes the store directory is rejected — the
        // loader deletes the resolved path on decode failure, so a
        // traversal here would be an arbitrary-file delete
        for bad in ["/etc/hosts", "../escape.idx", "a/b.idx", "..", ""] {
            std::fs::write(
                &path,
                format!(
                    "{{\"version\":1,\"artifacts\":{{\"x\":{{\"file\":{},\
                     \"kind\":\"flat\",\"shards\":1,\"bytes\":1,\"build_us\":1}}}}}}",
                    Json::Str(bad.to_string())
                ),
            )
            .unwrap();
            assert!(Manifest::load(&path).is_err(), "file {bad:?} must be rejected");
        }

        let _ = std::fs::remove_file(&path);

        // missing file is an empty manifest, not an error
        assert!(Manifest::load(tmp_path("never-written")).unwrap().is_empty());
    }
}
