//! Two-tier index cache: the in-memory LRU in front of the persistent
//! artifact store (DESIGN.md §7).
//!
//! Lookup path per [`crate::coordinator::WorkloadKey`]:
//!
//! ```text
//! L1 hit            -> Arc clone                      (same-process warm)
//! L1 miss, L2 hit   -> read + decode + promote to L1  (cross-restart warm)
//! L1 miss, L2 miss  -> build, populate L1 and L2      (cold)
//! ```
//!
//! A promotion re-enters L1 with the *recorded* build cost from the
//! artifact's manifest entry, so subsequent same-process hits meter their
//! savings exactly as if the index had been built locally. Builds are
//! written through to the store best-effort: a failed write warns and
//! keeps serving (the store is an accelerator, never a correctness
//! dependency — see the failure philosophy in [`crate::store`]).

use super::lease::{self, Acquire, Lease, LeaseSettings};
use super::{DiskStore, HeapBudget, Manifest, PagerSettings};
use crate::coordinator::cache::{CacheReport, CachedIndex, IndexCache, WorkloadKey};
use crate::mips::{VectorSet, WorkloadDelta};
use crate::workloads::WorkloadRegistry;
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How many generations of cheap delta artifacts accumulate before the
/// tiered cache seals a full snapshot at the current generation
/// (superseding the older family snapshots) — the deltas/snapshot
/// compaction policy of DESIGN.md §9.
pub const COMPACT_EVERY: u64 = 4;

/// What one tiered consultation did — the two-tier analogue of
/// [`crate::coordinator::CacheEvent`].
#[derive(Clone, Copy, Debug, Default)]
pub struct TieredEvent {
    /// Served from the in-memory tier (no I/O, no build).
    pub l1_hit: bool,
    /// Restored from the persistent tier and promoted into L1.
    pub l2_hit: bool,
    /// Served by patching a stale-but-patchable older generation forward
    /// (combined with `l1_hit`/`l2_hit` to say which tier held the base)
    /// — never by handing out the stale entry itself (DESIGN.md §9).
    pub patched: bool,
    /// Build cost actually paid by this call (zero unless both tiers
    /// missed).
    pub build_time: Duration,
    /// Build cost avoided — the resident/recorded build time of the entry
    /// served (zero on a cold build).
    pub saved: Duration,
    /// Wall-clock spent decoding the artifact (promotions only).
    pub promote_time: Duration,
    /// Wall-clock spent applying workload deltas (patched serves only).
    pub patch_time: Duration,
    /// This call won the cross-process build lease and built under it
    /// (DESIGN.md §13).
    pub lease_acquired: bool,
    /// This call found a peer holding the build lease and waited —
    /// whether it then promoted the peer's artifact or (after the lease
    /// backstop) built independently.
    pub lease_waited: bool,
    /// The lease was obtained by expiring a stale lock file left by a
    /// crashed or stalled peer.
    pub lease_takeover: bool,
}

impl TieredEvent {
    /// Fold this consultation into a per-job [`CacheReport`]. Patch time
    /// accrues in its own accumulator — `promoted` stays what it is
    /// documented to be, time spent decoding store artifacts.
    pub fn fold_into(&self, report: &mut CacheReport) {
        if self.patched {
            report.patched += 1;
            report.patch_time += self.patch_time;
        }
        if self.lease_acquired {
            report.lease_acquired += 1;
        }
        if self.lease_waited {
            report.lease_waited += 1;
        }
        if self.lease_takeover {
            report.lease_takeovers += 1;
        }
        if self.l1_hit {
            report.hits += 1;
            report.saved += self.saved;
        } else if self.l2_hit {
            report.l2_hits += 1;
            report.saved += self.saved;
            report.promoted += self.promote_time;
        } else {
            report.misses += 1;
        }
    }
}

/// The coordinator's two-tier warm-index cache: [`IndexCache`] (L1) over
/// an optional [`DiskStore`] (L2). With no store attached it behaves
/// exactly like the bare L1 cache, so cold-only deployments pay nothing.
///
/// With a store attached, the cache is also the coordination point for N
/// independent processes sharing the store directory (DESIGN.md §13): a
/// shared miss takes a build *lease* so exactly one process builds while
/// peers wait-and-promote, and the manifest *watch*
/// ([`TieredIndexCache::sync_peer_updates`]) invalidates stale L1 entries
/// when a peer commits a workload update.
pub struct TieredIndexCache {
    l1: IndexCache,
    l2: Option<DiskStore>,
    lease: LeaseSettings,
    watch: bool,
}

impl TieredIndexCache {
    /// An in-memory-only cache (no persistence) of at most `capacity`
    /// indices — PR 2 behavior, byte for byte.
    pub fn memory_only(capacity: usize) -> Self {
        Self::memory_only_with_budget(capacity, HeapBudget::unlimited())
    }

    /// An in-memory-only cache bounded by an entry count *and* a heap-byte
    /// budget ([`CachedIndex::heap_bytes`] accounting — mmap-borrowed
    /// storage counts as zero, DESIGN.md §12).
    pub fn memory_only_with_budget(capacity: usize, budget: HeapBudget) -> Self {
        let l1 = IndexCache::with_byte_budget(capacity, budget.limit().unwrap_or(0));
        TieredIndexCache { l1, l2: None, lease: LeaseSettings::default(), watch: true }
    }

    /// A tiered cache persisting to `dir` (created if needed), with an L1
    /// of at most `capacity` indices, no byte budget, and default pager
    /// settings. `capacity` 0 keeps L1 disabled: every warm consultation
    /// restores from disk — slower than resident serving but still far
    /// cheaper than a rebuild.
    pub fn with_store(capacity: usize, dir: impl AsRef<Path>) -> Result<Self> {
        Self::with_settings(capacity, HeapBudget::unlimited(), dir, PagerSettings::default())
    }

    /// The fully configured tiered cache: L1 bounded by `capacity` entries
    /// and `budget` heap bytes, L2 at `dir` restoring artifacts under
    /// `pager`. With the pager on, a promoted artifact larger than the
    /// heap budget still serves resident: its rows stay in the mapping
    /// (zero heap accounted), only its meta structures count against the
    /// budget.
    pub fn with_settings(
        capacity: usize,
        budget: HeapBudget,
        dir: impl AsRef<Path>,
        pager: PagerSettings,
    ) -> Result<Self> {
        let l1 = IndexCache::with_byte_budget(capacity, budget.limit().unwrap_or(0));
        Ok(TieredIndexCache {
            l1,
            l2: Some(DiskStore::open_with(dir, pager)?),
            lease: LeaseSettings::default(),
            watch: true,
        })
    }

    /// Override the cross-process build-lease settings (the `[store]`
    /// config section; DESIGN.md §13). Irrelevant without a store tier.
    pub fn with_lease(mut self, lease: LeaseSettings) -> Self {
        self.lease = lease;
        self
    }

    /// Enable/disable the cross-process manifest watch
    /// ([`TieredIndexCache::sync_peer_updates`]). On by default.
    pub fn with_watch(mut self, watch: bool) -> Self {
        self.watch = watch;
        self
    }

    /// The in-memory tier.
    pub fn l1(&self) -> &IndexCache {
        &self.l1
    }

    /// The persistent tier, when attached.
    pub fn store(&self) -> Option<&DiskStore> {
        self.l2.as_ref()
    }

    /// Memoized workload fingerprint — delegates to
    /// [`IndexCache::fingerprint_for`] (`class_tag` is the query class's
    /// [`crate::workloads::QueryClassKind::tag`]).
    pub fn fingerprint_for(&self, workload_id: u64, class_tag: u64, vs: &VectorSet) -> u128 {
        self.l1.fingerprint_for(workload_id, class_tag, vs)
    }

    /// The tiered serving-path primitive: L1, then L2 (promote), then
    /// `build` (populate both tiers). The build and all file I/O run
    /// outside every lock. With a store attached, a shared miss is gated
    /// on the cross-process build lease (DESIGN.md §13): one racer —
    /// whether a worker thread here or a whole peer process — builds
    /// while the rest wait and promote its artifact; with leases off the
    /// racers all build, wasted work but never a wrong result.
    ///
    /// Static-workload entry point: equivalent to
    /// [`TieredIndexCache::get_or_build_dynamic`] with no delta source, so
    /// stale-but-patchable promotion never applies.
    pub fn get_or_build(
        &self,
        key: WorkloadKey,
        build: impl FnOnce() -> (CachedIndex, Duration),
    ) -> (CachedIndex, TieredEvent) {
        self.get_or_build_dynamic(key, |_| None, build)
    }

    /// The generation-aware serving-path primitive (DESIGN.md §9). Lookup
    /// order per [`WorkloadKey`]:
    ///
    /// ```text
    /// L1 exact hit                  -> Arc clone
    /// L1 older generation + deltas  -> patch forward, promote, drop stale
    /// L2 exact snapshot             -> decode + promote
    /// L2 older snapshot + deltas    -> decode + patch forward + promote
    /// otherwise                     -> build at key.generation, populate
    /// ```
    ///
    /// `deltas_from(g)` must return the delta chain taking the workload
    /// from generation `g` to `key.generation` (the in-memory
    /// [`crate::workloads::WorkloadRegistry`] in a serving process; `None`
    /// falls back to the store's persisted chain, then to a rebuild). A
    /// stale entry is **never** returned: either the chain patches it all
    /// the way to `key.generation`, or the lookup degrades to a build —
    /// the `stale_generation_serves` metric stays structurally zero.
    pub fn get_or_build_dynamic(
        &self,
        key: WorkloadKey,
        deltas_from: impl Fn(u64) -> Option<Vec<Arc<WorkloadDelta>>>,
        build: impl FnOnce() -> (CachedIndex, Duration),
    ) -> (CachedIndex, TieredEvent) {
        if let Some(hit) = self.try_memory(key, &deltas_from) {
            return hit;
        }
        if let Some(hit) = self.try_store(key, &deltas_from) {
            return hit;
        }
        // Both tiers missed under our current view of the catalog. Before
        // committing to a build, one stat of the shared manifest: a peer
        // process may have persisted this artifact since our last read
        // (DESIGN.md §13).
        if let Some(store) = &self.l2 {
            if store.refresh() {
                if let Some(hit) = self.try_store(key, &deltas_from) {
                    return hit;
                }
            }
        }
        // A real shared miss: gate the build on the cross-process lease —
        // either we hold it (and peers wait on us), or a peer built while
        // we waited and we serve their artifact.
        let (lease, waited, takeover) = match self.build_gate(&key, &deltas_from) {
            Gate::Serve(value, ev) => return (value, ev),
            Gate::Build { lease, waited, takeover } => (lease, waited, takeover),
        };
        let lease_acquired = lease.is_some();
        let (value, build_time) = build();
        self.l1.insert(key, value.clone(), build_time);
        if let Some(store) = &self.l2 {
            if let Err(e) = store.save(&key, &value, build_time) {
                eprintln!("warning: artifact store write failed ({e:#}); serving from memory");
            }
        }
        // Release only after the artifact is committed, so a waiter that
        // sees the lease vanish finds the artifact on its next poll.
        drop(lease);
        (
            value,
            TieredEvent {
                build_time,
                lease_acquired,
                lease_waited: waited,
                lease_takeover: takeover,
                ..Default::default()
            },
        )
    }

    /// L1 consultation: exact hit, or stale-but-patchable entry patched
    /// forward (promote, evict the superseded generation so it can never
    /// be offered again).
    fn try_memory(
        &self,
        key: WorkloadKey,
        deltas_from: &impl Fn(u64) -> Option<Vec<Arc<WorkloadDelta>>>,
    ) -> Option<(CachedIndex, TieredEvent)> {
        if let Some((value, saved)) = self.l1.lookup(&key) {
            return Some((value, TieredEvent { l1_hit: true, saved, ..Default::default() }));
        }
        if key.generation > 0 {
            if let Some((stale_key, value, recorded_build)) = self.l1.lookup_patchable(&key) {
                if let Some(deltas) = self.chain_for(&key, stale_key.generation, &deltas_from)
                {
                    let t0 = Instant::now();
                    match patch_chain(&value, stale_key.generation, &deltas, &key) {
                        Ok(patched) => {
                            let patch_time = t0.elapsed();
                            self.l1.remove(&stale_key);
                            self.l1.insert(key, patched.clone(), recorded_build);
                            self.maybe_compact(&key, &patched, recorded_build);
                            return Some((
                                patched,
                                TieredEvent {
                                    l1_hit: true,
                                    patched: true,
                                    saved: recorded_build,
                                    patch_time,
                                    ..Default::default()
                                },
                            ));
                        }
                        Err(e) => {
                            eprintln!(
                                "warning: in-memory patch of workload \
                                 {:032x} to generation {} failed ({e}); rebuilding",
                                key.fingerprint, key.generation
                            );
                        }
                    }
                }
            }
        }
        None
    }

    /// L2 consultation: exact-generation snapshot promoted, or an older
    /// family snapshot decoded and patched forward. `None` on a store
    /// miss (or with no store attached) — the caller decides whether to
    /// poll again or build.
    fn try_store(
        &self,
        key: WorkloadKey,
        deltas_from: &impl Fn(u64) -> Option<Vec<Arc<WorkloadDelta>>>,
    ) -> Option<(CachedIndex, TieredEvent)> {
        if let Some(store) = &self.l2 {
            if let Some((found, value, recorded_build, promote_time)) = store.load_latest(&key)
            {
                if found == key.generation {
                    self.l1.insert(key, value.clone(), recorded_build);
                    return Some((
                        value,
                        TieredEvent {
                            l2_hit: true,
                            saved: recorded_build,
                            promote_time,
                            ..Default::default()
                        },
                    ));
                }
                if let Some(deltas) = self.chain_for(&key, found, &deltas_from) {
                    let t0 = Instant::now();
                    match patch_chain(&value, found, &deltas, &key) {
                        Ok(patched) => {
                            let patch_time = t0.elapsed();
                            self.l1.insert(key, patched.clone(), recorded_build);
                            self.maybe_compact(&key, &patched, recorded_build);
                            return Some((
                                patched,
                                TieredEvent {
                                    l2_hit: true,
                                    patched: true,
                                    saved: recorded_build,
                                    promote_time,
                                    patch_time,
                                    ..Default::default()
                                },
                            ));
                        }
                        Err(e) => {
                            eprintln!(
                                "warning: store-side patch of workload \
                                 {:032x} to generation {} failed ({e}); rebuilding",
                                key.fingerprint, key.generation
                            );
                        }
                    }
                }
            }
        }
        None
    }

    /// The cross-process build gate (DESIGN.md §13). Tries to acquire
    /// the build lease for `key`'s artifact; while a peer holds it, polls
    /// the store between sleeps and serves the peer's artifact the moment
    /// it lands. Degrades to an ungated build when leases are disabled,
    /// unsupported by the directory, or the holder outlives
    /// [`LeaseSettings::max_wait`].
    fn build_gate(
        &self,
        key: &WorkloadKey,
        deltas_from: &impl Fn(u64) -> Option<Vec<Arc<WorkloadDelta>>>,
    ) -> Gate {
        let store = match &self.l2 {
            Some(s) if self.lease.enabled => s,
            _ => return Gate::Build { lease: None, waited: false, takeover: false },
        };
        let id = Manifest::artifact_id(key);
        let t0 = Instant::now();
        let mut waited = false;
        loop {
            match lease::try_acquire(store.dir(), &id, self.lease.ttl) {
                Ok(Acquire::Held(l)) => {
                    // If we waited or expired a holder, their build may
                    // have landed between our last poll and this acquire
                    // — don't rebuild an artifact that just arrived.
                    if waited || l.took_over() {
                        store.refresh();
                        if let Some((value, mut ev)) = self.try_store(*key, deltas_from) {
                            ev.lease_waited = waited;
                            return Gate::Serve(value, ev);
                        }
                    }
                    let takeover = l.took_over();
                    return Gate::Build { lease: Some(l), waited, takeover };
                }
                Ok(Acquire::Busy { .. }) => {
                    waited = true;
                    if t0.elapsed() >= self.lease.max_wait {
                        eprintln!(
                            "warning: waited {:?} on the build lease for {id}; \
                             building independently",
                            self.lease.max_wait
                        );
                        return Gate::Build { lease: None, waited, takeover: false };
                    }
                    std::thread::sleep(self.lease.poll);
                    store.refresh();
                    if let Some((value, mut ev)) = self.try_store(*key, deltas_from) {
                        ev.lease_waited = true;
                        return Gate::Serve(value, ev);
                    }
                }
                Err(e) => {
                    eprintln!("warning: build lease unavailable ({e}); building independently");
                    return Gate::Build { lease: None, waited, takeover: false };
                }
            }
        }
    }

    /// The generation watch (DESIGN.md §13): poll the shared manifest
    /// (one `stat`) and, when peer processes have committed workload
    /// updates for `fingerprint` beyond `registry`'s current generation,
    /// bridge the persisted delta chain into the registry. Subsequent
    /// lookups then carry the advanced generation, so a stale L1 entry is
    /// patched forward or rebuilt — never served — keeping the
    /// `stale_generation_serves == 0` invariant across process
    /// boundaries. Returns the number of generations advanced (0 when
    /// already current, the watch is off, or no store is attached).
    pub fn sync_peer_updates(&self, fingerprint: u128, registry: &WorkloadRegistry) -> u64 {
        let store = match &self.l2 {
            Some(s) if self.watch => s,
            _ => return 0,
        };
        store.refresh();
        let top = store.max_delta_generation(fingerprint);
        let cur = registry.generation(fingerprint);
        if top <= cur {
            return 0;
        }
        match store.load_deltas(fingerprint, cur, top) {
            Some(chain) => registry.extend_family(fingerprint, cur, chain),
            // a broken/incomplete persisted chain: leave the registry
            // alone; affected lookups will rebuild at their generation
            None => 0,
        }
    }

    /// The delta chain from `from` to `key.generation`: the caller's
    /// in-memory source first, the store's persisted chain as fallback.
    fn chain_for(
        &self,
        key: &WorkloadKey,
        from: u64,
        deltas_from: &impl Fn(u64) -> Option<Vec<Arc<WorkloadDelta>>>,
    ) -> Option<Vec<Arc<WorkloadDelta>>> {
        let chain = deltas_from(from).or_else(|| {
            self.l2
                .as_ref()
                .and_then(|s| s.load_deltas(key.fingerprint, from, key.generation))
        })?;
        // refuse an incomplete chain: patching short of key.generation
        // would be a stale serve
        if chain.len() as u64 == key.generation - from {
            Some(chain)
        } else {
            None
        }
    }

    /// Deltas/snapshot compaction (DESIGN.md §9): once the current
    /// generation is [`COMPACT_EVERY`] past the newest persisted family
    /// snapshot (or none exists), seal a full snapshot at `key` — the
    /// store prunes the superseded family snapshots; delta artifacts stay.
    fn maybe_compact(&self, key: &WorkloadKey, value: &CachedIndex, build_time: Duration) {
        if let Some(store) = &self.l2 {
            let due = match store.latest_snapshot_generation(key) {
                Some(g) => key.generation.saturating_sub(g) >= COMPACT_EVERY,
                None => true,
            };
            if due {
                if let Err(e) = store.save(key, value, build_time) {
                    eprintln!(
                        "warning: artifact store compaction failed ({e:#}); serving from memory"
                    );
                }
            }
        }
    }
}

/// Outcome of [`TieredIndexCache::build_gate`]: either serve what a peer
/// built while we waited, or go build — holding the lease when we won it,
/// ungated when leases are off/unsupported/timed out.
enum Gate {
    Serve(CachedIndex, TieredEvent),
    Build { lease: Option<Lease>, waited: bool, takeover: bool },
}

/// Derive the deterministic patch seed for generation `g` of a workload
/// family — stable across processes so every serving node patching the
/// same chain builds the same structures.
fn patch_seed(fingerprint: u128, generation: u64) -> u64 {
    ((fingerprint >> 64) as u64)
        ^ (fingerprint as u64)
        ^ generation.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ 0xD13A
}

/// Apply a delta chain to a cached entry, one generation at a time.
fn patch_chain(
    base: &CachedIndex,
    from: u64,
    deltas: &[Arc<WorkloadDelta>],
    key: &WorkloadKey,
) -> Result<CachedIndex, crate::mips::PatchError> {
    let mut cur = base.clone();
    let mut generation = from;
    for d in deltas {
        generation += 1;
        let (next, _rebuilt) = cur.patch(d, patch_seed(key.fingerprint, generation))?;
        cur = next;
    }
    Ok(cur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lazy::{LazyEm, ScoreTransform, ShardSet, ShardedLazyEm};
    use crate::mips::{build_index, IndexKind, MipsIndex, VectorSet};
    use crate::util::rng::Rng;
    use std::cell::Cell;
    use std::path::PathBuf;
    use std::sync::Arc;

    fn random_set(n: usize, d: usize, seed: u64) -> VectorSet {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        VectorSet::new(data, n, d)
    }

    fn scratch_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("fastmwem-tiered-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn key(vs: &VectorSet, kind: IndexKind, shards: usize) -> WorkloadKey {
        WorkloadKey::for_vectors(vs, kind, shards)
    }

    /// Draw a fixed sequence of lazy-EM selections through an index.
    fn draw_sequence(index: &dyn MipsIndex, vs: &VectorSet, rng_seed: u64) -> Vec<usize> {
        let em = LazyEm::new(index, vs, ScoreTransform::Abs);
        let mut rng = Rng::new(rng_seed);
        let q: Vec<f32> = (0..vs.dim()).map(|i| ((i + 1) as f32 * 0.37).sin()).collect();
        (0..40).map(|_| em.select(&mut rng, &q, 1.0, 0.1).index).collect()
    }

    /// The acceptance bar (ISSUE 3): for flat and IVF, `select()` through
    /// an L2-restored index is bit-identical to `select()` through the
    /// freshly built index it snapshotted.
    #[test]
    fn restored_mono_indices_draw_bit_identically() {
        let dir = scratch_dir("mono-equiv");
        let vs = random_set(120, 6, 3);
        for kind in [IndexKind::Flat, IndexKind::Ivf] {
            let fresh = build_index(kind, vs.clone(), 77);
            let k = key(&vs, kind, 1);

            // cold process: build + persist
            let tiered = TieredIndexCache::with_store(4, &dir).unwrap();
            let (_, ev) = tiered.get_or_build(k, || {
                (CachedIndex::Mono(Arc::clone(&fresh)), Duration::ZERO)
            });
            assert!(!ev.l1_hit && !ev.l2_hit, "{kind}: first consultation builds");

            // restart: fresh L1, same directory -> promote from disk
            let restarted = TieredIndexCache::with_store(4, &dir).unwrap();
            let (restored, _) = tiered_expect_l2(&restarted, k);
            let restored = match restored {
                CachedIndex::Mono(i) => i,
                _ => panic!("{kind}: mono in, mono out"),
            };
            assert_eq!(
                draw_sequence(fresh.as_ref(), &vs, 9),
                draw_sequence(restored.as_ref(), &vs, 9),
                "{kind}: restored index must reproduce draws exactly"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn tiered_expect_l2(
        cache: &TieredIndexCache,
        k: WorkloadKey,
    ) -> (CachedIndex, TieredEvent) {
        let (value, ev) = cache.get_or_build(k, || unreachable!("must restore, not rebuild"));
        assert!(ev.l2_hit && !ev.l1_hit, "expected an L2 promotion");
        (value, ev)
    }

    /// Same bar for a sharded workload: the restored `ShardSet` reproduces
    /// `ShardedLazyEm::select` draws bit-identically.
    #[test]
    fn restored_shard_set_draws_bit_identically() {
        let dir = scratch_dir("sharded-equiv");
        let vs = random_set(90, 5, 4);
        let set = Arc::new(ShardSet::build(IndexKind::Flat, &vs, 3, 55));
        let k = key(&vs, IndexKind::Flat, 3);

        let tiered = TieredIndexCache::with_store(4, &dir).unwrap();
        tiered.get_or_build(k, || {
            (CachedIndex::Sharded(Arc::clone(&set)), Duration::ZERO)
        });

        let restarted = TieredIndexCache::with_store(4, &dir).unwrap();
        let (restored, _) = tiered_expect_l2(&restarted, k);
        let restored = match restored {
            CachedIndex::Sharded(s) => s,
            _ => panic!("sharded in, sharded out"),
        };
        assert_eq!(restored.bounds(), set.bounds());

        let fresh_em =
            ShardedLazyEm::with_shard_set(Arc::clone(&set), &vs, ScoreTransform::Abs);
        let restored_em = ShardedLazyEm::with_shard_set(restored, &vs, ScoreTransform::Abs);
        let q: Vec<f32> = (0..5).map(|i| (i as f32 * 0.21).cos()).collect();
        let mut r1 = Rng::new(8);
        let mut r2 = Rng::new(8);
        for _ in 0..50 {
            let a = fresh_em.select(&mut r1, &q, 1.0, 0.1);
            let b = restored_em.select(&mut r2, &q, 1.0, 0.1);
            assert_eq!(a.index, b.index);
            assert_eq!(a.work, b.work);
            assert!(a.value == b.value, "perturbed values must be bit-identical");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Tier accounting: L1 hit beats L2; L2 promotion refills L1; a
    /// memory-only cache never reports L2 activity.
    #[test]
    fn tier_order_and_promotion() {
        let dir = scratch_dir("tiers");
        let vs = random_set(40, 4, 5);
        let k = key(&vs, IndexKind::Flat, 1);
        let builds = Cell::new(0usize);
        let make = || {
            builds.set(builds.get() + 1);
            (
                CachedIndex::Mono(build_index(IndexKind::Flat, vs.clone(), 1)),
                Duration::from_millis(4),
            )
        };

        let tiered = TieredIndexCache::with_store(2, &dir).unwrap();
        let (_, ev1) = tiered.get_or_build(k, make);
        assert!(!ev1.l1_hit && !ev1.l2_hit && builds.get() == 1);
        let (_, ev2) = tiered.get_or_build(k, make);
        assert!(ev2.l1_hit, "second consultation in-process is an L1 hit");
        assert_eq!(builds.get(), 1);
        assert_eq!(ev2.saved, Duration::from_millis(4));

        // restart: L1 cold, promotion restores the recorded build time
        let restarted = TieredIndexCache::with_store(2, &dir).unwrap();
        let (_, ev3) = restarted.get_or_build(k, make);
        assert!(ev3.l2_hit && builds.get() == 1);
        assert_eq!(ev3.saved, Duration::from_millis(4), "recorded build time restored");
        let (_, ev4) = restarted.get_or_build(k, make);
        assert!(ev4.l1_hit, "promotion must refill L1");

        // fold_into: 1 build + 1 l1 hit + 1 l2 hit + 1 l1 hit
        let mut rep = CacheReport::default();
        for ev in [ev1, ev2, ev3, ev4] {
            ev.fold_into(&mut rep);
        }
        assert_eq!((rep.hits, rep.l2_hits, rep.misses), (2, 1, 1));
        assert_eq!(rep.saved, Duration::from_millis(12));

        // memory-only: same key, no store tier
        let memory = TieredIndexCache::memory_only(2);
        let (_, ev) = memory.get_or_build(k, make);
        assert!(!ev.l2_hit && builds.get() == 2);
        assert!(memory.store().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The §12 headline: an artifact whose owned row data exceeds the
    /// heap budget is served by mmap paging — zero decode restores, L1
    /// accounting under budget, and draws bit-identical to a fresh build.
    #[cfg(unix)]
    #[test]
    fn over_budget_artifact_serves_via_paging() {
        let dir = scratch_dir("budget");
        let vs = random_set(400, 16, 11);
        let k = key(&vs, IndexKind::Flat, 1);
        let make = || {
            (CachedIndex::Mono(build_index(IndexKind::Flat, vs.clone(), 1)), Duration::ZERO)
        };
        let owned_bytes = make().0.heap_bytes();

        // seed the store, then restart with a budget far below the rows
        TieredIndexCache::with_store(2, &dir).unwrap().get_or_build(k, make);
        let budget = HeapBudget::bytes(owned_bytes / 4);
        let tiered =
            TieredIndexCache::with_settings(2, budget, &dir, PagerSettings::default()).unwrap();
        let (value, ev) =
            tiered.get_or_build(k, || unreachable!("artifact on disk: must restore"));
        assert!(ev.l2_hit);

        let s = tiered.store().unwrap().stats();
        assert_eq!(
            (s.mmap_restores, s.decode_restores),
            (1, 0),
            "an over-budget restore must page, never decode"
        );
        assert!(
            value.heap_bytes() < owned_bytes / 4,
            "borrowed rows pin no heap ({} vs owned {owned_bytes})",
            value.heap_bytes()
        );
        assert!(tiered.l1().resident_bytes() <= budget.limit().unwrap());

        let fresh = build_index(IndexKind::Flat, vs.clone(), 1);
        match value {
            CachedIndex::Mono(idx) => assert_eq!(
                draw_sequence(fresh.as_ref(), &vs, 5),
                draw_sequence(idx.as_ref(), &vs, 5),
                "paged index must reproduce draws exactly"
            ),
            _ => panic!("mono in, mono out"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A corrupted artifact must fall back to a rebuild — never panic,
    /// never serve garbage.
    #[test]
    fn corrupt_artifact_falls_back_to_rebuild() {
        let dir = scratch_dir("fallback");
        let vs = random_set(30, 3, 6);
        let k = key(&vs, IndexKind::Flat, 1);
        let make = || {
            (CachedIndex::Mono(build_index(IndexKind::Flat, vs.clone(), 1)), Duration::ZERO)
        };

        let tiered = TieredIndexCache::with_store(2, &dir).unwrap();
        tiered.get_or_build(k, make);

        // flip one payload byte in the artifact on disk
        let file = dir.join(format!("{}.idx", crate::store::Manifest::artifact_id(&k)));
        let mut bytes = std::fs::read(&file).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&file, &bytes).unwrap();

        let restarted = TieredIndexCache::with_store(2, &dir).unwrap();
        let rebuilt = Cell::new(false);
        let (_, ev) = restarted.get_or_build(k, || {
            rebuilt.set(true);
            make()
        });
        assert!(rebuilt.get(), "corrupt artifact must trigger a rebuild");
        assert!(!ev.l2_hit);
        assert_eq!(restarted.store().unwrap().stats().load_failures, 1);

        // the rebuild re-persisted a good artifact
        let again = TieredIndexCache::with_store(2, &dir).unwrap();
        tiered_expect_l2(&again, k);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The dynamic-workload serving path (DESIGN.md §9): a generation-1
    /// request against a generation-0 entry patches forward and promotes —
    /// in memory, across a restart from the persisted snapshot + delta,
    /// and never serves the stale generation.
    #[test]
    fn stale_generations_patch_forward_never_serve() {
        let dir = scratch_dir("dynamic");
        let vs = random_set(50, 4, 9);
        let base_key = key(&vs, IndexKind::Flat, 1);
        let delta = Arc::new(crate::mips::WorkloadDelta::new(
            random_set(2, 4, 10),
            vec![7, 30],
        ));
        let effective = crate::mips::apply_delta_to_vectors(&vs, &delta).unwrap();
        let chain = {
            let delta = Arc::clone(&delta);
            move |from: u64| {
                assert_eq!(from, 0, "chain requested from the stale generation");
                Some(vec![Arc::clone(&delta)])
            }
        };

        let tiered = TieredIndexCache::with_store(4, &dir).unwrap();
        let (_, ev) = tiered.get_or_build(base_key, || {
            (CachedIndex::Mono(build_index(IndexKind::Flat, vs.clone(), 1)), Duration::ZERO)
        });
        assert!(!ev.l1_hit && !ev.l2_hit);
        tiered.store().unwrap().save_delta(base_key.fingerprint, 1, &delta).unwrap();

        // generation-1 request: the resident g0 entry is patched forward
        let g1 = base_key.at_generation(1);
        let (value, ev) = tiered.get_or_build_dynamic(g1, &chain, || {
            unreachable!("patchable entry resident: must patch, not rebuild")
        });
        assert!(ev.l1_hit && ev.patched && !ev.l2_hit);
        assert_eq!(value.live_len(), effective.len());
        assert!(!tiered.l1().contains(&base_key), "stale generation evicted");
        assert!(tiered.l1().contains(&g1), "patched entry promoted");

        // second consultation is a plain exact hit
        let (_, ev) = tiered.get_or_build_dynamic(g1, &chain, || unreachable!("exact hit"));
        assert!(ev.l1_hit && !ev.patched);

        // restart: cold L1, snapshot at g0 + persisted delta on disk; the
        // in-memory chain is absent (a fresh process), so the store chain
        // serves
        let restarted = TieredIndexCache::with_store(4, &dir).unwrap();
        let (value, ev) = restarted.get_or_build_dynamic(g1, |_| None, || {
            unreachable!("snapshot + delta on disk: must patch-restore")
        });
        assert!(ev.l2_hit && ev.patched);
        assert_eq!(value.live_len(), effective.len());

        // the patched flat index is bit-identical to a fresh build over
        // the effective rows
        match value {
            CachedIndex::Mono(idx) => {
                let fresh = build_index(IndexKind::Flat, effective.clone(), 1);
                let q = vec![0.3f32; 4];
                for (a, b) in idx.top_k(&q, 10).iter().zip(fresh.top_k(&q, 10).iter()) {
                    assert_eq!(a.id, b.id);
                    assert_eq!(a.score.to_bits(), b.score.to_bits());
                }
            }
            _ => panic!("mono in, mono out"),
        }

        // an incomplete chain must degrade to a rebuild, never serve stale
        let g3 = base_key.at_generation(3);
        let rebuilt = std::cell::Cell::new(false);
        let (_, ev) = restarted.get_or_build_dynamic(g3, |_| None, || {
            rebuilt.set(true);
            (
                CachedIndex::Mono(build_index(IndexKind::Flat, effective.clone(), 1)),
                Duration::ZERO,
            )
        });
        assert!(rebuilt.get(), "missing deltas g2..g3: must rebuild");
        assert!(!ev.patched && !ev.l1_hit && !ev.l2_hit);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The cross-process build-dedup headline (DESIGN.md §13): two caches
    /// (modeling two processes) miss the same cold key concurrently —
    /// exactly one builds under the lease, the other waits and promotes
    /// the winner's artifact from the store.
    #[test]
    fn shared_miss_builds_once_and_the_peer_promotes() {
        use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

        let dir = scratch_dir("lease-dedup");
        let vs = random_set(60, 4, 12);
        let k = key(&vs, IndexKind::Flat, 1);
        let fast_poll = LeaseSettings {
            poll: Duration::from_millis(5),
            ..LeaseSettings::default()
        };
        let a = TieredIndexCache::with_store(2, &dir).unwrap().with_lease(fast_poll);
        let b = TieredIndexCache::with_store(2, &dir).unwrap().with_lease(fast_poll);
        let builds = AtomicUsize::new(0);
        let a_building = AtomicBool::new(false);

        let (ev_a, ev_b) = std::thread::scope(|s| {
            let ha = s.spawn(|| {
                a.get_or_build(k, || {
                    a_building.store(true, Ordering::SeqCst);
                    builds.fetch_add(1, Ordering::SeqCst);
                    // a deliberately slow build: B must arrive mid-flight
                    std::thread::sleep(Duration::from_millis(300));
                    (
                        CachedIndex::Mono(build_index(IndexKind::Flat, vs.clone(), 1)),
                        Duration::from_millis(300),
                    )
                })
                .1
            });
            // start B only once A provably holds the lease (its build
            // closure runs strictly after acquisition)
            while !a_building.load(Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(1));
            }
            let hb = s.spawn(|| {
                b.get_or_build(k, || {
                    builds.fetch_add(1, Ordering::SeqCst);
                    (
                        CachedIndex::Mono(build_index(IndexKind::Flat, vs.clone(), 1)),
                        Duration::ZERO,
                    )
                })
                .1
            });
            (ha.join().unwrap(), hb.join().unwrap())
        });

        assert_eq!(builds.load(Ordering::SeqCst), 1, "exactly one build per shared miss");
        assert!(ev_a.lease_acquired && !ev_a.lease_waited && !ev_a.lease_takeover);
        assert!(ev_b.l2_hit, "the waiter serves the winner's artifact");
        assert!(ev_b.lease_waited && !ev_b.lease_acquired);

        // the metrics pipeline sees both sides
        let mut rep = CacheReport::default();
        ev_a.fold_into(&mut rep);
        ev_b.fold_into(&mut rep);
        assert_eq!((rep.lease_acquired, rep.lease_waited, rep.lease_takeovers), (1, 1, 0));
        assert_eq!((rep.misses, rep.l2_hits), (1, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Crash-mid-lease recovery (DESIGN.md §13 failure modes): a lock
    /// file left behind by a killed process — never refreshed, never
    /// released — must be expired and taken over after the TTL, not
    /// deadlock peers; and the takeover's own release leaves the dir
    /// clean.
    #[test]
    fn abandoned_lease_is_expired_and_taken_over() {
        let dir = scratch_dir("lease-crash");
        let vs = random_set(30, 3, 13);
        let k = key(&vs, IndexKind::Flat, 1);
        let tiered = TieredIndexCache::with_store(2, &dir).unwrap().with_lease(LeaseSettings {
            ttl: Duration::from_millis(100),
            poll: Duration::from_millis(10),
            ..LeaseSettings::default()
        });
        // the "crashed" holder's lock file, freshly written — peers must
        // honor it for a TTL before expiring it
        let lock = dir.join(format!("{}.lease", Manifest::artifact_id(&k)));
        std::fs::write(&lock, "token 424242:0\n").unwrap();

        let built = Cell::new(false);
        let (_, ev) = tiered.get_or_build(k, || {
            built.set(true);
            (CachedIndex::Mono(build_index(IndexKind::Flat, vs.clone(), 1)), Duration::ZERO)
        });
        assert!(built.get(), "the takeover must build (nothing was persisted)");
        assert!(ev.lease_takeover, "recovery must be reported as a takeover");
        assert!(ev.lease_waited, "the TTL grace period counts as waiting");
        assert!(ev.lease_acquired);
        assert!(!lock.exists(), "the recovered lease is released after the build");
        assert!(
            tiered.store().unwrap().contains(&k),
            "the artifact persisted despite the crashed predecessor"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Capacity-0 L1 with a store: every consultation decodes from disk —
    /// degraded but correct.
    #[test]
    fn zero_capacity_l1_still_serves_from_disk() {
        let dir = scratch_dir("l1-off");
        let vs = random_set(25, 3, 7);
        let k = key(&vs, IndexKind::Flat, 1);
        let make = || {
            (CachedIndex::Mono(build_index(IndexKind::Flat, vs.clone(), 1)), Duration::ZERO)
        };

        let tiered = TieredIndexCache::with_store(0, &dir).unwrap();
        let (_, ev) = tiered.get_or_build(k, make);
        assert!(!ev.l1_hit && !ev.l2_hit);
        for _ in 0..2 {
            let (_, ev) = tiered.get_or_build(k, || unreachable!("disk tier must serve"));
            assert!(ev.l2_hit, "with L1 disabled every warm consultation is an L2 hit");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
