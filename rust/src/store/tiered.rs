//! Two-tier index cache: the in-memory LRU in front of the persistent
//! artifact store (DESIGN.md §7).
//!
//! Lookup path per [`crate::coordinator::WorkloadKey`]:
//!
//! ```text
//! L1 hit            -> Arc clone                      (same-process warm)
//! L1 miss, L2 hit   -> read + decode + promote to L1  (cross-restart warm)
//! L1 miss, L2 miss  -> build, populate L1 and L2      (cold)
//! ```
//!
//! A promotion re-enters L1 with the *recorded* build cost from the
//! artifact's manifest entry, so subsequent same-process hits meter their
//! savings exactly as if the index had been built locally. Builds are
//! written through to the store best-effort: a failed write warns and
//! keeps serving (the store is an accelerator, never a correctness
//! dependency — see the failure philosophy in [`crate::store`]).

use super::DiskStore;
use crate::coordinator::cache::{CacheReport, CachedIndex, IndexCache, WorkloadKey};
use crate::mips::VectorSet;
use anyhow::Result;
use std::path::Path;
use std::time::Duration;

/// What one tiered consultation did — the two-tier analogue of
/// [`crate::coordinator::CacheEvent`].
#[derive(Clone, Copy, Debug, Default)]
pub struct TieredEvent {
    /// Served from the in-memory tier (no I/O, no build).
    pub l1_hit: bool,
    /// Restored from the persistent tier and promoted into L1.
    pub l2_hit: bool,
    /// Build cost actually paid by this call (zero unless both tiers
    /// missed).
    pub build_time: Duration,
    /// Build cost avoided — the resident/recorded build time of the entry
    /// served (zero on a cold build).
    pub saved: Duration,
    /// Wall-clock spent decoding the artifact (promotions only).
    pub promote_time: Duration,
}

impl TieredEvent {
    /// Fold this consultation into a per-job [`CacheReport`].
    pub fn fold_into(&self, report: &mut CacheReport) {
        if self.l1_hit {
            report.hits += 1;
            report.saved += self.saved;
        } else if self.l2_hit {
            report.l2_hits += 1;
            report.saved += self.saved;
            report.promoted += self.promote_time;
        } else {
            report.misses += 1;
        }
    }
}

/// The coordinator's two-tier warm-index cache: [`IndexCache`] (L1) over
/// an optional [`DiskStore`] (L2). With no store attached it behaves
/// exactly like the bare L1 cache, so cold-only deployments pay nothing.
pub struct TieredIndexCache {
    l1: IndexCache,
    l2: Option<DiskStore>,
}

impl TieredIndexCache {
    /// An in-memory-only cache (no persistence) of at most `capacity`
    /// indices — PR 2 behavior, byte for byte.
    pub fn memory_only(capacity: usize) -> Self {
        TieredIndexCache { l1: IndexCache::new(capacity), l2: None }
    }

    /// A tiered cache persisting to `dir` (created if needed), with an L1
    /// of at most `capacity` indices. `capacity` 0 keeps L1 disabled:
    /// every warm consultation decodes from disk — slower than resident
    /// serving but still far cheaper than a rebuild.
    pub fn with_store(capacity: usize, dir: impl AsRef<Path>) -> Result<Self> {
        Ok(TieredIndexCache { l1: IndexCache::new(capacity), l2: Some(DiskStore::open(dir)?) })
    }

    /// The in-memory tier.
    pub fn l1(&self) -> &IndexCache {
        &self.l1
    }

    /// The persistent tier, when attached.
    pub fn store(&self) -> Option<&DiskStore> {
        self.l2.as_ref()
    }

    /// Memoized workload fingerprint — delegates to
    /// [`IndexCache::fingerprint_for`].
    pub fn fingerprint_for(&self, workload_id: u64, vs: &VectorSet) -> u128 {
        self.l1.fingerprint_for(workload_id, vs)
    }

    /// The tiered serving-path primitive: L1, then L2 (promote), then
    /// `build` (populate both tiers). The build and all file I/O run
    /// outside every lock; racing workers on one cold key both build —
    /// wasted work, never a wrong result, exactly like the L1-only cache.
    pub fn get_or_build(
        &self,
        key: WorkloadKey,
        build: impl FnOnce() -> (CachedIndex, Duration),
    ) -> (CachedIndex, TieredEvent) {
        if let Some((value, saved)) = self.l1.lookup(&key) {
            return (value, TieredEvent { l1_hit: true, saved, ..Default::default() });
        }
        if let Some(store) = &self.l2 {
            if let Some((value, recorded_build, promote_time)) = store.load(&key) {
                self.l1.insert(key, value.clone(), recorded_build);
                return (
                    value,
                    TieredEvent {
                        l2_hit: true,
                        saved: recorded_build,
                        promote_time,
                        ..Default::default()
                    },
                );
            }
        }
        let (value, build_time) = build();
        self.l1.insert(key, value.clone(), build_time);
        if let Some(store) = &self.l2 {
            if let Err(e) = store.save(&key, &value, build_time) {
                eprintln!("warning: artifact store write failed ({e:#}); serving from memory");
            }
        }
        (value, TieredEvent { build_time, ..Default::default() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lazy::{LazyEm, ScoreTransform, ShardSet, ShardedLazyEm};
    use crate::mips::{build_index, IndexKind, MipsIndex, VectorSet};
    use crate::util::rng::Rng;
    use std::cell::Cell;
    use std::path::PathBuf;
    use std::sync::Arc;

    fn random_set(n: usize, d: usize, seed: u64) -> VectorSet {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        VectorSet::new(data, n, d)
    }

    fn scratch_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("fastmwem-tiered-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn key(vs: &VectorSet, kind: IndexKind, shards: usize) -> WorkloadKey {
        WorkloadKey::for_vectors(vs, kind, shards)
    }

    /// Draw a fixed sequence of lazy-EM selections through an index.
    fn draw_sequence(index: &dyn MipsIndex, vs: &VectorSet, rng_seed: u64) -> Vec<usize> {
        let em = LazyEm::new(index, vs, ScoreTransform::Abs);
        let mut rng = Rng::new(rng_seed);
        let q: Vec<f32> = (0..vs.dim()).map(|i| ((i + 1) as f32 * 0.37).sin()).collect();
        (0..40).map(|_| em.select(&mut rng, &q, 1.0, 0.1).index).collect()
    }

    /// The acceptance bar (ISSUE 3): for flat and IVF, `select()` through
    /// an L2-restored index is bit-identical to `select()` through the
    /// freshly built index it snapshotted.
    #[test]
    fn restored_mono_indices_draw_bit_identically() {
        let dir = scratch_dir("mono-equiv");
        let vs = random_set(120, 6, 3);
        for kind in [IndexKind::Flat, IndexKind::Ivf] {
            let fresh = build_index(kind, vs.clone(), 77);
            let k = key(&vs, kind, 1);

            // cold process: build + persist
            let tiered = TieredIndexCache::with_store(4, &dir).unwrap();
            let (_, ev) = tiered.get_or_build(k, || {
                (CachedIndex::Mono(Arc::clone(&fresh)), Duration::ZERO)
            });
            assert!(!ev.l1_hit && !ev.l2_hit, "{kind}: first consultation builds");

            // restart: fresh L1, same directory -> promote from disk
            let restarted = TieredIndexCache::with_store(4, &dir).unwrap();
            let (restored, _) = tiered_expect_l2(&restarted, k);
            let restored = match restored {
                CachedIndex::Mono(i) => i,
                _ => panic!("{kind}: mono in, mono out"),
            };
            assert_eq!(
                draw_sequence(fresh.as_ref(), &vs, 9),
                draw_sequence(restored.as_ref(), &vs, 9),
                "{kind}: restored index must reproduce draws exactly"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn tiered_expect_l2(
        cache: &TieredIndexCache,
        k: WorkloadKey,
    ) -> (CachedIndex, TieredEvent) {
        let (value, ev) = cache.get_or_build(k, || unreachable!("must restore, not rebuild"));
        assert!(ev.l2_hit && !ev.l1_hit, "expected an L2 promotion");
        (value, ev)
    }

    /// Same bar for a sharded workload: the restored `ShardSet` reproduces
    /// `ShardedLazyEm::select` draws bit-identically.
    #[test]
    fn restored_shard_set_draws_bit_identically() {
        let dir = scratch_dir("sharded-equiv");
        let vs = random_set(90, 5, 4);
        let set = Arc::new(ShardSet::build(IndexKind::Flat, &vs, 3, 55));
        let k = key(&vs, IndexKind::Flat, 3);

        let tiered = TieredIndexCache::with_store(4, &dir).unwrap();
        tiered.get_or_build(k, || {
            (CachedIndex::Sharded(Arc::clone(&set)), Duration::ZERO)
        });

        let restarted = TieredIndexCache::with_store(4, &dir).unwrap();
        let (restored, _) = tiered_expect_l2(&restarted, k);
        let restored = match restored {
            CachedIndex::Sharded(s) => s,
            _ => panic!("sharded in, sharded out"),
        };
        assert_eq!(restored.bounds(), set.bounds());

        let fresh_em =
            ShardedLazyEm::with_shard_set(Arc::clone(&set), &vs, ScoreTransform::Abs);
        let restored_em = ShardedLazyEm::with_shard_set(restored, &vs, ScoreTransform::Abs);
        let q: Vec<f32> = (0..5).map(|i| (i as f32 * 0.21).cos()).collect();
        let mut r1 = Rng::new(8);
        let mut r2 = Rng::new(8);
        for _ in 0..50 {
            let a = fresh_em.select(&mut r1, &q, 1.0, 0.1);
            let b = restored_em.select(&mut r2, &q, 1.0, 0.1);
            assert_eq!(a.index, b.index);
            assert_eq!(a.work, b.work);
            assert!(a.value == b.value, "perturbed values must be bit-identical");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Tier accounting: L1 hit beats L2; L2 promotion refills L1; a
    /// memory-only cache never reports L2 activity.
    #[test]
    fn tier_order_and_promotion() {
        let dir = scratch_dir("tiers");
        let vs = random_set(40, 4, 5);
        let k = key(&vs, IndexKind::Flat, 1);
        let builds = Cell::new(0usize);
        let make = || {
            builds.set(builds.get() + 1);
            (
                CachedIndex::Mono(build_index(IndexKind::Flat, vs.clone(), 1)),
                Duration::from_millis(4),
            )
        };

        let tiered = TieredIndexCache::with_store(2, &dir).unwrap();
        let (_, ev1) = tiered.get_or_build(k, make);
        assert!(!ev1.l1_hit && !ev1.l2_hit && builds.get() == 1);
        let (_, ev2) = tiered.get_or_build(k, make);
        assert!(ev2.l1_hit, "second consultation in-process is an L1 hit");
        assert_eq!(builds.get(), 1);
        assert_eq!(ev2.saved, Duration::from_millis(4));

        // restart: L1 cold, promotion restores the recorded build time
        let restarted = TieredIndexCache::with_store(2, &dir).unwrap();
        let (_, ev3) = restarted.get_or_build(k, make);
        assert!(ev3.l2_hit && builds.get() == 1);
        assert_eq!(ev3.saved, Duration::from_millis(4), "recorded build time restored");
        let (_, ev4) = restarted.get_or_build(k, make);
        assert!(ev4.l1_hit, "promotion must refill L1");

        // fold_into: 1 build + 1 l1 hit + 1 l2 hit + 1 l1 hit
        let mut rep = CacheReport::default();
        for ev in [ev1, ev2, ev3, ev4] {
            ev.fold_into(&mut rep);
        }
        assert_eq!((rep.hits, rep.l2_hits, rep.misses), (2, 1, 1));
        assert_eq!(rep.saved, Duration::from_millis(12));

        // memory-only: same key, no store tier
        let memory = TieredIndexCache::memory_only(2);
        let (_, ev) = memory.get_or_build(k, make);
        assert!(!ev.l2_hit && builds.get() == 2);
        assert!(memory.store().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A corrupted artifact must fall back to a rebuild — never panic,
    /// never serve garbage.
    #[test]
    fn corrupt_artifact_falls_back_to_rebuild() {
        let dir = scratch_dir("fallback");
        let vs = random_set(30, 3, 6);
        let k = key(&vs, IndexKind::Flat, 1);
        let make = || {
            (CachedIndex::Mono(build_index(IndexKind::Flat, vs.clone(), 1)), Duration::ZERO)
        };

        let tiered = TieredIndexCache::with_store(2, &dir).unwrap();
        tiered.get_or_build(k, make);

        // flip one payload byte in the artifact on disk
        let file = dir.join(format!("{}.idx", crate::store::Manifest::artifact_id(&k)));
        let mut bytes = std::fs::read(&file).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&file, &bytes).unwrap();

        let restarted = TieredIndexCache::with_store(2, &dir).unwrap();
        let rebuilt = Cell::new(false);
        let (_, ev) = restarted.get_or_build(k, || {
            rebuilt.set(true);
            make()
        });
        assert!(rebuilt.get(), "corrupt artifact must trigger a rebuild");
        assert!(!ev.l2_hit);
        assert_eq!(restarted.store().unwrap().stats().load_failures, 1);

        // the rebuild re-persisted a good artifact
        let again = TieredIndexCache::with_store(2, &dir).unwrap();
        tiered_expect_l2(&again, k);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Capacity-0 L1 with a store: every consultation decodes from disk —
    /// degraded but correct.
    #[test]
    fn zero_capacity_l1_still_serves_from_disk() {
        let dir = scratch_dir("l1-off");
        let vs = random_set(25, 3, 7);
        let k = key(&vs, IndexKind::Flat, 1);
        let make = || {
            (CachedIndex::Mono(build_index(IndexKind::Flat, vs.clone(), 1)), Duration::ZERO)
        };

        let tiered = TieredIndexCache::with_store(0, &dir).unwrap();
        let (_, ev) = tiered.get_or_build(k, make);
        assert!(!ev.l1_hit && !ev.l2_hit);
        for _ in 0..2 {
            let (_, ev) = tiered.get_or_build(k, || unreachable!("disk tier must serve"));
            assert!(ev.l2_hit, "with L1 disabled every warm consultation is an L2 hit");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
