//! Cross-process build leases: lock-file deduplication of index builds
//! over a shared artifact store (DESIGN.md §13).
//!
//! When N serving processes share one `--store-dir`, a cold workload
//! would otherwise be built N times — once per process — even though the
//! first finished build is immediately loadable by everyone else. A
//! *build lease* is a tiny lock file (`<artifact_id>.lease`) created with
//! `O_CREAT|O_EXCL` next to the artifact it guards: exactly one process
//! wins the create, builds, persists, and releases; the others observe
//! [`Acquire::Busy`], poll the store, and promote the winner's artifact
//! from L2 instead of building ([`crate::store::TieredIndexCache`] drives
//! that loop).
//!
//! Failure philosophy, same as the rest of the store: the lease is an
//! *optimization*, never a correctness dependency.
//!
//! * A holder that crashes mid-build leaves its lock file behind — with
//!   no heartbeat its mtime goes stale, and after [`LeaseSettings::ttl`]
//!   any waiter may remove the file and retake the lease (the `O_EXCL`
//!   re-create arbitrates racing takeovers: exactly one wins).
//! * A holder that is merely *slow* (build time > ttl) loses exclusivity
//!   and some peer duplicates the build. That is wasted work, not a
//!   hazard: artifact writes are content-deterministic, catalog commits
//!   merge with the on-disk manifest, and generation supersession only
//!   ever removes *older* snapshots — a late loser cannot clobber a
//!   newer artifact. Long builds can call [`Lease::refresh`] to keep the
//!   mtime live.
//! * A directory that cannot host lock files at all (permissions, exotic
//!   filesystems) surfaces [`LeaseError::Unsupported`]; callers degrade
//!   to independent builds — N processes behave like N strangers, which
//!   is exactly the pre-lease world.

use std::fmt;
use std::fs::{self, OpenOptions};
use std::io::{ErrorKind, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, SystemTime};

/// Knobs for the cross-process build-dedup protocol, carried by
/// [`crate::store::TieredIndexCache`] and settable from the `[store]`
/// config section (`lease`, `lease_ttl_ms`, `lease_poll_ms`,
/// `lease_wait_ms`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LeaseSettings {
    /// Whether misses try to acquire a build lease at all. Off means
    /// every process builds independently (the pre-lease behavior).
    pub enabled: bool,
    /// Age past which an unrefreshed lock file is considered abandoned
    /// and may be taken over. This is the "longest expected index build"
    /// knob: too short duplicates slow builds, too long stalls waiters
    /// behind a crashed holder.
    pub ttl: Duration,
    /// How often a waiter re-polls the store (and the lease) while the
    /// holder builds.
    pub poll: Duration,
    /// Upper bound on total waiting before a peer gives up on the holder
    /// and builds independently. A liveness backstop, not a tuning knob.
    pub max_wait: Duration,
}

impl Default for LeaseSettings {
    fn default() -> Self {
        LeaseSettings {
            enabled: true,
            ttl: Duration::from_secs(30),
            poll: Duration::from_millis(25),
            max_wait: Duration::from_secs(120),
        }
    }
}

/// Why a lease could not be used on this store directory. These degrade
/// the caller to an independent build — never to a failed job.
#[derive(Debug)]
pub enum LeaseError {
    /// The directory refused the lock-file protocol itself (create or
    /// stat failed for a reason other than contention), e.g. a read-only
    /// mount. Contains the offending path and the OS detail.
    Unsupported {
        /// The lock-file path that could not be created or inspected.
        path: PathBuf,
        /// Stringified OS error.
        detail: String,
    },
}

impl fmt::Display for LeaseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LeaseError::Unsupported { path, detail } => {
                write!(f, "store dir does not support lock files at {}: {detail}", path.display())
            }
        }
    }
}

impl std::error::Error for LeaseError {}

/// Outcome of one (non-blocking) acquisition attempt.
#[derive(Debug)]
pub enum Acquire {
    /// We hold the lease: build, persist, then drop the guard.
    Held(Lease),
    /// A live peer holds it; `age` is how old their lock file is. Poll
    /// the store and retry.
    Busy {
        /// Age of the current holder's lock file at the time we looked.
        age: Duration,
    },
}

/// RAII guard for a held build lease. Dropping it releases the lock file
/// (only if we still own it — a takeover by a peer after our TTL lapsed
/// is left untouched).
#[derive(Debug)]
pub struct Lease {
    path: PathBuf,
    token: String,
    took_over: bool,
}

/// Process-wide acquisition counter; combined with the pid it makes each
/// lease token unique without needing a clock or RNG.
static ACQUISITIONS: AtomicU64 = AtomicU64::new(0);

fn next_token() -> String {
    let n = ACQUISITIONS.fetch_add(1, Ordering::Relaxed);
    format!("{}:{n}", std::process::id())
}

impl Lease {
    /// True when this lease was obtained by expiring a stale lock file
    /// left behind by a crashed (or stalled) peer.
    pub fn took_over(&self) -> bool {
        self.took_over
    }

    /// Re-stamp the lock file's mtime so a long build keeps its
    /// exclusivity past [`LeaseSettings::ttl`]. Returns `false` if the
    /// file is gone or no longer ours (a peer already expired us) — the
    /// build should continue regardless; the worst case is a duplicate.
    pub fn refresh(&self) -> bool {
        if !self.owned() {
            return false;
        }
        // Rewriting the (tiny) body updates mtime on every platform we
        // care about; O_EXCL is deliberately absent — the file exists.
        fs::write(&self.path, format!("token {}\n", self.token)).is_ok()
    }

    fn owned(&self) -> bool {
        let mut body = String::new();
        match fs::File::open(&self.path).and_then(|mut f| f.read_to_string(&mut body)) {
            Ok(_) => body.contains(&self.token),
            Err(_) => false,
        }
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        // Read-check-delete is not atomic: a peer could take over in the
        // gap and we would delete *their* file. The consequence is one
        // duplicated build, which the store's merge-and-supersede write
        // path already tolerates — not worth a platform-locking API.
        if self.owned() {
            let _ = fs::remove_file(&self.path);
        }
    }
}

/// Path of the lock file guarding `artifact_id` inside `dir`.
pub fn lease_path(dir: &Path, artifact_id: &str) -> PathBuf {
    dir.join(format!("{artifact_id}.lease"))
}

/// One non-blocking attempt to acquire the build lease for `artifact_id`
/// in store directory `dir`.
///
/// Returns [`Acquire::Held`] if we created the lock file (or expired a
/// stale one and won the re-create race), [`Acquire::Busy`] if a peer's
/// lock file is younger than `ttl`, and [`LeaseError`] if the directory
/// rejected the protocol entirely. Never blocks and never sleeps; the
/// waiting loop (with its poll interval and max wait) belongs to the
/// caller, which interleaves store polls between attempts.
pub fn try_acquire(dir: &Path, artifact_id: &str, ttl: Duration) -> Result<Acquire, LeaseError> {
    let path = lease_path(dir, artifact_id);
    let mut took_over = false;
    // A few create→stat→expire rounds: each loop either creates the
    // file, observes a live holder, or removes a stale file and retries.
    // Bounded so a pathological directory (e.g. mtimes pinned in the
    // past) degrades to Busy instead of spinning.
    for _ in 0..4 {
        match OpenOptions::new().write(true).create_new(true).open(&path) {
            Ok(mut f) => {
                let token = next_token();
                // Body content is diagnostic; ownership is checked by
                // token match. A failed write still holds the O_EXCL
                // file, so the lease stands.
                let _ = writeln!(f, "token {token}");
                return Ok(Acquire::Held(Lease { path, token, took_over }));
            }
            Err(e) if e.kind() == ErrorKind::AlreadyExists => {
                let age = match fs::metadata(&path) {
                    Ok(md) => match md.modified() {
                        Ok(mtime) => SystemTime::now()
                            .duration_since(mtime)
                            .unwrap_or(Duration::ZERO),
                        Err(e) => {
                            return Err(LeaseError::Unsupported { path, detail: e.to_string() })
                        }
                    },
                    // Holder released between our create and stat: retry
                    // the create.
                    Err(e) if e.kind() == ErrorKind::NotFound => continue,
                    Err(e) => {
                        return Err(LeaseError::Unsupported { path, detail: e.to_string() })
                    }
                };
                if age <= ttl {
                    return Ok(Acquire::Busy { age });
                }
                // Stale: expire it and race for the re-create. NotFound
                // here means another waiter expired it first — fine, the
                // O_EXCL create above arbitrates.
                match fs::remove_file(&path) {
                    Ok(()) => took_over = true,
                    Err(e) if e.kind() == ErrorKind::NotFound => {}
                    Err(e) => {
                        return Err(LeaseError::Unsupported { path, detail: e.to_string() })
                    }
                }
            }
            Err(e) => return Err(LeaseError::Unsupported { path, detail: e.to_string() }),
        }
    }
    Ok(Acquire::Busy { age: Duration::ZERO })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fastmwem-lease-{name}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Age a lock file by rewinding its mtime — deterministic staleness
    /// without sleeping through real TTLs.
    fn backdate(path: &Path, secs: u64) {
        let f = OpenOptions::new().append(true).open(path).unwrap();
        f.set_modified(SystemTime::now() - Duration::from_secs(secs)).unwrap();
    }

    #[test]
    fn second_acquire_is_busy_and_release_reopens() {
        let dir = scratch_dir("busy");
        let ttl = Duration::from_secs(30);
        let a = match try_acquire(&dir, "art-1", ttl).unwrap() {
            Acquire::Held(l) => l,
            other => panic!("first acquire must hold, got {other:?}"),
        };
        assert!(!a.took_over());
        match try_acquire(&dir, "art-1", ttl).unwrap() {
            Acquire::Busy { age } => assert!(age < ttl),
            other => panic!("second acquire must be busy, got {other:?}"),
        }
        // Distinct artifacts don't contend.
        assert!(matches!(try_acquire(&dir, "art-2", ttl).unwrap(), Acquire::Held(_)));
        drop(a);
        assert!(!lease_path(&dir, "art-1").exists(), "drop must release the lock file");
        assert!(matches!(try_acquire(&dir, "art-1", ttl).unwrap(), Acquire::Held(_)));
    }

    #[test]
    fn stale_lease_is_taken_over_after_ttl() {
        let dir = scratch_dir("stale");
        // A lock file left behind by a "crashed" holder: no guard ever
        // drops, no refresh ever runs.
        let ttl = Duration::from_secs(10);
        fs::write(lease_path(&dir, "art"), "token 99999:0\n").unwrap();
        match try_acquire(&dir, "art", ttl).unwrap() {
            Acquire::Busy { .. } => {}
            other => panic!("fresh file must read as busy, got {other:?}"),
        }
        backdate(&lease_path(&dir, "art"), 60);
        match try_acquire(&dir, "art", ttl).unwrap() {
            Acquire::Held(l) => assert!(l.took_over(), "expiry path must report takeover"),
            other => panic!("stale file must be expired and retaken, got {other:?}"),
        }
    }

    #[test]
    fn refresh_keeps_a_slow_holder_live() {
        let dir = scratch_dir("refresh");
        let ttl = Duration::from_secs(10);
        let l = match try_acquire(&dir, "art", ttl).unwrap() {
            Acquire::Held(l) => l,
            other => panic!("must hold, got {other:?}"),
        };
        // The build has (notionally) outlived the TTL...
        backdate(&lease_path(&dir, "art"), 60);
        // ...but a refresh re-stamps the mtime, so waiters still see a
        // live holder instead of expiring it.
        assert!(l.refresh());
        match try_acquire(&dir, "art", ttl).unwrap() {
            Acquire::Busy { age } => assert!(age <= ttl),
            other => panic!("refreshed lease must stay busy, got {other:?}"),
        }
    }

    #[test]
    fn drop_after_takeover_leaves_the_new_owner_alone() {
        let dir = scratch_dir("expired-drop");
        let ttl = Duration::from_secs(10);
        let old = match try_acquire(&dir, "art", ttl).unwrap() {
            Acquire::Held(l) => l,
            other => panic!("must hold, got {other:?}"),
        };
        backdate(&lease_path(&dir, "art"), 60);
        // A waiter expires us and takes over.
        let new = match try_acquire(&dir, "art", ttl).unwrap() {
            Acquire::Held(l) => l,
            other => panic!("stale lease must be retaken, got {other:?}"),
        };
        assert!(new.took_over());
        // Our (stale) guard must notice it no longer owns the file and
        // leave the new holder's lock in place.
        drop(old);
        assert!(lease_path(&dir, "art").exists(), "usurped drop must not release the new lease");
        drop(new);
        assert!(!lease_path(&dir, "art").exists());
    }

    #[test]
    fn unsupported_dir_reports_typed_error() {
        let dir = scratch_dir("unsupported").join("does-not-exist");
        match try_acquire(&dir, "art", Duration::from_secs(1)) {
            Err(LeaseError::Unsupported { path, .. }) => {
                assert_eq!(path, lease_path(&dir, "art"));
            }
            other => panic!("missing dir must be Unsupported, got {other:?}"),
        }
    }

    #[test]
    fn contended_takeover_admits_exactly_one_winner() {
        let dir = scratch_dir("contended");
        fs::write(lease_path(&dir, "art"), "token 0:0\n").unwrap();
        backdate(&lease_path(&dir, "art"), 60);
        let ttl = Duration::from_secs(10);
        // Many threads race to expire the same stale lock: the O_EXCL
        // re-create must admit exactly one. Winners keep their guard
        // alive until every racer has attempted, so a late thread sees a
        // fresh Busy file rather than a released lock.
        let leases: Vec<Option<Lease>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| match try_acquire(&dir, "art", ttl) {
                        Ok(Acquire::Held(l)) => Some(l),
                        _ => None,
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(
            leases.iter().flatten().count(),
            1,
            "exactly one racer may win the takeover"
        );
    }
}
