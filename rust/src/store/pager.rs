//! Zero-copy artifact paging: restore an index over a memory-mapped v3
//! artifact instead of decoding it into heap (DESIGN.md §12).
//!
//! A v3 artifact ([`super::format`]) keeps its bulk row data in
//! page-aligned sections whose on-disk layout is byte-identical to the
//! in-memory blocked layout of [`VectorSet`]. [`mmap_artifact`] therefore
//! maps the whole file once ([`MmapRegion`]), validates the envelope, and
//! hands the decoder *borrowed* vector storage pointing straight into the
//! mapping — the OS pages rows in on first touch, and resident pages are
//! the kernel's to reclaim, not heap the process must budget. Only the
//! small meta structures (IVF lists, HNSW links, quantized codes, the
//! augmented-space norms recomputed from the rows) live on the heap.
//!
//! Exactness: a borrowed [`VectorSet`] serves `row(i)` as the same f32
//! bit patterns the owned copy would hold (the format is little-endian
//! and the blocked stride matches), so every score, every shortlist and
//! every lazy-Gumbel `select()` draw through an mmap-restored index is
//! bit-identical to the decode-restored and freshly built paths. The
//! restore-equivalence suite (`tests/mmap_equivalence.rs`) pins this.
//!
//! Failure philosophy: mapping is an accelerator. A platform without
//! `mmap`, a syscall failure, or a big-endian host ([`VectorSet::borrowed`]
//! refuses the reinterpretation) degrades to the copying decode path —
//! [`PagerFailure::Map`]. Corruption ([`PagerFailure::Artifact`]) is not
//! retried by decode: the same bytes would fail the same checks, so the
//! store drops the artifact and rebuilds.

use super::format::{self, StoreError};
use crate::coordinator::cache::{CachedIndex, WorkloadKey};
use crate::mips::VectorSet;
use crate::util::mmap::MmapRegion;
use std::fmt;
use std::path::Path;
use std::sync::Arc;

/// How the store restores artifacts (the `[pager]` config section).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PagerSettings {
    /// Map artifacts and borrow their sections (default). Off = always
    /// decode into heap (pre-v12 behavior).
    pub enabled: bool,
    /// Verify every section checksum eagerly at open time (default).
    /// Costs one sequential walk of the file — disabling keeps page-in
    /// fully lazy at the price of detecting bit rot only via the meta
    /// checksum and structural invariants.
    pub verify: bool,
}

impl Default for PagerSettings {
    fn default() -> Self {
        PagerSettings { enabled: true, verify: true }
    }
}

/// A byte ceiling for *heap-resident* index data — what the in-memory
/// cache tier is allowed to pin. Mmap-borrowed rows cost no heap
/// ([`VectorSet::heap_bytes`] counts them as zero), which is exactly what
/// lets a larger-than-RAM artifact serve under a small budget: the cache
/// accounts the meta structures, the kernel pages the rows.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HeapBudget(Option<usize>);

impl HeapBudget {
    /// No ceiling: entry-count capacity alone bounds the cache.
    pub fn unlimited() -> Self {
        HeapBudget(None)
    }

    /// A ceiling of `bytes` heap bytes.
    pub fn bytes(bytes: usize) -> Self {
        HeapBudget(Some(bytes))
    }

    /// A ceiling of `mb` mebibytes (an overflowing product means
    /// unlimited — no real budget is that large).
    pub fn from_mb(mb: usize) -> Self {
        HeapBudget(mb.checked_mul(1 << 20))
    }

    /// The ceiling in bytes; `None` means unlimited.
    pub fn limit(&self) -> Option<usize> {
        self.0
    }

    /// True when `resident` heap bytes exceed the ceiling.
    pub fn exceeded_by(&self, resident: usize) -> bool {
        self.0.is_some_and(|limit| resident > limit)
    }
}

/// Why an mmap restore did not produce an index.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PagerFailure {
    /// The mapping itself failed (unsupported platform, syscall error,
    /// big-endian borrow refusal). The artifact may be fine — the caller
    /// falls back to the decode path.
    Map(String),
    /// The artifact is unusable (corrupt, truncated, wrong key). Decoding
    /// the same bytes would fail identically — the caller drops the
    /// artifact and rebuilds.
    Artifact(StoreError),
}

impl fmt::Display for PagerFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PagerFailure::Map(why) => write!(f, "mmap unavailable: {why}"),
            PagerFailure::Artifact(e) => write!(f, "{e}"),
        }
    }
}

/// Restore the artifact at `path` for `expect` over a shared memory
/// mapping: map the file, validate the envelope (and, when `verify`, every
/// section checksum), then decode the meta stream against *borrowed*
/// section storage. The returned entry keeps the mapping alive through
/// `Arc<MmapRegion>` references inside its [`VectorSet`]s; dropping the
/// last clone unmaps the file.
pub fn mmap_artifact(
    path: &Path,
    expect: &WorkloadKey,
    verify: bool,
) -> Result<CachedIndex, PagerFailure> {
    let region = Arc::new(
        MmapRegion::map_file(path).map_err(|e| PagerFailure::Map(e.to_string()))?,
    );
    let view = format::open_artifact(region.bytes()).map_err(PagerFailure::Artifact)?;
    if view.key != *expect {
        return Err(PagerFailure::Artifact(StoreError::KeyMismatch));
    }
    if verify {
        format::verify_sections(region.bytes(), &view).map_err(PagerFailure::Artifact)?;
    }
    let mut sections = Vec::with_capacity(view.sections.len());
    for desc in &view.sections {
        let vs = VectorSet::borrowed(Arc::clone(&region), desc.offset, desc.rows, desc.dim)
            .map_err(PagerFailure::Map)?;
        sections.push(vs);
    }
    format::decode_payload(view.meta, sections).map_err(PagerFailure::Artifact)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lazy::ShardSet;
    use crate::mips::{build_index, IndexKind, VectorSet};
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn random_set(n: usize, d: usize, seed: u64) -> VectorSet {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        VectorSet::new(data, n, d)
    }

    fn scratch(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("fastmwem-pager-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn heap_budget_arithmetic() {
        assert_eq!(HeapBudget::unlimited().limit(), None);
        assert!(!HeapBudget::unlimited().exceeded_by(usize::MAX));
        let b = HeapBudget::from_mb(2);
        assert_eq!(b.limit(), Some(2 << 20));
        assert!(b.exceeded_by(2 * 1024 * 1024 + 1));
        assert!(!b.exceeded_by(2 * 1024 * 1024));
    }

    #[cfg(unix)]
    #[test]
    fn mmap_restore_is_bit_identical_and_borrows_rows() {
        let dir = scratch("equiv");
        let vs = random_set(150, 9, 1);
        let key = WorkloadKey::for_vectors(&vs, IndexKind::Flat, 1);
        let value = CachedIndex::Mono(build_index(IndexKind::Flat, vs.clone(), 1));
        let path = dir.join("a.idx");
        std::fs::write(&path, format::encode_artifact(&key, &value)).unwrap();

        let mapped = mmap_artifact(&path, &key, true).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let decoded = format::decode_artifact(&bytes, &key).unwrap();

        // borrowed storage costs no heap for the rows; the decoded copy
        // pays the full n×stride
        assert!(
            mapped.heap_bytes() < decoded.heap_bytes(),
            "mapped {} vs decoded {}",
            mapped.heap_bytes(),
            decoded.heap_bytes()
        );

        let (CachedIndex::Mono(a), CachedIndex::Mono(b)) = (&mapped, &decoded) else {
            panic!("mono in, mono out");
        };
        let mut qrng = Rng::new(2);
        for _ in 0..20 {
            let q: Vec<f32> = (0..9).map(|_| qrng.uniform(-1.0, 1.0) as f32).collect();
            for (x, y) in a.top_k(&q, 7).iter().zip(b.top_k(&q, 7).iter()) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.score.to_bits(), y.score.to_bits());
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[cfg(unix)]
    #[test]
    fn mmap_restore_covers_sharded_sets() {
        let dir = scratch("sharded");
        let vs = random_set(80, 5, 3);
        let set = ShardSet::build(IndexKind::Flat, &vs, 3, 9);
        let key = WorkloadKey::for_vectors(&vs, IndexKind::Flat, 3);
        let value = CachedIndex::Sharded(Arc::new(set));
        let path = dir.join("s.idx");
        std::fs::write(&path, format::encode_artifact(&key, &value)).unwrap();

        let mapped = mmap_artifact(&path, &key, true).unwrap();
        let CachedIndex::Sharded(s) = &mapped else { panic!("sharded in, sharded out") };
        assert_eq!(s.len(), 80);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[cfg(unix)]
    #[test]
    fn failure_modes_split_map_from_artifact() {
        let dir = scratch("failures");
        let vs = random_set(40, 4, 4);
        let key = WorkloadKey::for_vectors(&vs, IndexKind::Flat, 1);
        let value = CachedIndex::Mono(build_index(IndexKind::Flat, vs.clone(), 1));
        let path = dir.join("f.idx");
        let good = format::encode_artifact(&key, &value);
        std::fs::write(&path, &good).unwrap();

        // a missing file is a mapping failure (fallback territory)
        assert!(matches!(
            mmap_artifact(&dir.join("nope.idx"), &key, true),
            Err(PagerFailure::Map(_))
        ));

        // a wrong key is an artifact failure
        let other = WorkloadKey { fingerprint: 1, ..key };
        assert!(matches!(
            mmap_artifact(&path, &other, true),
            Err(PagerFailure::Artifact(StoreError::KeyMismatch))
        ));

        // a flipped section byte is caught eagerly when verify is on...
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        std::fs::write(&path, &bad).unwrap();
        assert!(matches!(
            mmap_artifact(&path, &key, true),
            Err(PagerFailure::Artifact(StoreError::ChecksumMismatch))
        ));
        // ...and sails through structurally when verify is off — the
        // documented trade; meta corruption is still always caught
        assert!(mmap_artifact(&path, &key, false).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
