//! Lightweight metrics registry: counters, gauges and timing histograms for
//! the coordinator and the eval harness. JSON-dumpable via `util::json`.
//!
//! Well-known coordinator counters: `jobs_completed` / `jobs_failed` /
//! `jobs_{release,lp}`, plus the warm-index serving trio `index_cache_hit`,
//! `index_cache_miss` and `index_build_saved_ms` (total index build time
//! skipped by cache hits; accumulated per job at µs precision in
//! `index_build_saved_us`, with the ms counter derived once at
//! `Coordinator::finish` so sub-ms builds are not zeroed away — see
//! DESIGN.md §6). When a persistent artifact store is attached
//! (DESIGN.md §7) the store tier adds `store_hit` / `store_miss` /
//! `store_promote_ms` (µs-accumulated like the saved counter) /
//! `store_bytes_written`, plus `store_artifacts` and
//! `store_load_failures` gauges.
//!
//! The long-lived serving runtime (DESIGN.md §8) adds admission counters
//! `jobs_admitted` / `jobs_denied_budget` / `jobs_rejected_queue` /
//! `jobs_refunded`, the latency series `latency_{release,lp}` and
//! `queue_wait` (summarized as p50/p95/p99 in the JSON dump), and
//! per-tenant spend gauges `tenant_<id>_eps_spent` /
//! `tenant_<id>_eps_admitted` alongside the uniform `tenant_eps_cap`.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// In-process metrics registry.
#[derive(Clone, Default, Debug)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    timings: BTreeMap<String, Vec<f64>>, // seconds
}

impl Metrics {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `by` to a counter (created at 0).
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Add a duration to a counter in whole milliseconds (truncating —
    /// sub-millisecond contributions round to 0). For durations that
    /// accumulate as monotone totals (e.g. `index_build_saved_ms`) rather
    /// than per-event samples; use [`Metrics::observe`] for distributions.
    pub fn inc_ms(&mut self, name: &str, d: Duration) {
        self.inc(name, d.as_millis() as u64);
    }

    /// Set a gauge to an absolute value.
    pub fn set_gauge(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Record one duration sample in a timing series.
    pub fn observe(&mut self, name: &str, d: Duration) {
        self.timings.entry(name.to_string()).or_default().push(d.as_secs_f64());
    }

    /// Time a closure and record it under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let started = Instant::now();
        let out = f();
        self.observe(name, started.elapsed());
        out
    }

    /// Current counter value (0 if never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Current gauge value, if ever set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// (count, mean, p50, p95, p99, max) of a timing series, in seconds.
    pub fn timing_summary(&self, name: &str) -> Option<TimingSummary> {
        let xs = self.timings.get(name)?;
        if xs.is_empty() {
            return None;
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let pct = |p: f64| sorted[((sorted.len() - 1) as f64 * p) as usize];
        Some(TimingSummary {
            count: xs.len(),
            mean: xs.iter().sum::<f64>() / xs.len() as f64,
            p50: pct(0.5),
            p95: pct(0.95),
            p99: pct(0.99),
            max: *sorted.last().unwrap(),
        })
    }

    /// Fold another registry into this one (counters add, gauges overwrite,
    /// timings concatenate).
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, v) in &other.timings {
            self.timings.entry(k.clone()).or_default().extend(v.iter().cloned());
        }
    }

    /// Dump counters, gauges and timing summaries as JSON.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        let counters: BTreeMap<String, Json> = self
            .counters
            .iter()
            .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
            .collect();
        obj.insert("counters".into(), Json::Obj(counters));
        let gauges: BTreeMap<String, Json> =
            self.gauges.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect();
        obj.insert("gauges".into(), Json::Obj(gauges));
        let timings: BTreeMap<String, Json> = self
            .timings
            .keys()
            .filter_map(|k| {
                self.timing_summary(k).map(|s| {
                    let mut t = BTreeMap::new();
                    t.insert("count".to_string(), Json::Num(s.count as f64));
                    t.insert("mean_s".to_string(), Json::Num(s.mean));
                    t.insert("p50_s".to_string(), Json::Num(s.p50));
                    t.insert("p95_s".to_string(), Json::Num(s.p95));
                    t.insert("p99_s".to_string(), Json::Num(s.p99));
                    t.insert("max_s".to_string(), Json::Num(s.max));
                    (k.clone(), Json::Obj(t))
                })
            })
            .collect();
        obj.insert("timings".into(), Json::Obj(timings));
        Json::Obj(obj)
    }
}

/// Summary of one timing series, in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingSummary {
    /// Number of samples.
    pub count: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile (the serving runtime's tail-latency headline).
    pub p99: f64,
    /// Largest sample.
    pub max: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let mut m = Metrics::new();
        m.inc("jobs", 1);
        m.inc("jobs", 2);
        m.set_gauge("eps", 0.5);
        assert_eq!(m.counter("jobs"), 3);
        assert_eq!(m.counter("missing"), 0);
        assert_eq!(m.gauge("eps"), Some(0.5));
    }

    #[test]
    fn inc_ms_truncates_to_whole_milliseconds() {
        let mut m = Metrics::new();
        m.inc_ms("saved", Duration::from_micros(2_500));
        m.inc_ms("saved", Duration::from_millis(3));
        m.inc_ms("saved", Duration::from_micros(900)); // < 1ms -> 0
        assert_eq!(m.counter("saved"), 5);
    }

    #[test]
    fn timing_summary_percentiles() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.observe("op", Duration::from_millis(i));
        }
        let s = m.timing_summary("op").unwrap();
        assert_eq!(s.count, 100);
        assert!((s.p50 - 0.050).abs() < 0.002);
        assert!((s.p95 - 0.095).abs() < 0.002);
        assert!((s.p99 - 0.099).abs() < 0.002);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.max - 0.100).abs() < 1e-9);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Metrics::new();
        a.inc("x", 1);
        let mut b = Metrics::new();
        b.inc("x", 2);
        b.observe("t", Duration::from_secs(1));
        a.merge(&b);
        assert_eq!(a.counter("x"), 3);
        assert_eq!(a.timing_summary("t").unwrap().count, 1);
    }

    #[test]
    fn json_dump_parses_back() {
        let mut m = Metrics::new();
        m.inc("a", 5);
        m.observe("t", Duration::from_millis(10));
        let j = m.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(
            parsed.get("counters").unwrap().get("a").unwrap().as_f64(),
            Some(5.0)
        );
    }
}
