//! Exact Binomial(n, p) sampling via geometric skipping.
//!
//! Step 9 of Algorithm 2 needs `C ~ Bin(m - √m, 1 - exp(-exp(-B)))` where
//! the success probability is tiny (E[C] = Θ(√m)); enumerating n Bernoulli
//! trials would reintroduce the Θ(m) cost the paper removes. Geometric
//! skipping jumps directly between successes: the gap until the next
//! success is `⌊ln U / ln(1-p)⌋ + 1`, giving O(np) expected time and an
//! exact Binomial distribution (it is just a re-parametrization of the
//! i.i.d. Bernoulli sequence).
//!
//! For p > 1/2 we sample the complement so the expected cost is
//! O(n·min(p, 1-p)).

use crate::util::rng::Rng;

/// Draw an exact sample from Binomial(n, p).
/// Non-finite p is treated as 0 (defensive: a NaN success probability must
/// not turn the geometric skip into an unbounded loop).
pub fn binomial(rng: &mut Rng, n: u64, p: f64) -> u64 {
    debug_assert!(!p.is_nan(), "binomial called with NaN probability");
    if n == 0 || !(p > 0.0) {
        return 0;
    }
    if p >= 1.0 {
        return n;
    }
    if p > 0.5 {
        return n - binomial_skip(rng, n, 1.0 - p);
    }
    binomial_skip(rng, n, p)
}

fn binomial_skip(rng: &mut Rng, n: u64, p: f64) -> u64 {
    // log(1-p) via log1p for accuracy at small p.
    let log_q = (-p).ln_1p();
    debug_assert!(log_q < 0.0);
    let mut count = 0u64;
    let mut pos = 0u64; // trials consumed
    loop {
        let u = rng.f64_open();
        // gap ∈ {1, 2, ...}: number of trials up to and including the next success
        let gap_f = (u.ln() / log_q).floor() + 1.0;
        if gap_f > (n - pos) as f64 {
            return count;
        }
        pos += gap_f as u64;
        if pos > n {
            return count;
        }
        count += 1;
        if pos == n {
            return count;
        }
    }
}

/// Positions (0-based trial indices) of the successes of a Bernoulli(p) run
/// of length n — used to sample the tail set T of Algorithms 4–6 in one
/// pass (each element of [n]\S independently "wins" with probability p).
pub fn bernoulli_positions(rng: &mut Rng, n: u64, p: f64) -> Vec<u64> {
    let mut out = Vec::new();
    if n == 0 || p <= 0.0 {
        return out;
    }
    if p >= 1.0 {
        return (0..n).collect();
    }
    let log_q = (-p).ln_1p();
    let mut pos: u64 = 0;
    loop {
        let u = rng.f64_open();
        let gap_f = (u.ln() / log_q).floor() + 1.0;
        if gap_f > (n - pos) as f64 {
            return out;
        }
        pos += gap_f as u64;
        if pos > n {
            return out;
        }
        out.push(pos - 1);
        if pos == n {
            return out;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_cases() {
        let mut r = Rng::new(1);
        assert_eq!(binomial(&mut r, 0, 0.3), 0);
        assert_eq!(binomial(&mut r, 10, 0.0), 0);
        assert_eq!(binomial(&mut r, 10, 1.0), 10);
    }

    #[test]
    fn mean_and_variance_small_p() {
        let mut r = Rng::new(2);
        let (n, p) = (10_000u64, 0.001);
        let trials = 20_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..trials {
            let c = binomial(&mut r, n, p) as f64;
            sum += c;
            sq += c * c;
        }
        let mean = sum / trials as f64;
        let var = sq / trials as f64 - mean * mean;
        let want_mean = n as f64 * p; // 10
        let want_var = n as f64 * p * (1.0 - p);
        assert!((mean - want_mean).abs() < 0.15, "mean {mean}");
        assert!((var - want_var).abs() < 0.5, "var {var}");
    }

    #[test]
    fn mean_large_p_uses_complement() {
        let mut r = Rng::new(3);
        let (n, p) = (1_000u64, 0.9);
        let trials = 20_000;
        let mut sum = 0.0;
        for _ in 0..trials {
            sum += binomial(&mut r, n, p) as f64;
        }
        let mean = sum / trials as f64;
        assert!((mean - 900.0).abs() < 1.0, "mean {mean}");
    }

    #[test]
    fn positions_match_count_distribution() {
        let mut r = Rng::new(4);
        let (n, p) = (5_000u64, 0.002);
        let trials = 5_000;
        let mut sum = 0.0;
        for _ in 0..trials {
            let pos = bernoulli_positions(&mut r, n, p);
            assert!(pos.windows(2).all(|w| w[0] < w[1]), "sorted distinct");
            assert!(pos.iter().all(|&i| i < n));
            sum += pos.len() as f64;
        }
        let mean = sum / trials as f64;
        assert!((mean - 10.0).abs() < 0.4, "mean {mean}");
    }

    #[test]
    fn count_is_never_above_n() {
        let mut r = Rng::new(5);
        for _ in 0..1_000 {
            assert!(binomial(&mut r, 50, 0.3) <= 50);
        }
    }
}
