//! Truncated Gumbel sampling (Lemma C.3).
//!
//! `Gumbel(0,1) | G > B` has the same law as `-ln(-ln U)` with
//! `U ~ Uniform(exp(-exp(-B)), 1)` — the tail-sample trick that lets
//! Algorithms 4–6 give each element of [n]\S its conditional noise without
//! touching the other n - √n - C elements.

use crate::util::rng::Rng;

/// Sample `G ~ Gumbel(0,1)` conditioned on `G > b`.
pub fn truncated_gumbel(rng: &mut Rng, b: f64) -> f64 {
    let lo = (-(-b).exp()).exp(); // exp(-exp(-B))
    // U ∈ (lo, 1); guard against u == lo or u == 1 for the double log.
    let mut u = rng.uniform(lo, 1.0);
    while u <= lo || u >= 1.0 {
        u = rng.uniform(lo, 1.0);
    }
    -(-u.ln()).ln()
}

/// Probability that a Gumbel(0,1) exceeds `b`: `1 - exp(-exp(-b))`.
pub fn gumbel_tail_prob(b: f64) -> f64 {
    -(-(-b).exp()).exp_m1()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_exceed_threshold() {
        let mut r = Rng::new(1);
        for &b in &[-2.0, 0.0, 1.5, 5.0] {
            for _ in 0..2_000 {
                let g = truncated_gumbel(&mut r, b);
                assert!(g > b, "g={g} b={b}");
            }
        }
    }

    #[test]
    fn tail_prob_matches_definition() {
        for &b in &[-1.0, 0.0, 2.0] {
            let want = 1.0 - (-(-b as f64).exp()).exp();
            assert!((gumbel_tail_prob(b) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn truncated_matches_rejection_sampling() {
        // Compare the mean of the inverse-CDF sampler with naive rejection.
        let b = 0.5;
        let mut r = Rng::new(2);
        let n = 200_000;
        let mut s1 = 0.0;
        for _ in 0..n {
            s1 += truncated_gumbel(&mut r, b);
        }
        let mut s2 = 0.0;
        let mut count = 0;
        while count < n {
            let g = r.gumbel();
            if g > b {
                s2 += g;
                count += 1;
            }
        }
        let (m1, m2) = (s1 / n as f64, s2 / n as f64);
        assert!((m1 - m2).abs() < 0.01, "inverse {m1} vs rejection {m2}");
    }

    #[test]
    fn extreme_threshold_is_finite() {
        let mut r = Rng::new(3);
        // Very negative B: lower bound ≈ 0, behaves like unconditional Gumbel.
        let g = truncated_gumbel(&mut r, -50.0);
        assert!(g.is_finite());
        // Large B: tail prob tiny but sampler must still return > B.
        let g = truncated_gumbel(&mut r, 20.0);
        assert!(g > 20.0 && g.is_finite());
    }
}
