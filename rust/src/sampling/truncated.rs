//! Truncated Gumbel sampling (Lemma C.3).
//!
//! `Gumbel(0,1) | G > B` has the same law as `-ln(-ln U)` with
//! `U ~ Uniform(exp(-exp(-B)), 1)` — the tail-sample trick that lets
//! Algorithms 4–6 give each element of [n]\S its conditional noise without
//! touching the other n - √n - C elements.

use crate::util::rng::Rng;

/// Sample `G ~ Gumbel(0,1)` conditioned on `G > b`.
///
/// Sampled in *complementary* space: with `t = 1 − U` drawn uniformly from
/// `(0, p)` where `p = 1 − exp(−exp(−b))` is the tail mass, the draw is
/// `−ln(−ln(1 − t)) = −ln(−ln_1p(−t))`. The naive parameterization
/// `U ~ Uniform(exp(−exp(−b)), 1)` breaks down for `b ≳ 36.7`: the lower
/// bound rounds to exactly 1.0 in f64 and no `u` strictly inside the
/// interval exists, so the old rejection loop spun forever. `exp_m1` keeps
/// `p` exact down to ~1e−300 and `ln_1p` keeps the double log exact for
/// tiny `t`, so large-`b` draws stay finite and strictly above `b`.
pub fn truncated_gumbel(rng: &mut Rng, b: f64) -> f64 {
    // tail mass p = 1 - exp(-exp(-b)), computed without cancellation
    let p = gumbel_tail_prob(b);
    // t ∈ (0, p): f64_open is strictly inside (0, 1), so the product is
    // strictly below p; it can only hit 0 if p underflowed or the
    // multiply did (p is never negative or NaN for finite b).
    let t = p * rng.f64_open();
    if t <= 0.0 {
        // exp(-b) underflowed (b ≳ 745) or the product rounded to zero:
        // at that depth the conditional overshoot G − b is Exp(1) to
        // within less than one ulp, so sample the asymptotic tail.
        return b + rng.exponential(1.0);
    }
    // G = -ln(-ln(1 - t)), with 1 - t evaluated via ln_1p
    -(-(-t).ln_1p()).ln()
}

/// Probability that a Gumbel(0,1) exceeds `b`: `1 - exp(-exp(-b))`.
pub fn gumbel_tail_prob(b: f64) -> f64 {
    -(-(-b).exp()).exp_m1()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_exceed_threshold() {
        let mut r = Rng::new(1);
        for &b in &[-2.0, 0.0, 1.5, 5.0] {
            for _ in 0..2_000 {
                let g = truncated_gumbel(&mut r, b);
                assert!(g > b, "g={g} b={b}");
            }
        }
    }

    #[test]
    fn tail_prob_matches_definition() {
        for &b in &[-1.0, 0.0, 2.0] {
            let want = 1.0 - (-(-b as f64).exp()).exp();
            assert!((gumbel_tail_prob(b) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn truncated_matches_rejection_sampling() {
        // Compare the mean of the inverse-CDF sampler with naive rejection.
        let b = 0.5;
        let mut r = Rng::new(2);
        let n = 200_000;
        let mut s1 = 0.0;
        for _ in 0..n {
            s1 += truncated_gumbel(&mut r, b);
        }
        let mut s2 = 0.0;
        let mut count = 0;
        while count < n {
            let g = r.gumbel();
            if g > b {
                s2 += g;
                count += 1;
            }
        }
        let (m1, m2) = (s1 / n as f64, s2 / n as f64);
        assert!((m1 - m2).abs() < 0.01, "inverse {m1} vs rejection {m2}");
    }

    #[test]
    fn extreme_threshold_is_finite() {
        let mut r = Rng::new(3);
        // Very negative B: lower bound ≈ 0, behaves like unconditional Gumbel.
        let g = truncated_gumbel(&mut r, -50.0);
        assert!(g.is_finite());
        // Large B: tail prob tiny but sampler must still return > B.
        let g = truncated_gumbel(&mut r, 20.0);
        assert!(g > 20.0 && g.is_finite());
    }

    /// Regression: at b = 40 the old parameterization had
    /// `exp(-exp(-40)) == 1.0` exactly in f64, so `uniform(lo, 1.0)` could
    /// never produce a value strictly inside the interval and the sampler
    /// looped forever. The complementary-space sampler must return finite
    /// draws strictly above b, at every depth of the tail.
    #[test]
    fn deep_tail_draws_are_finite_and_exceed_threshold() {
        let mut r = Rng::new(4);
        for &b in &[36.7, 40.0, 100.0, 700.0] {
            for _ in 0..2_000 {
                let g = truncated_gumbel(&mut r, b);
                assert!(g.is_finite(), "b={b}: non-finite draw {g}");
                assert!(g > b, "b={b}: draw {g} not above threshold");
            }
        }
        // past the exp(-b) underflow point the asymptotic Exp(1) tail kicks
        // in; draws must still be finite and above b
        for _ in 0..2_000 {
            let g = truncated_gumbel(&mut r, 800.0);
            assert!(g.is_finite() && g > 800.0, "underflow fallback: {g}");
        }
    }

    /// The b = 40 draws follow the conditional law: G − b is Exp(1) to
    /// within ~e^{-40}, so the mean overshoot must be ≈ 1.
    #[test]
    fn deep_tail_overshoot_is_exponential() {
        let mut r = Rng::new(5);
        let b = 40.0;
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += truncated_gumbel(&mut r, b) - b;
        }
        let mean = sum / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean overshoot {mean}");
    }
}
