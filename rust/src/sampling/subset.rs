//! Uniform sampling of distinct indices, with and without an exclusion set.
//!
//! Step 10 of Algorithm 2 samples C distinct queries from `Q \ S` where
//! |S| = √m. Floyd's algorithm gives C distinct draws in O(C) expected
//! time; the exclusion is handled by sampling from a compacted range of
//! size `n - |S|` and mapping each draw past the sorted excluded indices
//! with a binary search (O(C log |S|) total, no O(n) scan).

use crate::util::rng::Rng;
use std::collections::HashSet;

/// Floyd's algorithm: `c` distinct values uniform over `[0, n)`.
pub fn sample_distinct(rng: &mut Rng, n: usize, c: usize) -> Vec<usize> {
    assert!(c <= n, "cannot draw {c} distinct from {n}");
    let mut chosen: HashSet<usize> = HashSet::with_capacity(c * 2);
    let mut out = Vec::with_capacity(c);
    for j in (n - c)..n {
        let t = rng.usize_below(j + 1);
        let v = if chosen.contains(&t) { j } else { t };
        chosen.insert(v);
        out.push(v);
    }
    out
}

/// Map a rank in the compacted range `[0, n - excluded.len())` to the
/// corresponding index of `[0, n)` that skips `excluded` (must be sorted,
/// distinct). Binary search over the invariant
/// `index = rank + #{e ∈ excluded : e ≤ index}`.
pub fn rank_to_index(rank: usize, excluded_sorted: &[usize]) -> usize {
    let mut lo = 0usize;
    let mut hi = excluded_sorted.len();
    // find the number of excluded elements that fall at or below the result
    while lo < hi {
        let mid = (lo + hi) / 2;
        if excluded_sorted[mid] <= rank + mid {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    rank + lo
}

/// `c` distinct values uniform over `[0, n) \ excluded`.
/// `excluded` must be sorted and duplicate-free.
pub fn sample_distinct_excluding(
    rng: &mut Rng,
    n: usize,
    excluded_sorted: &[usize],
    c: usize,
) -> Vec<usize> {
    let avail = n - excluded_sorted.len();
    let ranks = sample_distinct(rng, avail, c);
    ranks
        .into_iter()
        .map(|r| rank_to_index(r, excluded_sorted))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_and_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..100 {
            let v = sample_distinct(&mut r, 50, 20);
            let set: HashSet<_> = v.iter().cloned().collect();
            assert_eq!(set.len(), 20);
            assert!(v.iter().all(|&x| x < 50));
        }
    }

    #[test]
    fn full_draw_is_permutation_set() {
        let mut r = Rng::new(2);
        let v = sample_distinct(&mut r, 10, 10);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn rank_mapping_skips_excluded() {
        let excluded = vec![2, 5, 6];
        // available indices of [0,10): 0,1,3,4,7,8,9
        let want = [0usize, 1, 3, 4, 7, 8, 9];
        for (rank, &idx) in want.iter().enumerate() {
            assert_eq!(rank_to_index(rank, &excluded), idx, "rank {rank}");
        }
    }

    #[test]
    fn rank_mapping_empty_exclusion_is_identity() {
        for rank in 0..20 {
            assert_eq!(rank_to_index(rank, &[]), rank);
        }
    }

    #[test]
    fn excluding_never_returns_excluded() {
        let mut r = Rng::new(3);
        let excluded = vec![0, 3, 4, 9, 17, 18, 19];
        for _ in 0..200 {
            let v = sample_distinct_excluding(&mut r, 20, &excluded, 5);
            let set: HashSet<_> = v.iter().cloned().collect();
            assert_eq!(set.len(), 5);
            for x in &v {
                assert!(!excluded.contains(x), "returned excluded {x}");
                assert!(*x < 20);
            }
        }
    }

    #[test]
    fn excluding_is_uniform_over_complement() {
        let mut r = Rng::new(4);
        let excluded = vec![1, 2];
        let mut counts = [0usize; 8];
        let trials = 60_000;
        for _ in 0..trials {
            for x in sample_distinct_excluding(&mut r, 8, &excluded, 1) {
                counts[x] += 1;
            }
        }
        assert_eq!(counts[1] + counts[2], 0);
        for (i, &c) in counts.iter().enumerate() {
            if i == 1 || i == 2 {
                continue;
            }
            let expect = trials / 6;
            assert!(
                (c as i64 - expect as i64).abs() < (expect / 10) as i64,
                "bucket {i}: {c}"
            );
        }
    }
}
