//! Exact samplers backing the lazy exponential mechanism (Algorithms 4–6).
//!
//! All of these run on the request path in the coordinator; none of them
//! live in the dispatched kernel layer (DESIGN.md §10), which stays a
//! deterministic function of its inputs.

pub mod binomial;
pub mod subset;
pub mod truncated;

pub use binomial::binomial;
pub use subset::{sample_distinct, sample_distinct_excluding};
pub use truncated::truncated_gumbel;
