//! Exact samplers backing the lazy exponential mechanism (Algorithms 4–6).
//!
//! All of these run on the request path in the Rust coordinator; none of
//! them exist in the AOT artifacts (determinism of the XLA side).

pub mod binomial;
pub mod subset;
pub mod truncated;

pub use binomial::binomial;
pub use subset::{sample_distinct, sample_distinct_excluding};
pub use truncated::truncated_gumbel;
