//! Scalar-private, low-sensitivity LP solver (Algorithm 3).
//!
//! MWU over the primal simplex; each round the *worst constraint* is
//! selected privately with score `Q_t(i) = A_i x̃ − b_i` — an inner product
//! `⟨A_i ∘ b_i, x̃ ∘ −1⟩` of static vectors against the evolving iterate,
//! so LazyEM applies and the per-round cost drops from Θ(d·m) to Θ(d·√m)
//! expected (Theorem 4.1).

use crate::dp::accountant::per_step_epsilon;
use crate::lazy::{LazyEm, ScoreTransform, ShardedLazyEm};
use crate::mips::{build_index, IndexKind, MipsIndex, VectorSet};
use crate::mwem::engine::{MwemEngine, SelectionOracle};
use crate::workloads::{LpConstraints, LpInstance};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How the worst constraint is selected each round: the exhaustive EM
/// baseline, LazyEM over one k-MIPS index, or LazyEM over S per-shard
/// indices (exact by max-stability, parallel index build — DESIGN.md §5).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SelectionMode {
    /// Score all m constraints and run the classic exponential mechanism.
    Exhaustive,
    /// Θ(√m)-expected-time LazyEM over one index of the given kind.
    Lazy(IndexKind),
    /// LazyEM over the given number of shards, each with its own index.
    LazySharded(IndexKind, usize),
}

impl std::fmt::Display for SelectionMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SelectionMode::Exhaustive => write!(f, "exhaustive"),
            SelectionMode::Lazy(k) => write!(f, "lazy-{k}"),
            SelectionMode::LazySharded(k, s) => write!(f, "lazy-{k}-x{s}"),
        }
    }
}

/// Configuration for the Algorithm 3 scalar-private solver.
#[derive(Clone, Debug)]
pub struct ScalarLpConfig {
    /// Number of MWU rounds T (paper: 9ρ²·log d / α²).
    pub t: usize,
    /// Total privacy budget ε.
    pub eps: f64,
    /// Total privacy budget δ.
    pub delta: f64,
    /// b-vector sensitivity Δ∞ between neighboring databases.
    pub delta_inf: f64,
    /// Constraint-selection mechanism.
    pub mode: SelectionMode,
    /// Mechanism seed.
    pub seed: u64,
    /// Record violation stats every `log_every` rounds (0 = never).
    pub log_every: usize,
}

impl ScalarLpConfig {
    /// Paper parameterization given a width estimate and target accuracy.
    pub fn paper(rho: f64, d: usize, alpha: f64, eps: f64, delta: f64, seed: u64) -> Self {
        let t = ((9.0 * rho * rho * (d as f64).ln() / (alpha * alpha)).ceil() as usize).max(1);
        ScalarLpConfig {
            t,
            eps,
            delta,
            delta_inf: 0.1,
            mode: SelectionMode::Exhaustive,
            seed,
            log_every: 0,
        }
    }

    /// Per-round ε₀ = ε / √(8T·log(1/δ)) (Algorithm 3 line 6).
    pub fn eps0(&self) -> f64 {
        per_step_epsilon(self.eps, self.delta, self.t as u64, 8.0)
    }
}

/// Per-logged-round statistics of the scalar-private solver.
#[derive(Clone, Debug)]
pub struct LpIterStat {
    /// Round number (1-based).
    pub iter: usize,
    /// Fraction of constraints violated by the running average.
    pub violation_fraction: f64,
    /// max_i (A_i x̄ − b_i) of the running average.
    pub max_violation: f64,
    /// Score evaluations charged to this round's selection.
    pub selection_work: usize,
}

/// Output of [`run_scalar`].
#[derive(Debug)]
pub struct ScalarLpResult {
    /// Averaged iterate x̄ = (1/T) Σ x̃⁽ᵗ⁾ (Algorithm 3's output).
    pub x: Vec<f32>,
    /// Per-logged-round statistics (empty when `log_every` = 0).
    pub stats: Vec<LpIterStat>,
    /// Solve wall-clock (excluding index build).
    pub total_time: Duration,
    /// Wall-clock spent building the k-MIPS index / shards.
    pub index_build_time: Duration,
    /// Mean selection time per round.
    pub avg_select_time: Duration,
    /// Mean selection work (score evaluations) per round.
    pub avg_select_work: f64,
    /// Per-round ε₀ actually used.
    pub eps0: f64,
}

/// Concatenate rows `A_i ∘ b_i` — the static MIPS dataset of Theorem 4.1.
pub fn concat_constraints(lp: &LpInstance) -> VectorSet {
    let (m, d) = (lp.m(), lp.d());
    let mut data = vec![0f32; m * (d + 1)];
    for i in 0..m {
        data[i * (d + 1)..i * (d + 1) + d].copy_from_slice(lp.a.row(i));
        data[i * (d + 1) + d] = lp.b[i];
    }
    VectorSet::new(data, m, d + 1)
}

/// Run Algorithm 3 on a feasibility LP over the simplex. Since the engine
/// refactor (DESIGN.md §14) this is a shell: build the static MIPS dataset
/// and the configured [`SelectionOracle`], then drive
/// [`LpConstraints::primal`] through the shared [`MwemEngine`].
pub fn run_scalar(cfg: &ScalarLpConfig, lp: &LpInstance) -> ScalarLpResult {
    let d = lp.d();
    let rho = lp.width().max(1e-12);
    let eps0 = cfg.eps0();
    let eta = ((d as f64).ln() / cfg.t as f64).sqrt();

    // Static MIPS dataset {A_i ∘ b_i}; query x̃ ∘ −1 gives A_i x̃ − b_i.
    let build_started = Instant::now();
    let cat = concat_constraints(lp);
    let index: Option<Arc<dyn MipsIndex>> = match cfg.mode {
        SelectionMode::Lazy(kind) => Some(build_index(kind, cat.clone(), cfg.seed ^ 0xA11CE)),
        _ => None,
    };
    let oracle = match cfg.mode {
        SelectionMode::Exhaustive => SelectionOracle::Exhaustive,
        SelectionMode::Lazy(_) => SelectionOracle::Lazy(LazyEm::new(
            index.as_deref().expect("index built for lazy mode"),
            &cat,
            ScoreTransform::Signed,
        )),
        SelectionMode::LazySharded(kind, shards) => SelectionOracle::Sharded(
            ShardedLazyEm::build(kind, &cat, shards, ScoreTransform::Signed, cfg.seed ^ 0xA11CE),
        ),
    };
    let index_build_time = build_started.elapsed();

    let mut class = LpConstraints::primal(lp, &cat, rho, eta, cfg.delta_inf, cfg.log_every);
    let report = MwemEngine::new(oracle, cfg.t, eps0, cfg.seed).run(&mut class);
    class.into_scalar_result(&report, index_build_time)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workloads::random_feasibility_lp;

    fn solve(mode: SelectionMode, seed: u64) -> (LpInstance, ScalarLpResult) {
        let mut rng = Rng::new(seed);
        let lp = random_feasibility_lp(&mut rng, 400, 12, 0.6);
        let cfg = ScalarLpConfig {
            t: 400,
            eps: 2.0,
            delta: 1e-3,
            delta_inf: 0.1,
            mode,
            seed: seed ^ 99,
            log_every: 0,
        };
        let res = run_scalar(&cfg, &lp);
        (lp, res)
    }

    #[test]
    fn exhaustive_reduces_violations() {
        let (lp, res) = solve(SelectionMode::Exhaustive, 1);
        let x0 = vec![1.0 / 12.0f32; 12];
        let before = lp.max_violation(&x0);
        let after = lp.max_violation(&res.x);
        assert!(after < before, "before {before} after {after}");
        assert!((res.x.iter().sum::<f32>() - 1.0).abs() < 1e-4);
    }

    #[test]
    fn lazy_flat_matches_exhaustive_quality() {
        let (lp, ex) = solve(SelectionMode::Exhaustive, 2);
        let (_, lz) = {
            let mut rng = Rng::new(2);
            let lp2 = random_feasibility_lp(&mut rng, 400, 12, 0.6);
            let cfg = ScalarLpConfig {
                t: 400,
                eps: 2.0,
                delta: 1e-3,
                delta_inf: 0.1,
                mode: SelectionMode::Lazy(IndexKind::Flat),
                seed: 2 ^ 99,
                log_every: 0,
            };
            let res = run_scalar(&cfg, &lp2);
            (lp2, res)
        };
        let v_ex = lp.max_violation(&ex.x);
        let v_lz = lp.max_violation(&lz.x);
        assert!(
            (v_ex - v_lz).abs() < 0.5,
            "exhaustive {v_ex} lazy {v_lz} (should be comparable)"
        );
    }

    #[test]
    fn sharded_matches_exhaustive_quality() {
        let (lp, ex) = solve(SelectionMode::Exhaustive, 5);
        let (_, sh) = solve(SelectionMode::LazySharded(IndexKind::Flat, 4), 5);
        let v_ex = lp.max_violation(&ex.x);
        let v_sh = lp.max_violation(&sh.x);
        assert!(
            (v_ex - v_sh).abs() < 0.5,
            "exhaustive {v_ex} sharded {v_sh} (should be comparable)"
        );
        assert!(sh.avg_select_work < 400.0, "work {}", sh.avg_select_work);
    }

    #[test]
    fn lazy_work_is_sublinear_in_m() {
        let mut rng = Rng::new(3);
        let lp = random_feasibility_lp(&mut rng, 2_500, 10, 0.6);
        let cfg = ScalarLpConfig {
            t: 50,
            eps: 1.0,
            delta: 1e-3,
            delta_inf: 0.1,
            mode: SelectionMode::Lazy(IndexKind::Flat),
            seed: 4,
            log_every: 0,
        };
        let res = run_scalar(&cfg, &lp);
        assert!(res.avg_select_work < 8.0 * 50.0, "work {}", res.avg_select_work);
    }

    #[test]
    fn paper_config_t_formula() {
        let cfg = ScalarLpConfig::paper(1.0, 20, 0.5, 1.0, 1e-3, 5);
        // T = 9·1·ln(20)/0.25 ≈ 108
        assert!((100..=120).contains(&cfg.t), "T = {}", cfg.t);
        assert!(cfg.eps0() > 0.0);
    }
}
