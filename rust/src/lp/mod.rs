//! Private linear programming (§4): the scalar-private solver (Algorithm 3)
//! and the constraint-private dense-MWU solver (§4.2), both in classic
//! (exhaustive EM) and fast (LazyEM) variants.

pub mod bregman;
pub mod dense;
pub mod scalar;

pub use bregman::bregman_project;
pub use dense::{run_dense, DenseLpConfig, DenseLpResult};
pub use scalar::{run_scalar, ScalarLpConfig, ScalarLpResult, SelectionMode};
