//! Bregman (KL) projection onto the set of 1/s-dense distributions
//! (Definition A.2): Γ_s(A)_a = (1/s)·min{1, c·A_a} with c chosen so that
//! Σ_a min{1, c·A_a} = s.
//!
//! Solved exactly by water-filling over the sorted weights: if the j
//! largest entries are capped at 1, then c = (s − j)/Σ_{rest} A, valid when
//! it caps exactly those j entries. O(n log n).

/// Project a non-negative measure onto the 1/s-dense simplex.
/// Returns the projected distribution (entries ≤ 1/s, summing to 1).
///
/// Panics if fewer than ⌈s⌉ entries are positive (the projection does not
/// exist); dense MWU keeps all weights strictly positive so this never
/// triggers on the solver path.
pub fn bregman_project(weights: &[f32], s: usize) -> Vec<f32> {
    let n = weights.len();
    assert!(s >= 1 && s <= n, "density parameter s={s} outside [1, {n}]");
    let positive = weights.iter().filter(|&&w| w > 0.0).count();
    assert!(positive >= s, "projection needs ≥ s positive entries ({positive} < {s})");

    // sort descending
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_unstable_by(|&a, &b| weights[b].total_cmp(&weights[a]));

    // suffix sums of the sorted weights
    let sorted: Vec<f64> = order.iter().map(|&i| weights[i] as f64).collect();
    let mut suffix = vec![0.0f64; n + 1];
    for i in (0..n).rev() {
        suffix[i] = suffix[i + 1] + sorted[i];
    }

    // find j = number of capped entries
    let sf = s as f64;
    let mut c = 0.0f64;
    let mut j_cap = 0usize;
    for j in 0..s {
        let denom = suffix[j];
        if denom <= 0.0 {
            break;
        }
        let cand = (sf - j as f64) / denom;
        // valid iff cand·A_(j) ≥ 1 for capped (or j = 0) and cand·A_(j+1) < 1… i.e.
        // the j-th largest is capped, the (j+1)-th is not.
        let caps_prev = j == 0 || cand * sorted[j - 1] >= 1.0 - 1e-12;
        let spares_next = cand * sorted[j] < 1.0 + 1e-12;
        if caps_prev && spares_next {
            c = cand;
            j_cap = j;
            break;
        }
        // otherwise continue; if we exhaust, cap the top s entries
        c = cand;
        j_cap = j + 1;
    }

    let mut out = vec![0f32; n];
    let inv_s = 1.0 / sf;
    let capped = (inv_s) as f32;
    // Clip-and-rescale the uncapped tail in one vectorized pass over the
    // already-sorted f64 copy (sorted[rank] == weights[order[rank]] as f64
    // exactly), then scatter back through the permutation.
    let mut tail = sorted;
    let tail = &mut tail[j_cap..];
    crate::runtime::kernels::clip_scale(tail, c, inv_s);
    for (rank, &i) in order.iter().enumerate() {
        out[i] = if rank < j_cap { capped } else { tail[rank - j_cap] as f32 };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_dense(y: &[f32], s: usize) {
        let sum: f64 = y.iter().map(|&x| x as f64).sum();
        assert!((sum - 1.0).abs() < 1e-4, "sum {sum}");
        let cap = 1.0 / s as f32 + 1e-6;
        for (i, &v) in y.iter().enumerate() {
            assert!(v <= cap, "entry {i} = {v} exceeds 1/s");
            assert!(v >= 0.0);
        }
    }

    #[test]
    fn uniform_input_stays_uniform() {
        let w = vec![1.0f32; 10];
        let y = bregman_project(&w, 5);
        check_dense(&y, 5);
        for &v in &y {
            assert!((v - 0.1).abs() < 1e-6);
        }
    }

    #[test]
    fn peaked_input_gets_clipped() {
        let mut w = vec![1.0f32; 10];
        w[0] = 1000.0;
        let y = bregman_project(&w, 4);
        check_dense(&y, 4);
        assert!((y[0] - 0.25).abs() < 1e-6, "heavy entry clipped to 1/s");
        // remaining mass spread over the rest proportionally
        let rest: f64 = y[1..].iter().map(|&x| x as f64).sum();
        assert!((rest - 0.75).abs() < 1e-4);
    }

    #[test]
    fn s_equals_n_gives_uniform() {
        let w = vec![5.0f32, 1.0, 0.1, 3.0];
        let y = bregman_project(&w, 4);
        check_dense(&y, 4);
        for &v in &y {
            assert!((v - 0.25).abs() < 1e-6);
        }
    }

    #[test]
    fn s_equals_one_is_unconstrained_normalize() {
        let w = vec![2.0f32, 6.0, 2.0];
        let y = bregman_project(&w, 1);
        check_dense(&y, 1);
        assert!((y[1] - 0.6).abs() < 1e-6);
    }

    #[test]
    fn neighboring_measures_project_close() {
        // Lemma A.3: measures identical except one extra element project to
        // within 1/s in L1.
        let mut w1 = vec![0f32; 101];
        let mut rng = crate::util::rng::Rng::new(5);
        for v in w1.iter_mut() {
            *v = rng.uniform(0.1, 2.0) as f32;
        }
        let mut w2 = w1.clone();
        w2[100] = 0.0; // w2 lacks the extra row
        // give w2 at least s positive entries still
        let s = 20;
        let y1 = bregman_project(&w1, s);
        let y2 = bregman_project(&w2[..100].to_vec().as_slice(), s);
        let l1: f64 = (0..100)
            .map(|i| ((y1[i] - y2[i]) as f64).abs())
            .sum::<f64>()
            + y1[100] as f64;
        assert!(l1 <= 2.0 / s as f64 + 1e-3, "L1 distance {l1}");
    }

    /// Property sweep: random weights, random s — output always 1/s-dense.
    #[test]
    fn property_random_inputs_dense() {
        let mut rng = crate::util::rng::Rng::new(7);
        for trial in 0..200 {
            let n = 5 + rng.usize_below(50);
            let s = 1 + rng.usize_below(n);
            let w: Vec<f32> =
                (0..n).map(|_| rng.uniform(0.001, 10.0) as f32).collect();
            let y = bregman_project(&w, s);
            check_dense(&y, s);
            // order preservation: larger weight ⇒ no smaller projection
            for i in 0..n {
                for j in 0..n {
                    if w[i] > w[j] {
                        assert!(
                            y[i] >= y[j] - 1e-6,
                            "trial {trial}: order violated"
                        );
                    }
                }
            }
        }
    }
}
