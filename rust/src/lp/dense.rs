//! Constraint-private LPs via Dense MWU (§4.2).
//!
//! Dual-space solver for packing/covering LPs: MWU maintains a measure over
//! the m constraints, projected each round onto the 1/s-dense simplex
//! (Bregman projection — the privacy lever of Lemma A.3); the dual oracle
//! picks the vertex v⁽ʲ⁾ = (OPT/c_j)·e_j minimizing expected violation,
//! privately, via the exponential mechanism with scores
//! Q(j, y) = −(OPT/c_j)·yᵀA_{:,j} = ⟨y, N_j⟩ — inner products of the m-dim
//! distribution y against d static vectors N_j, so LazyEM applies and the
//! per-round cost drops from O(m·d) to O(m·√d) (Theorem 4.4).

use crate::dp::accountant::per_step_epsilon;
use crate::lazy::{LazyEm, ScoreTransform, ShardedLazyEm};
#[cfg(test)]
use crate::mips::IndexKind;
use crate::mips::{build_index, MipsIndex, VectorSet};
use crate::mwem::engine::{MwemEngine, SelectionOracle};
use crate::runtime::kernels::dot;
use crate::workloads::{LpConstraints, PackingLp};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::scalar::SelectionMode;

/// Configuration for the §4.2 dense-MWU constraint-private solver.
#[derive(Clone, Debug)]
pub struct DenseLpConfig {
    /// Number of MWU rounds T.
    pub t: usize,
    /// Total privacy budget ε.
    pub eps: f64,
    /// Total privacy budget δ.
    pub delta: f64,
    /// Density parameter s: outputs may violate up to s−1 constraints.
    pub s: usize,
    /// Dual-oracle selection mechanism.
    pub mode: SelectionMode,
    /// Mechanism seed.
    pub seed: u64,
}

impl DenseLpConfig {
    /// Per-round ε₀ from the advanced-composition budget split.
    pub fn eps0(&self) -> f64 {
        per_step_epsilon(self.eps, self.delta, self.t as u64, 2.0)
    }
}

/// Output of [`run_dense`].
#[derive(Debug)]
pub struct DenseLpResult {
    /// Averaged primal solution x̄.
    pub x: Vec<f32>,
    /// Solve wall-clock (excluding index build).
    pub total_time: Duration,
    /// Wall-clock spent building the dual-oracle index / shards.
    pub index_build_time: Duration,
    /// Mean selection work (score evaluations) per round.
    pub avg_select_work: f64,
    /// Per-round ε₀ actually used.
    pub eps0: f64,
}

/// Static dual-oracle vectors N_j = −(OPT/c_j)·(Aᵀ)_j, each of dimension m.
pub fn oracle_vectors(lp: &PackingLp) -> VectorSet {
    let (m, d) = (lp.m(), lp.d());
    let mut data = vec![0f32; d * m];
    for j in 0..d {
        let scale = -(lp.opt as f32) / lp.c[j];
        for i in 0..m {
            data[j * m + i] = scale * lp.a.row(i)[j];
        }
    }
    VectorSet::new(data, d, m)
}

/// Run the dense-MWU constraint-private solver on a packing LP. Since the
/// engine refactor (DESIGN.md §14) this is a shell: derive the width /
/// step-size / sensitivity constants, build the dual-oracle
/// [`SelectionOracle`], then drive [`LpConstraints::dual`] through the
/// shared [`MwemEngine`].
pub fn run_dense(cfg: &DenseLpConfig, lp: &PackingLp) -> DenseLpResult {
    let (m, d) = (lp.m(), lp.d());
    let eps0 = cfg.eps0();
    let s = cfg.s.clamp(1, m);

    // width ρ ≥ sup ‖Ax − b‖∞ over the vertices (OPT/c_j)·e_j
    let mut rho = 1e-9f64;
    for j in 0..d {
        let scale = lp.opt / lp.c[j] as f64;
        for i in 0..m {
            let v = scale * lp.a.row(i)[j] as f64 - lp.b[i] as f64;
            rho = rho.max(v.abs());
        }
    }
    let eta = (((m as f64).ln() / cfg.t as f64).sqrt()).min(0.5);

    // sensitivity of the oracle scores (§G): 3·OPT/(c_min·s)
    let c_min = lp.c.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    let sens = 3.0 * lp.opt / (c_min * s as f64);

    let build_started = Instant::now();
    let nvecs = oracle_vectors(lp);
    let index: Option<Arc<dyn MipsIndex>> = match cfg.mode {
        SelectionMode::Lazy(kind) => Some(build_index(kind, nvecs.clone(), cfg.seed ^ 0xDEA1)),
        _ => None,
    };
    let oracle = match cfg.mode {
        SelectionMode::Exhaustive => SelectionOracle::Exhaustive,
        SelectionMode::Lazy(_) => SelectionOracle::Lazy(LazyEm::new(
            index.as_deref().expect("index built for lazy mode"),
            &nvecs,
            ScoreTransform::Signed,
        )),
        SelectionMode::LazySharded(kind, shards) => SelectionOracle::Sharded(
            ShardedLazyEm::build(kind, &nvecs, shards, ScoreTransform::Signed, cfg.seed ^ 0xDEA1),
        ),
    };
    let index_build_time = build_started.elapsed();

    let mut class = LpConstraints::dual(lp, &nvecs, rho, eta, sens, s);
    let report = MwemEngine::new(oracle, cfg.t, eps0, cfg.seed).run(&mut class);
    class.into_dense_result(&report, index_build_time)
}

/// Count constraints violated by more than alpha (Theorem 4.4's metric).
pub fn violated_constraints(lp: &PackingLp, x: &[f32], alpha: f64) -> usize {
    (0..lp.m())
        .filter(|&i| dot(lp.a.row(i), x) as f64 > lp.b[i] as f64 + alpha)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workloads::random_packing_lp;

    #[test]
    fn oracle_vectors_encode_scores() {
        let mut rng = Rng::new(1);
        let lp = random_packing_lp(&mut rng, 50, 6);
        let n = oracle_vectors(&lp);
        let y = vec![1.0 / 50.0f32; 50];
        for j in 0..6 {
            let want: f64 = -(lp.opt / lp.c[j] as f64)
                * (0..50)
                    .map(|i| y[i] as f64 * lp.a.row(i)[j] as f64)
                    .sum::<f64>();
            let got = dot(n.row(j), &y) as f64;
            assert!((got - want).abs() < 1e-4, "j={j}: {got} vs {want}");
        }
    }

    #[test]
    fn solver_violates_few_constraints() {
        let mut rng = Rng::new(2);
        let lp = random_packing_lp(&mut rng, 300, 10);
        let cfg = DenseLpConfig {
            t: 300,
            eps: 5.0,
            delta: 1e-3,
            s: 30,
            mode: SelectionMode::Exhaustive,
            seed: 3,
        };
        let res = run_dense(&cfg, &lp);
        // objective value of the averaged vertex solution is OPT by construction
        let cx: f64 =
            res.x.iter().zip(&lp.c).map(|(&x, &c)| (x * c) as f64).sum();
        assert!((cx - lp.opt).abs() < 0.05 * lp.opt, "c·x̄ = {cx} vs OPT {}", lp.opt);
        // allow generous alpha: violated count should be well under m
        let viol = violated_constraints(&lp, &res.x, 0.5);
        assert!(viol < 150, "violations {viol}");
    }

    #[test]
    fn lazy_mode_matches_exhaustive_roughly() {
        let mut rng = Rng::new(4);
        let lp = random_packing_lp(&mut rng, 200, 12);
        let mk = |mode| DenseLpConfig {
            t: 200,
            eps: 5.0,
            delta: 1e-3,
            s: 20,
            mode,
            seed: 5,
        };
        let ex = run_dense(&mk(SelectionMode::Exhaustive), &lp);
        let lz = run_dense(&mk(SelectionMode::Lazy(IndexKind::Flat)), &lp);
        let v_ex = violated_constraints(&lp, &ex.x, 0.5);
        let v_lz = violated_constraints(&lp, &lz.x, 0.5);
        assert!(
            (v_ex as i64 - v_lz as i64).abs() < 60,
            "exhaustive {v_ex} lazy {v_lz}"
        );
    }
}
