//! Constraint-private LPs via Dense MWU (§4.2).
//!
//! Dual-space solver for packing/covering LPs: MWU maintains a measure over
//! the m constraints, projected each round onto the 1/s-dense simplex
//! (Bregman projection — the privacy lever of Lemma A.3); the dual oracle
//! picks the vertex v⁽ʲ⁾ = (OPT/c_j)·e_j minimizing expected violation,
//! privately, via the exponential mechanism with scores
//! Q(j, y) = −(OPT/c_j)·yᵀA_{:,j} = ⟨y, N_j⟩ — inner products of the m-dim
//! distribution y against d static vectors N_j, so LazyEM applies and the
//! per-round cost drops from O(m·d) to O(m·√d) (Theorem 4.4).

use crate::dp::accountant::per_step_epsilon;
use crate::dp::mechanisms::exponential_mechanism;
use crate::lazy::{LazyEm, ScoreTransform, ShardedLazyEm};
use crate::mips::{build_index, MipsIndex, VectorSet};
#[cfg(test)]
use crate::mips::IndexKind;
use crate::runtime::kernels::dot;
use crate::util::rng::Rng;
use crate::workloads::PackingLp;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::bregman::bregman_project;
use super::scalar::SelectionMode;

/// Configuration for the §4.2 dense-MWU constraint-private solver.
#[derive(Clone, Debug)]
pub struct DenseLpConfig {
    /// Number of MWU rounds T.
    pub t: usize,
    /// Total privacy budget ε.
    pub eps: f64,
    /// Total privacy budget δ.
    pub delta: f64,
    /// Density parameter s: outputs may violate up to s−1 constraints.
    pub s: usize,
    /// Dual-oracle selection mechanism.
    pub mode: SelectionMode,
    /// Mechanism seed.
    pub seed: u64,
}

impl DenseLpConfig {
    /// Per-round ε₀ from the advanced-composition budget split.
    pub fn eps0(&self) -> f64 {
        per_step_epsilon(self.eps, self.delta, self.t as u64, 2.0)
    }
}

/// Output of [`run_dense`].
#[derive(Debug)]
pub struct DenseLpResult {
    /// Averaged primal solution x̄.
    pub x: Vec<f32>,
    /// Solve wall-clock (excluding index build).
    pub total_time: Duration,
    /// Wall-clock spent building the dual-oracle index / shards.
    pub index_build_time: Duration,
    /// Mean selection work (score evaluations) per round.
    pub avg_select_work: f64,
    /// Per-round ε₀ actually used.
    pub eps0: f64,
}

/// Static dual-oracle vectors N_j = −(OPT/c_j)·(Aᵀ)_j, each of dimension m.
pub fn oracle_vectors(lp: &PackingLp) -> VectorSet {
    let (m, d) = (lp.m(), lp.d());
    let mut data = vec![0f32; d * m];
    for j in 0..d {
        let scale = -(lp.opt as f32) / lp.c[j];
        for i in 0..m {
            data[j * m + i] = scale * lp.a.row(i)[j];
        }
    }
    VectorSet::new(data, d, m)
}

/// Run the dense-MWU constraint-private solver on a packing LP.
pub fn run_dense(cfg: &DenseLpConfig, lp: &PackingLp) -> DenseLpResult {
    let mut rng = Rng::new(cfg.seed);
    let (m, d) = (lp.m(), lp.d());
    let eps0 = cfg.eps0();
    let s = cfg.s.clamp(1, m);

    // width ρ ≥ sup ‖Ax − b‖∞ over the vertices (OPT/c_j)·e_j
    let mut rho = 1e-9f64;
    for j in 0..d {
        let scale = lp.opt / lp.c[j] as f64;
        for i in 0..m {
            let v = scale * lp.a.row(i)[j] as f64 - lp.b[i] as f64;
            rho = rho.max(v.abs());
        }
    }
    let eta = (((m as f64).ln() / cfg.t as f64).sqrt()).min(0.5);

    // sensitivity of the oracle scores (§G): 3·OPT/(c_min·s)
    let c_min = lp.c.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    let sens = 3.0 * lp.opt / (c_min * s as f64);

    let build_started = Instant::now();
    let nvecs = oracle_vectors(lp);
    let mut index: Option<Arc<dyn MipsIndex>> = None;
    let mut sharded: Option<ShardedLazyEm> = None;
    match cfg.mode {
        SelectionMode::Exhaustive => {}
        SelectionMode::Lazy(kind) => {
            index = Some(build_index(kind, nvecs.clone(), cfg.seed ^ 0xDEA1));
        }
        SelectionMode::LazySharded(kind, shards) => {
            sharded = Some(ShardedLazyEm::build(
                kind,
                &nvecs,
                shards,
                ScoreTransform::Signed,
                cfg.seed ^ 0xDEA1,
            ));
        }
    }
    let index_build_time = build_started.elapsed();

    let mut w = vec![1.0f32; m];
    let mut x_sum = vec![0.0f64; d];
    let started = Instant::now();
    let mut work_total = 0usize;

    for _t in 0..cfg.t {
        // project onto the 1/s-dense simplex (constraint privacy, Lemma A.3)
        let y = bregman_project(&w, s);

        // dual oracle: pick vertex j maximizing ⟨y, N_j⟩ privately
        let (j_t, work) = if let Some(em) = &sharded {
            let smp = em.select(&mut rng, &y, eps0, sens);
            (smp.index, smp.work)
        } else if let Some(idx) = &index {
            let em = LazyEm::new(idx.as_ref(), &nvecs, ScoreTransform::Signed);
            let smp = em.select(&mut rng, &y, eps0, sens);
            (smp.index, smp.work)
        } else {
            let scores: Vec<f32> = (0..d).map(|j| dot(nvecs.row(j), &y)).collect();
            (exponential_mechanism(&mut rng, &scores, eps0, sens), d)
        };
        work_total += work;

        // primal vertex x* = (OPT/c_j)·e_j; losses ℓ_i = (A_i x* − b_i)/ρ
        let scale = lp.opt / lp.c[j_t] as f64;
        x_sum[j_t] += scale;
        for i in 0..m {
            let viol = (scale * lp.a.row(i)[j_t] as f64 - lp.b[i] as f64) / rho;
            // up-weight violated constraints so the oracle avoids them next
            w[i] *= (eta * viol).exp() as f32;
        }
        // renormalize weights occasionally for numeric stability
        let max_w = w.iter().cloned().fold(0f32, f32::max);
        if max_w > 1e20 {
            for v in w.iter_mut() {
                *v /= max_w;
            }
        }
    }

    let inv = 1.0 / cfg.t.max(1) as f64;
    DenseLpResult {
        x: x_sum.iter().map(|&v| (v * inv) as f32).collect(),
        total_time: started.elapsed(),
        index_build_time,
        avg_select_work: work_total as f64 / cfg.t.max(1) as f64,
        eps0,
    }
}

/// Count constraints violated by more than alpha (Theorem 4.4's metric).
pub fn violated_constraints(lp: &PackingLp, x: &[f32], alpha: f64) -> usize {
    (0..lp.m())
        .filter(|&i| dot(lp.a.row(i), x) as f64 > lp.b[i] as f64 + alpha)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::random_packing_lp;

    #[test]
    fn oracle_vectors_encode_scores() {
        let mut rng = Rng::new(1);
        let lp = random_packing_lp(&mut rng, 50, 6);
        let n = oracle_vectors(&lp);
        let y = vec![1.0 / 50.0f32; 50];
        for j in 0..6 {
            let want: f64 = -(lp.opt / lp.c[j] as f64)
                * (0..50)
                    .map(|i| y[i] as f64 * lp.a.row(i)[j] as f64)
                    .sum::<f64>();
            let got = dot(n.row(j), &y) as f64;
            assert!((got - want).abs() < 1e-4, "j={j}: {got} vs {want}");
        }
    }

    #[test]
    fn solver_violates_few_constraints() {
        let mut rng = Rng::new(2);
        let lp = random_packing_lp(&mut rng, 300, 10);
        let cfg = DenseLpConfig {
            t: 300,
            eps: 5.0,
            delta: 1e-3,
            s: 30,
            mode: SelectionMode::Exhaustive,
            seed: 3,
        };
        let res = run_dense(&cfg, &lp);
        // objective value of the averaged vertex solution is OPT by construction
        let cx: f64 =
            res.x.iter().zip(&lp.c).map(|(&x, &c)| (x * c) as f64).sum();
        assert!((cx - lp.opt).abs() < 0.05 * lp.opt, "c·x̄ = {cx} vs OPT {}", lp.opt);
        // allow generous alpha: violated count should be well under m
        let viol = violated_constraints(&lp, &res.x, 0.5);
        assert!(viol < 150, "violations {viol}");
    }

    #[test]
    fn lazy_mode_matches_exhaustive_roughly() {
        let mut rng = Rng::new(4);
        let lp = random_packing_lp(&mut rng, 200, 12);
        let mk = |mode| DenseLpConfig {
            t: 200,
            eps: 5.0,
            delta: 1e-3,
            s: 20,
            mode,
            seed: 5,
        };
        let ex = run_dense(&mk(SelectionMode::Exhaustive), &lp);
        let lz = run_dense(&mk(SelectionMode::Lazy(IndexKind::Flat)), &lp);
        let v_ex = violated_constraints(&lp, &ex.x, 0.5);
        let v_lz = violated_constraints(&lp, &lz.x, 0.5);
        assert!(
            (v_ex as i64 - v_lz as i64).abs() < 60,
            "exhaustive {v_ex} lazy {v_lz}"
        );
    }
}
