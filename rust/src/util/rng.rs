//! Deterministic, dependency-free PRNG (xoshiro256++) with the sampling
//! primitives the paper's mechanisms need.
//!
//! Privacy-critical noise (Gumbel, Laplace, binomial tails) is sampled here
//! in the coordinator — never inside the dispatched kernels (DESIGN.md
//! §10) — so the kernel layer stays a deterministic function of its inputs.

/// splitmix64: seed expander with provable full-period mixing.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Deterministic given a seed; `split` derives
/// independent streams for parallel workers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator (any seed is fine; zero is remapped).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = splitmix64(&mut sm);
        }
        // Avoid the all-zero state (probability ~2^-256, but cheap to guard).
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Derive an independent stream (for worker `i` of a parallel job).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `(0, 1)` — safe to pass to `ln`.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 0.0 {
                return u;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Unbiased integer in `[0, n)` (Lemire's rejection method).
    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (no state carried between calls).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64_open();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Gumbel(0, 1) via inverse CDF: `-ln(-ln(U))`, U ∈ (0,1).
    #[inline]
    pub fn gumbel(&mut self) -> f64 {
        -(-self.f64_open().ln()).ln()
    }

    /// Laplace(0, scale) via inverse CDF.
    ///
    /// Uses [`Rng::f64_open`] so `u` is strictly inside `(-0.5, 0.5)`:
    /// the closed-interval `f64()` can return exactly 0.0, giving
    /// `u = -0.5` and `ln(0) = -∞` — an infinite noise sample that would
    /// poison every subsequent MWU round it touches. Note the center of
    /// the interval is still reachable: `u == 0` maps through
    /// `signum(+0.0) == 1.0` to a benign `-scale · ln(1) = 0` draw (there
    /// is no `signum(0) = 0` dead zone in IEEE `f64::signum`, but callers
    /// should not rely on the sign of a zero-magnitude draw).
    pub fn laplace(&mut self, scale: f64) -> f64 {
        let u = self.f64_open() - 0.5;
        -scale * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// Exponential with the given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64_open().ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_below_uniformish() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.usize_below(10)] += 1;
        }
        for &c in &counts {
            // each bucket expects 10_000; allow ±5%
            assert!((9_500..10_500).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn gumbel_moments() {
        // E[Gumbel(0,1)] = γ ≈ 0.5772, Var = π²/6 ≈ 1.6449
        let mut r = Rng::new(17);
        let n = 300_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gumbel();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.57722).abs() < 0.01, "mean {mean}");
        assert!((var - 1.64493).abs() < 0.03, "var {var}");
    }

    #[test]
    fn laplace_moments() {
        let mut r = Rng::new(19);
        let scale = 2.5;
        let n = 300_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.laplace(scale);
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 2.0 * scale * scale).abs() < 0.2, "var {var}");
    }

    /// Regression: a seed-swept million draws must all be finite. The old
    /// sampler used the closed-interval `f64()`, so a raw 0.0 produced
    /// `u = -0.5 → ln(0) = -∞` — one poisoned measurement per unlucky
    /// stream, caught here by sweeping many independent seeds.
    #[test]
    fn laplace_sweep_is_always_finite() {
        for seed in 0..10u64 {
            let mut r = Rng::new(seed.wrapping_mul(0x9E37_79B9) ^ 0xF1F1);
            for _ in 0..100_000 {
                let x = r.laplace(1.7);
                assert!(x.is_finite(), "seed {seed}: non-finite Laplace draw {x}");
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
