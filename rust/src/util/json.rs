//! Minimal JSON reader + writer (the offline build vendors no serde_json).
//!
//! Two frontends share one hardened lexer, following serde_json's
//! three-representation split (text / events / tree):
//!
//! * **Event layer** — [`parse_events`] drives a caller-supplied
//!   [`JsonVisitor`] with one callback per token, allocating nothing per
//!   event on the fast path (escape-free strings are handed out as slices
//!   of the input; escaped strings reuse one scratch buffer). This is the
//!   wire front end's request parser (DESIGN.md §11): a request body is
//!   validated and folded into a spec in a single pass, with no
//!   intermediate tree.
//! * **Tree layer** — [`Json::parse`] builds the familiar [`Json`] value
//!   by running a tree-builder visitor over the same event stream. Used
//!   for the artifact-store manifest (`store/manifest.rs`), bench/metrics
//!   dumps and the perf-gate baseline.
//!
//! Both frontends are safe against adversarial input: the parser is
//! **iterative** (an explicit container stack, so nesting depth is a typed
//! [`JsonErrorKind::TooDeep`] error instead of a stack overflow), number
//! tokens are length- and range-checked ([`JsonErrorKind::OversizedNumber`]
//! — `1e999` is an error, never a silent `inf` that the writer could not
//! round-trip), truncated input anywhere (mid-value, mid-escape) is a
//! typed truncation error, and the duplicate-key policy is explicit
//! ([`DuplicateKeys`]). Nothing in this module panics on untrusted bytes.
//!
//! Strings support the full escape grammar including `\uXXXX` surrogate
//! pairs beyond the BMP (unpaired surrogates decode to U+FFFD, matching
//! lenient parsers). The writer escapes every control character, so any
//! Rust string round-trips.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document under [`JsonLimits::default`].
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        Json::parse_with_limits(text, &JsonLimits::default())
    }

    /// Parse a complete JSON document under explicit [`JsonLimits`].
    pub fn parse_with_limits(text: &str, limits: &JsonLimits) -> Result<Json, JsonError> {
        let mut builder = TreeBuilder::default();
        parse_events(text, limits, &mut builder)?;
        // parse_events only returns Ok once one complete value was emitted,
        // so the builder always holds the finished tree here.
        builder.out.ok_or_else(|| JsonError::at(JsonErrorKind::Truncated, 0, "empty input"))
    }

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to usize, if this is a `Num`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// The numeric value truncated to u64, if this is a non-negative `Num`.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|f| *f >= 0.0).map(|f| f as u64)
    }

    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Member lookup, if this is an `Obj`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

/// Machine-checkable failure class of a [`JsonError`]. The wire front end
/// maps every kind to a 4xx response; none of them panic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JsonErrorKind {
    /// Structurally invalid input (unexpected character, bad literal,
    /// missing separator).
    Syntax,
    /// Input ended inside a value, string or container.
    Truncated,
    /// Input ended inside a `\` escape sequence.
    TruncatedEscape,
    /// An unknown escape or malformed `\uXXXX`.
    BadEscape,
    /// A number token that does not parse as a JSON number.
    BadNumber,
    /// A number token longer than the limit, or one whose value overflows
    /// f64 to ±∞ (`1e999`) — accepted by naive parsers, unround-trippable
    /// by any JSON writer.
    OversizedNumber,
    /// Containers nested deeper than [`JsonLimits::max_depth`].
    TooDeep,
    /// A repeated object key under [`DuplicateKeys::Reject`].
    DuplicateKey,
    /// A complete value followed by non-whitespace.
    TrailingData,
    /// The input is not valid UTF-8 (byte-level entry points).
    InvalidUtf8,
    /// An error raised by a [`JsonVisitor`] callback (e.g. an unknown
    /// request field in the wire protocol).
    Visitor,
}

impl fmt::Display for JsonErrorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            JsonErrorKind::Syntax => "syntax",
            JsonErrorKind::Truncated => "truncated",
            JsonErrorKind::TruncatedEscape => "truncated-escape",
            JsonErrorKind::BadEscape => "bad-escape",
            JsonErrorKind::BadNumber => "bad-number",
            JsonErrorKind::OversizedNumber => "oversized-number",
            JsonErrorKind::TooDeep => "too-deep",
            JsonErrorKind::DuplicateKey => "duplicate-key",
            JsonErrorKind::TrailingData => "trailing-data",
            JsonErrorKind::InvalidUtf8 => "invalid-utf8",
            JsonErrorKind::Visitor => "visitor",
        })
    }
}

/// Parse failure with a typed kind and a byte offset.
#[derive(Debug)]
pub struct JsonError {
    /// What class of failure this is.
    pub kind: JsonErrorKind,
    /// Byte position of the failure.
    pub pos: usize,
    /// Human-readable detail.
    pub msg: String,
}

impl JsonError {
    /// Construct an error of `kind` at byte `pos`.
    pub fn at(kind: JsonErrorKind, pos: usize, msg: impl Into<String>) -> Self {
        JsonError { kind, pos, msg: msg.into() }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error ({}) at byte {}: {}", self.kind, self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// What to do when an object repeats a key.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DuplicateKeys {
    /// The last occurrence wins (every event is still delivered; the tree
    /// frontend overwrites). Matches the historic lenient behavior.
    #[default]
    LastWins,
    /// Fail with [`JsonErrorKind::DuplicateKey`]. The wire protocol uses
    /// this: a request that says `"seed": 1, "seed": 2` is ambiguous and
    /// must not be half-honored.
    Reject,
}

/// Hardening limits for the parser. [`JsonLimits::default`] is permissive
/// enough for every trusted artifact in the repo (manifests, bench dumps);
/// the wire front end tightens it per request.
#[derive(Clone, Copy, Debug)]
pub struct JsonLimits {
    /// Maximum container nesting depth (inclusive). Exceeding it is a
    /// typed [`JsonErrorKind::TooDeep`] error, never a stack overflow —
    /// the parser carries an explicit stack.
    pub max_depth: usize,
    /// Maximum byte length of one number token.
    pub max_number_len: usize,
    /// Duplicate-key policy for objects.
    pub duplicate_keys: DuplicateKeys,
}

impl Default for JsonLimits {
    fn default() -> Self {
        JsonLimits { max_depth: 128, max_number_len: 512, duplicate_keys: DuplicateKeys::LastWins }
    }
}

/// Callback interface of the event layer: [`parse_events`] calls one
/// method per token, in document order. Every method may abort the parse
/// by returning an error (conventionally [`JsonErrorKind::Visitor`]).
/// String/key slices borrow from the input (or a scratch buffer) and are
/// only valid for the duration of the call — copy what you keep.
///
/// All methods default to "accept and ignore", so a visitor implements
/// only what it cares about.
pub trait JsonVisitor {
    /// `{` — an object opens.
    fn begin_object(&mut self, pos: usize) -> Result<(), JsonError> {
        let _ = pos;
        Ok(())
    }
    /// An object member key (the value's events follow).
    fn key(&mut self, key: &str, pos: usize) -> Result<(), JsonError> {
        let _ = (key, pos);
        Ok(())
    }
    /// `}` — the innermost object closes.
    fn end_object(&mut self, pos: usize) -> Result<(), JsonError> {
        let _ = pos;
        Ok(())
    }
    /// `[` — an array opens.
    fn begin_array(&mut self, pos: usize) -> Result<(), JsonError> {
        let _ = pos;
        Ok(())
    }
    /// `]` — the innermost array closes.
    fn end_array(&mut self, pos: usize) -> Result<(), JsonError> {
        let _ = pos;
        Ok(())
    }
    /// `null`.
    fn null(&mut self, pos: usize) -> Result<(), JsonError> {
        let _ = pos;
        Ok(())
    }
    /// `true` / `false`.
    fn boolean(&mut self, b: bool, pos: usize) -> Result<(), JsonError> {
        let _ = (b, pos);
        Ok(())
    }
    /// A number (range-checked: always finite).
    fn number(&mut self, n: f64, pos: usize) -> Result<(), JsonError> {
        let _ = (n, pos);
        Ok(())
    }
    /// A string value.
    fn string(&mut self, s: &str, pos: usize) -> Result<(), JsonError> {
        let _ = (s, pos);
        Ok(())
    }
}

/// One container on the explicit parse stack.
enum Frame {
    /// An object; under [`DuplicateKeys::Reject`] it remembers the keys
    /// seen so far (allocation is confined to that policy).
    Obj { seen: Vec<String> },
    /// An array.
    Arr,
}

/// What the main loop does next.
enum Step {
    /// Parse one value (possibly descending into a container).
    Value,
    /// A value just finished; consume `,`/`]`/`}` per the innermost frame.
    AfterValue,
}

/// Parse `text` as one complete JSON document, streaming events into
/// `visitor`. Returns only after a full value plus optional trailing
/// whitespace was consumed; anything else is a typed [`JsonError`].
pub fn parse_events(
    text: &str,
    limits: &JsonLimits,
    visitor: &mut dyn JsonVisitor,
) -> Result<(), JsonError> {
    let mut lex = Lexer { bytes: text.as_bytes(), pos: 0, scratch: String::new() };
    let mut stack: Vec<Frame> = Vec::new();
    let mut step = Step::Value;
    loop {
        match step {
            Step::Value => {
                lex.skip_ws();
                let pos = lex.pos;
                match lex.peek() {
                    None => {
                        return Err(JsonError::at(
                            JsonErrorKind::Truncated,
                            pos,
                            "expected a value, found end of input",
                        ))
                    }
                    Some(b'{') => {
                        if stack.len() >= limits.max_depth {
                            return Err(JsonError::at(
                                JsonErrorKind::TooDeep,
                                pos,
                                format!("nesting deeper than {}", limits.max_depth),
                            ));
                        }
                        lex.pos += 1;
                        visitor.begin_object(pos)?;
                        stack.push(Frame::Obj { seen: Vec::new() });
                        lex.skip_ws();
                        if lex.peek() == Some(b'}') {
                            let end = lex.pos;
                            lex.pos += 1;
                            visitor.end_object(end)?;
                            stack.pop();
                            step = Step::AfterValue;
                        } else {
                            object_key(&mut lex, limits, visitor, &mut stack)?;
                            // stay in Step::Value for the member's value
                        }
                    }
                    Some(b'[') => {
                        if stack.len() >= limits.max_depth {
                            return Err(JsonError::at(
                                JsonErrorKind::TooDeep,
                                pos,
                                format!("nesting deeper than {}", limits.max_depth),
                            ));
                        }
                        lex.pos += 1;
                        visitor.begin_array(pos)?;
                        stack.push(Frame::Arr);
                        lex.skip_ws();
                        if lex.peek() == Some(b']') {
                            let end = lex.pos;
                            lex.pos += 1;
                            visitor.end_array(end)?;
                            stack.pop();
                            step = Step::AfterValue;
                        }
                        // else: stay in Step::Value for the first element
                    }
                    Some(b'"') => {
                        let s = lex.string()?;
                        visitor.string(s, pos)?;
                        step = Step::AfterValue;
                    }
                    Some(b't') => {
                        lex.lit("true")?;
                        visitor.boolean(true, pos)?;
                        step = Step::AfterValue;
                    }
                    Some(b'f') => {
                        lex.lit("false")?;
                        visitor.boolean(false, pos)?;
                        step = Step::AfterValue;
                    }
                    Some(b'n') => {
                        lex.lit("null")?;
                        visitor.null(pos)?;
                        step = Step::AfterValue;
                    }
                    Some(c) if c == b'-' || c.is_ascii_digit() => {
                        let n = lex.number(limits)?;
                        visitor.number(n, pos)?;
                        step = Step::AfterValue;
                    }
                    Some(_) => {
                        return Err(JsonError::at(
                            JsonErrorKind::Syntax,
                            pos,
                            "unexpected character",
                        ))
                    }
                }
            }
            Step::AfterValue => {
                match stack.last() {
                    None => {
                        lex.skip_ws();
                        if lex.pos != lex.bytes.len() {
                            return Err(JsonError::at(
                                JsonErrorKind::TrailingData,
                                lex.pos,
                                "trailing characters after the document",
                            ));
                        }
                        return Ok(());
                    }
                    Some(Frame::Obj { .. }) => {
                        lex.skip_ws();
                        let pos = lex.pos;
                        match lex.peek() {
                            Some(b',') => {
                                lex.pos += 1;
                                object_key(&mut lex, limits, visitor, &mut stack)?;
                                step = Step::Value;
                            }
                            Some(b'}') => {
                                lex.pos += 1;
                                visitor.end_object(pos)?;
                                stack.pop();
                                // step stays AfterValue for the parent
                            }
                            None => {
                                return Err(JsonError::at(
                                    JsonErrorKind::Truncated,
                                    pos,
                                    "unterminated object",
                                ))
                            }
                            Some(_) => {
                                return Err(JsonError::at(
                                    JsonErrorKind::Syntax,
                                    pos,
                                    "expected ',' or '}'",
                                ))
                            }
                        }
                    }
                    Some(Frame::Arr) => {
                        lex.skip_ws();
                        let pos = lex.pos;
                        match lex.peek() {
                            Some(b',') => {
                                lex.pos += 1;
                                step = Step::Value;
                            }
                            Some(b']') => {
                                lex.pos += 1;
                                visitor.end_array(pos)?;
                                stack.pop();
                                // step stays AfterValue for the parent
                            }
                            None => {
                                return Err(JsonError::at(
                                    JsonErrorKind::Truncated,
                                    pos,
                                    "unterminated array",
                                ))
                            }
                            Some(_) => {
                                return Err(JsonError::at(
                                    JsonErrorKind::Syntax,
                                    pos,
                                    "expected ',' or ']'",
                                ))
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Parse one object member key (cursor on whitespace before the `"`),
/// enforce the duplicate-key policy, emit the key event and consume the
/// `:` separator. The caller supplies the next value via [`Step::Value`].
fn object_key(
    lex: &mut Lexer<'_>,
    limits: &JsonLimits,
    visitor: &mut dyn JsonVisitor,
    stack: &mut [Frame],
) -> Result<(), JsonError> {
    lex.skip_ws();
    let pos = lex.pos;
    if lex.peek() != Some(b'"') {
        let kind = if lex.peek().is_none() {
            JsonErrorKind::Truncated
        } else {
            JsonErrorKind::Syntax
        };
        return Err(JsonError::at(kind, pos, "expected a string key"));
    }
    // StrLoc is Copy, so the decoded key can be re-borrowed cheaply for
    // the duplicate check, the bookkeeping copy, and the key event.
    let loc = lex.string_loc()?;
    if limits.duplicate_keys == DuplicateKeys::Reject {
        if let Some(Frame::Obj { seen }) = stack.last_mut() {
            let key = lex.last_string(loc);
            if seen.iter().any(|k| k == key) {
                return Err(JsonError::at(
                    JsonErrorKind::DuplicateKey,
                    pos,
                    format!("duplicate key {key:?}"),
                ));
            }
            let owned = key.to_string();
            seen.push(owned);
        }
    }
    visitor.key(lex.last_string(loc), pos)?;
    lex.skip_ws();
    if lex.peek() != Some(b':') {
        let kind = if lex.peek().is_none() {
            JsonErrorKind::Truncated
        } else {
            JsonErrorKind::Syntax
        };
        return Err(JsonError::at(kind, lex.pos, "expected ':' after object key"));
    }
    lex.pos += 1;
    Ok(())
}

struct Lexer<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Reused decode buffer for strings containing escapes; escape-free
    /// strings are handed out as input slices and never touch it.
    scratch: String,
}

/// Where the last decoded string lives.
#[derive(Clone, Copy)]
enum StrLoc {
    /// Borrowed from the input: byte range `start..end`.
    Input(usize, usize),
    /// Decoded into the scratch buffer.
    Scratch,
}

impl Lexer<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn lit(&mut self, s: &str) -> Result<(), JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(())
        } else if self.bytes.len() - self.pos < s.len()
            && s.as_bytes().starts_with(&self.bytes[self.pos..])
        {
            Err(JsonError::at(JsonErrorKind::Truncated, self.pos, "truncated literal"))
        } else {
            Err(JsonError::at(JsonErrorKind::Syntax, self.pos, "bad literal"))
        }
    }

    fn number(&mut self, limits: &JsonLimits) -> Result<f64, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        if self.pos - start > limits.max_number_len {
            return Err(JsonError::at(
                JsonErrorKind::OversizedNumber,
                start,
                format!("number token longer than {} bytes", limits.max_number_len),
            ));
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text
            .parse()
            .map_err(|_| JsonError::at(JsonErrorKind::BadNumber, start, "bad number"))?;
        if !n.is_finite() {
            return Err(JsonError::at(
                JsonErrorKind::OversizedNumber,
                start,
                "number overflows f64",
            ));
        }
        Ok(n)
    }

    /// If the bytes at `pos` are a `\uXXXX` escape in the low-surrogate
    /// range (DC00–DFFF), return its value *without* consuming anything.
    fn peek_low_surrogate(&self) -> Option<u32> {
        let b = self.bytes.get(self.pos..self.pos + 6)?;
        if b[0] != b'\\' || b[1] != b'u' {
            return None;
        }
        let hex = std::str::from_utf8(&b[2..6]).ok()?;
        let cp = u32::from_str_radix(hex, 16).ok()?;
        (0xDC00..0xE000).contains(&cp).then_some(cp)
    }

    /// Four hex digits of a `\uXXXX` escape (cursor past the `u`).
    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(JsonError::at(
                JsonErrorKind::TruncatedEscape,
                self.pos,
                "input ends inside \\u escape",
            ));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| JsonError::at(JsonErrorKind::BadEscape, self.pos, "bad \\u digits"))?;
        let cp = u32::from_str_radix(hex, 16)
            .map_err(|_| JsonError::at(JsonErrorKind::BadEscape, self.pos, "bad \\u digits"))?;
        self.pos += 4;
        Ok(cp)
    }

    /// Decode one string token (cursor on the opening `"`). Returns where
    /// the decoded text lives; [`Lexer::last_string`] materializes it.
    fn string_loc(&mut self) -> Result<StrLoc, JsonError> {
        debug_assert_eq!(self.peek(), Some(b'"'));
        self.pos += 1;
        let content_start = self.pos;
        // Fast path: scan for the closing quote; bail to the slow path at
        // the first escape.
        let mut i = self.pos;
        while let Some(&b) = self.bytes.get(i) {
            match b {
                b'"' => {
                    std::str::from_utf8(&self.bytes[content_start..i]).map_err(|_| {
                        JsonError::at(
                            JsonErrorKind::InvalidUtf8,
                            content_start,
                            "string is not valid UTF-8",
                        )
                    })?;
                    self.pos = i + 1;
                    return Ok(StrLoc::Input(content_start, i));
                }
                b'\\' => break,
                _ => i += 1,
            }
        }
        if self.bytes.get(i).is_none() {
            return Err(JsonError::at(
                JsonErrorKind::Truncated,
                content_start - 1,
                "unterminated string",
            ));
        }
        // Slow path: copy the escape-free prefix, then decode escapes into
        // the reusable scratch buffer.
        self.scratch.clear();
        let prefix = std::str::from_utf8(&self.bytes[content_start..i]).map_err(|_| {
            JsonError::at(JsonErrorKind::InvalidUtf8, content_start, "string is not valid UTF-8")
        })?;
        self.scratch.push_str(prefix);
        self.pos = i;
        loop {
            match self.peek() {
                None => {
                    return Err(JsonError::at(
                        JsonErrorKind::Truncated,
                        self.pos,
                        "unterminated string",
                    ))
                }
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(StrLoc::Scratch);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let Some(esc) = self.peek() else {
                        return Err(JsonError::at(
                            JsonErrorKind::TruncatedEscape,
                            self.pos,
                            "input ends inside escape",
                        ));
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => self.scratch.push('"'),
                        b'\\' => self.scratch.push('\\'),
                        b'/' => self.scratch.push('/'),
                        b'b' => self.scratch.push('\u{8}'),
                        b'f' => self.scratch.push('\u{c}'),
                        b'n' => self.scratch.push('\n'),
                        b'r' => self.scratch.push('\r'),
                        b't' => self.scratch.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                // high surrogate: pair it with an
                                // immediately following low-surrogate
                                // escape (RFC 8259 §7). Anything else
                                // decodes to U+FFFD without consuming the
                                // next escape, so the surrounding data
                                // survives an unpaired surrogate.
                                match self.peek_low_surrogate() {
                                    Some(lo) => {
                                        self.pos += 6; // the "\uXXXX"
                                        let c = 0x10000
                                            + ((cp - 0xD800) << 10)
                                            + (lo - 0xDC00);
                                        char::from_u32(c).unwrap_or('\u{fffd}')
                                    }
                                    None => '\u{fffd}',
                                }
                            } else {
                                char::from_u32(cp).unwrap_or('\u{fffd}')
                            };
                            self.scratch.push(ch);
                        }
                        _ => {
                            return Err(JsonError::at(
                                JsonErrorKind::BadEscape,
                                self.pos - 1,
                                "unknown escape",
                            ))
                        }
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..]).map_err(|_| {
                        JsonError::at(
                            JsonErrorKind::InvalidUtf8,
                            self.pos,
                            "string is not valid UTF-8",
                        )
                    })?;
                    let ch = s.chars().next().unwrap();
                    self.scratch.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    /// Decode one string token and hand out the text.
    fn string(&mut self) -> Result<&str, JsonError> {
        let loc = self.string_loc()?;
        Ok(self.last_string(loc))
    }

    /// Materialize a [`StrLoc`] as text.
    fn last_string(&self, loc: StrLoc) -> &str {
        match loc {
            // validated in string_loc
            StrLoc::Input(s, e) => std::str::from_utf8(&self.bytes[s..e]).unwrap(),
            StrLoc::Scratch => &self.scratch,
        }
    }
}

struct TreeFrameObj {
    map: BTreeMap<String, Json>,
    pending_key: Option<String>,
}

enum TreeFrame {
    Obj(TreeFrameObj),
    Arr(Vec<Json>),
}

/// The tree frontend: folds the event stream into a [`Json`] value with an
/// explicit stack (depth is bounded by [`JsonLimits::max_depth`] upstream).
#[derive(Default)]
struct TreeBuilder {
    stack: Vec<TreeFrame>,
    out: Option<Json>,
}

impl TreeBuilder {
    fn place(&mut self, v: Json) {
        match self.stack.last_mut() {
            None => self.out = Some(v),
            Some(TreeFrame::Arr(items)) => items.push(v),
            Some(TreeFrame::Obj(o)) => {
                // parse_events guarantees a key event precedes every member
                // value, so pending_key is always set here.
                let key = o.pending_key.take().unwrap_or_default();
                o.map.insert(key, v);
            }
        }
    }
}

impl JsonVisitor for TreeBuilder {
    fn begin_object(&mut self, _pos: usize) -> Result<(), JsonError> {
        self.stack
            .push(TreeFrame::Obj(TreeFrameObj { map: BTreeMap::new(), pending_key: None }));
        Ok(())
    }
    fn key(&mut self, key: &str, _pos: usize) -> Result<(), JsonError> {
        if let Some(TreeFrame::Obj(o)) = self.stack.last_mut() {
            o.pending_key = Some(key.to_string());
        }
        Ok(())
    }
    fn end_object(&mut self, _pos: usize) -> Result<(), JsonError> {
        if let Some(TreeFrame::Obj(o)) = self.stack.pop() {
            self.place(Json::Obj(o.map));
        }
        Ok(())
    }
    fn begin_array(&mut self, _pos: usize) -> Result<(), JsonError> {
        self.stack.push(TreeFrame::Arr(Vec::new()));
        Ok(())
    }
    fn end_array(&mut self, _pos: usize) -> Result<(), JsonError> {
        if let Some(TreeFrame::Arr(items)) = self.stack.pop() {
            self.place(Json::Arr(items));
        }
        Ok(())
    }
    fn null(&mut self, _pos: usize) -> Result<(), JsonError> {
        self.place(Json::Null);
        Ok(())
    }
    fn boolean(&mut self, b: bool, _pos: usize) -> Result<(), JsonError> {
        self.place(Json::Bool(b));
        Ok(())
    }
    fn number(&mut self, n: f64, _pos: usize) -> Result<(), JsonError> {
        self.place(Json::Num(n));
        Ok(())
    }
    fn string(&mut self, s: &str, _pos: usize) -> Result<(), JsonError> {
        self.place(Json::Str(s.to_string()));
        Ok(())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => write!(f, "{}", fmt_f64(*n)),
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Canonical JSON number formatting, shared by the tree writer and the
/// wire protocol's streaming response encoder (`server/proto.rs`) — the
/// wire soak asserts byte-identical release output across both paths, so
/// there must be exactly one formatter.
pub fn fmt_f64(n: f64) -> String {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
            "version": 1,
            "entries": [
                {"name": "scores_m1024_u1024",
                 "inputs": [{"shape": [1024, 1024], "dtype": "float32"}]}
            ]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("scores_m1024_u1024"));
        let shape = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(1024));
    }

    #[test]
    fn round_trips_values() {
        for text in [
            r#"{"a":1,"b":[true,false,null],"c":"x\ny"}"#,
            r#"[1.5,-2,3e2]"#,
            r#""unicode: é""#,
        ] {
            let j = Json::parse(text).unwrap();
            let again = Json::parse(&j.to_string()).unwrap();
            assert_eq!(j, again, "text: {text}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn numbers_parse() {
        assert_eq!(Json::parse("-1.25e2").unwrap().as_f64(), Some(-125.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
        assert_eq!(Json::parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(Json::parse("-7").unwrap().as_u64(), None, "negatives are not u64");
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("1").unwrap().as_bool(), None);
    }

    /// Control characters survive a write→parse round trip: the writer
    /// must escape everything below 0x20 (the store manifest may carry
    /// arbitrary strings).
    #[test]
    fn control_characters_round_trip() {
        let nasty: String =
            (0u32..0x20).map(|c| char::from_u32(c).unwrap()).chain(['"', '\\']).collect();
        let written = Json::Str(nasty.clone()).to_string();
        for b in written.bytes() {
            assert!(b >= 0x20, "writer must not emit raw control byte {b:#04x}");
        }
        assert_eq!(Json::parse(&written).unwrap().as_str(), Some(nasty.as_str()));

        // explicit escape forms parse too
        assert_eq!(
            Json::parse(r#""\u0000\u0001\u001f\b\f""#).unwrap().as_str(),
            Some("\u{0}\u{1}\u{1f}\u{8}\u{c}")
        );
    }

    /// `\uXXXX` surrogate pairs decode to the astral character; unpaired
    /// surrogates degrade to U+FFFD instead of corrupting the string.
    #[test]
    fn surrogate_pairs_decode() {
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap().as_str(),
            Some("\u{1F600}")
        );
        assert_eq!(
            Json::parse(r#""a\ud83d\ude00b""#).unwrap().as_str(),
            Some("a\u{1F600}b")
        );
        // unpaired high / lone low surrogates
        assert_eq!(Json::parse(r#""\ud83dx""#).unwrap().as_str(), Some("\u{fffd}x"));
        assert_eq!(Json::parse(r#""\ude00""#).unwrap().as_str(), Some("\u{fffd}"));
        // an unpaired high surrogate must not swallow the next escape...
        assert_eq!(
            Json::parse(r#""\ud83d\u0041""#).unwrap().as_str(),
            Some("\u{fffd}A")
        );
        // ...nor a valid pair that follows it
        assert_eq!(
            Json::parse(r#""\ud83d\ud83d\ude00""#).unwrap().as_str(),
            Some("\u{fffd}\u{1F600}")
        );
        // a raw astral char round-trips through the writer
        let j = Json::Str("\u{1F980}".to_string());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    // ---- hardening regressions (wire-input threat model) ----

    fn kind_of(text: &str) -> JsonErrorKind {
        Json::parse(text).unwrap_err().kind
    }

    /// Nesting past the depth limit is a typed error from both frontends,
    /// never a stack overflow: the parser is iterative.
    #[test]
    fn adversarial_depth_is_a_typed_error() {
        let deep: String = "[".repeat(100_000);
        assert_eq!(kind_of(&deep), JsonErrorKind::TooDeep);
        let deep_obj: String = "{\"k\":".repeat(100_000);
        assert_eq!(kind_of(&deep_obj), JsonErrorKind::TooDeep);

        // a no-op visitor over the event layer hits the same guard
        struct Ignore;
        impl JsonVisitor for Ignore {}
        let err = parse_events(&deep, &JsonLimits::default(), &mut Ignore).unwrap_err();
        assert_eq!(err.kind, JsonErrorKind::TooDeep);

        // depth at the limit still parses
        let limits = JsonLimits { max_depth: 3, ..JsonLimits::default() };
        assert!(Json::parse_with_limits("[[[1]]]", &limits).is_ok());
        assert_eq!(
            Json::parse_with_limits("[[[[1]]]]", &limits).unwrap_err().kind,
            JsonErrorKind::TooDeep
        );
    }

    /// Numbers that overflow f64 (or absurdly long tokens) are rejected —
    /// a naive parser admits `1e999` as inf, which no JSON writer can
    /// round-trip.
    #[test]
    fn oversized_numbers_are_typed_errors() {
        assert_eq!(kind_of("1e999"), JsonErrorKind::OversizedNumber);
        assert_eq!(kind_of("-1e999"), JsonErrorKind::OversizedNumber);
        let long = "9".repeat(2_000);
        assert_eq!(kind_of(&long), JsonErrorKind::OversizedNumber);
        // the largest finite magnitudes still parse
        assert_eq!(Json::parse("1e308").unwrap().as_f64(), Some(1e308));
        assert_eq!(Json::parse("-1.5e-300").unwrap().as_f64(), Some(-1.5e-300));
        // malformed tokens are BadNumber, not a panic
        assert_eq!(kind_of("-"), JsonErrorKind::BadNumber);
        assert_eq!(kind_of("1.2.3"), JsonErrorKind::BadNumber);
    }

    /// Truncation anywhere — mid-value, mid-string, mid-escape — is a
    /// typed truncation error.
    #[test]
    fn truncated_input_is_a_typed_error() {
        assert_eq!(kind_of(""), JsonErrorKind::Truncated);
        assert_eq!(kind_of("{\"a\":"), JsonErrorKind::Truncated);
        assert_eq!(kind_of("[1,2"), JsonErrorKind::Truncated);
        assert_eq!(kind_of("\"abc"), JsonErrorKind::Truncated);
        assert_eq!(kind_of("tru"), JsonErrorKind::Truncated);
        // escapes cut off by end-of-input
        assert_eq!(kind_of("\"\\"), JsonErrorKind::TruncatedEscape);
        assert_eq!(kind_of("\"\\u12"), JsonErrorKind::TruncatedEscape);
        // bad (but complete) escapes are a different class
        assert_eq!(kind_of("\"\\q\""), JsonErrorKind::BadEscape);
        assert_eq!(kind_of("\"\\uzzzz\""), JsonErrorKind::BadEscape);
    }

    /// The duplicate-key policy: lenient frontends keep last-wins (the
    /// historic behavior); the wire profile rejects with a typed error.
    #[test]
    fn duplicate_key_policy() {
        let text = r#"{"seed":1,"seed":2}"#;
        // default: last wins
        assert_eq!(Json::parse(text).unwrap().get("seed").unwrap().as_f64(), Some(2.0));
        // strict: typed rejection
        let strict =
            JsonLimits { duplicate_keys: DuplicateKeys::Reject, ..JsonLimits::default() };
        let err = Json::parse_with_limits(text, &strict).unwrap_err();
        assert_eq!(err.kind, JsonErrorKind::DuplicateKey);
        assert!(err.msg.contains("seed"), "{}", err.msg);
        // distinct keys are unaffected, including across nesting levels
        let ok = r#"{"a":{"a":1},"b":2}"#;
        assert!(Json::parse_with_limits(ok, &strict).is_ok());
    }

    #[test]
    fn trailing_data_is_a_typed_error() {
        assert_eq!(kind_of("1 2"), JsonErrorKind::TrailingData);
        assert_eq!(kind_of("{} x"), JsonErrorKind::TrailingData);
    }

    /// The event layer delivers tokens in document order, hands out
    /// escape-free strings without copying, and lets a visitor abort.
    #[test]
    fn event_layer_streams_in_order() {
        #[derive(Default)]
        struct Tape(Vec<String>);
        impl JsonVisitor for Tape {
            fn begin_object(&mut self, _p: usize) -> Result<(), JsonError> {
                self.0.push("{".into());
                Ok(())
            }
            fn key(&mut self, k: &str, _p: usize) -> Result<(), JsonError> {
                self.0.push(format!("k:{k}"));
                Ok(())
            }
            fn end_object(&mut self, _p: usize) -> Result<(), JsonError> {
                self.0.push("}".into());
                Ok(())
            }
            fn begin_array(&mut self, _p: usize) -> Result<(), JsonError> {
                self.0.push("[".into());
                Ok(())
            }
            fn end_array(&mut self, _p: usize) -> Result<(), JsonError> {
                self.0.push("]".into());
                Ok(())
            }
            fn null(&mut self, _p: usize) -> Result<(), JsonError> {
                self.0.push("null".into());
                Ok(())
            }
            fn boolean(&mut self, b: bool, _p: usize) -> Result<(), JsonError> {
                self.0.push(format!("b:{b}"));
                Ok(())
            }
            fn number(&mut self, n: f64, _p: usize) -> Result<(), JsonError> {
                self.0.push(format!("n:{n}"));
                Ok(())
            }
            fn string(&mut self, s: &str, _p: usize) -> Result<(), JsonError> {
                self.0.push(format!("s:{s}"));
                Ok(())
            }
        }
        let mut tape = Tape::default();
        parse_events(
            r#"{"kind":"release","dims":[1,2],"ok":true,"x":null,"esc":"a\nb"}"#,
            &JsonLimits::default(),
            &mut tape,
        )
        .unwrap();
        assert_eq!(
            tape.0,
            vec![
                "{", "k:kind", "s:release", "k:dims", "[", "n:1", "n:2", "]", "k:ok",
                "b:true", "k:x", "null", "k:esc", "s:a\nb", "}"
            ]
            .into_iter()
            .map(String::from)
            .collect::<Vec<_>>()
        );

        // a visitor error aborts with position and Visitor kind
        struct Abort;
        impl JsonVisitor for Abort {
            fn number(&mut self, _n: f64, pos: usize) -> Result<(), JsonError> {
                Err(JsonError::at(JsonErrorKind::Visitor, pos, "no numbers allowed"))
            }
        }
        let err = parse_events("[1]", &JsonLimits::default(), &mut Abort).unwrap_err();
        assert_eq!((err.kind, err.pos), (JsonErrorKind::Visitor, 1));
    }

    /// The canonical number formatter is shared with the wire encoder;
    /// pin its behavior.
    #[test]
    fn canonical_number_formatting() {
        assert_eq!(fmt_f64(0.0), "0");
        assert_eq!(fmt_f64(-3.0), "-3");
        assert_eq!(fmt_f64(1.5), "1.5");
        assert_eq!(fmt_f64(1e15), "1000000000000000");
        assert_eq!(fmt_f64(0.1f32 as f64), "0.10000000149011612");
    }
}
