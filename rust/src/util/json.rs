//! Minimal JSON parser + writer (the offline build vendors no serde_json).
//!
//! Supports the full JSON grammar, including `\uXXXX` escapes with
//! surrogate pairs beyond the BMP (unpaired surrogates decode to U+FFFD,
//! matching lenient parsers). The writer escapes every control character,
//! so any Rust string round-trips. Used for `artifacts/manifest.json`, the
//! artifact-store manifest (`store/manifest.rs`) and experiment result
//! dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to usize, if this is a `Num`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// The numeric value truncated to u64, if this is a non-negative `Num`.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|f| *f >= 0.0).map(|f| f as u64)
    }

    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Member lookup, if this is an `Obj`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

/// Parse failure with a byte offset.
#[derive(Debug)]
pub struct JsonError {
    /// Byte position of the failure.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    /// If the next bytes are a `\uXXXX` escape in the low-surrogate range
    /// (DC00–DFFF), return its value *without* consuming anything.
    fn peek_low_surrogate(&self) -> Option<u32> {
        let b = self.bytes.get(self.pos..self.pos + 6)?;
        if b[0] != b'\\' || b[1] != b'u' {
            return None;
        }
        let hex = std::str::from_utf8(&b[2..6]).ok()?;
        let cp = u32::from_str_radix(hex, 16).ok()?;
        (0xDC00..0xE000).contains(&cp).then_some(cp)
    }

    /// Four hex digits of a `\uXXXX` escape (cursor past the `u`).
    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("bad \\u"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                // high surrogate: pair it with an
                                // immediately following low-surrogate
                                // escape (RFC 8259 §7). Anything else
                                // decodes to U+FFFD without consuming the
                                // next escape, so the surrounding data
                                // survives an unpaired surrogate.
                                match self.peek_low_surrogate() {
                                    Some(lo) => {
                                        self.pos += 6; // the "\uXXXX"
                                        let c = 0x10000
                                            + ((cp - 0xD800) << 10)
                                            + (lo - 0xDC00);
                                        char::from_u32(c).unwrap_or('\u{fffd}')
                                    }
                                    None => '\u{fffd}',
                                }
                            } else {
                                char::from_u32(cp).unwrap_or('\u{fffd}')
                            };
                            out.push(ch);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
            "version": 1,
            "entries": [
                {"name": "scores_m1024_u1024",
                 "inputs": [{"shape": [1024, 1024], "dtype": "float32"}]}
            ]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("scores_m1024_u1024"));
        let shape = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(1024));
    }

    #[test]
    fn round_trips_values() {
        for text in [
            r#"{"a":1,"b":[true,false,null],"c":"x\ny"}"#,
            r#"[1.5,-2,3e2]"#,
            r#""unicode: é""#,
        ] {
            let j = Json::parse(text).unwrap();
            let again = Json::parse(&j.to_string()).unwrap();
            assert_eq!(j, again, "text: {text}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn numbers_parse() {
        assert_eq!(Json::parse("-1.25e2").unwrap().as_f64(), Some(-125.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
        assert_eq!(Json::parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(Json::parse("-7").unwrap().as_u64(), None, "negatives are not u64");
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("1").unwrap().as_bool(), None);
    }

    /// Control characters survive a write→parse round trip: the writer
    /// must escape everything below 0x20 (the store manifest may carry
    /// arbitrary strings).
    #[test]
    fn control_characters_round_trip() {
        let nasty: String =
            (0u32..0x20).map(|c| char::from_u32(c).unwrap()).chain(['"', '\\']).collect();
        let written = Json::Str(nasty.clone()).to_string();
        for b in written.bytes() {
            assert!(b >= 0x20, "writer must not emit raw control byte {b:#04x}");
        }
        assert_eq!(Json::parse(&written).unwrap().as_str(), Some(nasty.as_str()));

        // explicit escape forms parse too
        assert_eq!(
            Json::parse(r#""\u0000\u0001\u001f\b\f""#).unwrap().as_str(),
            Some("\u{0}\u{1}\u{1f}\u{8}\u{c}")
        );
    }

    /// `\uXXXX` surrogate pairs decode to the astral character; unpaired
    /// surrogates degrade to U+FFFD instead of corrupting the string.
    #[test]
    fn surrogate_pairs_decode() {
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap().as_str(),
            Some("\u{1F600}")
        );
        assert_eq!(
            Json::parse(r#""a\ud83d\ude00b""#).unwrap().as_str(),
            Some("a\u{1F600}b")
        );
        // unpaired high / lone low surrogates
        assert_eq!(Json::parse(r#""\ud83dx""#).unwrap().as_str(), Some("\u{fffd}x"));
        assert_eq!(Json::parse(r#""\ude00""#).unwrap().as_str(), Some("\u{fffd}"));
        // an unpaired high surrogate must not swallow the next escape...
        assert_eq!(
            Json::parse(r#""\ud83d\u0041""#).unwrap().as_str(),
            Some("\u{fffd}A")
        );
        // ...nor a valid pair that follows it
        assert_eq!(
            Json::parse(r#""\ud83d\ud83d\ude00""#).unwrap().as_str(),
            Some("\u{fffd}\u{1F600}")
        );
        // a raw astral char round-trips through the writer
        let j = Json::Str("\u{1F980}".to_string());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
