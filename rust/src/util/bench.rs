//! Tiny benchmarking harness (the offline build vendors no criterion).
//!
//! `cargo bench` runs each `benches/*.rs` binary (harness = false); they
//! use [`bench`] / [`bench_n`] for warmup + repeated timing with median and
//! spread reporting, printing aligned rows that EXPERIMENTS.md quotes.

use std::time::{Duration, Instant};

/// Timing summary of one benched case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case label.
    pub name: String,
    /// Timed iterations (after warmup).
    pub iters: usize,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Median per-iteration time.
    pub p50: Duration,
    /// 90th-percentile per-iteration time.
    pub p90: Duration,
    /// Fastest iteration.
    pub min: Duration,
}

impl BenchResult {
    /// Print the aligned row `header` set up.
    pub fn print(&self) {
        println!(
            "  {:<44} {:>12} {:>12} {:>12}  x{}",
            self.name,
            fmt_dur(self.p50),
            fmt_dur(self.mean),
            fmt_dur(self.p90),
            self.iters
        );
    }
}

/// Print a section header plus the column legend for [`BenchResult::print`].
pub fn header(title: &str) {
    println!("\n== {title} ==");
    println!("  {:<44} {:>12} {:>12} {:>12}", "case", "p50", "mean", "p90");
}

/// Human-scale duration formatting (ns/us/ms/s).
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench_n<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    let res = BenchResult {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[iters / 2],
        p90: samples[(iters * 9) / 10],
        min: samples[0],
    };
    res.print();
    res
}

/// Auto-calibrated variant: picks an iteration count so the run takes
/// roughly `budget` wall-clock.
pub fn bench<T>(name: &str, budget: Duration, mut f: impl FnMut() -> T) -> BenchResult {
    // calibrate with one run
    let t0 = Instant::now();
    std::hint::black_box(f());
    let one = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (budget.as_secs_f64() / one.as_secs_f64()).clamp(3.0, 3_000.0) as usize;
    bench_n(name, 1, iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_n_reports_ordered_percentiles() {
        let r = bench_n("noop", 2, 20, || 1 + 1);
        assert_eq!(r.iters, 20);
        assert!(r.min <= r.p50 && r.p50 <= r.p90);
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(Duration::from_nanos(500)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("us"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with("s"));
    }
}
