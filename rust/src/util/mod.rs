//! Shared utilities: deterministic RNG, numeric helpers, CSV emission.

pub mod bench;
pub mod csv;
pub mod json;
pub mod math;
pub mod rng;

pub use rng::Rng;
