//! Shared utilities: deterministic RNG, numeric helpers, aligned buffers,
//! CSV emission.

pub mod align;
pub mod bench;
pub mod csv;
pub mod json;
pub mod math;
pub mod mmap;
pub mod rng;

pub use rng::Rng;
