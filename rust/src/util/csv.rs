//! Minimal CSV writer for experiment results (no external dependency).
//!
//! Every eval driver emits one CSV per figure under `results/`, with the
//! same series the paper plots; EXPERIMENTS.md references these files.

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV emitter with a fixed column count.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create `path` (and parent dirs), writing `header` as the first row.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<Self> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, cols: header.len() })
    }

    /// Write one pre-stringified row.
    pub fn row(&mut self, values: &[String]) -> std::io::Result<()> {
        debug_assert_eq!(values.len(), self.cols, "column count mismatch");
        writeln!(self.out, "{}", values.join(","))
    }

    /// Write one all-numeric row.
    pub fn row_f64(&mut self, values: &[f64]) -> std::io::Result<()> {
        let strs: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        self.row(&strs)
    }

    /// Flush the underlying buffer.
    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// Convenience macro-free row builder mixing types.
pub fn cells(parts: &[&dyn std::fmt::Display]) -> Vec<String> {
    parts.iter().map(|p| format!("{p}")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("fast_mwem_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&cells(&[&1, &2.5])).unwrap();
            w.row_f64(&[3.0, 4.0]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2.5\n3,4\n");
        std::fs::remove_dir_all(dir).ok();
    }
}
