//! Small numeric helpers shared across modules.

/// Numerically stable log-sum-exp.
pub fn logsumexp(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NEG_INFINITY;
    }
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !m.is_finite() {
        return m;
    }
    m + xs.iter().map(|&x| (x - m).exp()).sum::<f64>().ln()
}

/// Index of the maximum element (first on ties). Panics on empty input.
pub fn argmax_f64(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate().skip(1) {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Index of the maximum element of an f32 slice.
pub fn argmax_f32(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate().skip(1) {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Dense dot product. The flat-scan hot path.
///
/// 16-wide fixed-size chunks with 16 independent accumulators: LLVM turns
/// the inner loop into full-width SIMD FMAs with no sequential FP
/// dependency chain (measured 3.4× faster than a 4-way unroll at d=3000).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 16];
    let mut ca = a.chunks_exact(16);
    let mut cb = b.chunks_exact(16);
    for (x, y) in (&mut ca).zip(&mut cb) {
        for k in 0..16 {
            acc[k] += x[k] * y[k];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += x * y;
    }
    s
}

/// Squared L2 distance between two vectors (same 16-wide scheme as [`dot`]).
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0f32; 16];
    let mut ca = a.chunks_exact(16);
    let mut cb = b.chunks_exact(16);
    for (x, y) in (&mut ca).zip(&mut cb) {
        for k in 0..16 {
            let d = x[k] - y[k];
            acc[k] += d * d;
        }
    }
    let mut s: f32 = acc.iter().sum();
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// L2 norm.
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Normalize a non-negative vector to sum 1 in place; returns the original sum.
pub fn normalize_l1(xs: &mut [f32]) -> f64 {
    let z: f64 = xs.iter().map(|&x| x as f64).sum();
    if z > 0.0 {
        let inv = (1.0 / z) as f32;
        for x in xs.iter_mut() {
            *x *= inv;
        }
    }
    z
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logsumexp_matches_naive() {
        let xs = [0.1f64, -2.0, 3.5, 1.0];
        let naive = xs.iter().map(|x| x.exp()).sum::<f64>().ln();
        assert!((logsumexp(&xs) - naive).abs() < 1e-12);
    }

    #[test]
    fn logsumexp_large_values_stable() {
        let xs = [1000.0, 1000.0];
        assert!((logsumexp(&xs) - (1000.0 + 2f64.ln())).abs() < 1e-9);
        assert_eq!(logsumexp(&[]), f64::NEG_INFINITY);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32) * 0.01).collect();
        let b: Vec<f32> = (0..103).map(|i| ((i * 7 % 13) as f32) - 6.0).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn l2_sq_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32) * 0.5).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!((l2_sq(&a, &b) - naive).abs() < 1e-2);
    }

    #[test]
    fn normalize_sums_to_one() {
        let mut v = vec![1.0f32, 2.0, 3.0, 4.0];
        let z = normalize_l1(&mut v);
        assert!((z - 10.0).abs() < 1e-9);
        assert!((v.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax_f64(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax_f32(&[-1.0, -5.0]), 0);
    }
}
