//! Read-only memory-mapped file regions for the zero-copy artifact pager
//! (DESIGN.md §12).
//!
//! The store's v3 artifact format places raw row data in page-aligned
//! *sections* precisely so a restore can point the index at the bytes on
//! disk instead of decoding them into heap. This module owns the one
//! `unsafe` boundary that makes that possible: a [`MmapRegion`] wraps a
//! whole artifact file mapped `PROT_READ`/`MAP_PRIVATE` and unmaps it on
//! drop. Everything above (the borrowed [`crate::mips::VectorSet`]
//! storage, the pager, the tiered cache) shares the region through an
//! `Arc` and sees only safe `&[u8]` / `&[f32]` views.
//!
//! The offline build vendors no `libc` crate, but `std` itself links the
//! platform C library on unix targets, so the two syscall wrappers the
//! pager needs are declared directly. On non-unix targets (or when the
//! syscall fails) [`MmapRegion::map_file`] returns an error and the store
//! falls back to its decode-into-heap restore path — paging is an
//! optimization, never a correctness requirement.

use std::fmt;
use std::fs::File;
use std::io::Read;
use std::path::Path;

/// The page size the v3 artifact format aligns sections to. Fixed at the
/// smallest page size of the supported targets (4 KiB) and embedded in the
/// format contract, so artifacts written on one machine map on another.
pub const PAGE_SIZE: usize = 4096;

#[cfg(unix)]
mod sys {
    // std links the platform libc on unix; declare the two calls we need
    // rather than vendoring a crate (DESIGN.md §3 keeps the build offline).
    use std::ffi::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// How a region's bytes are held.
enum Backing {
    /// A live `mmap(2)` mapping, unmapped on drop (unix only).
    #[cfg(unix)]
    Mapped { ptr: *const u8, len: usize },
    /// Whole file copied into a 64-byte-aligned heap buffer — the
    /// portability fallback, also used by tests to exercise borrowed
    /// storage without touching the filesystem. The aligned base keeps
    /// `f32` views valid at the same offsets a page-aligned mapping
    /// would give them (a plain `Vec<u8>` guarantees no alignment).
    Heap {
        buf: crate::util::align::AlignedVec,
        len: usize,
    },
}

/// An immutable byte region backed by a memory-mapped file (or, as a
/// fallback, a heap copy). Shared via `Arc` by every borrowed
/// [`crate::mips::VectorSet`] restored from one artifact, so the mapping
/// outlives all views into it.
pub struct MmapRegion {
    backing: Backing,
}

// SAFETY: the region is immutable after construction — the mapping is
// PROT_READ and no API hands out `&mut` — so shared references may cross
// threads freely.
unsafe impl Send for MmapRegion {}
unsafe impl Sync for MmapRegion {}

impl MmapRegion {
    /// Map `path` read-only. Errors if the file cannot be opened, is
    /// empty, or the mapping syscall fails; on non-unix targets this
    /// always errors and callers fall back to [`MmapRegion::read_file`].
    pub fn map_file(path: &Path) -> std::io::Result<MmapRegion> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let file = File::open(path)?;
            let len = file.metadata()?.len();
            if len == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    "cannot map an empty file",
                ));
            }
            if len > usize::MAX as u64 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    "file exceeds address space",
                ));
            }
            let len = len as usize;
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr == sys::MAP_FAILED || ptr.is_null() {
                return Err(std::io::Error::last_os_error());
            }
            // File descriptor can close now; the mapping keeps its own
            // reference to the pages.
            Ok(MmapRegion { backing: Backing::Mapped { ptr: ptr as *const u8, len } })
        }
        #[cfg(not(unix))]
        {
            let _ = path;
            Err(std::io::Error::new(
                std::io::ErrorKind::Unsupported,
                "mmap is only available on unix targets",
            ))
        }
    }

    /// Read `path` fully into a heap-backed region — the decode-path
    /// equivalent, used when mapping is unavailable.
    pub fn read_file(path: &Path) -> std::io::Result<MmapRegion> {
        let mut bytes = Vec::new();
        File::open(path)?.read_to_end(&mut bytes)?;
        Ok(MmapRegion::from_bytes(bytes))
    }

    /// Copy an in-memory buffer into an aligned heap region (tests,
    /// decode fallback).
    pub fn from_bytes(bytes: Vec<u8>) -> MmapRegion {
        let len = bytes.len();
        let mut buf = crate::util::align::AlignedVec::zeroed(len.div_ceil(4));
        // SAFETY: the AlignedVec owns len.div_ceil(4) f32s = at least
        // `len` writable bytes, disjoint from `bytes`.
        unsafe {
            std::ptr::copy_nonoverlapping(bytes.as_ptr(), buf.as_mut_ptr() as *mut u8, len);
        }
        MmapRegion { backing: Backing::Heap { buf, len } }
    }

    /// True when the bytes live in a real `mmap` mapping (resident pages
    /// are the kernel's to reclaim, not heap the process must budget).
    pub fn is_mapped(&self) -> bool {
        match &self.backing {
            #[cfg(unix)]
            Backing::Mapped { .. } => true,
            Backing::Heap { .. } => false,
        }
    }

    /// The whole region as bytes.
    pub fn bytes(&self) -> &[u8] {
        match &self.backing {
            #[cfg(unix)]
            // SAFETY: ptr/len came from a successful mmap that lives until
            // drop, and the mapping is never mutated.
            Backing::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            // SAFETY: buf owns at least `len` initialized bytes.
            Backing::Heap { buf, len } => unsafe {
                std::slice::from_raw_parts(buf.as_ptr() as *const u8, *len)
            },
        }
    }

    /// Total length in bytes.
    pub fn len(&self) -> usize {
        match &self.backing {
            #[cfg(unix)]
            Backing::Mapped { len, .. } => *len,
            Backing::Heap { len, .. } => *len,
        }
    }

    /// True when the region holds no bytes.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// View `byte_offset..byte_offset + n_f32s*4` as an `f32` slice.
    /// Panics if the range is out of bounds or `byte_offset` is not
    /// 4-byte aligned relative to an aligned base — callers
    /// ([`crate::mips::VectorSet::borrowed`]) validate alignment against
    /// the format's page-aligned section contract before constructing
    /// views. Only meaningful on little-endian targets, where the on-disk
    /// LE f32 bit patterns coincide with the in-memory representation;
    /// the pager refuses to borrow on big-endian builds.
    pub fn f32_slice(&self, byte_offset: usize, n_f32s: usize) -> &[f32] {
        let bytes = self.bytes();
        let end = byte_offset.checked_add(n_f32s * 4).expect("f32 view overflows");
        assert!(end <= bytes.len(), "f32 view out of region bounds");
        let base = bytes[byte_offset..end].as_ptr();
        assert_eq!(base as usize % 4, 0, "f32 view must be 4-byte aligned");
        // SAFETY: range checked above, alignment asserted, f32 has no
        // invalid bit patterns, and the region is immutable.
        unsafe { std::slice::from_raw_parts(base as *const f32, n_f32s) }
    }
}

impl Drop for MmapRegion {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Backing::Mapped { ptr, len } = self.backing {
            // SAFETY: ptr/len are the exact values a successful mmap
            // returned, unmapped exactly once.
            unsafe {
                sys::munmap(ptr as *mut std::ffi::c_void, len);
            }
        }
    }
}

impl fmt::Debug for MmapRegion {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("MmapRegion")
            .field("len", &self.len())
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heap_region_views_bytes_and_f32s() {
        let mut bytes = Vec::new();
        for v in [1.0f32, -2.5, 0.0, 3.25] {
            bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        let region = MmapRegion::from_bytes(bytes.clone());
        assert!(!region.is_mapped());
        assert_eq!(region.len(), 16);
        assert_eq!(region.bytes(), &bytes[..]);
        let fs = region.f32_slice(4, 2);
        assert_eq!(fs[0].to_bits(), (-2.5f32).to_bits());
        assert_eq!(fs[1].to_bits(), 0.0f32.to_bits());
    }

    #[cfg(unix)]
    #[test]
    fn mapped_region_matches_file_contents() {
        let dir = std::env::temp_dir().join(format!("fmwem_mmap_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("region.bin");
        let payload: Vec<u8> = (0..=255u8).cycle().take(3 * PAGE_SIZE + 17).collect();
        std::fs::write(&path, &payload).unwrap();

        let region = MmapRegion::map_file(&path).unwrap();
        assert!(region.is_mapped());
        assert_eq!(region.len(), payload.len());
        assert_eq!(region.bytes(), &payload[..]);
        // page-aligned base: the format relies on section offsets staying
        // 4-byte aligned once the mapping base is page-aligned
        assert_eq!(region.bytes().as_ptr() as usize % PAGE_SIZE, 0);

        drop(region);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }

    #[cfg(unix)]
    #[test]
    fn mapping_missing_or_empty_file_is_an_error() {
        let dir = std::env::temp_dir().join(format!("fmwem_mmap_empty_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let missing = dir.join("nope.bin");
        assert!(MmapRegion::map_file(&missing).is_err());
        let empty = dir.join("empty.bin");
        std::fs::write(&empty, b"").unwrap();
        assert!(MmapRegion::map_file(&empty).is_err());
        let _ = std::fs::remove_file(&empty);
        let _ = std::fs::remove_dir(&dir);
    }
}
