//! Cache-line-aligned f32 buffers for the SIMD kernel layer (DESIGN.md
//! §10).
//!
//! [`AlignedVec`] is a fixed-capacity-ish `Box<[f32]>` look-alike whose
//! allocation starts on a 64-byte boundary — one cache line, and wide
//! enough for any vector register this crate dispatches to (AVX2's 32-byte
//! `__m256`, NEON's 16-byte `float32x4_t`). [`crate::mips::VectorSet`]
//! stores its row-major payload in one so that every *row* starts aligned
//! (rows are padded to a multiple of the 16-lane kernel block — see
//! `VectorSet::stride`).
//!
//! The kernels themselves use unaligned loads (`loadu`) and therefore stay
//! correct on arbitrary `&[f32]` inputs such as borrowed query slices; the
//! alignment here is a throughput property (no cache-line-straddling rows),
//! not a safety requirement.

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;

/// Alignment of every [`AlignedVec`] allocation, in bytes.
pub const ALIGN: usize = 64;

/// A heap `[f32]` buffer aligned to [`ALIGN`] bytes. Always zero-initialized
/// at allocation; grows only through [`AlignedVec::resize_zeroed`] (the
/// append path), which reallocates and zero-fills the tail.
pub struct AlignedVec {
    ptr: NonNull<f32>,
    len: usize,
}

impl AlignedVec {
    fn layout(len: usize) -> Layout {
        Layout::from_size_align(len * std::mem::size_of::<f32>(), ALIGN)
            .expect("AlignedVec layout overflow")
    }

    /// A zero-filled buffer of `len` f32s on a 64-byte boundary.
    pub fn zeroed(len: usize) -> Self {
        if len == 0 {
            return AlignedVec { ptr: NonNull::dangling(), len: 0 };
        }
        let layout = Self::layout(len);
        // SAFETY: layout has non-zero size (len > 0) and valid alignment.
        let raw = unsafe { alloc_zeroed(layout) };
        let Some(ptr) = NonNull::new(raw.cast::<f32>()) else {
            handle_alloc_error(layout)
        };
        AlignedVec { ptr, len }
    }

    /// Number of f32 elements.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the buffer holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Grow (or shrink) to `new_len` elements, preserving the common
    /// prefix; any newly exposed tail is zero-filled. Reallocates — the
    /// buffer address may change.
    pub fn resize_zeroed(&mut self, new_len: usize) {
        if new_len == self.len {
            return;
        }
        let mut next = AlignedVec::zeroed(new_len);
        let keep = self.len.min(new_len);
        next[..keep].copy_from_slice(&self[..keep]);
        *self = next;
    }
}

impl Drop for AlignedVec {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: allocated by `zeroed` with this exact layout.
            unsafe { dealloc(self.ptr.as_ptr().cast(), Self::layout(self.len)) };
        }
    }
}

impl Clone for AlignedVec {
    fn clone(&self) -> Self {
        let mut out = AlignedVec::zeroed(self.len);
        out.copy_from_slice(self);
        out
    }
}

impl Deref for AlignedVec {
    type Target = [f32];
    #[inline]
    fn deref(&self) -> &[f32] {
        // SAFETY: ptr is valid for len elements (or dangling with len 0).
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl DerefMut for AlignedVec {
    #[inline]
    fn deref_mut(&mut self) -> &mut [f32] {
        // SAFETY: ptr is valid for len elements and uniquely borrowed.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl std::fmt::Debug for AlignedVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AlignedVec").field("len", &self.len).finish()
    }
}

// SAFETY: AlignedVec owns its allocation exclusively, like Vec<f32>.
unsafe impl Send for AlignedVec {}
// SAFETY: shared access is read-only through Deref, like Vec<f32>.
unsafe impl Sync for AlignedVec {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeroed_is_aligned_and_zero() {
        for len in [1usize, 3, 16, 17, 1000] {
            let v = AlignedVec::zeroed(len);
            assert_eq!(v.len(), len);
            assert_eq!(v.as_ptr() as usize % ALIGN, 0, "len={len}");
            assert!(v.iter().all(|&x| x == 0.0));
        }
        assert!(AlignedVec::zeroed(0).is_empty());
    }

    #[test]
    fn resize_preserves_prefix_and_zeroes_tail() {
        let mut v = AlignedVec::zeroed(4);
        v.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        v.resize_zeroed(7);
        assert_eq!(&v[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&v[4..], &[0.0, 0.0, 0.0]);
        assert_eq!(v.as_ptr() as usize % ALIGN, 0);
        v.resize_zeroed(2);
        assert_eq!(&v[..], &[1.0, 2.0]);
        v.resize_zeroed(0);
        assert!(v.is_empty());
    }

    #[test]
    fn clone_is_deep() {
        let mut a = AlignedVec::zeroed(3);
        a.copy_from_slice(&[1.0, 2.0, 3.0]);
        let b = a.clone();
        a[0] = 9.0;
        assert_eq!(&b[..], &[1.0, 2.0, 3.0]);
    }
}
