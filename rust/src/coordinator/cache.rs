//! Warm-index serving: a workload-keyed cache of pre-built k-MIPS indices
//! (DESIGN.md §6).
//!
//! The paper's sublinear per-iteration bound only pays off once the index
//! build — the Θ(m·d)+ preprocessing of Algorithm 2 — is amortized.
//! Release servers in the Hardt–Ligett–McSherry tradition answer many
//! query batches against one fixed workload, so under repeated traffic the
//! build is the single biggest serving-path cost the coordinator can
//! avoid. [`IndexCache`] keys pre-built indices by a *workload
//! fingerprint* — a content hash of the query vectors × the
//! [`IndexKind`] × the shard count — and hands out `Arc` clones: a hit
//! skips construction entirely, a miss builds once and populates the
//! cache, and least-recently-used entries are evicted beyond a
//! configurable capacity.
//!
//! Privacy note: the cache stores only *public* workload structure (the
//! query matrix and its index), never data-dependent state — the histogram,
//! the MWU iterates and all mechanism randomness stay per-job — so sharing
//! an index across jobs does not change any job's privacy guarantee.

use crate::lazy::ShardSet;
use crate::mips::{IndexKind, MipsIndex, VectorSet};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// FNV-1a step over one 64-bit word.
#[inline]
fn fnv_mix(h: u64, x: u64) -> u64 {
    (h ^ x).wrapping_mul(0x0000_0100_0000_01b3)
}

/// Content fingerprint of a vector set: two independent FNV-1a passes over
/// the shape and the raw f32 bit patterns (different offset bases; the
/// second pass mixes rotated words), concatenated into 128 bits.
///
/// Bit-identical rows in the same shape always fingerprint equal. The
/// converse is probabilistic, not guaranteed — FNV is not
/// collision-resistant — but a false match requires two *simultaneous*
/// independent 64-bit collisions, negligible for the trusted in-process
/// workloads the cache serves (the cache is not an integrity boundary).
pub fn fingerprint_vectors(vs: &VectorSet) -> u128 {
    let mut h1 = 0xcbf2_9ce4_8422_2325u64;
    let mut h2 = 0x6c62_272e_07bb_0142u64;
    h1 = fnv_mix(h1, vs.len() as u64);
    h1 = fnv_mix(h1, vs.dim() as u64);
    h2 = fnv_mix(h2, vs.dim() as u64);
    h2 = fnv_mix(h2, vs.len() as u64);
    for row in vs.rows() {
        for &v in row {
            let bits = u64::from(v.to_bits());
            h1 = fnv_mix(h1, bits);
            h2 = fnv_mix(h2, bits.rotate_left(17));
        }
    }
    ((h1 as u128) << 64) | h2 as u128
}

/// Cache key: which pre-built index can serve a job. Two jobs share an
/// entry iff they answer the same query set (by content fingerprint *and*
/// generation) with the same index implementation at the same shard count.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WorkloadKey {
    /// [`fingerprint_vectors`] of the *base* (generation-0) query matrix.
    /// Generations of one evolving workload share this fingerprint — it is
    /// the family identity the stale-but-patchable lookup matches on.
    pub fingerprint: u128,
    /// Which index implementation backs the entry.
    pub kind: IndexKind,
    /// Shard count (1 = monolithic index; ≥ 2 = a [`ShardSet`]).
    pub shards: usize,
    /// Monotonically increasing workload generation (DESIGN.md §9): 0 for
    /// a static workload, bumped by every `WorkloadUpdate`. An entry at an
    /// older generation of the same family is *stale-but-patchable* —
    /// the cache applies the missing deltas and promotes rather than
    /// serving it — never a hit.
    pub generation: u64,
}

impl WorkloadKey {
    /// Key for a generation-0 index of `kind` over `vs` split into
    /// `shards` shards. `shards` is clamped to `[1, m]` exactly like
    /// [`ShardSet::build`] clamps it, so over-asked shard counts that
    /// would build identical sets also share one cache entry.
    pub fn for_vectors(vs: &VectorSet, kind: IndexKind, shards: usize) -> Self {
        WorkloadKey {
            fingerprint: fingerprint_vectors(vs),
            kind,
            shards: shards.clamp(1, vs.len().max(1)),
            generation: 0,
        }
    }

    /// The same key at workload generation `g`.
    pub fn at_generation(mut self, g: u64) -> Self {
        self.generation = g;
        self
    }

    /// True when `other` indexes a different generation of the same
    /// workload family (same fingerprint, kind and shard count).
    pub fn same_family(&self, other: &WorkloadKey) -> bool {
        self.fingerprint == other.fingerprint
            && self.kind == other.kind
            && self.shards == other.shards
    }
}

/// A cached, `Arc`-shared index: monolithic or sharded. Cloning is cheap
/// (reference count only); the underlying index is immutable.
#[derive(Clone)]
pub enum CachedIndex {
    /// One monolithic k-MIPS index (`shards == 1` keys).
    Mono(Arc<dyn MipsIndex>),
    /// A sharded index set (`shards ≥ 2` keys).
    Sharded(Arc<ShardSet>),
}

impl CachedIndex {
    /// Live (selectable) candidates of the underlying index.
    pub fn live_len(&self) -> usize {
        match self {
            CachedIndex::Mono(i) => i.len(),
            CachedIndex::Sharded(s) => s.len(),
        }
    }

    /// Heap bytes pinned by this entry: the index's own accounting, which
    /// counts owned vector storage in full and mmap-borrowed storage as
    /// zero — resident mapped pages are the kernel's to reclaim, not heap
    /// the cache must budget (DESIGN.md §12).
    pub fn heap_bytes(&self) -> usize {
        match self {
            CachedIndex::Mono(i) => i.heap_bytes(),
            CachedIndex::Sharded(s) => s.heap_bytes(),
        }
    }

    /// Apply one workload delta, dispatching to the mono or sharded patch
    /// seam (DESIGN.md §9). Returns the patched entry and whether an
    /// amortized full rebuild ran instead of an incremental patch.
    pub fn patch(
        &self,
        delta: &crate::mips::WorkloadDelta,
        seed: u64,
    ) -> Result<(CachedIndex, bool), crate::mips::PatchError> {
        match self {
            CachedIndex::Mono(i) => {
                i.patch(delta, seed).map(|p| (CachedIndex::Mono(p.index), p.rebuilt))
            }
            CachedIndex::Sharded(s) => s
                .patch(delta, seed)
                .map(|(set, rebuilt)| (CachedIndex::Sharded(Arc::new(set)), rebuilt)),
        }
    }
}

/// What one cache consultation did — returned by
/// [`IndexCache::get_or_build`] so callers can meter their own hit/miss
/// counters per job.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheEvent {
    /// True when the entry was already resident (no build ran).
    pub hit: bool,
    /// Build cost actually paid by this call (zero on a hit).
    pub build_time: Duration,
    /// Build cost avoided — the cached entry's recorded build time (zero
    /// on a miss).
    pub saved: Duration,
}

/// Per-job accumulation of cache consultations, carried alongside the job
/// outcome so the pool can fold it into [`crate::metrics::Metrics`]
/// (`index_cache_hit` / `index_cache_miss` / `index_build_saved_ms`, plus
/// the store tier's `store_hit` / `store_miss` / `store_promote_ms` when a
/// persistent artifact store is attached — DESIGN.md §7).
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheReport {
    /// Consultations served from the in-memory (L1) cache.
    pub hits: u64,
    /// Consultations that missed every tier and paid a build.
    pub misses: u64,
    /// Consultations that missed L1 but were restored (promoted) from the
    /// persistent store tier instead of rebuilt.
    pub l2_hits: u64,
    /// Consultations served by patching a stale-but-patchable entry (an
    /// older generation of the workload, from either tier) forward instead
    /// of rebuilding — the dynamic-workload fast path (DESIGN.md §9).
    /// Every patched consultation is also counted in `hits` (patched in
    /// memory) or `l2_hits` (patched during a store promotion).
    pub patched: u64,
    /// Total build time skipped thanks to hits in either tier.
    pub saved: Duration,
    /// Total wall-clock spent decoding store artifacts on promotions —
    /// the price paid in place of the skipped builds.
    pub promoted: Duration,
    /// Total wall-clock spent applying workload deltas on patched serves
    /// (DESIGN.md §9) — kept separate from `promoted` so the store's
    /// decode metric is never inflated by in-memory patch work.
    pub patch_time: Duration,
    /// Misses where this process won the build lease and paid the build
    /// on behalf of every peer sharing the store dir (DESIGN.md §13).
    pub lease_acquired: u64,
    /// Consultations that waited on a peer's build lease — whether they
    /// then promoted the peer's artifact from L2 or acquired the expired
    /// lease themselves.
    pub lease_waited: u64,
    /// Leases expired and taken over from a crashed or stalled peer.
    pub lease_takeovers: u64,
    /// Peer-committed workload generations adopted via the manifest watch
    /// before this process could serve a stale generation (DESIGN.md §13).
    pub peer_invalidations: u64,
}

impl CacheReport {
    /// Fold one L1-only consultation into the running report.
    pub fn absorb(&mut self, ev: CacheEvent) {
        if ev.hit {
            self.hits += 1;
            self.saved += ev.saved;
        } else {
            self.misses += 1;
        }
    }

    /// Fold this job's cache activity into a metrics registry — the one
    /// recording convention shared by the batch pool and the serving
    /// runtime. An L1 miss counts as `index_cache_miss` whether it
    /// promoted from the store tier or paid a build; store counters accrue
    /// only when a persistent tier is attached. Durations accumulate at µs
    /// precision (`*_us`); the headline ms counters are derived once at
    /// shutdown so sub-ms builds are not zeroed away (DESIGN.md §6).
    pub fn record_into(&self, m: &mut crate::metrics::Metrics, store_attached: bool) {
        m.inc("index_cache_hit", self.hits);
        m.inc("index_cache_miss", self.misses + self.l2_hits);
        m.inc("index_cache_patched", self.patched);
        m.inc("index_patch_us", self.patch_time.as_micros() as u64);
        m.inc("index_build_saved_us", self.saved.as_micros() as u64);
        if store_attached {
            m.inc("store_hit", self.l2_hits);
            m.inc("store_miss", self.misses);
            m.inc("store_promote_us", self.promoted.as_micros() as u64);
            m.inc("lease_acquired", self.lease_acquired);
            m.inc("lease_waited", self.lease_waited);
            m.inc("lease_takeovers", self.lease_takeovers);
            m.inc("peer_invalidations", self.peer_invalidations);
        }
    }
}

/// Lifetime statistics of an [`IndexCache`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Entries currently resident.
    pub entries: usize,
    /// Lifetime lookup hits.
    pub hits: u64,
    /// Lifetime lookup misses.
    pub misses: u64,
    /// Entries evicted to stay within capacity (count or bytes).
    pub evictions: u64,
    /// Heap bytes pinned by resident entries ([`CachedIndex::heap_bytes`]
    /// summed — mmap-borrowed storage counts as zero).
    pub bytes: usize,
    /// Total build time skipped by hits.
    pub saved: Duration,
}

struct Entry {
    value: CachedIndex,
    build_time: Duration,
    last_used: u64,
    /// [`CachedIndex::heap_bytes`] at insert time (indices are immutable,
    /// so the figure never drifts).
    bytes: usize,
}

struct Inner {
    entries: HashMap<WorkloadKey, Entry>,
    /// Running sum of every resident entry's `bytes`.
    bytes: usize,
    /// Memoized content fingerprints by (workload id, class tag, rows,
    /// dim) — see [`IndexCache::fingerprint_for`].
    fingerprints: HashMap<(u64, u64, usize, usize), u128>,
    /// Monotonic use counter backing the LRU order.
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    saved: Duration,
}

/// A bounded, thread-safe, LRU cache of pre-built k-MIPS indices keyed by
/// [`WorkloadKey`]. One instance lives in the
/// [`crate::coordinator::Coordinator`] and is shared by all workers;
/// standalone use (benches, tests) works the same way.
///
/// The interior lock guards only the map — index *builds* run outside it
/// (see [`IndexCache::get_or_build`]), so a slow HNSW build never blocks
/// other workers' lookups.
pub struct IndexCache {
    capacity: usize,
    /// Heap-byte ceiling across resident entries; 0 = unlimited. Enforced
    /// alongside the entry count: eviction runs while either bound is
    /// exceeded (but always keeps the most recent insert, so one
    /// over-budget entry still serves rather than thrashing).
    max_bytes: usize,
    inner: Mutex<Inner>,
}

impl IndexCache {
    /// An empty cache holding at most `capacity` indices with no byte
    /// ceiling. Capacity 0 disables storage: every lookup misses and
    /// nothing is retained.
    pub fn new(capacity: usize) -> Self {
        Self::with_byte_budget(capacity, 0)
    }

    /// An empty cache bounded by both an entry count and a heap-byte
    /// budget (`max_bytes` 0 = unlimited). Byte accounting uses
    /// [`CachedIndex::heap_bytes`], so mmap-paged entries cost only their
    /// meta structures — the mechanism that lets a larger-than-RAM
    /// artifact stay resident under a small budget (DESIGN.md §12).
    pub fn with_byte_budget(capacity: usize, max_bytes: usize) -> Self {
        IndexCache {
            capacity,
            max_bytes,
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                bytes: 0,
                fingerprints: HashMap::new(),
                tick: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
                saved: Duration::ZERO,
            }),
        }
    }

    /// [`fingerprint_vectors`] memoized by `(workload_id, class_tag, rows,
    /// dim)`: a (workload id, query class) pair names deterministic
    /// content, so the m×d content scan runs once per workload instead of
    /// once per job — the warm path then pays only a map probe. The class
    /// tag ([`crate::workloads::QueryClassKind::tag`]) is part of the memo
    /// key because two classes of one workload id synthesize *different*
    /// content at the same shape; without it a memoized linear fingerprint
    /// would be served for a convex workload (and the wrong cached index
    /// with it). Sound only when the caller guarantees one (id, class) ↔
    /// one content per shape (true for the coordinator's seed-synthesized
    /// workloads); callers without that guarantee should use
    /// [`fingerprint_vectors`] directly. The memo is cleared if it ever
    /// outgrows 64× the entry capacity, bounding memory.
    pub fn fingerprint_for(&self, workload_id: u64, class_tag: u64, vs: &VectorSet) -> u128 {
        let memo_key = (workload_id, class_tag, vs.len(), vs.dim());
        if let Some(&fp) = self.inner.lock().unwrap().fingerprints.get(&memo_key) {
            return fp;
        }
        let fp = fingerprint_vectors(vs); // the scan runs outside the lock
        let mut g = self.inner.lock().unwrap();
        if g.fingerprints.len() >= self.capacity.max(1) * 64 {
            g.fingerprints.clear();
        }
        g.fingerprints.insert(memo_key, fp);
        fp
    }

    /// Maximum resident entries.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Heap-byte ceiling (0 = unlimited).
    pub fn max_bytes(&self) -> usize {
        self.max_bytes
    }

    /// Heap bytes currently pinned by resident entries.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Entries currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when `key` is resident (does not touch LRU order or counters).
    pub fn contains(&self, key: &WorkloadKey) -> bool {
        self.inner.lock().unwrap().entries.contains_key(key)
    }

    /// Lifetime statistics snapshot.
    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().unwrap();
        CacheStats {
            entries: g.entries.len(),
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            bytes: g.bytes,
            saved: g.saved,
        }
    }

    /// Look `key` up, counting a hit (and refreshing its LRU slot) or a
    /// miss. On a hit returns the entry and its recorded build time — the
    /// cost the caller just avoided.
    pub fn lookup(&self, key: &WorkloadKey) -> Option<(CachedIndex, Duration)> {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        inner.tick += 1;
        match inner.entries.get_mut(key) {
            Some(e) => {
                e.last_used = inner.tick;
                inner.hits += 1;
                inner.saved += e.build_time;
                Some((e.value.clone(), e.build_time))
            }
            None => {
                inner.misses += 1;
                None
            }
        }
    }

    /// Stale-but-patchable lookup (DESIGN.md §9): the resident entry of
    /// `key`'s workload family at the *highest generation strictly below*
    /// `key.generation`, if any. The caller patches it forward with the
    /// missing deltas and promotes the result under `key` — a stale entry
    /// is never handed out as a hit, and this scan leaves the hit/miss
    /// counters and LRU order untouched (the exact-key [`IndexCache::lookup`]
    /// that preceded it already metered the miss).
    pub fn lookup_patchable(&self, key: &WorkloadKey) -> Option<(WorkloadKey, CachedIndex, Duration)> {
        let g = self.inner.lock().unwrap();
        g.entries
            .iter()
            .filter(|(k, _)| k.same_family(key) && k.generation < key.generation)
            .max_by_key(|(k, _)| k.generation)
            .map(|(k, e)| (*k, e.value.clone(), e.build_time))
    }

    /// Drop an entry (a stale generation superseded by a patched promote).
    /// Returns true when something was removed.
    pub fn remove(&self, key: &WorkloadKey) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.entries.remove(key) {
            Some(e) => {
                g.bytes -= e.bytes;
                true
            }
            None => false,
        }
    }

    /// Insert an entry built at cost `build_time`, evicting least-recently
    /// used entries while over the entry-count capacity *or* the heap-byte
    /// budget. The just-inserted entry itself is never evicted — a single
    /// over-budget index still serves (degraded accounting beats
    /// thrashing), which the byte budget makes rare in the first place:
    /// mmap-paged entries pin only their meta structures. A no-op when
    /// capacity is 0.
    pub fn insert(&self, key: WorkloadKey, value: CachedIndex, build_time: Duration) {
        if self.capacity == 0 {
            return;
        }
        let bytes = value.heap_bytes(); // the walk runs outside the lock
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(old) = inner
            .entries
            .insert(key, Entry { value, build_time, last_used: tick, bytes })
        {
            inner.bytes -= old.bytes;
        }
        inner.bytes += bytes;
        while inner.entries.len() > 1
            && (inner.entries.len() > self.capacity
                || (self.max_bytes > 0 && inner.bytes > self.max_bytes))
        {
            let oldest = inner
                .entries
                .iter()
                .filter(|(k, _)| **k != key)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            match oldest {
                Some(k) => {
                    let e = inner.entries.remove(&k).expect("oldest key is resident");
                    inner.bytes -= e.bytes;
                    inner.evictions += 1;
                }
                None => break,
            }
        }
    }

    /// The serving-path primitive: return the cached entry for `key`, or
    /// run `build` — which must return the entry plus its measured build
    /// time — and populate the cache. The build runs *outside* the cache
    /// lock; if two workers race on the same cold key both build and the
    /// later insert wins (wasted work, never a wrong result — the entries
    /// are interchangeable by construction).
    pub fn get_or_build(
        &self,
        key: WorkloadKey,
        build: impl FnOnce() -> (CachedIndex, Duration),
    ) -> (CachedIndex, CacheEvent) {
        if let Some((value, saved)) = self.lookup(&key) {
            return (value, CacheEvent { hit: true, build_time: Duration::ZERO, saved });
        }
        let (value, build_time) = build();
        self.insert(key, value.clone(), build_time);
        (value, CacheEvent { hit: false, build_time, saved: Duration::ZERO })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mips::build_index;
    use std::cell::Cell;

    fn vs(n: usize, d: usize, salt: f32) -> VectorSet {
        let data: Vec<f32> = (0..n * d).map(|i| i as f32 * 0.25 + salt).collect();
        VectorSet::new(data, n, d)
    }

    fn mono(v: &VectorSet) -> CachedIndex {
        CachedIndex::Mono(build_index(IndexKind::Flat, v.clone(), 1))
    }

    fn key(fp: u128) -> WorkloadKey {
        WorkloadKey { fingerprint: fp, kind: IndexKind::Flat, shards: 1, generation: 0 }
    }

    #[test]
    fn fingerprint_is_content_and_shape_sensitive() {
        let a = vs(4, 3, 0.0);
        let b = vs(4, 3, 0.0);
        assert_eq!(fingerprint_vectors(&a), fingerprint_vectors(&b));

        // same data, different shape
        let c = VectorSet::new(a.to_vec(), 3, 4);
        assert_ne!(fingerprint_vectors(&a), fingerprint_vectors(&c));

        // one value changed
        let mut data = a.to_vec();
        data[5] += 1.0;
        let d = VectorSet::new(data, 4, 3);
        assert_ne!(fingerprint_vectors(&a), fingerprint_vectors(&d));
    }

    #[test]
    fn workload_key_separates_kind_and_shards() {
        let v = vs(8, 2, 0.5);
        let base = WorkloadKey::for_vectors(&v, IndexKind::Flat, 1);
        assert_ne!(base, WorkloadKey::for_vectors(&v, IndexKind::Hnsw, 1));
        assert_ne!(base, WorkloadKey::for_vectors(&v, IndexKind::Flat, 4));
        // a later generation is a different key of the same family
        let gen1 = base.at_generation(1);
        assert_ne!(base, gen1);
        assert!(base.same_family(&gen1));
        assert!(!gen1.same_family(&WorkloadKey::for_vectors(&v, IndexKind::Hnsw, 1)));
        // shards clamp to [1, m] — the same clamp ShardSet::build applies,
        // so interchangeable builds share one key
        assert_eq!(base, WorkloadKey::for_vectors(&v, IndexKind::Flat, 0));
        assert_eq!(
            WorkloadKey::for_vectors(&v, IndexKind::Flat, 20),
            WorkloadKey::for_vectors(&v, IndexKind::Flat, 8),
        );
    }

    #[test]
    fn hit_skips_build_and_meters_savings() {
        let cache = IndexCache::new(2);
        let v = vs(6, 3, 1.0);
        let k = key(7);
        let builds = Cell::new(0usize);
        let make = || {
            builds.set(builds.get() + 1);
            (mono(&v), Duration::from_millis(5))
        };

        let (_, ev1) = cache.get_or_build(k, make);
        assert!(!ev1.hit);
        assert_eq!(ev1.build_time, Duration::from_millis(5));
        assert_eq!(builds.get(), 1);

        let (_, ev2) = cache.get_or_build(k, || {
            builds.set(builds.get() + 1);
            (mono(&v), Duration::ZERO)
        });
        assert!(ev2.hit, "second consultation must hit");
        assert_eq!(builds.get(), 1, "hit must not rebuild");
        assert_eq!(ev2.saved, Duration::from_millis(5));

        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(s.saved, Duration::from_millis(5));
    }

    #[test]
    fn fingerprint_memo_matches_direct_hash() {
        let cache = IndexCache::new(2);
        let v = vs(6, 3, 4.0);
        let direct = fingerprint_vectors(&v);
        assert_eq!(cache.fingerprint_for(11, 0, &v), direct);
        assert_eq!(cache.fingerprint_for(11, 0, &v), direct); // memoized path
        assert_eq!(cache.fingerprint_for(12, 0, &v), direct); // same content, new id
        assert_eq!(cache.fingerprint_for(11, 1, &v), direct); // same id, new class tag
    }

    #[test]
    fn eviction_at_capacity_is_lru() {
        let cache = IndexCache::new(2);
        let v = vs(6, 3, 2.0);
        cache.insert(key(1), mono(&v), Duration::ZERO);
        cache.insert(key(2), mono(&v), Duration::ZERO);
        // touch key 1 so key 2 becomes the LRU entry
        assert!(cache.lookup(&key(1)).is_some());
        cache.insert(key(3), mono(&v), Duration::ZERO);

        assert_eq!(cache.len(), 2);
        assert!(cache.contains(&key(1)), "recently used entry must survive");
        assert!(!cache.contains(&key(2)), "LRU entry must be evicted");
        assert!(cache.contains(&key(3)));
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn byte_budget_evicts_lru_but_keeps_newest() {
        let v = vs(64, 8, 1.0);
        let per = mono(&v).heap_bytes();
        assert!(per > 0, "owned flat index must account its rows");

        // budget for exactly two entries; the third insert evicts the LRU
        let cache = IndexCache::with_byte_budget(10, per * 2);
        cache.insert(key(1), mono(&v), Duration::ZERO);
        cache.insert(key(2), mono(&v), Duration::ZERO);
        assert_eq!(cache.resident_bytes(), per * 2);
        assert!(cache.lookup(&key(1)).is_some(), "touch 1 so 2 is LRU");
        cache.insert(key(3), mono(&v), Duration::ZERO);
        assert!(!cache.contains(&key(2)), "byte pressure evicts the LRU entry");
        assert!(cache.contains(&key(1)) && cache.contains(&key(3)));
        assert_eq!(cache.stats().bytes, per * 2);

        // a single entry larger than the whole budget still serves...
        let tight = IndexCache::with_byte_budget(10, 1);
        tight.insert(key(9), mono(&v), Duration::ZERO);
        assert!(tight.contains(&key(9)));
        // ...and is evicted only when a newer insert needs the room
        tight.insert(key(10), mono(&v), Duration::ZERO);
        assert!(!tight.contains(&key(9)) && tight.contains(&key(10)));
        // remove() releases its accounting
        assert!(tight.remove(&key(10)));
        assert_eq!(tight.resident_bytes(), 0);

        // re-inserting the same key replaces, not double-counts
        let cache = IndexCache::with_byte_budget(10, 0);
        cache.insert(key(4), mono(&v), Duration::ZERO);
        cache.insert(key(4), mono(&v), Duration::ZERO);
        assert_eq!(cache.resident_bytes(), per);
    }

    #[test]
    fn capacity_zero_disables_storage() {
        let cache = IndexCache::new(0);
        let v = vs(6, 3, 3.0);
        let builds = Cell::new(0usize);
        for _ in 0..3 {
            let (_, ev) = cache.get_or_build(key(9), || {
                builds.set(builds.get() + 1);
                (mono(&v), Duration::ZERO)
            });
            assert!(!ev.hit);
        }
        assert_eq!(builds.get(), 3, "a disabled cache builds every time");
        assert!(cache.is_empty());
        assert_eq!(cache.stats().misses, 3);
    }

    /// Generation-aware lookup: an exact key never matches an older
    /// generation; `lookup_patchable` finds the newest older entry of the
    /// family; `remove` drops a superseded stale entry.
    #[test]
    fn stale_generations_are_patchable_never_hits() {
        let cache = IndexCache::new(4);
        let v = vs(6, 3, 5.0);
        let k0 = key(21);
        let k2 = k0.at_generation(2);
        let k5 = k0.at_generation(5);
        cache.insert(k0, mono(&v), Duration::from_millis(3));
        cache.insert(k2, mono(&v), Duration::from_millis(4));

        // exact lookup at generation 5 misses — stale entries never hit
        assert!(cache.lookup(&k5).is_none());
        // ...but the newest older family member is patchable
        let (stale_key, _, build) = cache.lookup_patchable(&k5).unwrap();
        assert_eq!(stale_key, k2, "highest generation below the request wins");
        assert_eq!(build, Duration::from_millis(4));
        // a different family is never offered
        assert!(cache.lookup_patchable(&key(99).at_generation(5)).is_none());
        // generation 0 has nothing below it
        assert!(cache.lookup_patchable(&k0).is_none());

        assert!(cache.remove(&k2));
        assert!(!cache.remove(&k2), "second remove is a no-op");
        let (stale_key, _, _) = cache.lookup_patchable(&k5).unwrap();
        assert_eq!(stale_key, k0, "next-oldest family member steps up");
    }

    #[test]
    fn report_absorbs_events() {
        let ms3 = Duration::from_millis(3);
        let mut rep = CacheReport::default();
        rep.absorb(CacheEvent { hit: false, build_time: ms3, saved: Duration::ZERO });
        rep.absorb(CacheEvent { hit: true, build_time: Duration::ZERO, saved: ms3 });
        rep.absorb(CacheEvent { hit: true, build_time: Duration::ZERO, saved: ms3 });
        assert_eq!((rep.hits, rep.misses), (2, 1));
        assert_eq!(rep.saved, Duration::from_millis(6));
    }
}
