//! Job specifications and results for the coordinator, plus the shard
//! search job ([`ShardSearchJob`]) that [`crate::lazy::ShardedLazyEm`]
//! fans out over [`super::pool::parallel_map`], plus the job executors —
//! [`execute`] (cold) and [`execute_with_cache`] (warm-index serving via
//! the tiered cache: in-memory LRU over the persistent artifact store,
//! DESIGN.md §6–§7).

use super::cache::{fingerprint_vectors, CacheReport, CachedIndex, WorkloadKey};
use crate::lazy::{LazySample, ShardSet, ShardedLazyEm};
use crate::store::{TieredEvent, TieredIndexCache};
use crate::lp::{run_scalar, ScalarLpConfig, SelectionMode};
use crate::mips::{build_index, IndexKind};
use crate::mwem::{FastMwemConfig, Histogram, MwemConfig, NativeBackend, QuerySet};
use crate::util::rng::Rng;
use crate::workloads::{self, LpInstance, QueryClassKind, WorkloadRegistry};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One shard's slice of a sharded lazy-EM draw: which shard to search and
/// the pre-split RNG stream it must consume. Streams are split on the
/// submitting thread, so a batch of these jobs produces the same draw
/// regardless of how the pool schedules them.
#[derive(Clone, Debug)]
pub struct ShardSearchJob {
    /// Index of the shard to draw from.
    pub shard_id: usize,
    /// Independent randomness for this shard's Gumbel perturbations.
    pub rng: Rng,
}

/// Execute one [`ShardSearchJob`] against a [`ShardedLazyEm`]: retrieve the
/// shard's top-k for `query`, take its lazy Gumbel max (scores pre-scaled
/// by `scale` = ε₀/(2Δ)), and return the shard's winner with a global
/// candidate id.
pub fn execute_shard_search(
    em: &ShardedLazyEm,
    query: &[f32],
    scale: f64,
    job: ShardSearchJob,
) -> LazySample {
    em.shard_draw(job.shard_id, job.rng, query, scale)
}

/// Private linear query release job (§3).
#[derive(Clone, Debug)]
pub struct ReleaseJobSpec {
    /// Domain size U.
    pub u: usize,
    /// Number of queries m.
    pub m: usize,
    /// Dataset size n.
    pub n: usize,
    /// Number of MWEM rounds T.
    pub t: usize,
    /// Privacy budget ε for this job.
    pub eps: f64,
    /// Privacy budget δ for this job.
    pub delta: f64,
    /// None → classic MWEM; Some(kind) → Fast-MWEM with that index.
    pub index: Option<IndexKind>,
    /// Number of lazy-EM shards (≤ 1 → one monolithic index).
    pub shards: usize,
    /// Query class answered by this release: linear counting queries or a
    /// beyond-linear convex-loss workload (DESIGN.md §14). The class picks
    /// the synthesis generator, so it is part of the workload's content
    /// identity: two classes of one `workload` seed fingerprint — and
    /// cache — independently.
    pub class: QueryClassKind,
    /// Workload identity — the synthesis seed for the (histogram, query
    /// set) pair. Jobs sharing `workload` (and shape) answer the same
    /// query set, so their k-MIPS index is shared through the
    /// coordinator's [`IndexCache`] instead of being rebuilt per job.
    pub workload: u64,
    /// Submitting tenant — the admission key for the serving runtime's
    /// per-tenant privacy accountant ([`crate::server::TenantBudget`],
    /// DESIGN.md §8). The batch coordinator's global ε cap ignores it.
    pub tenant: u64,
    /// Mechanism randomness seed — fresh per job even when the workload
    /// repeats, so repeated jobs are independent DP releases.
    pub seed: u64,
}

/// Scalar-private LP job (§4.1).
#[derive(Clone, Debug)]
pub struct LpJobSpec {
    /// Number of constraints m.
    pub m: usize,
    /// Number of variables d.
    pub d: usize,
    /// Number of MWU rounds T.
    pub t: usize,
    /// Privacy budget ε for this job.
    pub eps: f64,
    /// Privacy budget δ for this job.
    pub delta: f64,
    /// b-vector sensitivity Δ∞ between neighboring databases.
    pub delta_inf: f64,
    /// Constraint-selection mechanism (exhaustive / lazy / sharded lazy).
    pub mode: SelectionMode,
    /// Submitting tenant — the admission key for the serving runtime's
    /// per-tenant privacy accountant ([`crate::server::TenantBudget`],
    /// DESIGN.md §8). The batch coordinator's global ε cap ignores it.
    pub tenant: u64,
    /// Workload / mechanism seed.
    pub seed: u64,
}

/// Dynamic-workload update job (DESIGN.md §9): append/retire query rows of
/// an evolving workload. Updates touch only *public* workload structure
/// (the query matrix — never the histogram, iterates or mechanism
/// randomness), so they are data-independent and spend **zero ε**; they
/// still ride the serving queue like any other job so ordering, admission
/// accounting and drain semantics hold.
#[derive(Clone, Debug)]
pub struct WorkloadUpdateSpec {
    /// Workload id whose query set evolves — the same synthesis seed
    /// release jobs carry, so the update and the releases agree on the
    /// base (generation-0) content.
    pub workload: u64,
    /// Domain size U of the base workload (row dimension).
    pub u: usize,
    /// Base query count m (generation-0 shape).
    pub m: usize,
    /// Dataset size n of the base workload (the base synthesis consumes
    /// histogram randomness before query randomness, so the update must
    /// reproduce both to fingerprint the family).
    pub n: usize,
    /// Rows to append (synthesized deterministically per generation).
    pub insert: usize,
    /// Live rows to retire (clamped so at least one row survives).
    pub tombstone: usize,
    /// Submitting tenant — updates are admission-checked like any job but
    /// reserve ε = 0.
    pub tenant: u64,
}

/// A unit of work accepted by the [`super::Coordinator`].
#[derive(Clone, Debug)]
pub enum JobSpec {
    /// Private linear-query release (classic or Fast-MWEM).
    Release(ReleaseJobSpec),
    /// Scalar-private LP feasibility solve.
    Lp(LpJobSpec),
    /// Dynamic-workload update: evolve a workload's query set in place
    /// (zero-ε, data-independent — DESIGN.md §9).
    Update(WorkloadUpdateSpec),
}

impl JobSpec {
    /// Short label used for per-kind metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            JobSpec::Release(_) => "release",
            JobSpec::Lp(_) => "lp",
            JobSpec::Update(_) => "update",
        }
    }

    /// Nominal privacy budget ε this job charges at admission. Workload
    /// updates are data-independent and charge zero.
    pub fn eps(&self) -> f64 {
        match self {
            JobSpec::Release(r) => r.eps,
            JobSpec::Lp(l) => l.eps,
            JobSpec::Update(_) => 0.0,
        }
    }

    /// Submitting tenant id — the serving runtime's admission key.
    pub fn tenant(&self) -> u64 {
        match self {
            JobSpec::Release(r) => r.tenant,
            JobSpec::Lp(l) => l.tenant,
            JobSpec::Update(u) => u.tenant,
        }
    }
}

/// What a finished job reports back.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    /// Final quality metric: max query error (release) / max violation (LP).
    pub quality: f64,
    /// Privacy ε spent per the accountant.
    pub eps_spent: f64,
    /// Privacy δ spent per the accountant.
    pub delta_spent: f64,
    /// Mean selection work per round (score evaluations).
    pub avg_select_work: f64,
    /// End-to-end solver wall-clock.
    pub total_time: Duration,
    /// The released artifact itself — the averaged synthetic histogram
    /// (release jobs) or the LP iterate x̄ (lp jobs); `None` for
    /// bookkeeping jobs with nothing to release (updates). The wire front
    /// end streams this back chunked (DESIGN.md §11) instead of returning
    /// only summary statistics.
    pub output: Option<Vec<f32>>,
}

/// One job's result as delivered by [`super::Coordinator::finish`].
#[derive(Debug)]
pub struct JobResult {
    /// Submission id (dense, in submission order).
    pub job_id: usize,
    /// The spec's [`JobSpec::kind`] label.
    pub kind: &'static str,
    /// The outcome, or the error that failed the job.
    pub outcome: anyhow::Result<JobOutcome>,
}

/// Execute a job cold (no index reuse, no dynamic-workload state).
/// Equivalent to [`execute_with_cache`] with no cache and no registry;
/// kept as the simple entry point for one-shot callers.
pub fn execute(spec: &JobSpec) -> anyhow::Result<JobOutcome> {
    execute_with_cache(spec, None, None).map(|(outcome, _)| outcome)
}

/// Reject structurally invalid specs with a clean `Err` instead of letting
/// them panic (or degenerate) deep inside a solver. The serving runtime
/// relies on this fail-fast path: a failed job becomes a failed
/// [`JobResult`] whose tenant reservation is refunded, and a persistent
/// worker survives it.
fn validate(spec: &JobSpec) -> anyhow::Result<()> {
    match spec {
        JobSpec::Release(r) => anyhow::ensure!(
            r.u > 0
                && r.m > 0
                && r.n > 0
                && r.t > 0
                && r.eps > 0.0
                && r.delta > 0.0
                && r.delta < 1.0,
            "invalid release spec: u={} m={} n={} t={} eps={} delta={} \
             (sizes, rounds and ε must be positive; 0 < δ < 1)",
            r.u,
            r.m,
            r.n,
            r.t,
            r.eps,
            r.delta
        ),
        JobSpec::Lp(l) => anyhow::ensure!(
            l.m > 0
                && l.d > 0
                && l.t > 0
                && l.eps > 0.0
                && l.delta > 0.0
                && l.delta < 1.0
                && l.delta_inf > 0.0,
            "invalid lp spec: m={} d={} t={} eps={} delta={} delta_inf={} \
             (sizes, rounds, ε and Δ∞ must be positive; 0 < δ < 1)",
            l.m,
            l.d,
            l.t,
            l.eps,
            l.delta,
            l.delta_inf
        ),
        JobSpec::Update(u) => anyhow::ensure!(
            u.u > 0 && u.m > 0 && u.n > 0 && (u.insert > 0 || u.tombstone > 0),
            "invalid update spec: u={} m={} n={} insert={} tombstone={} \
             (base shape must be positive and the update must change something)",
            u.u,
            u.m,
            u.n,
            u.insert,
            u.tombstone
        ),
    }
    Ok(())
}

/// Execute a job (called on a worker thread), consulting the coordinator's
/// tiered warm-index cache when one is supplied: a release job whose
/// workload key is resident in memory reuses the shared `Arc` index; an L1
/// miss with a persisted artifact decodes and promotes it (cross-restart
/// warm serving, DESIGN.md §7); a double miss builds once and populates
/// both tiers for subsequent jobs. Workloads are synthesized from the
/// spec's `workload` seed — a stand-in for loading a caller-provided
/// dataset.
///
/// With a [`WorkloadRegistry`] attached the workload may be *dynamic*
/// (DESIGN.md §9): release jobs answer the family's current generation —
/// the effective query set is the base plus the replayed delta chain, the
/// cache key carries the generation, and stale cached generations are
/// patched forward rather than rebuilt (and never served). `Update` jobs
/// require the registry and error cleanly without one.
pub fn execute_with_cache(
    spec: &JobSpec,
    cache: Option<&TieredIndexCache>,
    registry: Option<&WorkloadRegistry>,
) -> anyhow::Result<(JobOutcome, CacheReport)> {
    validate(spec)?;
    let mut report = CacheReport::default();
    match spec {
        JobSpec::Release(r) => {
            let mut rng = Rng::new(r.workload);
            let h: Histogram = workloads::gaussian_histogram(&mut rng, r.u, r.n);
            let base_q: QuerySet = workloads::synthesize_queries(&mut rng, r.class, r.m, r.u);
            // Resolve the family's current generation and materialize the
            // effective query set. Static serving (no registry) stays on
            // the generation-0 fast path with zero extra work.
            let (generation, family_fp, q) = match registry {
                Some(reg) => {
                    let fp = match cache {
                        Some(c) => {
                            c.fingerprint_for(r.workload, r.class.tag(), base_q.vectors())
                        }
                        None => fingerprint_vectors(base_q.vectors()),
                    };
                    reg.ensure_base(fp, r.m);
                    // Adopt any generations a peer process committed to the
                    // shared store before we read the family's generation —
                    // the cross-process half of the never-serve-stale
                    // invariant (DESIGN.md §13).
                    if let Some(c) = cache {
                        report.peer_invalidations += c.sync_peer_updates(fp, reg);
                    }
                    if reg.generation(fp) == 0 {
                        (0, Some(fp), base_q)
                    } else {
                        let (g, vs) = reg.effective_vectors(fp, base_q.vectors())?;
                        (g, Some(fp), QuerySet::new(vs))
                    }
                }
                None => (0, None, base_q),
            };
            let cfg = MwemConfig::paper(r.t, r.u, r.eps, r.delta, r.seed ^ 0xC0FFEE);
            let (result, work) = match r.index {
                None => {
                    let res = crate::mwem::run_classic(&cfg, &q, &h, &mut NativeBackend);
                    let w = res.avg_select_work;
                    (res, w)
                }
                Some(kind) => {
                    let fcfg = FastMwemConfig::new(cfg, kind).with_shards(r.shards);
                    // One build closure serves both the cached and the
                    // uncached path. Builds are seeded from the *workload*
                    // (not the per-job mechanism seed) and `shards` is
                    // clamped against the BASE row count — the clamp must
                    // be generation-independent because `key.shards` is
                    // part of the family identity: if it drifted with the
                    // effective row count, stale-but-patchable lookups
                    // would never match across generations. A fresh
                    // `ShardSet::build` re-clamps internally if the
                    // effective set shrank below the shard count.
                    let shards = r.shards.clamp(1, r.m.max(1));
                    let build_seed = r.workload ^ 0x5EED;
                    let build = || {
                        let t0 = Instant::now();
                        let built = if shards > 1 {
                            CachedIndex::Sharded(Arc::new(ShardSet::build(
                                kind,
                                q.vectors(),
                                shards,
                                build_seed,
                            )))
                        } else {
                            CachedIndex::Mono(build_index(
                                kind,
                                q.vectors().clone(),
                                build_seed,
                            ))
                        };
                        (built, t0.elapsed())
                    };
                    let (cached, ev) = match cache {
                        Some(c) => {
                            // memoized per workload id: the content scan
                            // runs once per workload, not once per job.
                            // The fingerprint is always the *base*
                            // content's — the family identity — while the
                            // generation distinguishes the evolved states.
                            let key = WorkloadKey {
                                fingerprint: match family_fp {
                                    Some(fp) => fp,
                                    None => c.fingerprint_for(
                                        r.workload,
                                        r.class.tag(),
                                        q.vectors(),
                                    ),
                                },
                                kind,
                                shards,
                                generation,
                            };
                            let (cached, ev) = c.get_or_build_dynamic(
                                key,
                                |from| {
                                    registry
                                        .and_then(|reg| reg.deltas(key.fingerprint, from, generation))
                                },
                                build,
                            );
                            ev.fold_into(&mut report);
                            (cached, ev)
                        }
                        None => {
                            let (built, build_time) = build();
                            let ev = TieredEvent { build_time, ..Default::default() };
                            (built, ev)
                        }
                    };
                    let out = match cached {
                        CachedIndex::Mono(index) => crate::mwem::run_fast_with_index(
                            &fcfg,
                            &q,
                            &h,
                            &mut NativeBackend,
                            index.as_ref(),
                            ev.build_time,
                        ),
                        CachedIndex::Sharded(set) => crate::mwem::run_fast_with_shard_set(
                            &fcfg,
                            &q,
                            &h,
                            &mut NativeBackend,
                            &set,
                            ev.build_time,
                        ),
                    };
                    let w = out.result.avg_select_work;
                    (out.result, w)
                }
            };
            let quality = q.max_error(h.probs(), &result.p_avg);
            Ok((
                JobOutcome {
                    quality,
                    eps_spent: result.privacy_spent.0,
                    delta_spent: result.privacy_spent.1,
                    avg_select_work: work,
                    total_time: result.total_time,
                    output: Some(result.p_avg),
                },
                report,
            ))
        }
        JobSpec::Lp(l) => {
            let mut rng = Rng::new(l.seed);
            let lp: LpInstance = workloads::random_feasibility_lp(&mut rng, l.m, l.d, 0.6);
            let cfg = ScalarLpConfig {
                t: l.t,
                eps: l.eps,
                delta: l.delta,
                delta_inf: l.delta_inf,
                mode: l.mode,
                seed: l.seed ^ 0xBEEF,
                log_every: 0,
            };
            let res = run_scalar(&cfg, &lp);
            Ok((
                JobOutcome {
                    quality: lp.max_violation(&res.x),
                    eps_spent: l.eps,
                    delta_spent: l.delta,
                    avg_select_work: res.avg_select_work,
                    total_time: res.total_time,
                    output: Some(res.x),
                },
                report,
            ))
        }
        JobSpec::Update(u) => {
            let reg = registry.ok_or_else(|| {
                anyhow::anyhow!(
                    "WorkloadUpdate requires a dynamic-workload registry — \
                     submit updates through a coordinator or serving runtime"
                )
            })?;
            let t0 = Instant::now();
            // Reproduce the base synthesis (histogram randomness is drawn
            // before query randomness, so both must be consumed) to derive
            // the family fingerprint the release jobs will use.
            let mut rng = Rng::new(u.workload);
            let _h: Histogram = workloads::gaussian_histogram(&mut rng, u.u, u.n);
            let base_q: QuerySet = workloads::binary_queries(&mut rng, u.m, u.u);
            let fp = match cache {
                // updates evolve linear-query families only, so the memo
                // tag matches the releases they target
                Some(c) => c.fingerprint_for(
                    u.workload,
                    QueryClassKind::Linear.tag(),
                    base_q.vectors(),
                ),
                None => fingerprint_vectors(base_q.vectors()),
            };
            reg.ensure_base(fp, u.m);
            // Land this update on top of any chain a peer already
            // committed, not beside it: sync first so the generation we
            // mint extends the store's delta log (DESIGN.md §13).
            if let Some(c) = cache {
                report.peer_invalidations += c.sync_peer_updates(fp, reg);
            }
            let (generation, delta) =
                reg.append_synthesized(fp, u.u, u.insert, u.tombstone)?;
            // Persist the compact delta artifact so the new generation
            // survives restarts; stale cached indices are patched forward
            // lazily on their next lookup (never served stale — the
            // generation in the cache key guarantees it).
            if let Some(store) = cache.and_then(|c| c.store()) {
                if let Err(e) = store.save_delta(fp, generation, &delta) {
                    eprintln!(
                        "warning: could not persist workload delta g{generation} \
                         ({e:#}); the update is in-memory only"
                    );
                }
            }
            Ok((
                JobOutcome {
                    // updates are data-independent bookkeeping: no release
                    // quality to report, zero privacy spend
                    quality: 0.0,
                    eps_spent: 0.0,
                    delta_spent: 0.0,
                    avg_select_work: delta.rows_touched() as f64,
                    total_time: t0.elapsed(),
                    output: None,
                },
                report,
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn release_job_executes() {
        let spec = JobSpec::Release(ReleaseJobSpec {
            u: 64,
            m: 50,
            n: 300,
            t: 50,
            eps: 1.0,
            delta: 1e-3,
            index: Some(IndexKind::Flat),
            shards: 1,
            class: QueryClassKind::Linear,
            workload: 1,
            tenant: 0,
            seed: 1,
        });
        let out = execute(&spec).unwrap();
        assert!(out.quality.is_finite() && out.quality >= 0.0);
        assert!(out.eps_spent > 0.0);
    }

    /// Convex-loss release rides the same executor: lazy selection over
    /// the loss rows, sublinear work, and a distinct cache identity from
    /// the linear class of the same workload seed.
    #[test]
    fn convex_release_job_executes_and_caches_separately() {
        let cache = TieredIndexCache::memory_only(4);
        let spec = |class| {
            JobSpec::Release(ReleaseJobSpec {
                u: 64,
                m: 400,
                n: 300,
                t: 40,
                eps: 1.0,
                delta: 1e-3,
                index: Some(IndexKind::Flat),
                shards: 1,
                class,
                workload: 7,
                tenant: 0,
                seed: 1,
            })
        };
        for class in [QueryClassKind::ConvexLsq, QueryClassKind::ConvexLogistic] {
            let (out, _) = execute_with_cache(&spec(class), Some(&cache), None).unwrap();
            assert!(out.quality.is_finite() && out.quality >= 0.0);
            assert!(out.eps_spent > 0.0);
            // lazy selection stays sublinear on the dense loss rows
            assert!(out.avg_select_work < 400.0, "work {}", out.avg_select_work);
        }
        let (_, rep) =
            execute_with_cache(&spec(QueryClassKind::Linear), Some(&cache), None).unwrap();
        assert_eq!(
            (rep.hits, rep.misses),
            (0, 1),
            "linear class of the same workload seed must not hit a convex entry"
        );
        assert_eq!(cache.l1().len(), 3, "three classes -> three cache entries");
    }

    #[test]
    fn sharded_release_job_executes() {
        let spec = JobSpec::Release(ReleaseJobSpec {
            u: 64,
            m: 200,
            n: 300,
            t: 50,
            eps: 1.0,
            delta: 1e-3,
            index: Some(IndexKind::Flat),
            shards: 4,
            class: QueryClassKind::Linear,
            workload: 1,
            tenant: 0,
            seed: 1,
        });
        let out = execute(&spec).unwrap();
        assert!(out.quality.is_finite() && out.quality >= 0.0);
        // per-shard k + tails, summed over 4 shards, stays well below m
        assert!(out.avg_select_work < 200.0, "work {}", out.avg_select_work);
    }

    /// Two jobs on one workload: the first misses and populates the cache,
    /// the second hits and reuses the very same index build.
    #[test]
    fn repeated_workload_jobs_share_one_cached_index() {
        let cache = TieredIndexCache::memory_only(2);
        let spec = |seed: u64| {
            JobSpec::Release(ReleaseJobSpec {
                u: 32,
                m: 40,
                n: 200,
                t: 15,
                eps: 1.0,
                delta: 1e-3,
                index: Some(IndexKind::Flat),
                shards: 1,
                class: QueryClassKind::Linear,
                workload: 9,
                tenant: 0,
                seed,
            })
        };
        let (out1, rep1) = execute_with_cache(&spec(1), Some(&cache), None).unwrap();
        let (out2, rep2) = execute_with_cache(&spec(2), Some(&cache), None).unwrap();
        assert_eq!((rep1.hits, rep1.misses), (0, 1));
        assert_eq!((rep2.hits, rep2.misses), (1, 0));
        assert_eq!(cache.l1().len(), 1, "one workload -> one resident entry");
        assert!(out1.quality.is_finite() && out2.quality.is_finite());
    }

    /// The dynamic-workload flow end to end at the job layer: an update
    /// bumps the generation, the next release job answers the evolved
    /// query set by *patching* the cached index (no rebuild), and a job on
    /// the old generation is never served.
    #[test]
    fn update_job_evolves_the_workload_and_patches_the_cache() {
        let cache = TieredIndexCache::memory_only(4);
        let registry = WorkloadRegistry::new();
        let release = |seed: u64| {
            JobSpec::Release(ReleaseJobSpec {
                u: 32,
                m: 40,
                n: 200,
                t: 15,
                eps: 1.0,
                delta: 1e-3,
                index: Some(IndexKind::Flat),
                shards: 1,
                class: QueryClassKind::Linear,
                workload: 9,
                tenant: 0,
                seed,
            })
        };
        let update = JobSpec::Update(WorkloadUpdateSpec {
            workload: 9,
            u: 32,
            m: 40,
            n: 200,
            insert: 2,
            tombstone: 1,
            tenant: 0,
        });

        // generation 0: cold build
        let (_, rep) =
            execute_with_cache(&release(1), Some(&cache), Some(&registry)).unwrap();
        assert_eq!((rep.misses, rep.patched), (1, 0));

        // the update spends zero ε and bumps the family to generation 1
        let (out, _) = execute_with_cache(&update, Some(&cache), Some(&registry)).unwrap();
        assert_eq!(out.eps_spent, 0.0);
        assert_eq!(out.avg_select_work, 3.0, "2 inserts + 1 tombstone touched");

        // the next release patches the resident generation-0 index forward
        let (out1, rep) =
            execute_with_cache(&release(2), Some(&cache), Some(&registry)).unwrap();
        assert_eq!((rep.hits, rep.patched, rep.misses), (1, 1, 0));
        assert!(out1.quality.is_finite());

        // and a repeat at the same generation is a plain hit
        let (_, rep) =
            execute_with_cache(&release(3), Some(&cache), Some(&registry)).unwrap();
        assert_eq!((rep.hits, rep.patched), (1, 0));

        // updates without a registry fail cleanly (zero ε at stake)
        assert!(execute_with_cache(&update, Some(&cache), None).is_err());
    }

    #[test]
    fn lp_job_executes() {
        let spec = JobSpec::Lp(LpJobSpec {
            m: 100,
            d: 8,
            t: 60,
            eps: 1.0,
            delta: 1e-3,
            delta_inf: 0.1,
            mode: SelectionMode::Exhaustive,
            tenant: 0,
            seed: 2,
        });
        let out = execute(&spec).unwrap();
        assert!(out.quality.is_finite());
    }

    /// Structurally invalid specs fail fast with a clean error — the
    /// refund path the serving runtime's admission control depends on.
    #[test]
    fn invalid_specs_error_instead_of_panicking() {
        let mut release = ReleaseJobSpec {
            u: 32,
            m: 30,
            n: 200,
            t: 0, // zero rounds: invalid
            eps: 1.0,
            delta: 1e-3,
            index: Some(IndexKind::Flat),
            shards: 1,
            class: QueryClassKind::Linear,
            workload: 1,
            tenant: 0,
            seed: 1,
        };
        let err = execute(&JobSpec::Release(release.clone())).unwrap_err();
        assert!(err.to_string().contains("invalid release spec"), "{err}");
        release.t = 10;
        release.eps = 0.0; // zero budget: invalid
        assert!(execute(&JobSpec::Release(release)).is_err());

        let mut lp = LpJobSpec {
            m: 50,
            d: 0, // zero variables: invalid
            t: 10,
            eps: 1.0,
            delta: 1e-3,
            delta_inf: 0.1,
            mode: SelectionMode::Exhaustive,
            tenant: 0,
            seed: 1,
        };
        let err = execute(&JobSpec::Lp(lp.clone())).unwrap_err();
        assert!(err.to_string().contains("invalid lp spec"), "{err}");
        lp.d = 8;
        lp.delta_inf = 0.0; // degenerate sensitivity: selection scale -> inf
        assert!(execute(&JobSpec::Lp(lp)).is_err());
    }
}
