//! Job specifications and results for the coordinator.

use crate::mips::IndexKind;
use crate::mwem::{FastMwemConfig, Histogram, MwemConfig, NativeBackend, QuerySet};
use crate::lp::{run_scalar, ScalarLpConfig, SelectionMode};
use crate::util::rng::Rng;
use crate::workloads::{self, LpInstance};
use std::time::Duration;

/// Private linear query release job (§3).
#[derive(Clone, Debug)]
pub struct ReleaseJobSpec {
    /// Domain size U.
    pub u: usize,
    /// Number of queries m.
    pub m: usize,
    /// Dataset size n.
    pub n: usize,
    pub t: usize,
    pub eps: f64,
    pub delta: f64,
    /// None → classic MWEM; Some(kind) → Fast-MWEM with that index.
    pub index: Option<IndexKind>,
    pub seed: u64,
}

/// Scalar-private LP job (§4.1).
#[derive(Clone, Debug)]
pub struct LpJobSpec {
    pub m: usize,
    pub d: usize,
    pub t: usize,
    pub eps: f64,
    pub delta: f64,
    pub delta_inf: f64,
    pub mode: SelectionMode,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub enum JobSpec {
    Release(ReleaseJobSpec),
    Lp(LpJobSpec),
}

impl JobSpec {
    pub fn kind(&self) -> &'static str {
        match self {
            JobSpec::Release(_) => "release",
            JobSpec::Lp(_) => "lp",
        }
    }
}

/// What a finished job reports back.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    /// Final quality metric: max query error (release) / max violation (LP).
    pub quality: f64,
    /// Privacy spent (ε, δ) per the accountant.
    pub eps_spent: f64,
    pub delta_spent: f64,
    /// Mean selection work per round (score evaluations).
    pub avg_select_work: f64,
    pub total_time: Duration,
}

#[derive(Debug)]
pub struct JobResult {
    pub job_id: usize,
    pub kind: &'static str,
    pub outcome: anyhow::Result<JobOutcome>,
}

/// Execute a job (called on a worker thread). Workloads are synthesized
/// from the spec's seed — a stand-in for loading a caller-provided dataset.
pub fn execute(spec: &JobSpec) -> anyhow::Result<JobOutcome> {
    match spec {
        JobSpec::Release(r) => {
            let mut rng = Rng::new(r.seed);
            let h: Histogram = workloads::gaussian_histogram(&mut rng, r.u, r.n);
            let q: QuerySet = workloads::binary_queries(&mut rng, r.m, r.u);
            let cfg = MwemConfig::paper(r.t, r.u, r.eps, r.delta, r.seed ^ 0xC0FFEE);
            let (result, work) = match r.index {
                None => {
                    let res = crate::mwem::run_classic(&cfg, &q, &h, &mut NativeBackend);
                    let w = res.avg_select_work;
                    (res, w)
                }
                Some(kind) => {
                    let out = crate::mwem::run_fast(
                        &FastMwemConfig::new(cfg, kind),
                        &q,
                        &h,
                        &mut NativeBackend,
                    );
                    let w = out.result.avg_select_work;
                    (out.result, w)
                }
            };
            let quality = q.max_error(h.probs(), &result.p_avg);
            Ok(JobOutcome {
                quality,
                eps_spent: result.privacy_spent.0,
                delta_spent: result.privacy_spent.1,
                avg_select_work: work,
                total_time: result.total_time,
            })
        }
        JobSpec::Lp(l) => {
            let mut rng = Rng::new(l.seed);
            let lp: LpInstance = workloads::random_feasibility_lp(&mut rng, l.m, l.d, 0.6);
            let cfg = ScalarLpConfig {
                t: l.t,
                eps: l.eps,
                delta: l.delta,
                delta_inf: l.delta_inf,
                mode: l.mode,
                seed: l.seed ^ 0xBEEF,
                log_every: 0,
            };
            let res = run_scalar(&cfg, &lp);
            Ok(JobOutcome {
                quality: lp.max_violation(&res.x),
                eps_spent: l.eps,
                delta_spent: l.delta,
                avg_select_work: res.avg_select_work,
                total_time: res.total_time,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn release_job_executes() {
        let spec = JobSpec::Release(ReleaseJobSpec {
            u: 64,
            m: 50,
            n: 300,
            t: 50,
            eps: 1.0,
            delta: 1e-3,
            index: Some(IndexKind::Flat),
            seed: 1,
        });
        let out = execute(&spec).unwrap();
        assert!(out.quality.is_finite() && out.quality >= 0.0);
        assert!(out.eps_spent > 0.0);
    }

    #[test]
    fn lp_job_executes() {
        let spec = JobSpec::Lp(LpJobSpec {
            m: 100,
            d: 8,
            t: 60,
            eps: 1.0,
            delta: 1e-3,
            delta_inf: 0.1,
            mode: SelectionMode::Exhaustive,
            seed: 2,
        });
        let out = execute(&spec).unwrap();
        assert!(out.quality.is_finite());
    }
}
