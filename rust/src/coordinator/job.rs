//! Job specifications and results for the coordinator, plus the shard
//! search job ([`ShardSearchJob`]) that [`crate::lazy::ShardedLazyEm`]
//! fans out over [`super::pool::parallel_map`].

use crate::lazy::{LazySample, ShardedLazyEm};
use crate::mips::IndexKind;
use crate::mwem::{FastMwemConfig, Histogram, MwemConfig, NativeBackend, QuerySet};
use crate::lp::{run_scalar, ScalarLpConfig, SelectionMode};
use crate::util::rng::Rng;
use crate::workloads::{self, LpInstance};
use std::time::Duration;

/// One shard's slice of a sharded lazy-EM draw: which shard to search and
/// the pre-split RNG stream it must consume. Streams are split on the
/// submitting thread, so a batch of these jobs produces the same draw
/// regardless of how the pool schedules them.
#[derive(Clone, Debug)]
pub struct ShardSearchJob {
    /// Index of the shard to draw from.
    pub shard_id: usize,
    /// Independent randomness for this shard's Gumbel perturbations.
    pub rng: Rng,
}

/// Execute one [`ShardSearchJob`] against a [`ShardedLazyEm`]: retrieve the
/// shard's top-k for `query`, take its lazy Gumbel max (scores pre-scaled
/// by `scale` = ε₀/(2Δ)), and return the shard's winner with a global
/// candidate id.
pub fn execute_shard_search(
    em: &ShardedLazyEm,
    query: &[f32],
    scale: f64,
    job: ShardSearchJob,
) -> LazySample {
    em.shard_draw(job.shard_id, job.rng, query, scale)
}

/// Private linear query release job (§3).
#[derive(Clone, Debug)]
pub struct ReleaseJobSpec {
    /// Domain size U.
    pub u: usize,
    /// Number of queries m.
    pub m: usize,
    /// Dataset size n.
    pub n: usize,
    /// Number of MWEM rounds T.
    pub t: usize,
    /// Privacy budget ε for this job.
    pub eps: f64,
    /// Privacy budget δ for this job.
    pub delta: f64,
    /// None → classic MWEM; Some(kind) → Fast-MWEM with that index.
    pub index: Option<IndexKind>,
    /// Number of lazy-EM shards (≤ 1 → one monolithic index).
    pub shards: usize,
    /// Workload / mechanism seed.
    pub seed: u64,
}

/// Scalar-private LP job (§4.1).
#[derive(Clone, Debug)]
pub struct LpJobSpec {
    /// Number of constraints m.
    pub m: usize,
    /// Number of variables d.
    pub d: usize,
    /// Number of MWU rounds T.
    pub t: usize,
    /// Privacy budget ε for this job.
    pub eps: f64,
    /// Privacy budget δ for this job.
    pub delta: f64,
    /// b-vector sensitivity Δ∞ between neighboring databases.
    pub delta_inf: f64,
    /// Constraint-selection mechanism (exhaustive / lazy / sharded lazy).
    pub mode: SelectionMode,
    /// Workload / mechanism seed.
    pub seed: u64,
}

/// A unit of work accepted by the [`super::Coordinator`].
#[derive(Clone, Debug)]
pub enum JobSpec {
    /// Private linear-query release (classic or Fast-MWEM).
    Release(ReleaseJobSpec),
    /// Scalar-private LP feasibility solve.
    Lp(LpJobSpec),
}

impl JobSpec {
    /// Short label used for per-kind metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            JobSpec::Release(_) => "release",
            JobSpec::Lp(_) => "lp",
        }
    }
}

/// What a finished job reports back.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    /// Final quality metric: max query error (release) / max violation (LP).
    pub quality: f64,
    /// Privacy ε spent per the accountant.
    pub eps_spent: f64,
    /// Privacy δ spent per the accountant.
    pub delta_spent: f64,
    /// Mean selection work per round (score evaluations).
    pub avg_select_work: f64,
    /// End-to-end solver wall-clock.
    pub total_time: Duration,
}

/// One job's result as delivered by [`super::Coordinator::finish`].
#[derive(Debug)]
pub struct JobResult {
    /// Submission id (dense, in submission order).
    pub job_id: usize,
    /// The spec's [`JobSpec::kind`] label.
    pub kind: &'static str,
    /// The outcome, or the error that failed the job.
    pub outcome: anyhow::Result<JobOutcome>,
}

/// Execute a job (called on a worker thread). Workloads are synthesized
/// from the spec's seed — a stand-in for loading a caller-provided dataset.
pub fn execute(spec: &JobSpec) -> anyhow::Result<JobOutcome> {
    match spec {
        JobSpec::Release(r) => {
            let mut rng = Rng::new(r.seed);
            let h: Histogram = workloads::gaussian_histogram(&mut rng, r.u, r.n);
            let q: QuerySet = workloads::binary_queries(&mut rng, r.m, r.u);
            let cfg = MwemConfig::paper(r.t, r.u, r.eps, r.delta, r.seed ^ 0xC0FFEE);
            let (result, work) = match r.index {
                None => {
                    let res = crate::mwem::run_classic(&cfg, &q, &h, &mut NativeBackend);
                    let w = res.avg_select_work;
                    (res, w)
                }
                Some(kind) => {
                    let out = crate::mwem::run_fast(
                        &FastMwemConfig::new(cfg, kind).with_shards(r.shards),
                        &q,
                        &h,
                        &mut NativeBackend,
                    );
                    let w = out.result.avg_select_work;
                    (out.result, w)
                }
            };
            let quality = q.max_error(h.probs(), &result.p_avg);
            Ok(JobOutcome {
                quality,
                eps_spent: result.privacy_spent.0,
                delta_spent: result.privacy_spent.1,
                avg_select_work: work,
                total_time: result.total_time,
            })
        }
        JobSpec::Lp(l) => {
            let mut rng = Rng::new(l.seed);
            let lp: LpInstance = workloads::random_feasibility_lp(&mut rng, l.m, l.d, 0.6);
            let cfg = ScalarLpConfig {
                t: l.t,
                eps: l.eps,
                delta: l.delta,
                delta_inf: l.delta_inf,
                mode: l.mode,
                seed: l.seed ^ 0xBEEF,
                log_every: 0,
            };
            let res = run_scalar(&cfg, &lp);
            Ok(JobOutcome {
                quality: lp.max_violation(&res.x),
                eps_spent: l.eps,
                delta_spent: l.delta,
                avg_select_work: res.avg_select_work,
                total_time: res.total_time,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn release_job_executes() {
        let spec = JobSpec::Release(ReleaseJobSpec {
            u: 64,
            m: 50,
            n: 300,
            t: 50,
            eps: 1.0,
            delta: 1e-3,
            index: Some(IndexKind::Flat),
            shards: 1,
            seed: 1,
        });
        let out = execute(&spec).unwrap();
        assert!(out.quality.is_finite() && out.quality >= 0.0);
        assert!(out.eps_spent > 0.0);
    }

    #[test]
    fn sharded_release_job_executes() {
        let spec = JobSpec::Release(ReleaseJobSpec {
            u: 64,
            m: 200,
            n: 300,
            t: 50,
            eps: 1.0,
            delta: 1e-3,
            index: Some(IndexKind::Flat),
            shards: 4,
            seed: 1,
        });
        let out = execute(&spec).unwrap();
        assert!(out.quality.is_finite() && out.quality >= 0.0);
        // per-shard k + tails, summed over 4 shards, stays well below m
        assert!(out.avg_select_work < 200.0, "work {}", out.avg_select_work);
    }

    #[test]
    fn lp_job_executes() {
        let spec = JobSpec::Lp(LpJobSpec {
            m: 100,
            d: 8,
            t: 60,
            eps: 1.0,
            delta: 1e-3,
            delta_inf: 0.1,
            mode: SelectionMode::Exhaustive,
            seed: 2,
        });
        let out = execute(&spec).unwrap();
        assert!(out.quality.is_finite());
    }
}
