//! Job coordinator: a thread-pool service that runs private-release and
//! private-LP jobs with per-job privacy budgets and aggregated metrics.
//!
//! This is the "serving" face of the library: callers submit [`JobSpec`]s,
//! a leader thread dispatches them to workers over channels, each worker
//! runs the requested solver, and results stream back with privacy spend
//! recorded by the [`crate::dp::Accountant`]. Repeated workloads are the
//! common case under serving traffic, so the pool shares a tiered
//! warm-index cache — the in-memory [`IndexCache`] (DESIGN.md §6) over an
//! optional persistent artifact store
//! ([`crate::store::TieredIndexCache`], DESIGN.md §7): release jobs that
//! answer the same query set reuse one pre-built k-MIPS index instead of
//! rebuilding it per job, even across coordinator restarts. (The offline
//! build vendors no tokio; the pool is std::thread + mpsc — see
//! DESIGN.md §3.)
//!
//! The coordinator is a *batch* harness: submit a known set of jobs, then
//! `finish()`. For the long-lived steady-state request path — a bounded
//! MPMC queue, persistent workers, per-tenant budget admission and
//! graceful drain — see [`crate::server`] (DESIGN.md §8).

pub mod cache;
pub mod job;
pub mod pool;

pub use cache::{
    fingerprint_vectors, CacheEvent, CacheReport, CacheStats, CachedIndex, IndexCache,
    WorkloadKey,
};
pub use job::{
    execute, execute_shard_search, execute_with_cache, JobOutcome, JobResult, JobSpec,
    LpJobSpec, ReleaseJobSpec, ShardSearchJob, WorkloadUpdateSpec,
};
pub use pool::{parallel_map, Coordinator, CoordinatorConfig};
