//! Job coordinator: a thread-pool service that runs private-release and
//! private-LP jobs with per-job privacy budgets and aggregated metrics.
//!
//! This is the "serving" face of the library: callers submit [`JobSpec`]s,
//! a leader thread dispatches them to workers over channels, each worker
//! runs the requested solver, and results stream back with privacy spend
//! recorded by the [`crate::dp::Accountant`]. (The offline build vendors
//! no tokio; the pool is std::thread + mpsc — see DESIGN.md §3.)

pub mod job;
pub mod pool;

pub use job::{
    execute_shard_search, JobOutcome, JobResult, JobSpec, LpJobSpec, ReleaseJobSpec,
    ShardSearchJob,
};
pub use pool::{parallel_map, Coordinator, CoordinatorConfig};
