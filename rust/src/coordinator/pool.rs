//! Leader/worker thread pool with bounded queueing and metrics.

use super::job::{execute, JobResult, JobSpec};
use crate::metrics::Metrics;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Fan a batch of independent jobs across `workers` scoped threads and
/// return the results in input order.
///
/// This is the pool's synchronous sibling of [`Coordinator`]: the same
/// leader/worker decomposition, but for borrowed, short-lived work — shard
/// index builds and per-shard search jobs
/// ([`crate::coordinator::job::ShardSearchJob`]) — where the caller blocks
/// until the whole batch is done. Items are dealt round-robin so similarly
/// sized shards land on distinct threads. `workers = 0` or `1` (or a
/// single-item batch) degrades to an inline sequential map with no thread
/// overhead.
pub fn parallel_map<T, R>(
    workers: usize,
    items: Vec<T>,
    f: impl Fn(T) -> R + Sync,
) -> Vec<R>
where
    T: Send,
    R: Send,
{
    let n = items.len();
    let workers = workers.clamp(1, n.max(1));
    if workers <= 1 {
        return items.into_iter().map(f).collect();
    }
    let f = &f;
    let mut chunks: Vec<Vec<(usize, T)>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        chunks[i % workers].push((i, item));
    }
    let mut indexed: Vec<(usize, R)> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = chunks
            .into_iter()
            .map(|chunk| {
                s.spawn(move || {
                    chunk.into_iter().map(|(i, item)| (i, f(item))).collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            indexed.extend(h.join().expect("parallel_map worker panicked"));
        }
    });
    indexed.sort_by_key(|&(i, _)| i);
    indexed.into_iter().map(|(_, r)| r).collect()
}

/// Pool sizing and admission control for a [`Coordinator`].
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Number of worker threads.
    pub workers: usize,
    /// Global privacy cap across all accepted jobs (ε). Jobs whose budget
    /// would exceed the cap are rejected at submission.
    pub eps_cap: Option<f64>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { workers: 4, eps_cap: None }
    }
}

enum Message {
    Run(usize, JobSpec),
    Shutdown,
}

/// A running coordinator: submit jobs, then `finish()` to collect results.
pub struct Coordinator {
    tx: mpsc::Sender<Message>,
    results_rx: mpsc::Receiver<JobResult>,
    workers: Vec<JoinHandle<()>>,
    next_id: usize,
    submitted_eps: f64,
    cfg: CoordinatorConfig,
    metrics: Arc<Mutex<Metrics>>,
}

impl Coordinator {
    /// Spawn the worker threads and start accepting jobs.
    pub fn start(cfg: CoordinatorConfig) -> Self {
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let (results_tx, results_rx) = mpsc::channel::<JobResult>();
        let metrics = Arc::new(Mutex::new(Metrics::new()));

        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let results_tx = results_tx.clone();
                let metrics = Arc::clone(&metrics);
                std::thread::spawn(move || loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Message::Run(job_id, spec)) => {
                            let started = Instant::now();
                            let kind = spec.kind();
                            let outcome = execute(&spec);
                            {
                                let mut m = metrics.lock().unwrap();
                                m.inc("jobs_completed", 1);
                                m.inc(&format!("jobs_{kind}"), 1);
                                m.observe("job_duration", started.elapsed());
                                if outcome.is_err() {
                                    m.inc("jobs_failed", 1);
                                }
                            }
                            let _ = results_tx.send(JobResult { job_id, kind, outcome });
                        }
                        Ok(Message::Shutdown) | Err(_) => return,
                    }
                })
            })
            .collect();

        Coordinator {
            tx,
            results_rx,
            workers,
            next_id: 0,
            submitted_eps: 0.0,
            cfg,
            metrics,
        }
    }

    /// Submit a job; returns its id, or an error if the global ε cap would
    /// be exceeded (the budget-manager role of the coordinator).
    pub fn submit(&mut self, spec: JobSpec) -> anyhow::Result<usize> {
        let eps = match &spec {
            JobSpec::Release(r) => r.eps,
            JobSpec::Lp(l) => l.eps,
        };
        if let Some(cap) = self.cfg.eps_cap {
            anyhow::ensure!(
                self.submitted_eps + eps <= cap + 1e-12,
                "privacy cap exceeded: {} + {} > {}",
                self.submitted_eps,
                eps,
                cap
            );
        }
        self.submitted_eps += eps;
        let id = self.next_id;
        self.next_id += 1;
        self.tx.send(Message::Run(id, spec)).expect("workers alive");
        Ok(id)
    }

    /// Number of jobs accepted so far.
    pub fn submitted(&self) -> usize {
        self.next_id
    }

    /// Shut down and return all results (unordered) plus merged metrics.
    pub fn finish(self) -> (Vec<JobResult>, Metrics) {
        for _ in &self.workers {
            let _ = self.tx.send(Message::Shutdown);
        }
        drop(self.tx);
        let mut results = Vec::with_capacity(self.next_id);
        for _ in 0..self.next_id {
            match self.results_rx.recv() {
                Ok(r) => results.push(r),
                Err(_) => break,
            }
        }
        for w in self.workers {
            let _ = w.join();
        }
        results.sort_by_key(|r| r.job_id);
        let metrics = Arc::try_unwrap(self.metrics)
            .map(|m| m.into_inner().unwrap())
            .unwrap_or_default();
        (results, metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::ReleaseJobSpec;
    use crate::mips::IndexKind;

    fn small_release(seed: u64, eps: f64) -> JobSpec {
        JobSpec::Release(ReleaseJobSpec {
            u: 32,
            m: 30,
            n: 200,
            t: 20,
            eps,
            delta: 1e-3,
            index: Some(IndexKind::Flat),
            shards: 1,
            seed,
        })
    }

    #[test]
    fn parallel_map_preserves_order_and_runs_everything() {
        for workers in [0usize, 1, 2, 3, 16] {
            let items: Vec<usize> = (0..23).collect();
            let out = parallel_map(workers, items, |i| i * i);
            assert_eq!(out, (0..23).map(|i| i * i).collect::<Vec<_>>(), "workers={workers}");
        }
        let empty: Vec<usize> = Vec::new();
        assert!(parallel_map(4, empty, |i: usize| i).is_empty());
    }

    #[test]
    fn runs_jobs_in_parallel_and_collects_all() {
        let mut c = Coordinator::start(CoordinatorConfig { workers: 3, eps_cap: None });
        for i in 0..6 {
            c.submit(small_release(i, 1.0)).unwrap();
        }
        let (results, metrics) = c.finish();
        assert_eq!(results.len(), 6);
        assert!(results.iter().all(|r| r.outcome.is_ok()));
        // sorted by id
        assert!(results.windows(2).all(|w| w[0].job_id < w[1].job_id));
        assert_eq!(metrics.counter("jobs_completed"), 6);
        assert_eq!(metrics.counter("jobs_failed"), 0);
        assert_eq!(metrics.timing_summary("job_duration").unwrap().count, 6);
    }

    #[test]
    fn privacy_cap_rejects_over_budget() {
        let mut c =
            Coordinator::start(CoordinatorConfig { workers: 1, eps_cap: Some(2.5) });
        assert!(c.submit(small_release(1, 1.0)).is_ok());
        assert!(c.submit(small_release(2, 1.0)).is_ok());
        assert!(c.submit(small_release(3, 1.0)).is_err(), "third job busts the cap");
        let (results, _) = c.finish();
        assert_eq!(results.len(), 2);
    }
}
