//! Leader/worker thread pool with bounded queueing, a shared tiered
//! warm-index cache (in-memory LRU + optional persistent artifact store),
//! and metrics.

use super::cache::IndexCache;
use super::job::{execute_with_cache, JobResult, JobSpec};
use crate::metrics::Metrics;
use crate::store::{DiskStore, HeapBudget, LeaseSettings, PagerSettings, TieredIndexCache};
use crate::workloads::WorkloadRegistry;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Fan a batch of independent jobs across `workers` scoped threads and
/// return the results in input order.
///
/// This is the pool's synchronous sibling of [`Coordinator`]: the same
/// leader/worker decomposition, but for borrowed, short-lived work — shard
/// index builds and per-shard search jobs
/// ([`crate::coordinator::job::ShardSearchJob`]) — where the caller blocks
/// until the whole batch is done. Items are dealt round-robin so similarly
/// sized shards land on distinct threads. `workers = 0` or `1` (or a
/// single-item batch) degrades to an inline sequential map with no thread
/// overhead.
pub fn parallel_map<T, R>(
    workers: usize,
    items: Vec<T>,
    f: impl Fn(T) -> R + Sync,
) -> Vec<R>
where
    T: Send,
    R: Send,
{
    let n = items.len();
    let workers = workers.clamp(1, n.max(1));
    if workers <= 1 {
        return items.into_iter().map(f).collect();
    }
    let f = &f;
    let mut chunks: Vec<Vec<(usize, T)>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        chunks[i % workers].push((i, item));
    }
    let mut indexed: Vec<(usize, R)> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = chunks
            .into_iter()
            .map(|chunk| {
                s.spawn(move || {
                    chunk.into_iter().map(|(i, item)| (i, f(item))).collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            indexed.extend(h.join().expect("parallel_map worker panicked"));
        }
    });
    indexed.sort_by_key(|&(i, _)| i);
    indexed.into_iter().map(|(_, r)| r).collect()
}

/// Pool sizing and admission control for a [`Coordinator`].
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Number of worker threads.
    pub workers: usize,
    /// Global privacy cap across all accepted jobs (ε). Jobs whose budget
    /// would exceed the cap are rejected at submission.
    pub eps_cap: Option<f64>,
    /// Warm-index cache capacity: how many pre-built k-MIPS indices
    /// (keyed by workload fingerprint × index kind × shard count) stay
    /// resident across jobs. 0 disables the in-memory tier (DESIGN.md §6).
    pub cache_capacity: usize,
    /// Persistent artifact store directory (DESIGN.md §7). `Some(dir)`
    /// snapshots built indices to disk and restores them across
    /// coordinator restarts; `None` keeps warm serving in-memory only.
    pub store_dir: Option<PathBuf>,
    /// Heap ceiling for L1-resident index data (DESIGN.md §12);
    /// mmap-borrowed rows count as zero against it.
    pub heap_budget: HeapBudget,
    /// How store artifacts are restored: zero-copy mmap paging vs heap
    /// decode (DESIGN.md §12).
    pub pager: PagerSettings,
    /// Build-lease protocol for multi-process store sharing (DESIGN.md
    /// §13): on a shared miss exactly one process builds while peers
    /// wait-and-promote. Ignored without a store.
    pub lease: LeaseSettings,
    /// Manifest generation watch (DESIGN.md §13): poll the shared
    /// manifest's stamp so peer-committed workload updates invalidate
    /// stale local state before it can serve. Ignored without a store.
    pub watch: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 4,
            eps_cap: None,
            cache_capacity: 8,
            store_dir: None,
            heap_budget: HeapBudget::unlimited(),
            pager: PagerSettings::default(),
            lease: LeaseSettings::default(),
            watch: true,
        }
    }
}

/// Shutdown-time metric derivation shared by the batch pool and the
/// serving runtime ([`crate::server::Server::drain`]): derive the headline
/// ms counters from the µs accumulators (so only the final totals, not
/// each job, are truncated) and publish the cache/store gauges.
pub(crate) fn finalize_serving_metrics(m: &mut Metrics, cache: Option<&TieredIndexCache>) {
    // Which kernel arm served this process (0 scalar, 1 avx2, 2 neon).
    m.set_gauge("kernel", crate::runtime::kernels::active().arm.gauge_value());
    let saved_us = m.counter("index_build_saved_us");
    m.inc("index_build_saved_ms", saved_us / 1000);
    if let Some(cache) = cache {
        let s = cache.l1().stats();
        m.set_gauge("index_cache_entries", s.entries as f64);
        m.set_gauge("index_cache_evictions", s.evictions as f64);
        m.set_gauge("index_cache_bytes", s.bytes as f64);
        // Structurally zero by construction (DESIGN.md §9: stale cache
        // generations are patched forward or rebuilt, never handed out);
        // materialized here so the CI dynamic smoke can assert on it and
        // any future regression shows up as a nonzero counter.
        m.inc("stale_generation_serves", 0);
        m.inc("index_cache_patched", 0);
        let patch_us = m.counter("index_patch_us");
        m.inc("index_patch_ms", patch_us / 1000);
        if let Some(store) = cache.store() {
            let st = store.stats();
            let promote_us = m.counter("store_promote_us");
            m.inc("store_promote_ms", promote_us / 1000);
            m.inc("store_bytes_written", st.bytes_written);
            // Which restore path promotions took (DESIGN.md §12): mapped
            // page-ins vs heap decodes. The CI mmap smoke asserts a
            // budget-constrained serve never decodes.
            m.inc("store_mmap_restore", st.mmap_restores);
            m.inc("store_decode_restore", st.decode_restores);
            m.set_gauge("store_artifacts", st.artifacts as f64);
            m.set_gauge("store_deltas", st.deltas as f64);
            m.set_gauge("store_load_failures", st.load_failures as f64);
            // Multi-process coordination counters (DESIGN.md §13),
            // materialized even at zero so the CI multi-process smoke can
            // assert on every process's metrics dump uniformly.
            m.inc("lease_acquired", 0);
            m.inc("lease_waited", 0);
            m.inc("lease_takeovers", 0);
            m.inc("peer_invalidations", 0);
            m.set_gauge("store_manifest_reloads", st.manifest_reloads as f64);
        }
    }
}

enum Message {
    Run(usize, JobSpec),
    Shutdown,
}

/// A running coordinator: submit jobs, then `finish()` to collect results.
pub struct Coordinator {
    tx: mpsc::Sender<Message>,
    results_rx: mpsc::Receiver<JobResult>,
    workers: Vec<JoinHandle<()>>,
    next_id: usize,
    submitted_eps: f64,
    cfg: CoordinatorConfig,
    metrics: Arc<Mutex<Metrics>>,
    cache: Option<Arc<TieredIndexCache>>,
    registry: Arc<WorkloadRegistry>,
}

impl Coordinator {
    /// Spawn the worker threads and start accepting jobs.
    ///
    /// When `cfg.store_dir` is set but the store cannot be opened (for
    /// example an unwritable path), the coordinator logs a warning and
    /// degrades to in-memory-only warm serving — the store is an
    /// accelerator, never a startup dependency.
    pub fn start(cfg: CoordinatorConfig) -> Self {
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let (results_tx, results_rx) = mpsc::channel::<JobResult>();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let cache: Option<Arc<TieredIndexCache>> =
            if cfg.cache_capacity > 0 || cfg.store_dir.is_some() {
                let tiered = match &cfg.store_dir {
                    Some(dir) => TieredIndexCache::with_settings(
                        cfg.cache_capacity,
                        cfg.heap_budget,
                        dir,
                        cfg.pager,
                    )
                    .unwrap_or_else(|e| {
                        eprintln!(
                            "warning: cannot open artifact store {dir:?} ({e:#}); \
                             serving in-memory only"
                        );
                        TieredIndexCache::memory_only_with_budget(
                            cfg.cache_capacity,
                            cfg.heap_budget,
                        )
                    }),
                    None => TieredIndexCache::memory_only_with_budget(
                        cfg.cache_capacity,
                        cfg.heap_budget,
                    ),
                }
                .with_lease(cfg.lease)
                .with_watch(cfg.watch);
                Some(Arc::new(tiered))
            } else {
                None
            };

        // Dynamic-workload state: restore persisted delta chains so a
        // restarted coordinator resumes at the generations it left off.
        let registry = Arc::new(WorkloadRegistry::new());
        if let Some(store) = cache.as_deref().and_then(TieredIndexCache::store) {
            registry.restore(store.delta_chains());
        }

        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let results_tx = results_tx.clone();
                let metrics = Arc::clone(&metrics);
                let cache = cache.clone();
                let registry = Arc::clone(&registry);
                std::thread::spawn(move || loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(Message::Run(job_id, spec)) => {
                            let started = Instant::now();
                            let kind = spec.kind();
                            let outcome = execute_with_cache(
                                &spec,
                                cache.as_deref(),
                                Some(registry.as_ref()),
                            );
                            let store_on =
                                cache.as_deref().is_some_and(|c| c.store().is_some());
                            {
                                let mut m = metrics.lock().unwrap();
                                m.inc("jobs_completed", 1);
                                m.inc(&format!("jobs_{kind}"), 1);
                                m.observe("job_duration", started.elapsed());
                                match &outcome {
                                    Ok((_, rep)) => rep.record_into(&mut m, store_on),
                                    Err(_) => m.inc("jobs_failed", 1),
                                }
                            }
                            let outcome = outcome.map(|(o, _)| o);
                            let _ = results_tx.send(JobResult { job_id, kind, outcome });
                        }
                        Ok(Message::Shutdown) | Err(_) => return,
                    }
                })
            })
            .collect();

        Coordinator {
            tx,
            results_rx,
            workers,
            next_id: 0,
            submitted_eps: 0.0,
            cfg,
            metrics,
            cache,
            registry,
        }
    }

    /// The dynamic-workload registry shared by this pool's workers
    /// (DESIGN.md §9).
    pub fn registry(&self) -> &WorkloadRegistry {
        &self.registry
    }

    /// The in-memory warm-index tier, when warm serving is enabled
    /// (`cache_capacity > 0` or a `store_dir`).
    pub fn cache(&self) -> Option<&IndexCache> {
        self.cache.as_deref().map(TieredIndexCache::l1)
    }

    /// The full tiered cache (L1 + optional artifact store), when warm
    /// serving is enabled.
    pub fn tiered_cache(&self) -> Option<&TieredIndexCache> {
        self.cache.as_deref()
    }

    /// The persistent artifact store, when one is attached.
    pub fn store(&self) -> Option<&DiskStore> {
        self.cache.as_deref().and_then(TieredIndexCache::store)
    }

    /// Submit a job; returns its id, or an error if the global ε cap would
    /// be exceeded (the budget-manager role of the coordinator). For
    /// per-tenant admission and a long-lived request path, use
    /// [`crate::server::Server`] instead.
    pub fn submit(&mut self, spec: JobSpec) -> anyhow::Result<usize> {
        let eps = spec.eps();
        if let Some(cap) = self.cfg.eps_cap {
            anyhow::ensure!(
                self.submitted_eps + eps <= cap + 1e-12,
                "privacy cap exceeded: {} + {} > {}",
                self.submitted_eps,
                eps,
                cap
            );
        }
        self.submitted_eps += eps;
        let id = self.next_id;
        self.next_id += 1;
        self.tx.send(Message::Run(id, spec)).expect("workers alive");
        Ok(id)
    }

    /// Number of jobs accepted so far.
    pub fn submitted(&self) -> usize {
        self.next_id
    }

    /// Shut down and return all results (unordered) plus merged metrics.
    pub fn finish(self) -> (Vec<JobResult>, Metrics) {
        for _ in &self.workers {
            let _ = self.tx.send(Message::Shutdown);
        }
        drop(self.tx);
        let mut results = Vec::with_capacity(self.next_id);
        for _ in 0..self.next_id {
            match self.results_rx.recv() {
                Ok(r) => results.push(r),
                Err(_) => break,
            }
        }
        for w in self.workers {
            let _ = w.join();
        }
        results.sort_by_key(|r| r.job_id);
        {
            let mut m = self.metrics.lock().unwrap();
            finalize_serving_metrics(&mut m, self.cache.as_deref());
        }
        let metrics = Arc::try_unwrap(self.metrics)
            .map(|m| m.into_inner().unwrap())
            .unwrap_or_default();
        (results, metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{LpJobSpec, ReleaseJobSpec};
    use crate::lp::SelectionMode;
    use crate::mips::IndexKind;

    fn small_release(seed: u64, eps: f64) -> JobSpec {
        release_on_workload(seed, seed, eps)
    }

    /// A release job pinned to an explicit workload (cache-sharing tests).
    fn release_on_workload(workload: u64, seed: u64, eps: f64) -> JobSpec {
        JobSpec::Release(ReleaseJobSpec {
            u: 32,
            m: 30,
            n: 200,
            t: 20,
            eps,
            delta: 1e-3,
            index: Some(IndexKind::Flat),
            shards: 1,
            class: crate::workloads::QueryClassKind::Linear,
            workload,
            tenant: 0,
            seed,
        })
    }

    fn small_lp(seed: u64, eps: f64) -> JobSpec {
        JobSpec::Lp(LpJobSpec {
            m: 60,
            d: 6,
            t: 15,
            eps,
            delta: 1e-3,
            delta_inf: 0.1,
            mode: SelectionMode::Exhaustive,
            tenant: 0,
            seed,
        })
    }

    #[test]
    fn parallel_map_preserves_order_and_runs_everything() {
        for workers in [0usize, 1, 2, 3, 16] {
            let items: Vec<usize> = (0..23).collect();
            let out = parallel_map(workers, items, |i| i * i);
            assert_eq!(out, (0..23).map(|i| i * i).collect::<Vec<_>>(), "workers={workers}");
        }
        let empty: Vec<usize> = Vec::new();
        assert!(parallel_map(4, empty, |i: usize| i).is_empty());
    }

    #[test]
    fn runs_jobs_in_parallel_and_collects_all() {
        let mut c = Coordinator::start(CoordinatorConfig {
            workers: 3,
            eps_cap: None,
            cache_capacity: 8,
            ..Default::default()
        });
        for i in 0..6 {
            c.submit(small_release(i, 1.0)).unwrap();
        }
        let (results, metrics) = c.finish();
        assert_eq!(results.len(), 6);
        assert!(results.iter().all(|r| r.outcome.is_ok()));
        // sorted by id
        assert!(results.windows(2).all(|w| w[0].job_id < w[1].job_id));
        assert_eq!(metrics.counter("jobs_completed"), 6);
        assert_eq!(metrics.counter("jobs_failed"), 0);
        assert_eq!(metrics.timing_summary("job_duration").unwrap().count, 6);
    }

    #[test]
    fn privacy_cap_rejects_over_budget() {
        let mut c = Coordinator::start(CoordinatorConfig {
            workers: 1,
            eps_cap: Some(2.5),
            cache_capacity: 0,
            store_dir: None,
            ..Default::default()
        });
        assert!(c.submit(small_release(1, 1.0)).is_ok());
        assert!(c.submit(small_release(2, 1.0)).is_ok());
        assert!(c.submit(small_release(3, 1.0)).is_err(), "third job busts the cap");
        let (results, _) = c.finish();
        assert_eq!(results.len(), 2);
    }

    /// The ε cap accounts Release and Lp budgets against one global total,
    /// in submission order, regardless of job kind.
    #[test]
    fn privacy_cap_accounts_mixed_lp_and_release_batches() {
        let mut c = Coordinator::start(CoordinatorConfig {
            workers: 2,
            eps_cap: Some(2.0),
            cache_capacity: 4,
            store_dir: None,
            ..Default::default()
        });
        assert!(c.submit(small_release(1, 0.9)).is_ok()); // 0.9
        assert!(c.submit(small_lp(2, 0.9)).is_ok()); // 1.8
        assert!(c.submit(small_lp(3, 0.3)).is_err(), "1.8 + 0.3 busts the cap");
        assert!(c.submit(small_release(4, 0.2)).is_ok(), "1.8 + 0.2 lands on the cap");
        assert!(c.submit(small_lp(5, 0.1)).is_err(), "cap is exhausted");

        let (results, metrics) = c.finish();
        assert_eq!(results.len(), 3);
        // the LP jobs charge exactly their nominal ε; release jobs report
        // the accountant's composed total, which must be positive
        for r in &results {
            let o = r.outcome.as_ref().expect("job ok");
            assert!(o.eps_spent > 0.0);
            if r.kind == "lp" {
                assert!((o.eps_spent - 0.9).abs() < 1e-12);
            }
        }
        assert_eq!(metrics.counter("jobs_release"), 2);
        assert_eq!(metrics.counter("jobs_lp"), 1);
        assert_eq!(metrics.counter("jobs_failed"), 0);
    }

    /// Repeated workloads on a single worker: first job misses and
    /// populates, later jobs hit; distinct workloads get their own entry.
    #[test]
    fn repeated_workloads_hit_the_index_cache() {
        let mut c = Coordinator::start(CoordinatorConfig {
            workers: 1, // serialize so later jobs observe the first insert
            eps_cap: None,
            cache_capacity: 4,
            store_dir: None,
            ..Default::default()
        });
        for seed in 0..3 {
            c.submit(release_on_workload(7, 100 + seed, 1.0)).unwrap();
        }
        c.submit(release_on_workload(8, 200, 1.0)).unwrap();
        let cache = c.cache().expect("cache enabled");
        assert_eq!(cache.capacity(), 4);

        let (results, metrics) = c.finish();
        assert_eq!(results.len(), 4);
        assert!(results.iter().all(|r| r.outcome.is_ok()));
        assert_eq!(metrics.counter("index_cache_hit"), 2);
        assert_eq!(metrics.counter("index_cache_miss"), 2);
        assert_eq!(metrics.gauge("index_cache_entries"), Some(2.0));
        assert_eq!(metrics.gauge("index_cache_evictions"), Some(0.0));
    }

    /// `cache_capacity: 0` turns the cache off without changing serving
    /// behavior: jobs still run, no cache metrics accrue, and — because
    /// index builds are seeded from the workload either way — every job's
    /// release is bit-identical to the cached coordinator's.
    #[test]
    fn cache_disabled_still_serves() {
        // HNSW: the one index whose construction is seed-dependent, so the
        // bit-equality assertion below would catch any cache-on/off
        // build-seed divergence
        let hnsw_release = |seed: u64| {
            JobSpec::Release(ReleaseJobSpec {
                u: 32,
                m: 60,
                n: 200,
                t: 20,
                eps: 1.0,
                delta: 1e-3,
                index: Some(IndexKind::Hnsw),
                shards: 1,
                class: crate::workloads::QueryClassKind::Linear,
                workload: 7,
                tenant: 0,
                seed,
            })
        };
        let run = |capacity: usize| {
            let mut c = Coordinator::start(CoordinatorConfig {
                workers: 1,
                eps_cap: None,
                cache_capacity: capacity,
                store_dir: None,
                ..Default::default()
            });
            assert_eq!(c.cache().is_some(), capacity > 0);
            c.submit(hnsw_release(1)).unwrap();
            c.submit(hnsw_release(2)).unwrap();
            c.finish()
        };
        let (results, metrics) = run(0);
        assert_eq!(results.len(), 2);
        assert!(results.iter().all(|r| r.outcome.is_ok()));
        assert_eq!(metrics.counter("index_cache_hit"), 0);
        assert_eq!(metrics.counter("index_cache_miss"), 0);
        assert_eq!(metrics.gauge("index_cache_entries"), None);

        let (cached_results, cached_metrics) = run(4);
        assert_eq!(cached_metrics.counter("index_cache_hit"), 1);
        for (a, b) in results.iter().zip(cached_results.iter()) {
            let (oa, ob) = (a.outcome.as_ref().unwrap(), b.outcome.as_ref().unwrap());
            assert_eq!(oa.quality, ob.quality, "cache must not change any release");
        }
    }

    /// The persistent-store PR's acceptance bar at the coordinator level:
    /// a second coordinator on the same `store_dir` restores the first
    /// one's index from disk (store hit, zero builds) and produces the
    /// bit-identical release for the same (workload, seed).
    #[test]
    fn restarted_coordinator_restores_indices_from_store() {
        let dir = std::env::temp_dir()
            .join(format!("fastmwem-pool-restart-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let run = |seed: u64| {
            let mut c = Coordinator::start(CoordinatorConfig {
                workers: 1,
                eps_cap: None,
                cache_capacity: 4,
                store_dir: Some(dir.clone()),
                ..Default::default()
            });
            assert!(c.store().is_some(), "store must attach");
            c.submit(release_on_workload(7, seed, 1.0)).unwrap();
            let (results, metrics) = c.finish();
            let quality = results[0].outcome.as_ref().unwrap().quality;
            (quality, metrics)
        };

        let (cold_quality, cold_metrics) = run(500);
        assert_eq!(cold_metrics.counter("store_hit"), 0);
        assert_eq!(cold_metrics.counter("store_miss"), 1, "cold run builds once");
        assert!(cold_metrics.counter("store_bytes_written") > 0);

        // "restart": a brand-new coordinator, same directory
        let (warm_quality, warm_metrics) = run(500);
        assert_eq!(warm_metrics.counter("store_hit"), 1, "restart must restore");
        assert_eq!(warm_metrics.counter("store_miss"), 0, "restart must not rebuild");
        assert_eq!(warm_metrics.counter("index_cache_miss"), 1, "L1 starts cold");
        assert_eq!(
            cold_quality, warm_quality,
            "restored index must reproduce the release bit-for-bit"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
