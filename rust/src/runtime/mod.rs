//! Runtime: loads the AOT HLO-text artifacts produced by `make artifacts`
//! and executes them on the PJRT CPU client via the `xla` crate.
//!
//! Python never runs here — artifacts are compiled once per process
//! ([`XlaEngine`] caches executables) and the request path is pure Rust.

pub mod backend;
pub mod engine;
pub mod manifest;

pub use backend::XlaBackend;
pub use engine::XlaEngine;
pub use manifest::{ArtifactEntry, Manifest, TensorSpec};
