//! Runtime layer: the kernel dispatch table and the CPU compute backend.
//!
//! [`kernels`] owns the per-process SIMD/scalar selection (DESIGN.md §10);
//! [`CpuBackend`] adapts it to the [`crate::mwem::MwemBackend`] seam.

pub mod backend;
pub mod kernels;

pub use backend::CpuBackend;
