//! PJRT execution engine: compile-once cache over the HLO-text artifacts.

use super::manifest::{ArtifactEntry, Manifest};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::Path;
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// PJRT execution engine: a CPU client plus a compile-once executable cache.
pub struct XlaEngine {
    client: PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, PjRtLoadedExecutable>,
}

impl XlaEngine {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir.as_ref())?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(XlaEngine { client, manifest, cache: HashMap::new() })
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Look up an artifact by name.
    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))
    }

    /// Compile (or fetch the cached) executable for an artifact.
    fn executable(&mut self, name: &str) -> Result<&PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let entry = self.entry(name)?.clone();
            let proto = HloModuleProto::from_text_file(
                entry.file.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("parsing {:?}: {e:?}", entry.file))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Upload an f32 tensor to the device (reusable across executions).
    pub fn buffer_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("host→device: {e:?}"))
    }

    // Scalars go through buffer_from_host_buffer with empty dims:
    // buffer_from_host_literal(Literal::scalar(..)) aborts inside
    // xla_extension 0.5.1 ("Unhandled primitive type") when the process has
    // created more than one PJRT client.
    /// Upload one f32 scalar.
    pub fn buffer_scalar_f32(&self, x: f32) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&[x], &[], None)
            .map_err(|e| anyhow!("scalar f32: {e:?}"))
    }

    /// Upload one i32 scalar.
    pub fn buffer_scalar_i32(&self, x: i32) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&[x], &[], None)
            .map_err(|e| anyhow!("scalar i32: {e:?}"))
    }

    /// Execute artifact `name` with device-resident arguments; returns all
    /// outputs as f32 vectors (artifacts are lowered with return_tuple=True).
    pub fn execute(&mut self, name: &str, args: &[&PjRtBuffer]) -> Result<Vec<Vec<f32>>> {
        let n_outputs = self.entry(name)?.outputs.len();
        let exe = self.executable(name)?;
        let results = exe.execute_b(args).map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = results[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("device→host: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == n_outputs,
            "artifact {name}: expected {n_outputs} outputs, got {}",
            parts.len()
        );
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }

    /// Convenience: execute with host slices (one-shot upload).
    pub fn execute_host(
        &mut self,
        name: &str,
        args: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let bufs: Vec<PjRtBuffer> = args
            .iter()
            .map(|(data, dims)| self.buffer_f32(data, dims))
            .collect::<Result<_>>()?;
        let refs: Vec<&PjRtBuffer> = bufs.iter().collect();
        self.execute(name, &refs)
    }

    /// Pad a (rows × cols) matrix into a (target_rows × target_cols) zero
    /// matrix — the shape-grid contract with `aot.py` (padded rows/cols are
    /// zero so scores/updates are unaffected; see model.py docstrings).
    pub fn pad_matrix(
        data: &[f32],
        rows: usize,
        cols: usize,
        target_rows: usize,
        target_cols: usize,
    ) -> Vec<f32> {
        assert!(rows <= target_rows && cols <= target_cols);
        let mut out = vec![0f32; target_rows * target_cols];
        for r in 0..rows {
            out[r * target_cols..r * target_cols + cols]
                .copy_from_slice(&data[r * cols..(r + 1) * cols]);
        }
        out
    }

    /// Pad a vector with zeros to `target` length.
    pub fn pad_vec(data: &[f32], target: usize) -> Vec<f32> {
        assert!(data.len() <= target);
        let mut out = vec![0f32; target];
        out[..data.len()].copy_from_slice(data);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_matrix_places_rows() {
        let m = XlaEngine::pad_matrix(&[1.0, 2.0, 3.0, 4.0], 2, 2, 3, 4);
        assert_eq!(
            m,
            vec![1.0, 2.0, 0.0, 0.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]
        );
    }

    #[test]
    fn pad_vec_appends_zeros() {
        assert_eq!(XlaEngine::pad_vec(&[1.0, 2.0], 4), vec![1.0, 2.0, 0.0, 0.0]);
    }

    // Execution tests live in rust/tests/runtime_integration.rs (they need
    // the artifacts directory built by `make artifacts`).
}
