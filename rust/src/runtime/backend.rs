//! [`CpuBackend`]: the [`crate::mwem::MwemBackend`] implementation backed by
//! the runtime-dispatched kernel layer ([`super::kernels`]).
//!
//! This replaced the earlier XLA/PJRT artifact path: the dense steps MWEM
//! actually needs — the `|Q·d|` score matvec and the multiplicative weight
//! update — are bandwidth-bound loops that the SIMD kernels serve directly
//! from the blocked [`crate::mips::VectorSet`] layout, with no device
//! transfer, padding grid, or ahead-of-time compilation step.

use super::kernels;
use crate::mwem::{MwemBackend, QuerySet};
use crate::util::math::normalize_l1;

/// [`MwemBackend`] running the dense steps through the kernel dispatch
/// table resolved at startup.
pub struct CpuBackend {
    /// Number of backend calls performed (for perf accounting).
    pub calls: usize,
}

impl CpuBackend {
    /// A backend using the process-wide kernel dispatch
    /// ([`kernels::active`]).
    pub fn new() -> Self {
        CpuBackend { calls: 0 }
    }

    /// The kernel arm this backend executes on.
    pub fn arm(&self) -> kernels::KernelArm {
        kernels::active().arm
    }
}

impl Default for CpuBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl MwemBackend for CpuBackend {
    fn abs_scores(&mut self, q: &QuerySet, d: &[f32]) -> Vec<f32> {
        self.calls += 1;
        q.vectors().rows().map(|row| kernels::dot(row, d).abs()).collect()
    }

    fn mwu_update(&mut self, w: &mut [f32], c: &[f32], s: f32) -> Vec<f32> {
        self.calls += 1;
        kernels::exp_mul(w, c, s);
        let mut p = w.to_vec();
        normalize_l1(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mwem::NativeBackend;

    #[test]
    fn cpu_backend_matches_native_backend_bitwise() {
        // CpuBackend is NativeBackend routed through the dispatch table;
        // whatever arm is active, outputs must match the scalar-path
        // NativeBackend within the kernel contract (exp_mul tolerance is
        // exercised in tests/kernel_equivalence.rs; here shapes are small
        // and in-range so results coincide to f32 round-off).
        let (m, u) = (13, 37);
        let flat: Vec<f32> = (0..m * u).map(|i| ((i * 31 + 7) % 97) as f32 / 97.0).collect();
        let q = QuerySet::new(crate::mips::VectorSet::new(flat, m, u));
        let d: Vec<f32> = (0..u).map(|i| ((i * 7 % 5) as f32 - 2.0) * 0.125).collect();
        let mut cpu = CpuBackend::new();
        let mut native = NativeBackend;
        let a = cpu.abs_scores(&q, &d);
        let b = native.abs_scores(&q, &d);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= 1e-6, "{x} vs {y}");
        }

        let c: Vec<f32> = (0..u).map(|i| (i as f32 - 18.0) / 37.0).collect();
        let mut w1: Vec<f32> = vec![1.0; u];
        let mut w2 = w1.clone();
        let p1 = cpu.mwu_update(&mut w1, &c, 0.5);
        let p2 = native.mwu_update(&mut w2, &c, 0.5);
        for (x, y) in p1.iter().zip(&p2) {
            assert!((x - y).abs() <= 1e-6, "{x} vs {y}");
        }
        assert_eq!(cpu.calls, 2);
    }
}
