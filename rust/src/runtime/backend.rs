//! [`XlaBackend`]: the [`crate::mwem::MwemBackend`] implementation that runs
//! MWEM's dense numeric steps through the AOT artifacts.
//!
//! The query matrix Q is uploaded to the device once (padded to the
//! artifact's shape grid) and reused across iterations via `execute_b`, so
//! the per-round transfer is only the O(U) difference vector.

use super::engine::XlaEngine;
use crate::mwem::{MwemBackend, QuerySet};
use anyhow::{anyhow, Result};
use xla::PjRtBuffer;

/// [`MwemBackend`] running the dense steps through the AOT artifacts.
pub struct XlaBackend {
    engine: XlaEngine,
    /// Device-resident padded Q + its artifact binding.
    q_cache: Option<QCache>,
    /// Number of XLA executions performed (for perf accounting).
    pub calls: usize,
}

struct QCache {
    buf: PjRtBuffer,
    art: String,
    art_u: usize,
    m: usize,
    u: usize,
}

impl XlaBackend {
    /// Wrap an already-loaded engine.
    pub fn new(engine: XlaEngine) -> Self {
        XlaBackend { engine, q_cache: None, calls: 0 }
    }

    /// Load the artifacts directory and wrap the resulting engine.
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(Self::new(XlaEngine::load(artifacts_dir)?))
    }

    /// The underlying engine.
    pub fn engine(&self) -> &XlaEngine {
        &self.engine
    }

    fn ensure_q(&mut self, q: &QuerySet) -> Result<()> {
        let (m, u) = (q.m(), q.u());
        if let Some(c) = &self.q_cache {
            if c.m == m && c.u == u {
                return Ok(());
            }
        }
        let entry = self
            .engine
            .manifest()
            .best_scores(m, u)
            .ok_or_else(|| anyhow!("no scores artifact fits m={m}, u={u}"))?;
        let (art_m, art_u) = (entry.inputs[0].shape[0], entry.inputs[0].shape[1]);
        let name = entry.name.clone();
        let padded = XlaEngine::pad_matrix(q.vectors().as_slice(), m, u, art_m, art_u);
        let buf = self.engine.buffer_f32(&padded, &[art_m, art_u])?;
        self.q_cache = Some(QCache { buf, art: name, art_u, m, u });
        Ok(())
    }

    fn try_abs_scores(&mut self, q: &QuerySet, d: &[f32]) -> Result<Vec<f32>> {
        self.ensure_q(q)?;
        let cache = self.q_cache.as_ref().unwrap();
        let d_pad = XlaEngine::pad_vec(d, cache.art_u);
        let d_buf = self.engine.buffer_f32(&d_pad, &[cache.art_u])?;
        let art = cache.art.clone();
        let m = cache.m;
        let cache = self.q_cache.as_ref().unwrap();
        let outs = self.engine.execute(&art, &[&cache.buf, &d_buf])?;
        self.calls += 1;
        Ok(outs[0][..m].to_vec())
    }

    fn try_mwu_update(&mut self, w: &mut [f32], c: &[f32], s: f32) -> Result<Vec<f32>> {
        let u = w.len();
        let entry = self
            .engine
            .manifest()
            .best_mwu(u)
            .ok_or_else(|| anyhow!("no mwu artifact fits u={u}"))?;
        let art_u = entry.inputs[0].shape[0];
        let name = entry.name.clone();
        let w_pad = XlaEngine::pad_vec(w, art_u);
        let c_pad = XlaEngine::pad_vec(c, art_u);
        let w_buf = self.engine.buffer_f32(&w_pad, &[art_u])?;
        let c_buf = self.engine.buffer_f32(&c_pad, &[art_u])?;
        let s_buf = self.engine.buffer_scalar_f32(s)?;
        let outs = self.engine.execute(&name, &[&w_buf, &c_buf, &s_buf])?;
        self.calls += 1;
        w.copy_from_slice(&outs[0][..u]);
        Ok(outs[1][..u].to_vec())
    }
}

impl MwemBackend for XlaBackend {
    fn abs_scores(&mut self, q: &QuerySet, d: &[f32]) -> Vec<f32> {
        self.try_abs_scores(q, d)
            .expect("XLA abs_scores failed — are artifacts built for this shape?")
    }

    fn mwu_update(&mut self, w: &mut [f32], c: &[f32], s: f32) -> Vec<f32> {
        self.try_mwu_update(w, c, s)
            .expect("XLA mwu_update failed — are artifacts built for this shape?")
    }
}

// Integration tests (requiring built artifacts) live in
// rust/tests/runtime_integration.rs.
