//! Runtime-dispatched SIMD scoring kernels (DESIGN.md §10).
//!
//! The paper's Θ(√m) win is in *index operations*; the per-iteration
//! constant that remains is raw dot-product and weight-update throughput.
//! This module owns that constant: one function-pointer table per
//! [`KernelArm`], selected **once** at startup, covering the four hot
//! loops named in the roadmap —
//!
//! * [`dot`] — flat scan, IVF list scan (via
//!   [`crate::mips::AugmentedSpace`]), query scoring;
//! * [`l2_sq`] — k-means assignment distances;
//! * [`exp_mul`] — the MWU weight update `w_i ← w_i · exp(s·c_i)` in
//!   `mwem/classic.rs` / `mwem/fast.rs`;
//! * [`clip_scale`] — the LP Bregman projection's clip-and-rescale pass
//!   `x ← min(c·x, 1) / s`.
//!
//! The scalar reference lives in [`crate::util::math`] and never changes —
//! it is the differential baseline every SIMD arm is proven against
//! (`rust/tests/kernel_equivalence.rs`).
//!
//! # Numeric contract
//!
//! `dot`, `l2_sq` and `clip_scale` are **bit-identical** to the scalar
//! reference on every input, including NaN/±inf/subnormal payloads: the
//! SIMD bodies replicate the scalar code's 16-lane accumulator scheme
//! lane for lane (separate multiply and add — no FMA contraction, exactly
//! like the scalar build), reduce the lanes in the same sequential order,
//! and use min operations whose NaN semantics match `f64::min`.
//!
//! `exp_mul` is the one tolerance-bearing kernel: in-range inputs
//! (`s·c_i ∈ [−87, 88]`) use a degree-5 polynomial `exp` (Cephes
//! range-reduction) and may differ from `f32::exp` by up to
//! [`EXP_MUL_MAX_ULPS`] ULPs; any 8-lane block containing an
//! out-of-range, NaN or infinite input falls back to scalar `f32::exp`
//! for that block, so special values behave exactly like the reference.
//! The bound is asserted by the differential harness.
//!
//! # Selection
//!
//! Resolution order: explicit [`init`] (the `[kernels]` config section /
//! `--kernels=` flag) > the `FAST_MWEM_KERNELS` environment variable >
//! auto-detection (`avx2` where the CPU supports it, `neon` on aarch64,
//! `scalar` otherwise). Valid names: `scalar`, `native` (auto-detect),
//! `avx2`, `neon`. The choice is process-wide and sticky — the first
//! resolution wins; [`init`] after first use reports a conflict instead
//! of silently switching mid-run.

use std::sync::OnceLock;

#[cfg(target_arch = "aarch64")]
pub mod neon;
#[cfg(target_arch = "x86_64")]
pub mod x86;

/// Maximum ULP divergence of [`exp_mul`]'s polynomial fast path from the
/// scalar `w_i · exp(s·c_i)` reference, per element, for in-range inputs.
/// Documented tolerance policy (DESIGN.md §10), asserted by
/// `rust/tests/kernel_equivalence.rs`.
pub const EXP_MUL_MAX_ULPS: u32 = 8;

/// Which kernel implementation backs the process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelArm {
    /// The portable reference in [`crate::util::math`] — always available.
    Scalar,
    /// AVX2 `std::arch` kernels (x86_64 with runtime feature detection).
    Avx2,
    /// NEON `std::arch` kernels (aarch64; baseline feature there).
    Neon,
}

impl KernelArm {
    /// Stable gauge encoding for metrics (`kernel` gauge): 0 scalar,
    /// 1 avx2, 2 neon.
    pub fn gauge_value(self) -> f64 {
        match self {
            KernelArm::Scalar => 0.0,
            KernelArm::Avx2 => 1.0,
            KernelArm::Neon => 2.0,
        }
    }
}

impl std::fmt::Display for KernelArm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelArm::Scalar => write!(f, "scalar"),
            KernelArm::Avx2 => write!(f, "avx2"),
            KernelArm::Neon => write!(f, "neon"),
        }
    }
}

/// One resolved set of kernel entry points. All four functions share the
/// numeric contract in the module docs.
#[derive(Clone, Copy)]
pub struct Kernels {
    /// Which arm this table belongs to.
    pub arm: KernelArm,
    /// Dense dot product ⟨a, b⟩ (slices must have equal length).
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// Squared L2 distance ‖a − b‖².
    pub l2_sq: fn(&[f32], &[f32]) -> f32,
    /// MWU weight update: `w[i] *= exp(s * c[i])` elementwise.
    pub exp_mul: fn(&mut [f32], &[f32], f32),
    /// Bregman clip-and-rescale: `x[i] = min(c * x[i], 1.0) * inv_s`.
    pub clip_scale: fn(&mut [f64], f64, f64),
}

fn scalar_exp_mul(w: &mut [f32], c: &[f32], s: f32) {
    debug_assert_eq!(w.len(), c.len());
    for (wi, &ci) in w.iter_mut().zip(c) {
        *wi *= (s * ci).exp();
    }
}

fn scalar_clip_scale(xs: &mut [f64], c: f64, inv_s: f64) {
    for x in xs.iter_mut() {
        *x = (c * *x).min(1.0) * inv_s;
    }
}

static SCALAR: Kernels = Kernels {
    arm: KernelArm::Scalar,
    dot: crate::util::math::dot,
    l2_sq: crate::util::math::l2_sq,
    exp_mul: scalar_exp_mul,
    clip_scale: scalar_clip_scale,
};

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    arm: KernelArm::Avx2,
    dot: x86::dot,
    l2_sq: x86::l2_sq,
    exp_mul: x86::exp_mul,
    clip_scale: x86::clip_scale,
};

#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels {
    arm: KernelArm::Neon,
    dot: neon::dot,
    l2_sq: neon::l2_sq,
    exp_mul: neon::exp_mul,
    clip_scale: neon::clip_scale,
};

/// The specific arm's table, if this build/CPU supports it. `Scalar`
/// always resolves. This is the seam the differential harness uses to
/// compare arms *in-process*, independent of the active dispatch choice.
pub fn table(arm: KernelArm) -> Option<&'static Kernels> {
    match arm {
        KernelArm::Scalar => Some(&SCALAR),
        #[cfg(target_arch = "x86_64")]
        KernelArm::Avx2 => x86::available().then_some(&AVX2),
        #[cfg(target_arch = "aarch64")]
        KernelArm::Neon => Some(&NEON),
        _ => None,
    }
}

/// Every arm this build/CPU can run, scalar first.
pub fn available_arms() -> Vec<KernelArm> {
    [KernelArm::Scalar, KernelArm::Avx2, KernelArm::Neon]
        .into_iter()
        .filter(|&a| table(a).is_some())
        .collect()
}

/// The best auto-detected arm: SIMD where the hardware has it, scalar
/// otherwise.
pub fn native_arm() -> KernelArm {
    for arm in [KernelArm::Avx2, KernelArm::Neon] {
        if table(arm).is_some() {
            return arm;
        }
    }
    KernelArm::Scalar
}

fn resolve(name: &str) -> Result<&'static Kernels, String> {
    match name.to_ascii_lowercase().as_str() {
        "scalar" => Ok(&SCALAR),
        "native" | "auto" => Ok(table(native_arm()).expect("native arm must resolve")),
        "avx2" => table(KernelArm::Avx2)
            .ok_or_else(|| "avx2 kernels not supported on this CPU/arch".to_string()),
        "neon" => table(KernelArm::Neon)
            .ok_or_else(|| "neon kernels not supported on this arch".to_string()),
        other => Err(format!(
            "unknown kernel dispatch {other:?} (expected scalar, native, avx2 or neon)"
        )),
    }
}

static ACTIVE: OnceLock<&'static Kernels> = OnceLock::new();

/// Pin the process-wide dispatch to `name` (config/CLI path). Returns the
/// arm now active. Errors if `name` is invalid or unsupported here, or if
/// dispatch was already resolved to a *different* arm (first choice wins;
/// kernels never switch mid-run).
pub fn init(name: &str) -> Result<KernelArm, String> {
    let want = resolve(name)?;
    let got = ACTIVE.get_or_init(|| want);
    if got.arm != want.arm {
        return Err(format!(
            "kernel dispatch already resolved to {} (cannot switch to {})",
            got.arm, want.arm
        ));
    }
    Ok(got.arm)
}

/// The process-wide kernel table. First use resolves it: the
/// `FAST_MWEM_KERNELS` environment variable if set (panicking loudly on an
/// invalid value — a misconfigured forced arm must not silently fall
/// back), else auto-detection.
#[inline]
pub fn active() -> &'static Kernels {
    ACTIVE.get_or_init(|| match std::env::var("FAST_MWEM_KERNELS") {
        Ok(name) => resolve(&name)
            .unwrap_or_else(|e| panic!("FAST_MWEM_KERNELS={name}: {e}")),
        Err(_) => table(native_arm()).expect("native arm must resolve"),
    })
}

/// Dense dot product through the active dispatch.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    (active().dot)(a, b)
}

/// Squared L2 distance through the active dispatch.
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    (active().l2_sq)(a, b)
}

/// MWU weight update `w[i] *= exp(s·c[i])` through the active dispatch.
#[inline]
pub fn exp_mul(w: &mut [f32], c: &[f32], s: f32) {
    (active().exp_mul)(w, c, s)
}

/// Bregman clip-and-rescale `x[i] = min(c·x[i], 1)·inv_s` through the
/// active dispatch.
#[inline]
pub fn clip_scale(xs: &mut [f64], c: f64, inv_s: f64) {
    (active().clip_scale)(xs, c, inv_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_table_always_available_and_first() {
        let arms = available_arms();
        assert_eq!(arms[0], KernelArm::Scalar);
        assert!(table(KernelArm::Scalar).is_some());
        // the scalar table IS the util::math reference
        let a = [1.0f32, 2.0, 3.0];
        let b = [0.5f32, -1.0, 2.0];
        let t = table(KernelArm::Scalar).unwrap();
        assert_eq!((t.dot)(&a, &b).to_bits(), crate::util::math::dot(&a, &b).to_bits());
    }

    #[test]
    fn resolve_rejects_unknown_names() {
        assert!(resolve("scalar").is_ok());
        assert!(resolve("native").is_ok());
        assert!(resolve("sse9").is_err());
    }

    #[test]
    fn active_dispatch_is_sticky_and_consistent() {
        let arm = active().arm;
        assert_eq!(active().arm, arm, "repeat resolution must not change");
        // init to the same arm is fine; to a different available arm errs
        assert_eq!(init(&arm.to_string()), Ok(arm));
        for other in available_arms() {
            if other != arm {
                assert!(init(&other.to_string()).is_err());
            }
        }
    }

    #[test]
    fn gauge_values_are_stable() {
        assert_eq!(KernelArm::Scalar.gauge_value(), 0.0);
        assert_eq!(KernelArm::Avx2.gauge_value(), 1.0);
        assert_eq!(KernelArm::Neon.gauge_value(), 2.0);
    }
}
