//! AVX2 kernel bodies (x86_64, runtime-detected).
//!
//! Every function here replicates the scalar reference in
//! [`crate::util::math`] / [`super`] *lane for lane*: the 16-float block is
//! two `__m256` accumulators updated with separate multiply and add (the
//! scalar build performs no FMA contraction, so neither do we), the lanes
//! reduce in the same sequential order as `acc.iter().sum()`, and the
//! remainder loop is the same scalar tail. That makes `dot`, `l2_sq` and
//! `clip_scale` bit-identical to the reference on every input — the
//! property `rust/tests/kernel_equivalence.rs` asserts exhaustively.
//!
//! `exp_mul` uses a degree-5 polynomial exp (Cephes-style range reduction,
//! the sse_mathfun lineage) on in-range blocks and scalar `f32::exp` on
//! any block containing out-of-range or non-finite inputs; see
//! [`super::EXP_MUL_MAX_ULPS`] for the tolerance policy.

#![allow(unsafe_code)]

use std::arch::x86_64::*;

/// Runtime CPU support check for this module's kernels.
pub fn available() -> bool {
    is_x86_feature_detected!("avx2")
}

/// AVX2 dot product, bit-identical to the scalar reference.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: dispatch only installs this table when available() is true.
    unsafe { dot_avx2(a, b) }
}

/// AVX2 squared L2 distance, bit-identical to the scalar reference.
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: dispatch only installs this table when available() is true.
    unsafe { l2_sq_avx2(a, b) }
}

/// AVX2 MWU weight update `w[i] *= exp(s·c[i])` (tolerance-bearing; see
/// module docs).
pub fn exp_mul(w: &mut [f32], c: &[f32], s: f32) {
    debug_assert_eq!(w.len(), c.len());
    // SAFETY: dispatch only installs this table when available() is true.
    unsafe { exp_mul_avx2(w, c, s) }
}

/// AVX2 Bregman clip-and-rescale `x[i] = min(c·x[i], 1)·inv_s`,
/// bit-identical to the scalar reference.
pub fn clip_scale(xs: &mut [f64], c: f64, inv_s: f64) {
    // SAFETY: dispatch only installs this table when available() is true.
    unsafe { clip_scale_avx2(xs, c, inv_s) }
}

#[target_feature(enable = "avx2")]
unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let blocks = n / 16;
    for blk in 0..blocks {
        let i = blk * 16;
        let x0 = _mm256_loadu_ps(pa.add(i));
        let y0 = _mm256_loadu_ps(pb.add(i));
        let x1 = _mm256_loadu_ps(pa.add(i + 8));
        let y1 = _mm256_loadu_ps(pb.add(i + 8));
        // mul then add, not FMA: the scalar reference rounds twice
        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(x0, y0));
        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(x1, y1));
    }
    let mut lanes = [0f32; 16];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc0);
    _mm256_storeu_ps(lanes.as_mut_ptr().add(8), acc1);
    // sequential lane reduction — same order as acc.iter().sum()
    let mut s: f32 = lanes.iter().sum();
    for i in blocks * 16..n {
        s += *pa.add(i) * *pb.add(i);
    }
    s
}

#[target_feature(enable = "avx2")]
unsafe fn l2_sq_avx2(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let blocks = n / 16;
    for blk in 0..blocks {
        let i = blk * 16;
        let d0 = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
        let d1 =
            _mm256_sub_ps(_mm256_loadu_ps(pa.add(i + 8)), _mm256_loadu_ps(pb.add(i + 8)));
        acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(d0, d0));
        acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(d1, d1));
    }
    let mut lanes = [0f32; 16];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc0);
    _mm256_storeu_ps(lanes.as_mut_ptr().add(8), acc1);
    let mut s: f32 = lanes.iter().sum();
    for i in blocks * 16..n {
        let d = *pa.add(i) - *pb.add(i);
        s += d * d;
    }
    s
}

// Cephes-style exp constants (sse_mathfun lineage). Inputs outside
// [EXP_LO, EXP_HI] (or non-finite) take the scalar path, so the
// polynomial never has to represent overflow/underflow/subnormal results.
const EXP_LO: f32 = -87.0;
const EXP_HI: f32 = 88.0;
const LOG2EF: f32 = std::f32::consts::LOG2_E;
const EXP_C1: f32 = 0.693_359_4;
const EXP_C2: f32 = -2.121_944_4e-4;
const EXP_P0: f32 = 1.987_569_1e-4;
const EXP_P1: f32 = 1.398_199_9e-3;
const EXP_P2: f32 = 8.333_452e-3;
const EXP_P3: f32 = 4.166_579_6e-2;
const EXP_P4: f32 = 1.666_666_5e-1;
const EXP_P5: f32 = 5.000_000_2e-1;

/// Polynomial exp over one 8-lane block. Caller guarantees every lane of
/// `x` is in `[EXP_LO, EXP_HI]`.
#[target_feature(enable = "avx2")]
unsafe fn exp_ps(x: __m256) -> __m256 {
    let one = _mm256_set1_ps(1.0);
    // n = floor(x·log2(e) + 0.5)
    let fx = _mm256_add_ps(_mm256_mul_ps(x, _mm256_set1_ps(LOG2EF)), _mm256_set1_ps(0.5));
    let fx = _mm256_floor_ps(fx);
    // r = x − n·C1 − n·C2  (two-part ln 2 keeps the reduction exact-ish)
    let r = _mm256_sub_ps(x, _mm256_mul_ps(fx, _mm256_set1_ps(EXP_C1)));
    let r = _mm256_sub_ps(r, _mm256_mul_ps(fx, _mm256_set1_ps(EXP_C2)));
    let r2 = _mm256_mul_ps(r, r);
    // degree-5 Horner in r
    let mut y = _mm256_set1_ps(EXP_P0);
    y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P1));
    y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P2));
    y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P3));
    y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P4));
    y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(EXP_P5));
    y = _mm256_add_ps(_mm256_add_ps(_mm256_mul_ps(y, r2), r), one);
    // 2^n via the exponent field (|n| ≤ 127 within [EXP_LO, EXP_HI])
    let n = _mm256_cvttps_epi32(fx);
    let pow2n =
        _mm256_castsi256_ps(_mm256_slli_epi32(_mm256_add_epi32(n, _mm256_set1_epi32(127)), 23));
    _mm256_mul_ps(y, pow2n)
}

#[target_feature(enable = "avx2")]
unsafe fn exp_mul_avx2(w: &mut [f32], c: &[f32], s: f32) {
    let n = w.len();
    let sv = _mm256_set1_ps(s);
    let lo = _mm256_set1_ps(EXP_LO);
    let hi = _mm256_set1_ps(EXP_HI);
    let pw = w.as_mut_ptr();
    let pc = c.as_ptr();
    let blocks = n / 8;
    for blk in 0..blocks {
        let i = blk * 8;
        let t = _mm256_mul_ps(sv, _mm256_loadu_ps(pc.add(i)));
        // ordered compares: a NaN lane fails both and routes to scalar
        let in_range = _mm256_and_ps(
            _mm256_cmp_ps(t, lo, _CMP_GE_OQ),
            _mm256_cmp_ps(t, hi, _CMP_LE_OQ),
        );
        if _mm256_movemask_ps(in_range) == 0xFF {
            let wv = _mm256_loadu_ps(pw.add(i));
            _mm256_storeu_ps(pw.add(i), _mm256_mul_ps(wv, exp_ps(t)));
        } else {
            for k in i..i + 8 {
                *pw.add(k) *= (s * *pc.add(k)).exp();
            }
        }
    }
    for k in blocks * 8..n {
        *pw.add(k) *= (s * *pc.add(k)).exp();
    }
}

#[target_feature(enable = "avx2")]
unsafe fn clip_scale_avx2(xs: &mut [f64], c: f64, inv_s: f64) {
    let n = xs.len();
    let p = xs.as_mut_ptr();
    let cv = _mm256_set1_pd(c);
    let iv = _mm256_set1_pd(inv_s);
    let one = _mm256_set1_pd(1.0);
    let blocks = n / 4;
    for blk in 0..blocks {
        let i = blk * 4;
        let x = _mm256_loadu_pd(p.add(i));
        // minpd(t, 1.0) returns 1.0 when t is NaN — same as f64::min
        let t = _mm256_min_pd(_mm256_mul_pd(cv, x), one);
        _mm256_storeu_pd(p.add(i), _mm256_mul_pd(t, iv));
    }
    for i in blocks * 4..n {
        *p.add(i) = (c * *p.add(i)).min(1.0) * inv_s;
    }
}
