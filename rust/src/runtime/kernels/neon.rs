//! NEON kernel bodies (aarch64, where NEON is a baseline feature).
//!
//! Same lane-for-lane contract as the AVX2 module: the 16-float block is
//! four `float32x4_t` accumulators updated with separate multiply and add
//! (no FMA contraction — the scalar reference rounds twice), lanes reduce
//! in the same sequential order as `acc.iter().sum()`, and the remainder
//! loop is the scalar tail — so `dot`, `l2_sq` and `clip_scale` are
//! bit-identical to [`crate::util::math`]. `exp_mul` delegates to the
//! scalar body on this arch (the MWU update is memory-bound at the sizes
//! we run; a polynomial NEON exp is not worth a second tolerance surface).

#![allow(unsafe_code)]

use std::arch::aarch64::*;

/// Runtime support check — NEON is baseline on aarch64.
pub fn available() -> bool {
    true
}

/// NEON dot product, bit-identical to the scalar reference.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let blocks = n / 16;
    // SAFETY: in-bounds pointer arithmetic over the checked-equal slices.
    unsafe {
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut acc2 = vdupq_n_f32(0.0);
        let mut acc3 = vdupq_n_f32(0.0);
        for blk in 0..blocks {
            let i = blk * 16;
            // mul then add, not vfmaq: the scalar reference rounds twice
            acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i))));
            acc1 = vaddq_f32(
                acc1,
                vmulq_f32(vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4))),
            );
            acc2 = vaddq_f32(
                acc2,
                vmulq_f32(vld1q_f32(pa.add(i + 8)), vld1q_f32(pb.add(i + 8))),
            );
            acc3 = vaddq_f32(
                acc3,
                vmulq_f32(vld1q_f32(pa.add(i + 12)), vld1q_f32(pb.add(i + 12))),
            );
        }
        let mut lanes = [0f32; 16];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        vst1q_f32(lanes.as_mut_ptr().add(8), acc2);
        vst1q_f32(lanes.as_mut_ptr().add(12), acc3);
        // sequential lane reduction — same order as acc.iter().sum()
        let mut s: f32 = lanes.iter().sum();
        for i in blocks * 16..n {
            s += *pa.add(i) * *pb.add(i);
        }
        s
    }
}

/// NEON squared L2 distance, bit-identical to the scalar reference.
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    let blocks = n / 16;
    // SAFETY: in-bounds pointer arithmetic over the checked-equal slices.
    unsafe {
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut acc2 = vdupq_n_f32(0.0);
        let mut acc3 = vdupq_n_f32(0.0);
        for blk in 0..blocks {
            let i = blk * 16;
            let d0 = vsubq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
            let d1 = vsubq_f32(vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
            let d2 = vsubq_f32(vld1q_f32(pa.add(i + 8)), vld1q_f32(pb.add(i + 8)));
            let d3 = vsubq_f32(vld1q_f32(pa.add(i + 12)), vld1q_f32(pb.add(i + 12)));
            acc0 = vaddq_f32(acc0, vmulq_f32(d0, d0));
            acc1 = vaddq_f32(acc1, vmulq_f32(d1, d1));
            acc2 = vaddq_f32(acc2, vmulq_f32(d2, d2));
            acc3 = vaddq_f32(acc3, vmulq_f32(d3, d3));
        }
        let mut lanes = [0f32; 16];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        vst1q_f32(lanes.as_mut_ptr().add(8), acc2);
        vst1q_f32(lanes.as_mut_ptr().add(12), acc3);
        let mut s: f32 = lanes.iter().sum();
        for i in blocks * 16..n {
            let d = *pa.add(i) - *pb.add(i);
            s += d * d;
        }
        s
    }
}

/// MWU weight update — scalar body on aarch64 (see module docs).
pub fn exp_mul(w: &mut [f32], c: &[f32], s: f32) {
    debug_assert_eq!(w.len(), c.len());
    for (wi, &ci) in w.iter_mut().zip(c) {
        *wi *= (s * ci).exp();
    }
}

/// NEON Bregman clip-and-rescale, bit-identical to the scalar reference.
pub fn clip_scale(xs: &mut [f64], c: f64, inv_s: f64) {
    let n = xs.len();
    let p = xs.as_mut_ptr();
    let blocks = n / 2;
    // SAFETY: in-bounds pointer arithmetic over the slice.
    unsafe {
        let cv = vdupq_n_f64(c);
        let iv = vdupq_n_f64(inv_s);
        let one = vdupq_n_f64(1.0);
        for blk in 0..blocks {
            let i = blk * 2;
            let x = vld1q_f64(p.add(i));
            // FMINNM (minNum): returns 1.0 when c·x is NaN — same as f64::min
            let t = vminnmq_f64(vmulq_f64(cv, x), one);
            vst1q_f64(p.add(i), vmulq_f64(t, iv));
        }
        for i in blocks * 2..n {
            *p.add(i) = (c * *p.add(i)).min(1.0) * inv_s;
        }
    }
}
