//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one artifact input/output tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Tensor dimensions (empty = scalar).
    pub shape: Vec<usize>,
    /// Element dtype name ("float32", "int32", ...).
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count (product of the dims; 1 for scalars).
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact: its HLO-text file plus its I/O signature.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    /// Artifact name (e.g. `scores_m1024_u1024`).
    pub name: String,
    /// Absolute path of the `.hlo.txt` file.
    pub file: PathBuf,
    /// Input tensor signature, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor signature.
    pub outputs: Vec<TensorSpec>,
}

/// The parsed `artifacts/manifest.json`.
#[derive(Debug)]
pub struct Manifest {
    /// Manifest schema version (currently 1).
    pub version: usize,
    /// Shape-grid label the artifacts were lowered for.
    pub grid: String,
    /// Artifacts by name.
    pub entries: BTreeMap<String, ArtifactEntry>,
}

fn tensor_spec(j: &Json) -> Result<TensorSpec> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing shape"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = j
        .get("dtype")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing dtype"))?
        .to_string();
    Ok(TensorSpec { shape, dtype })
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let version =
            j.get("version").and_then(Json::as_usize).ok_or_else(|| anyhow!("no version"))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let grid =
            j.get("grid").and_then(Json::as_str).unwrap_or("default").to_string();
        let mut entries = BTreeMap::new();
        for e in j.get("entries").and_then(Json::as_arr).ok_or_else(|| anyhow!("no entries"))? {
            let name = e
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("entry without name"))?
                .to_string();
            let file = dir.join(
                e.get("file").and_then(Json::as_str).ok_or_else(|| anyhow!("entry without file"))?,
            );
            let inputs = e
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("no inputs"))?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("no outputs"))?
                .iter()
                .map(tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            entries.insert(name.clone(), ArtifactEntry { name, file, inputs, outputs });
        }
        Ok(Manifest { version, grid, entries })
    }

    /// Smallest `scores_m*_u*` artifact that fits (m, u), if any.
    pub fn best_scores(&self, m: usize, u: usize) -> Option<&ArtifactEntry> {
        self.best_2d("scores_m", m, u)
    }

    /// Smallest `step_m*_u*` artifact that fits (m, u), if any.
    pub fn best_step(&self, m: usize, u: usize) -> Option<&ArtifactEntry> {
        self.best_2d("step_m", m, u)
    }

    /// Smallest `dot_m*_d*` artifact that fits (m, d), if any.
    pub fn best_dot(&self, m: usize, d: usize) -> Option<&ArtifactEntry> {
        self.best_2d("dot_m", m, d)
    }

    /// Smallest `mwu_u*` artifact with domain ≥ u.
    pub fn best_mwu(&self, u: usize) -> Option<&ArtifactEntry> {
        self.entries
            .values()
            .filter(|e| e.name.starts_with("mwu_u"))
            .filter(|e| e.inputs[0].shape[0] >= u)
            .min_by_key(|e| e.inputs[0].shape[0])
    }

    fn best_2d(&self, prefix: &str, a: usize, b: usize) -> Option<&ArtifactEntry> {
        self.entries
            .values()
            .filter(|e| e.name.starts_with(prefix))
            .filter(|e| {
                let s = &e.inputs[if prefix.starts_with("step") { 1 } else { 0 }].shape;
                s.len() == 2 && s[0] >= a && s[1] >= b
            })
            .min_by_key(|e| {
                let s = &e.inputs[if prefix.starts_with("step") { 1 } else { 0 }].shape;
                s[0] * s[1]
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let text = r#"{
          "version": 1, "grid": "test",
          "entries": [
            {"name": "scores_m64_u32", "file": "scores_m64_u32.hlo.txt",
             "inputs": [{"shape": [64, 32], "dtype": "float32"},
                         {"shape": [32], "dtype": "float32"}],
             "outputs": [{"shape": [64], "dtype": "float32"}]},
            {"name": "scores_m128_u64", "file": "scores_m128_u64.hlo.txt",
             "inputs": [{"shape": [128, 64], "dtype": "float32"},
                         {"shape": [64], "dtype": "float32"}],
             "outputs": [{"shape": [128], "dtype": "float32"}]},
            {"name": "mwu_u64", "file": "mwu_u64.hlo.txt",
             "inputs": [{"shape": [64], "dtype": "float32"},
                         {"shape": [64], "dtype": "float32"},
                         {"shape": [], "dtype": "float32"}],
             "outputs": [{"shape": [64], "dtype": "float32"},
                          {"shape": [64], "dtype": "float32"}]}
          ]
        }"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn loads_and_selects_best_fit() {
        let dir = std::env::temp_dir().join("fast_mwem_manifest_test");
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 3);
        // exact fit
        assert_eq!(m.best_scores(64, 32).unwrap().name, "scores_m64_u32");
        // needs padding → larger artifact
        assert_eq!(m.best_scores(65, 32).unwrap().name, "scores_m128_u64");
        assert_eq!(m.best_scores(10, 40).unwrap().name, "scores_m128_u64");
        // too large → none
        assert!(m.best_scores(1024, 1024).is_none());
        assert_eq!(m.best_mwu(10).unwrap().name, "mwu_u64");
        assert!(m.best_mwu(100).is_none());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load(Path::new("/nonexistent/x")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
