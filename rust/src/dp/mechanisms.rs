//! The classic private-selection mechanisms (Definition 2.2).
//!
//! `exponential_mechanism` is the exhaustive O(m) baseline the paper
//! accelerates; the sublinear replacement lives in [`crate::lazy`]. Both
//! are implemented through the Gumbel-Max trick (Lemma 3.2) so their output
//! distributions are *identical* — which is exactly the paper's Theorem 3.3
//! argument — and so experiments can share noise-generation code paths.

use crate::util::rng::Rng;

/// ε-DP exponential mechanism over `scores` with the given sensitivity:
/// samples index i with probability ∝ exp(ε·s_i / (2Δ)). O(m) time.
pub fn exponential_mechanism(rng: &mut Rng, scores: &[f32], eps: f64, sensitivity: f64) -> usize {
    debug_assert!(!scores.is_empty());
    let scale = eps / (2.0 * sensitivity);
    let mut best = 0usize;
    let mut best_val = f64::NEG_INFINITY;
    for (i, &s) in scores.iter().enumerate() {
        let v = scale * s as f64 + rng.gumbel();
        if v > best_val {
            best_val = v;
            best = i;
        }
    }
    best
}

/// Report-noisy-max with Gumbel noise at temperature 2Δ/ε — distributionally
/// the same as the exponential mechanism (Gumbel-max trick); exposed
/// separately because some callers want the noisy *score* too.
pub fn report_noisy_max(
    rng: &mut Rng,
    scores: &[f32],
    eps: f64,
    sensitivity: f64,
) -> (usize, f64) {
    debug_assert!(!scores.is_empty());
    let scale = eps / (2.0 * sensitivity);
    let mut best = 0usize;
    let mut best_val = f64::NEG_INFINITY;
    for (i, &s) in scores.iter().enumerate() {
        let v = scale * s as f64 + rng.gumbel();
        if v > best_val {
            best_val = v;
            best = i;
        }
    }
    (best, best_val)
}

/// ε-DP Laplace mechanism for a scalar statistic with the given sensitivity.
pub fn laplace_mechanism(rng: &mut Rng, value: f64, sensitivity: f64, eps: f64) -> f64 {
    value + rng.laplace(sensitivity / eps)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// χ²-style check that EM's empirical distribution matches
    /// exp(ε s/(2Δ)) / Z over a small candidate set.
    #[test]
    fn em_matches_target_distribution() {
        let scores = [0.0f32, 0.5, 1.0, 0.25];
        let (eps, sens) = (2.0, 0.5);
        let scale = eps / (2.0 * sens);
        let weights: Vec<f64> = scores.iter().map(|&s| (scale * s as f64).exp()).collect();
        let z: f64 = weights.iter().sum();

        let mut rng = Rng::new(99);
        let trials = 200_000;
        let mut counts = [0usize; 4];
        for _ in 0..trials {
            counts[exponential_mechanism(&mut rng, &scores, eps, sens)] += 1;
        }
        for i in 0..4 {
            let want = weights[i] / z;
            let got = counts[i] as f64 / trials as f64;
            assert!(
                (got - want).abs() < 0.01,
                "candidate {i}: got {got} want {want}"
            );
        }
    }

    #[test]
    fn em_prefers_max_under_high_eps() {
        let scores = [0.1f32, 0.9, 0.2];
        let mut rng = Rng::new(7);
        let mut hits = 0;
        for _ in 0..1_000 {
            if exponential_mechanism(&mut rng, &scores, 200.0, 1.0) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 990, "hits {hits}");
    }

    #[test]
    fn em_uniform_under_zero_scores() {
        let scores = [0.5f32; 5];
        let mut rng = Rng::new(8);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[exponential_mechanism(&mut rng, &scores, 1.0, 1.0)] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 10_000).abs() < 600, "count {c}");
        }
    }

    #[test]
    fn rnm_returns_consistent_argmax() {
        let scores = [0.0f32, 10.0];
        let mut rng = Rng::new(9);
        let (idx, val) = report_noisy_max(&mut rng, &scores, 100.0, 1.0);
        assert_eq!(idx, 1);
        assert!(val > 0.0);
    }

    #[test]
    fn laplace_mechanism_centred_on_value() {
        let mut rng = Rng::new(10);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += laplace_mechanism(&mut rng, 5.0, 1.0, 2.0);
        }
        assert!((sum / n as f64 - 5.0).abs() < 0.02);
    }
}
