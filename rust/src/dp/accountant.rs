//! Privacy accounting via (advanced) composition (Theorem B.1).

/// Total (ε̃, δ̃) after `k` adaptive uses of an (ε, δ)-DP mechanism
/// (Dwork–Rothblum–Vadhan advanced composition, Theorem B.1):
/// ε̃ = ε·√(2k·ln(1/δ')) + 2kε², δ̃ = kδ + δ'.
pub fn advanced_composition(eps: f64, delta: f64, k: u64, delta_prime: f64) -> (f64, f64) {
    let kf = k as f64;
    let eps_total = eps * (2.0 * kf * (1.0 / delta_prime).ln()).sqrt() + 2.0 * kf * eps * eps;
    let delta_total = kf * delta + delta_prime;
    (eps_total, delta_total)
}

/// The paper's inverse budgeting rule: per-iteration ε₀ so that T
/// compositions stay within (ε, δ). Algorithm 2 uses
/// ε₀ = ε / √(T·ln(1/δ)); Algorithm 3 the more conservative
/// ε₀ = ε / √(8T·log(1/δ)). `slack` selects the constant (1.0 or 8.0).
pub fn per_step_epsilon(eps: f64, delta: f64, t: u64, slack: f64) -> f64 {
    assert!(t > 0 && eps > 0.0 && (0.0..1.0).contains(&delta) && delta > 0.0);
    eps / (slack * t as f64 * (1.0 / delta).ln()).sqrt()
}

/// Running budget tracker for a job: records every mechanism invocation and
/// reports the composed total. Used by the coordinator to expose per-job
/// privacy spend in metrics and to fail-fast when a config would overshoot.
#[derive(Debug, Clone)]
pub struct Accountant {
    /// (ε, δ) of each recorded invocation.
    events: Vec<(f64, f64)>,
    /// δ' slack used when composing.
    delta_prime: f64,
}

impl Accountant {
    /// Fresh accountant with the composition slack δ'.
    pub fn new(delta_prime: f64) -> Self {
        Accountant { events: Vec::new(), delta_prime }
    }

    /// Record one (ε, δ)-DP mechanism invocation.
    pub fn record(&mut self, eps: f64, delta: f64) {
        self.events.push((eps, delta));
    }

    /// Record `n` identical invocations.
    pub fn record_n(&mut self, eps: f64, delta: f64, n: u64) {
        for _ in 0..n {
            self.events.push((eps, delta));
        }
    }

    /// Number of recorded invocations.
    pub fn steps(&self) -> usize {
        self.events.len()
    }

    /// Basic (sequential) composition: sums ε and δ.
    pub fn basic_total(&self) -> (f64, f64) {
        let eps: f64 = self.events.iter().map(|e| e.0).sum();
        let delta: f64 = self.events.iter().map(|e| e.1).sum();
        (eps, delta)
    }

    /// Advanced composition assuming homogeneous events (uses the max ε and
    /// max δ across events — a sound upper bound for mixed runs).
    pub fn advanced_total(&self) -> (f64, f64) {
        if self.events.is_empty() {
            return (0.0, 0.0);
        }
        let eps = self.events.iter().map(|e| e.0).fold(0.0, f64::max);
        let delta = self.events.iter().map(|e| e.1).fold(0.0, f64::max);
        advanced_composition(eps, delta, self.events.len() as u64, self.delta_prime)
    }

    /// The tighter of basic vs advanced composition.
    pub fn best_total(&self) -> (f64, f64) {
        let (eb, db) = self.basic_total();
        let (ea, da) = self.advanced_total();
        if ea < eb {
            (ea, da)
        } else {
            (eb, db)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advanced_beats_basic_for_many_steps() {
        let eps0 = 0.01;
        let k = 10_000;
        let (adv, _) = advanced_composition(eps0, 0.0, k, 1e-6);
        let basic = eps0 * k as f64;
        assert!(adv < basic, "advanced {adv} basic {basic}");
    }

    #[test]
    fn per_step_round_trips_within_budget() {
        let (eps, delta, t) = (1.0, 1e-3, 500u64);
        let eps0 = per_step_epsilon(eps, delta, t, 8.0);
        // composing T steps of eps0 must stay within ~eps for small eps0
        let (total, _) = advanced_composition(eps0, 0.0, t, delta);
        // the √8 slack makes this strictly under budget incl. the 2kε² term
        assert!(total <= eps * 1.01, "total {total}");
    }

    #[test]
    fn accountant_basic_and_advanced() {
        let mut a = Accountant::new(1e-6);
        a.record_n(0.005, 0.0, 2000);
        assert_eq!(a.steps(), 2000);
        let (eb, _) = a.basic_total();
        assert!((eb - 10.0).abs() < 1e-9);
        let (ea, da) = a.advanced_total();
        assert!(ea < eb);
        assert!(da >= 1e-6);
        let (best, _) = a.best_total();
        assert!((best - ea).abs() < 1e-12);
    }

    #[test]
    fn accountant_prefers_basic_for_few_steps() {
        let mut a = Accountant::new(1e-6);
        a.record(0.5, 0.0);
        let (eb, _) = a.basic_total();
        let (best, _) = a.best_total();
        assert!((best - eb).abs() < 1e-12);
    }

    #[test]
    fn empty_accountant_is_zero() {
        let a = Accountant::new(1e-6);
        assert_eq!(a.best_total(), (0.0, 0.0));
    }
}
