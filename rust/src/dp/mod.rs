//! Differential-privacy substrate: composition accounting and the basic
//! mechanisms (exponential mechanism, report-noisy-max, Laplace).

pub mod accountant;
pub mod mechanisms;

pub use accountant::{advanced_composition, per_step_epsilon, Accountant};
pub use mechanisms::{exponential_mechanism, laplace_mechanism, report_noisy_max};
