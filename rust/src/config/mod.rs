//! Layered configuration: an INI/TOML-subset file format plus `--key=value`
//! CLI overrides (the offline build vendors no clap/toml — see DESIGN.md §3).
//!
//! Format:
//! ```text
//! # comment
//! seed = 42
//! [mwem]
//! t = 2000
//! index = "hnsw"
//! ```
//! Keys are addressed as `section.key` (top-level keys have no prefix).
//!
//! Typed section views live next to their consumers: `[sharding]`,
//! `[cache]`, `[store]`, `[dynamic]`, `[kernels]`, `[pager]` and
//! `[workload]` below ([`ShardingConfig`], [`CacheConfig`],
//! [`StoreConfig`], [`DynamicConfig`], [`KernelConfig`], [`PagerConfig`],
//! [`WorkloadConfig`]); the `[server]`
//! section of the
//! long-lived serving runtime is read by
//! [`crate::server::ServerConfig::from_config`] (DESIGN.md §8), and the
//! `[wire]` section of its network front end (listen address, connection
//! caps, bearer tokens) by [`crate::server::WireConfig::from_config`]
//! (DESIGN.md §11).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Flat `section.key → value` configuration store.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// An empty configuration.
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse the INI/TOML subset.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: malformed section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected key = value", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let mut val = v.trim().to_string();
            if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                val = val[1..val.len() - 1].to_string();
            }
            values.insert(key, val);
        }
        Ok(Config { values })
    }

    /// Parse a config file from disk.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    /// Apply `--key=value` style CLI overrides (highest precedence).
    pub fn apply_overrides<'a>(&mut self, args: impl IntoIterator<Item = &'a str>) -> Result<()> {
        for a in args {
            let Some(rest) = a.strip_prefix("--") else {
                bail!("override {a:?} must start with --");
            };
            let Some((k, v)) = rest.split_once('=') else {
                bail!("override {a:?} must be --key=value");
            };
            self.values.insert(k.to_string(), v.to_string());
        }
        Ok(())
    }

    /// Set a key programmatically (same precedence as a CLI override).
    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.values.insert(key.to_string(), value.to_string());
    }

    /// Raw string value of a key, if present.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// String value with a default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get_str(key).unwrap_or(default).to_string()
    }

    /// Typed value of a key, if present (error on parse failure).
    pub fn get<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.values.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("config key {key}: cannot parse {s:?}")),
        }
    }

    /// Typed value with a default (error on parse failure).
    pub fn or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        Ok(self.get(key)?.unwrap_or(default))
    }

    /// All known keys, in sorted order.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

/// Typed view of the `[sharding]` section (DESIGN.md §5): how the lazy
/// exponential mechanism is split across per-shard k-MIPS indices.
///
/// ```text
/// [sharding]
/// shards = 4            # 1 = monolithic index (the default)
/// workers = 0           # pool width for shard jobs; 0 = one per shard
/// parallel_select = false  # fan per-draw shard searches onto the pool
/// ```
///
/// The CLI also accepts `--shards=N` as shorthand for
/// `--sharding.shards=N`. `shards` applies everywhere; the two
/// select-time parallelism knobs are consumed by the Fast-MWEM release
/// path (`FastMwemConfig::with_sharding`) — the LP solvers' sharded mode
/// carries only the shard count and runs its per-draw searches inline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardingConfig {
    /// Number of lazy-EM shards (≤ 1 → one monolithic index).
    pub shards: usize,
    /// Pool width for per-draw shard searches (0 → one per shard).
    /// Index *builds* always use one pool thread per shard.
    pub workers: usize,
    /// Run each draw's S shard searches on the pool instead of inline.
    pub parallel_select: bool,
}

impl Default for ShardingConfig {
    fn default() -> Self {
        ShardingConfig { shards: 1, workers: 0, parallel_select: false }
    }
}

impl ShardingConfig {
    /// Read the `[sharding]` section, honoring the `--shards=N` shorthand
    /// (the shorthand wins over `sharding.shards`).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let section = cfg.or("sharding.shards", 1usize)?;
        Ok(ShardingConfig {
            shards: cfg.or("shards", section)?,
            workers: cfg.or("sharding.workers", 0usize)?,
            parallel_select: cfg.or("sharding.parallel_select", false)?,
        })
    }
}

/// Typed view of the `[cache]` section (DESIGN.md §6): the coordinator's
/// warm-index cache of pre-built k-MIPS indices, shared across jobs that
/// answer the same workload.
///
/// ```text
/// [cache]
/// capacity = 8   # pre-built indices kept resident; 0 disables the cache
/// ```
///
/// The CLI also accepts `--cache-capacity=N` as shorthand for
/// `--cache.capacity=N` (the shorthand wins over the section value).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheConfig {
    /// Maximum pre-built indices kept resident (LRU-evicted beyond this;
    /// 0 disables caching).
    pub capacity: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig { capacity: 8 }
    }
}

impl CacheConfig {
    /// Read the `[cache]` section, honoring the `--cache-capacity=N`
    /// shorthand (the shorthand wins over `cache.capacity`).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let section = cfg.or("cache.capacity", CacheConfig::default().capacity)?;
        Ok(CacheConfig { capacity: cfg.or("cache-capacity", section)? })
    }
}

/// Typed view of the `[store]` section (DESIGN.md §7): the persistent
/// artifact store that snapshots built k-MIPS indices to disk so warm
/// serving survives coordinator restarts.
///
/// ```text
/// [store]
/// dir = "artifacts/index-store"   # unset disables persistence
/// lease = true          # build-lease dedup across processes (DESIGN.md §13)
/// lease_ttl_ms = 30000  # lease expiry (max expected build time)
/// lease_poll_ms = 25    # waiter poll cadence
/// lease_wait_ms = 120000  # give up waiting and build independently
/// watch = true          # manifest generation watch across processes
/// ```
///
/// The CLI also accepts `--store-dir=PATH` as shorthand for
/// `--store.dir=PATH` (the shorthand wins over the section value). The
/// lease and watch knobs only matter when two or more processes share
/// one `dir` (DESIGN.md §13); single-process serving pays one
/// uncontended lock-file create per build and one stat per miss.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StoreConfig {
    /// Store directory (`None` = no persistence; warm serving stays
    /// in-memory only).
    pub dir: Option<String>,
    /// Build-lease deduplication across processes sharing `dir`.
    pub lease: bool,
    /// Lease expiry in ms — a holder silent this long is presumed dead
    /// and its lease is taken over.
    pub lease_ttl_ms: u64,
    /// Waiter poll cadence in ms while a peer holds the build lease.
    pub lease_poll_ms: u64,
    /// Upper bound in ms on waiting for a peer's build before degrading
    /// to an independent build.
    pub lease_wait_ms: u64,
    /// Manifest generation watch: adopt peer-committed workload updates
    /// before serving (keeps `stale_generation_serves == 0` across
    /// processes).
    pub watch: bool,
}

impl Default for StoreConfig {
    fn default() -> Self {
        let l = crate::store::LeaseSettings::default();
        StoreConfig {
            dir: None,
            lease: l.enabled,
            lease_ttl_ms: l.ttl.as_millis() as u64,
            lease_poll_ms: l.poll.as_millis() as u64,
            lease_wait_ms: l.max_wait.as_millis() as u64,
            watch: true,
        }
    }
}

impl StoreConfig {
    /// Read the `[store]` section, honoring the `--store-dir=PATH`
    /// shorthand (the shorthand wins over `store.dir`).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let d = StoreConfig::default();
        let dir = cfg
            .get_str("store-dir")
            .or_else(|| cfg.get_str("store.dir"))
            .map(str::to_string);
        Ok(StoreConfig {
            dir,
            lease: cfg.or("store.lease", d.lease)?,
            lease_ttl_ms: cfg.or("store.lease_ttl_ms", d.lease_ttl_ms)?,
            lease_poll_ms: cfg.or("store.lease_poll_ms", d.lease_poll_ms)?,
            lease_wait_ms: cfg.or("store.lease_wait_ms", d.lease_wait_ms)?,
            watch: cfg.or("store.watch", d.watch)?,
        })
    }

    /// The `[store]` lease knobs as the store layer's
    /// [`crate::store::LeaseSettings`].
    pub fn lease_settings(&self) -> crate::store::LeaseSettings {
        crate::store::LeaseSettings {
            enabled: self.lease,
            ttl: std::time::Duration::from_millis(self.lease_ttl_ms),
            poll: std::time::Duration::from_millis(self.lease_poll_ms),
            max_wait: std::time::Duration::from_millis(self.lease_wait_ms),
        }
    }
}

/// Typed view of the `[dynamic]` section (DESIGN.md §9): how evolving
/// workloads are exercised — the size of each synthesized update and, in
/// daemon mode, how often tenants submit one.
///
/// ```text
/// [dynamic]
/// update_every = 0   # daemon: one WorkloadUpdate every N jobs per tenant (0 = off)
/// insert = 4         # rows appended per update
/// tombstone = 2      # rows retired per update
/// ```
///
/// The CLI also accepts `--update-every=N`, `--update-insert=N` and
/// `--update-tombstone=N` as shorthands (shorthands win over section
/// values). The deltas/snapshot compaction cadence is fixed at
/// [`crate::store::tiered::COMPACT_EVERY`] generations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DynamicConfig {
    /// In `serve --daemon`, submit one `WorkloadUpdate` every N jobs per
    /// tenant (0 disables updates — every workload stays static).
    pub update_every: usize,
    /// Rows appended by each synthesized update.
    pub insert: usize,
    /// Live rows retired by each synthesized update.
    pub tombstone: usize,
}

impl Default for DynamicConfig {
    fn default() -> Self {
        DynamicConfig { update_every: 0, insert: 4, tombstone: 2 }
    }
}

impl DynamicConfig {
    /// Read the `[dynamic]` section, honoring the `--update-*` shorthands.
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let d = DynamicConfig::default();
        Ok(DynamicConfig {
            update_every: cfg
                .or("update-every", cfg.or("dynamic.update_every", d.update_every)?)?,
            insert: cfg.or("update-insert", cfg.or("dynamic.insert", d.insert)?)?,
            tombstone: cfg
                .or("update-tombstone", cfg.or("dynamic.tombstone", d.tombstone)?)?,
        })
    }
}

/// Typed view of the `[kernels]` section (DESIGN.md §10): which scoring
/// kernel arm the process runs on.
///
/// ```text
/// [kernels]
/// dispatch = "native"   # scalar | native | avx2 | neon
/// ```
///
/// The CLI also accepts `--kernels=NAME` as shorthand for
/// `--kernels.dispatch=NAME` (the shorthand wins over the section value).
/// An empty/unset value defers to the `FAST_MWEM_KERNELS` environment
/// variable and then auto-detection
/// ([`crate::runtime::kernels::active`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct KernelConfig {
    /// Requested dispatch arm (`None` = env var / auto-detect).
    pub dispatch: Option<String>,
}

impl KernelConfig {
    /// Read the `[kernels]` section, honoring the `--kernels=NAME`
    /// shorthand (the shorthand wins over `kernels.dispatch`).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let dispatch = cfg
            .get_str("kernels")
            .or_else(|| cfg.get_str("kernels.dispatch"))
            .map(str::to_string);
        Ok(KernelConfig { dispatch })
    }

    /// Pin the process-wide kernel dispatch if the config requested one.
    /// Returns the arm now active, or `None` when nothing was requested
    /// (leaving env-var/auto resolution to first kernel use).
    pub fn apply(&self) -> Result<Option<crate::runtime::kernels::KernelArm>> {
        match &self.dispatch {
            None => Ok(None),
            Some(name) => crate::runtime::kernels::init(name)
                .map(Some)
                .map_err(|e| anyhow::anyhow!("[kernels] dispatch: {e}")),
        }
    }
}

/// Typed view of the `[pager]` section (DESIGN.md §12): how the artifact
/// store restores snapshots — zero-copy mmap paging vs heap decode — how
/// much heap the warm-index L1 tier may pin, and whether the quantized
/// shortlist tier is on.
///
/// ```text
/// [pager]
/// enabled = true        # mmap v3 artifacts; false = always decode into heap
/// verify = true         # eager section-checksum walk at open time
/// heap_budget_mb = 0    # L1 heap ceiling in MiB (0 = unlimited)
/// quant = "off"         # quantized shortlist tier: off | int8 | f16
/// ```
///
/// The CLI also accepts `--heap-budget-mb=N` and `--quant=MODE` as
/// shorthands (shorthands win over section values). The pager and the
/// quant tier are both pure accelerators: every `select()` draw is
/// bit-identical with them on or off, so none of these knobs enters
/// [`crate::coordinator::WorkloadKey`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PagerConfig {
    /// Restore artifacts over a shared memory mapping (default). Off =
    /// always decode into heap.
    pub enabled: bool,
    /// Verify every section checksum eagerly at artifact open time.
    pub verify: bool,
    /// Heap ceiling for L1-resident index data, in MiB (0 = unlimited).
    /// Mmap-borrowed rows count as zero against it.
    pub heap_budget_mb: usize,
    /// Quantized shortlist tier mode (`None`/"off" = tier off).
    pub quant: Option<String>,
}

impl Default for PagerConfig {
    fn default() -> Self {
        PagerConfig { enabled: true, verify: true, heap_budget_mb: 0, quant: None }
    }
}

impl PagerConfig {
    /// Read the `[pager]` section, honoring the `--heap-budget-mb=N` and
    /// `--quant=MODE` shorthands (shorthands win over section values).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let d = PagerConfig::default();
        let quant = cfg
            .get_str("quant")
            .or_else(|| cfg.get_str("pager.quant"))
            .map(str::to_string)
            .filter(|q| q != "off");
        Ok(PagerConfig {
            enabled: cfg.or("pager.enabled", d.enabled)?,
            verify: cfg.or("pager.verify", d.verify)?,
            heap_budget_mb: cfg
                .or("heap-budget-mb", cfg.or("pager.heap_budget_mb", d.heap_budget_mb)?)?,
            quant,
        })
    }

    /// The store-facing restore settings.
    pub fn settings(&self) -> crate::store::PagerSettings {
        crate::store::PagerSettings { enabled: self.enabled, verify: self.verify }
    }

    /// The L1 heap ceiling (`heap_budget_mb` 0 = unlimited).
    pub fn heap_budget(&self) -> crate::store::HeapBudget {
        match self.heap_budget_mb {
            0 => crate::store::HeapBudget::unlimited(),
            mb => crate::store::HeapBudget::from_mb(mb),
        }
    }

    /// Pin the process-wide quantized-shortlist mode this config requests
    /// (including clearing it when unset). Returns the mode now ambient
    /// (`None` = tier off).
    pub fn apply_quant(&self) -> Result<Option<crate::mips::QuantMode>> {
        let mode = match &self.quant {
            None => None,
            Some(name) => Some(
                name.parse::<crate::mips::QuantMode>()
                    .map_err(|e| anyhow::anyhow!("[pager] quant: {e}"))?,
            ),
        };
        crate::mips::quant::set_ambient_mode(mode);
        Ok(mode)
    }
}

/// Typed view of the `[workload]` section (DESIGN.md §14): which query
/// class release jobs synthesize and answer through the generic
/// mechanism engine.
///
/// ```text
/// [workload]
/// class = "linear"   # linear | convex-lsq | convex-logistic
/// ```
///
/// The CLI also accepts `--class=NAME` as shorthand for
/// `--workload.class=NAME` (the shorthand wins over the section value).
/// The class enters [`crate::coordinator::WorkloadKey`] through the
/// fingerprint, so the tiered store never serves one class's artifact
/// for another.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkloadConfig {
    /// Query class released by `repro release` / served release jobs.
    pub class: crate::workloads::QueryClassKind,
}

impl WorkloadConfig {
    /// Read the `[workload]` section, honoring the `--class=NAME`
    /// shorthand (the shorthand wins over `workload.class`).
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let name = cfg
            .get_str("class")
            .or_else(|| cfg.get_str("workload.class"))
            .unwrap_or("linear");
        let class = name
            .parse::<crate::workloads::QueryClassKind>()
            .map_err(|e| anyhow::anyhow!("[workload] class: {e}"))?;
        Ok(WorkloadConfig { class })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        # top-level
        seed = 42
        results_dir = "results"

        [mwem]
        t = 2000
        eps = 1.0
        index = "hnsw"

        [lp]
        delta_inf = 0.1
    "#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.or("seed", 0u64).unwrap(), 42);
        assert_eq!(c.str_or("results_dir", "x"), "results");
        assert_eq!(c.or("mwem.t", 0usize).unwrap(), 2000);
        assert_eq!(c.or("mwem.eps", 0.0f64).unwrap(), 1.0);
        assert_eq!(c.str_or("mwem.index", ""), "hnsw");
        assert_eq!(c.or("lp.delta_inf", 0.0f64).unwrap(), 0.1);
        // default when missing
        assert_eq!(c.or("missing", 7i32).unwrap(), 7);
    }

    #[test]
    fn cli_overrides_win() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.apply_overrides(["--mwem.t=500", "--new.key=hello"]).unwrap();
        assert_eq!(c.or("mwem.t", 0usize).unwrap(), 500);
        assert_eq!(c.str_or("new.key", ""), "hello");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("novalue").is_err());
        let mut c = Config::new();
        assert!(c.apply_overrides(["--bad"]).is_err());
        assert!(c.apply_overrides(["noprefix=1"]).is_err());
    }

    #[test]
    fn bad_type_is_error() {
        let c = Config::parse("x = notanumber").unwrap();
        assert!(c.or("x", 1u32).is_err());
    }

    #[test]
    fn cache_section_parses_with_defaults_and_shorthand() {
        // defaults when nothing is set
        let c = Config::new();
        assert_eq!(CacheConfig::from_config(&c).unwrap(), CacheConfig::default());

        // section value
        let c = Config::parse("[cache]\ncapacity = 3\n").unwrap();
        assert_eq!(CacheConfig::from_config(&c).unwrap().capacity, 3);

        // --cache-capacity=0 shorthand beats the section value
        let mut c = Config::parse("[cache]\ncapacity = 3\n").unwrap();
        c.apply_overrides(["--cache-capacity=0"]).unwrap();
        assert_eq!(CacheConfig::from_config(&c).unwrap().capacity, 0);
    }

    #[test]
    fn store_section_parses_with_defaults_and_shorthand() {
        // default: no persistence
        let c = Config::new();
        assert_eq!(StoreConfig::from_config(&c).unwrap(), StoreConfig::default());

        // section value
        let c = Config::parse("[store]\ndir = \"idx-store\"\n").unwrap();
        assert_eq!(
            StoreConfig::from_config(&c).unwrap().dir.as_deref(),
            Some("idx-store")
        );

        // --store-dir shorthand beats the section value
        let mut c = Config::parse("[store]\ndir = \"idx-store\"\n").unwrap();
        c.apply_overrides(["--store-dir=/tmp/other"]).unwrap();
        assert_eq!(
            StoreConfig::from_config(&c).unwrap().dir.as_deref(),
            Some("/tmp/other")
        );

        // multi-process knobs (DESIGN.md §13) parse and map onto the
        // store layer's LeaseSettings
        let c = Config::parse(
            "[store]\nlease = false\nlease_ttl_ms = 5000\nlease_poll_ms = 10\n\
             lease_wait_ms = 9000\nwatch = false\n",
        )
        .unwrap();
        let s = StoreConfig::from_config(&c).unwrap();
        assert!(!s.lease && !s.watch);
        let l = s.lease_settings();
        assert!(!l.enabled);
        assert_eq!(l.ttl, std::time::Duration::from_millis(5000));
        assert_eq!(l.poll, std::time::Duration::from_millis(10));
        assert_eq!(l.max_wait, std::time::Duration::from_millis(9000));
        // defaults: lease + watch on, TTL in the tens of seconds
        let d = StoreConfig::default();
        assert!(d.lease && d.watch);
        assert_eq!(d.lease_settings(), crate::store::LeaseSettings::default());
    }

    #[test]
    fn dynamic_section_parses_with_defaults_and_shorthand() {
        // defaults when nothing is set
        let c = Config::new();
        assert_eq!(DynamicConfig::from_config(&c).unwrap(), DynamicConfig::default());

        // full section
        let c = Config::parse("[dynamic]\nupdate_every = 6\ninsert = 8\ntombstone = 3\n")
            .unwrap();
        let d = DynamicConfig::from_config(&c).unwrap();
        assert_eq!(d, DynamicConfig { update_every: 6, insert: 8, tombstone: 3 });

        // shorthands beat the section values
        let mut c = Config::parse("[dynamic]\nupdate_every = 6\n").unwrap();
        c.apply_overrides(["--update-every=2", "--update-insert=1"]).unwrap();
        let d = DynamicConfig::from_config(&c).unwrap();
        assert_eq!((d.update_every, d.insert, d.tombstone), (2, 1, 2));
    }

    #[test]
    fn kernels_section_parses_with_defaults_and_shorthand() {
        // default: no explicit dispatch (env/auto resolution)
        let c = Config::new();
        assert_eq!(KernelConfig::from_config(&c).unwrap(), KernelConfig::default());

        // section value
        let c = Config::parse("[kernels]\ndispatch = \"scalar\"\n").unwrap();
        assert_eq!(
            KernelConfig::from_config(&c).unwrap().dispatch.as_deref(),
            Some("scalar")
        );

        // --kernels shorthand beats the section value
        let mut c = Config::parse("[kernels]\ndispatch = \"scalar\"\n").unwrap();
        c.apply_overrides(["--kernels=native"]).unwrap();
        assert_eq!(
            KernelConfig::from_config(&c).unwrap().dispatch.as_deref(),
            Some("native")
        );
    }

    #[test]
    fn pager_section_parses_with_defaults_and_shorthand() {
        // defaults: pager on, verify on, no budget, quant off
        let c = Config::new();
        let p = PagerConfig::from_config(&c).unwrap();
        assert_eq!(p, PagerConfig::default());
        assert_eq!(p.heap_budget(), crate::store::HeapBudget::unlimited());
        assert_eq!(
            p.settings(),
            crate::store::PagerSettings { enabled: true, verify: true }
        );

        // full section; quant = "off" stays None
        let c = Config::parse(
            "[pager]\nenabled = false\nverify = false\nheap_budget_mb = 3\nquant = \"off\"\n",
        )
        .unwrap();
        let p = PagerConfig::from_config(&c).unwrap();
        assert!(!p.enabled && !p.verify);
        assert_eq!(p.heap_budget_mb, 3);
        assert_eq!(p.heap_budget().limit(), Some(3 << 20));
        assert_eq!(p.quant, None);

        // shorthands beat the section values
        let mut c =
            Config::parse("[pager]\nheap_budget_mb = 3\nquant = \"int8\"\n").unwrap();
        c.apply_overrides(["--heap-budget-mb=7", "--quant=f16"]).unwrap();
        let p = PagerConfig::from_config(&c).unwrap();
        assert_eq!(p.heap_budget_mb, 7);
        assert_eq!(p.quant.as_deref(), Some("f16"));

        // an unknown quant mode is a typed config error, caught at apply
        let c = Config::parse("[pager]\nquant = \"int4\"\n").unwrap();
        assert!(PagerConfig::from_config(&c).unwrap().apply_quant().is_err());
    }

    #[test]
    fn workload_section_parses_with_defaults_and_shorthand() {
        use crate::workloads::QueryClassKind;
        // default: linear
        let c = Config::new();
        assert_eq!(WorkloadConfig::from_config(&c).unwrap().class, QueryClassKind::Linear);

        // section value
        let c = Config::parse("[workload]\nclass = \"convex-lsq\"\n").unwrap();
        assert_eq!(
            WorkloadConfig::from_config(&c).unwrap().class,
            QueryClassKind::ConvexLsq
        );

        // --class shorthand beats the section value
        let mut c = Config::parse("[workload]\nclass = \"convex-lsq\"\n").unwrap();
        c.apply_overrides(["--class=convex-logistic"]).unwrap();
        assert_eq!(
            WorkloadConfig::from_config(&c).unwrap().class,
            QueryClassKind::ConvexLogistic
        );

        // an unknown class is a typed config error
        let c = Config::parse("[workload]\nclass = \"cubic\"\n").unwrap();
        assert!(WorkloadConfig::from_config(&c).is_err());
    }

    #[test]
    fn sharding_section_parses_with_defaults_and_shorthand() {
        // defaults when nothing is set
        let c = Config::new();
        assert_eq!(ShardingConfig::from_config(&c).unwrap(), ShardingConfig::default());

        // full section
        let c = Config::parse(
            "[sharding]\nshards = 4\nworkers = 2\nparallel_select = true\n",
        )
        .unwrap();
        let s = ShardingConfig::from_config(&c).unwrap();
        assert_eq!(s, ShardingConfig { shards: 4, workers: 2, parallel_select: true });

        // --shards=8 shorthand beats the section value
        let mut c = Config::parse("[sharding]\nshards = 4\n").unwrap();
        c.apply_overrides(["--shards=8"]).unwrap();
        assert_eq!(ShardingConfig::from_config(&c).unwrap().shards, 8);
    }
}
