//! Layered configuration: an INI/TOML-subset file format plus `--key=value`
//! CLI overrides (the offline build vendors no clap/toml — see DESIGN.md §3).
//!
//! Format:
//! ```text
//! # comment
//! seed = 42
//! [mwem]
//! t = 2000
//! index = "hnsw"
//! ```
//! Keys are addressed as `section.key` (top-level keys have no prefix).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse the INI/TOML subset.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: malformed section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected key = value", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let mut val = v.trim().to_string();
            if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                val = val[1..val.len() - 1].to_string();
            }
            values.insert(key, val);
        }
        Ok(Config { values })
    }

    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::parse(&text)
    }

    /// Apply `--key=value` style CLI overrides (highest precedence).
    pub fn apply_overrides<'a>(&mut self, args: impl IntoIterator<Item = &'a str>) -> Result<()> {
        for a in args {
            let Some(rest) = a.strip_prefix("--") else {
                bail!("override {a:?} must start with --");
            };
            let Some((k, v)) = rest.split_once('=') else {
                bail!("override {a:?} must be --key=value");
            };
            self.values.insert(k.to_string(), v.to_string());
        }
        Ok(())
    }

    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get_str(key).unwrap_or(default).to_string()
    }

    pub fn get<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.values.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("config key {key}: cannot parse {s:?}")),
        }
    }

    pub fn or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        Ok(self.get(key)?.unwrap_or(default))
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        # top-level
        seed = 42
        results_dir = "results"

        [mwem]
        t = 2000
        eps = 1.0
        index = "hnsw"

        [lp]
        delta_inf = 0.1
    "#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.or("seed", 0u64).unwrap(), 42);
        assert_eq!(c.str_or("results_dir", "x"), "results");
        assert_eq!(c.or("mwem.t", 0usize).unwrap(), 2000);
        assert_eq!(c.or("mwem.eps", 0.0f64).unwrap(), 1.0);
        assert_eq!(c.str_or("mwem.index", ""), "hnsw");
        assert_eq!(c.or("lp.delta_inf", 0.0f64).unwrap(), 0.1);
        // default when missing
        assert_eq!(c.or("missing", 7i32).unwrap(), 7);
    }

    #[test]
    fn cli_overrides_win() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.apply_overrides(["--mwem.t=500", "--new.key=hello"]).unwrap();
        assert_eq!(c.or("mwem.t", 0usize).unwrap(), 500);
        assert_eq!(c.str_or("new.key", ""), "hello");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("novalue").is_err());
        let mut c = Config::new();
        assert!(c.apply_overrides(["--bad"]).is_err());
        assert!(c.apply_overrides(["noprefix=1"]).is_err());
    }

    #[test]
    fn bad_type_is_error() {
        let c = Config::parse("x = notanumber").unwrap();
        assert!(c.or("x", 1u32).is_err());
    }
}
