//! Shared plumbing for the figure drivers.

use std::path::PathBuf;

#[derive(Clone, Debug)]
pub struct EvalOpts {
    /// Shrink sweeps for CI-speed runs (shapes preserved).
    pub quick: bool,
    pub out_dir: PathBuf,
    pub seed: u64,
}

impl Default for EvalOpts {
    fn default() -> Self {
        EvalOpts { quick: false, out_dir: PathBuf::from("results"), seed: 20260204 }
    }
}

impl EvalOpts {
    pub fn quick() -> Self {
        EvalOpts { quick: true, ..Default::default() }
    }

    pub fn csv_path(&self, name: &str) -> PathBuf {
        self.out_dir.join(format!("{name}.csv"))
    }

    /// Pick between full-scale and quick-scale parameters.
    pub fn pick<T: Copy>(&self, full: T, quick: T) -> T {
        if self.quick {
            quick
        } else {
            full
        }
    }

    pub fn pick_vec<T: Clone>(&self, full: &[T], quick: &[T]) -> Vec<T> {
        if self.quick {
            quick.to_vec()
        } else {
            full.to_vec()
        }
    }
}

/// Pretty-print one table row to stdout.
pub fn print_row(cols: &[String]) {
    println!("  {}", cols.join("  |  "));
}
