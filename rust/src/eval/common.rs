//! Shared plumbing for the figure drivers.

use std::path::PathBuf;

/// Options shared by every figure driver.
#[derive(Clone, Debug)]
pub struct EvalOpts {
    /// Shrink sweeps for CI-speed runs (shapes preserved).
    pub quick: bool,
    /// Directory the per-figure CSVs are written to.
    pub out_dir: PathBuf,
    /// Base seed; each driver salts it per sweep point.
    pub seed: u64,
    /// Lazy-EM shard count applied to the Fast-MWEM runs of the figure
    /// drivers (1 = the paper's monolithic index). The `shards` driver
    /// sweeps this axis explicitly regardless of the value here.
    pub shards: usize,
}

impl Default for EvalOpts {
    fn default() -> Self {
        EvalOpts {
            quick: false,
            out_dir: PathBuf::from("results"),
            seed: 20260204,
            shards: 1,
        }
    }
}

impl EvalOpts {
    /// Defaults with quick mode on.
    pub fn quick() -> Self {
        EvalOpts { quick: true, ..Default::default() }
    }

    /// `out_dir/<name>.csv`.
    pub fn csv_path(&self, name: &str) -> PathBuf {
        self.out_dir.join(format!("{name}.csv"))
    }

    /// Pick between full-scale and quick-scale parameters.
    pub fn pick<T: Copy>(&self, full: T, quick: T) -> T {
        if self.quick {
            quick
        } else {
            full
        }
    }

    /// Pick between full-scale and quick-scale sweeps.
    pub fn pick_vec<T: Clone>(&self, full: &[T], quick: &[T]) -> Vec<T> {
        if self.quick {
            quick.to_vec()
        } else {
            full.to_vec()
        }
    }
}

/// Pretty-print one table row to stdout.
pub fn print_row(cols: &[String]) {
    println!("  {}", cols.join("  |  "));
}
