//! Figure drivers for the private-LP experiments (§5.2, §J).

use super::common::{print_row, EvalOpts};
use crate::lp::{run_scalar, ScalarLpConfig, SelectionMode};
use crate::mips::IndexKind;
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;
use crate::workloads::random_feasibility_lp;
use anyhow::Result;

const MODES: &[(&str, SelectionMode)] = &[
    ("exhaustive", SelectionMode::Exhaustive),
    ("flat", SelectionMode::Lazy(IndexKind::Flat)),
    ("ivf", SelectionMode::Lazy(IndexKind::Ivf)),
    ("hnsw", SelectionMode::Lazy(IndexKind::Hnsw)),
    // the sharded-LazyEM axis (DESIGN.md §5): same selection distribution,
    // S-way parallel index build
    ("hnsw-x4", SelectionMode::LazySharded(IndexKind::Hnsw, 4)),
];

fn lp_config(t: usize, mode: SelectionMode, seed: u64, log_every: usize) -> ScalarLpConfig {
    ScalarLpConfig {
        t,
        eps: 1.0,
        delta: 1e-3,
        delta_inf: 0.1,
        mode,
        seed,
        log_every,
    }
}

/// Figure 5: fraction of violated constraints over iterations per index —
/// Fast-MWEM tracks the exhaustive baseline (d=20, Δ∞=0.1, α=0.5).
pub fn fig5_violations(opts: &EvalOpts) -> Result<()> {
    let d = 20;
    let m = opts.pick(5_000usize, 1_000);
    let t = opts.pick(5_000usize, 500);
    let log_every = t / 20;

    let mut csv = CsvWriter::create(
        opts.csv_path("fig5_violations"),
        &["mode", "iter", "violation_fraction", "max_violation"],
    )?;
    println!("Fig 5: violated constraints across indices (m={m}, d={d}, T={t})");

    let mut rng = Rng::new(opts.seed ^ 0xF5);
    let lp = random_feasibility_lp(&mut rng, m, d, 0.6);

    for (name, mode) in MODES {
        let cfg = lp_config(t, *mode, opts.seed, log_every);
        let res = run_scalar(&cfg, &lp);
        for s in &res.stats {
            csv.row(&[
                name.to_string(),
                s.iter.to_string(),
                format!("{}", s.violation_fraction),
                format!("{}", s.max_violation),
            ])?;
        }
        let last = res.stats.last().unwrap();
        print_row(&[
            name.to_string(),
            format!("final violation fraction {:.4}", last.violation_fraction),
            format!("max violation {:.4}", last.max_violation),
        ]);
    }
    csv.flush()?;
    Ok(())
}

/// Figure 8 (§J) + the §5.2 runtime plot: per-iteration time and build time
/// at large m — HNSW shows the sublinear win, IVF may not (as in the paper).
pub fn fig8_runtime_large_m(opts: &EvalOpts) -> Result<()> {
    let d = 20;
    let t = opts.pick(40usize, 10);
    let ms = opts.pick_vec(
        &[50_000usize, 100_000, 200_000, 400_000],
        &[5_000usize, 10_000, 20_000],
    );

    let mut csv = CsvWriter::create(
        opts.csv_path("fig8_lp_runtime"),
        &["m", "mode", "select_us", "build_s", "work"],
    )?;
    println!("Fig 8: LP selection time vs m (d={d}, T={t})");
    print_row(&["m".into(), "mode".into(), "per-iter select".into(), "build".into()]);

    for &m in &ms {
        let mut rng = Rng::new(opts.seed ^ 0xF8 ^ m as u64);
        let lp = random_feasibility_lp(&mut rng, m, d, 0.6);
        for (name, mode) in MODES {
            let cfg = lp_config(t, *mode, opts.seed, 0);
            let res = run_scalar(&cfg, &lp);
            let sel_us = res.avg_select_time.as_secs_f64() * 1e6;
            let build_s = res.index_build_time.as_secs_f64();
            csv.row(&[
                m.to_string(),
                name.to_string(),
                format!("{sel_us}"),
                format!("{build_s}"),
                format!("{}", res.avg_select_work),
            ])?;
            print_row(&[
                format!("{m}"),
                name.to_string(),
                format!("{sel_us:.0}us"),
                format!("{build_s:.2}s"),
            ]);
        }
    }
    csv.flush()?;
    Ok(())
}

/// Figure 9 (§J): error (max violation) trajectories for solving the LP —
/// IVF/HNSW behave like the exhaustive baseline.
pub fn fig9_error_and_violations(opts: &EvalOpts) -> Result<()> {
    let d = 20;
    let m = opts.pick(20_000usize, 2_000);
    let t = opts.pick(2_000usize, 400);
    let log_every = t / 20;

    let mut csv = CsvWriter::create(
        opts.csv_path("fig9_lp_error"),
        &["mode", "iter", "max_violation", "violation_fraction", "select_work"],
    )?;
    println!("Fig 9: LP max violation over iterations (m={m}, d={d}, T={t})");

    let mut rng = Rng::new(opts.seed ^ 0xF9);
    let lp = random_feasibility_lp(&mut rng, m, d, 0.6);

    for (name, mode) in MODES {
        let cfg = lp_config(t, *mode, opts.seed, log_every);
        let res = run_scalar(&cfg, &lp);
        for s in &res.stats {
            csv.row(&[
                name.to_string(),
                s.iter.to_string(),
                format!("{}", s.max_violation),
                format!("{}", s.violation_fraction),
                s.selection_work.to_string(),
            ])?;
        }
        let last = res.stats.last().unwrap();
        print_row(&[
            name.to_string(),
            format!("final max violation {:.4}", last.max_violation),
            format!("avg work {:.0}", res.avg_select_work),
        ]);
    }
    csv.flush()?;
    Ok(())
}
