//! Figure driver for the beyond-linear convex-loss release axis
//! (DESIGN.md §14): least-squares and logistic loss workloads driven
//! through the same [`MwemEngine`](crate::mwem::MwemEngine) as the
//! linear-query figures, with exhaustive vs lazy selection compared on
//! both error and per-round selection work.

use super::common::{print_row, EvalOpts};
use crate::mips::IndexKind;
use crate::mwem::{run_classic, run_fast, FastMwemConfig, MwemConfig, NativeBackend};
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;
use crate::workloads::{gaussian_histogram, synthesize_queries, QueryClassKind};
use anyhow::Result;

/// Convex-loss release: classic exhaustive selection vs the lazy HNSW
/// oracle over the same engine, for both loss families. The headline is
/// twofold — the lazy run's final error tracks the exhaustive run (same
/// softmax selection distribution over the embedded loss vectors), and
/// its per-round selection work is sublinear in `m`.
pub fn fig_convex_losses(opts: &EvalOpts) -> Result<()> {
    let u = opts.pick(1024usize, 256);
    let n = 500;
    let t = opts.pick(2_000usize, 200);
    let ms = opts.pick_vec(&[2_000usize, 10_000], &[1_000usize]);

    let mut csv = CsvWriter::create(
        opts.csv_path("fig_convex"),
        &["class", "m", "err_classic", "err_lazy", "work_classic", "work_lazy", "work_ratio"],
    )?;
    println!(
        "Convex-loss release: classic vs lazy HNSW (U={u}, T={t}, shards={})",
        opts.shards
    );
    print_row(&[
        "class".into(),
        "m".into(),
        "err classic".into(),
        "err lazy".into(),
        "work lazy/classic".into(),
    ]);

    for class in [QueryClassKind::ConvexLsq, QueryClassKind::ConvexLogistic] {
        for &m in &ms {
            let mut rng = Rng::new(opts.seed ^ class.tag() ^ m as u64);
            let h = gaussian_histogram(&mut rng, u, n);
            let q = synthesize_queries(&mut rng, class, m, u);
            let mut cfg = MwemConfig::paper(t, u, 1.0, 1e-3, opts.seed ^ class.tag());
            cfg.log_every = 0;

            let classic = run_classic(&cfg, &q, &h, &mut NativeBackend);
            let err_classic = q.max_error(h.probs(), &classic.p_avg);

            let out = run_fast(
                &FastMwemConfig::new(cfg, IndexKind::Hnsw).with_shards(opts.shards),
                &q,
                &h,
                &mut NativeBackend,
            );
            let err_lazy = q.max_error(h.probs(), &out.result.p_avg);
            let ratio = out.result.avg_select_work / classic.avg_select_work.max(1.0);

            csv.row(&[
                class.to_string(),
                m.to_string(),
                format!("{err_classic}"),
                format!("{err_lazy}"),
                format!("{}", classic.avg_select_work),
                format!("{}", out.result.avg_select_work),
                format!("{ratio}"),
            ])?;
            print_row(&[
                class.to_string(),
                format!("{m}"),
                format!("{err_classic:.4}"),
                format!("{err_lazy:.4}"),
                format!("{ratio:.3}"),
            ]);
        }
    }
    csv.flush()?;
    Ok(())
}
