//! Evaluation harness: one driver per paper figure (see DESIGN.md §4).
//!
//! Every driver prints the paper-style series to stdout and writes a CSV
//! under the results directory; EXPERIMENTS.md records paper-vs-measured.
//!
//! `quick` mode shrinks the sweeps so the full suite runs in minutes —
//! the shapes (who wins, scaling exponents, crossovers) are preserved.

pub mod common;
pub mod fig_convex;
pub mod fig_lp;
pub mod fig_queries;

pub use common::EvalOpts;

use anyhow::{bail, Result};

/// All figure ids: the paper's figures in paper order, then the repo's own
/// extension figures (`shards` — the sharded-LazyEM sweep of DESIGN.md §5;
/// `convex` — the convex-loss query-class axis of DESIGN.md §14).
pub const ALL: &[&str] = &[
    "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "shards",
    "convex",
];

/// Run one driver (or "all").
pub fn run(which: &str, opts: &EvalOpts) -> Result<()> {
    match which {
        "fig1" => fig_queries::fig1_speedup(opts),
        "fig2" => fig_queries::fig2_error_diff(opts),
        "fig3" => fig_queries::fig3_error_over_iters(opts),
        "fig4" => fig_queries::fig4_runtime_vs_m(opts),
        "fig5" => fig_lp::fig5_violations(opts),
        "fig6" => fig_queries::fig6_margin(opts),
        "fig7" => fig_queries::fig7_error_vs_n(opts),
        "fig8" => fig_lp::fig8_runtime_large_m(opts),
        "fig9" => fig_lp::fig9_error_and_violations(opts),
        "shards" => fig_queries::fig_shards_sweep(opts),
        "convex" => fig_convex::fig_convex_losses(opts),
        "all" => {
            for f in ALL {
                println!("\n================ {f} ================");
                run(f, opts)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other}; known: {ALL:?} or 'all'"),
    }
}
