//! Figure drivers for the linear-query experiments (§5.1, §I).

use super::common::{print_row, EvalOpts};
use crate::mips::IndexKind;
use crate::mwem::{
    run_classic, run_fast, FastMwemConfig, Histogram, MwemConfig, NativeBackend, QuerySet,
};
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;
use crate::workloads::{binary_queries, gaussian_histogram};
use anyhow::Result;

fn workload(opts: &EvalOpts, u: usize, n: usize, m: usize, salt: u64) -> (Histogram, QuerySet) {
    let mut rng = Rng::new(opts.seed ^ salt);
    (gaussian_histogram(&mut rng, u, n), binary_queries(&mut rng, m, u))
}

/// Figure 1 + Figure 4 share a sweep of per-iteration selection time vs m;
/// Figure 1 reports the speed-up factor of IVF/HNSW over exhaustive search.
pub fn fig1_speedup(opts: &EvalOpts) -> Result<()> {
    let u = opts.pick(3000usize, 512);
    let n = 500;
    let t = opts.pick(30usize, 10);
    let ms = opts.pick_vec(&[10_000usize, 20_000, 50_000, 100_000], &[2_000usize, 5_000, 10_000]);

    let mut csv = CsvWriter::create(
        opts.csv_path("fig1_speedup"),
        &["m", "shards", "classic_us", "ivf_us", "hnsw_us", "speedup_ivf", "speedup_hnsw"],
    )?;
    println!(
        "Fig 1: Fast-MWEM speed-up over exhaustive search (U={u}, T={t}, shards={})",
        opts.shards
    );
    print_row(&["m".into(), "speedup IVF".into(), "speedup HNSW".into()]);

    for &m in &ms {
        let (h, q) = workload(opts, u, n, m, m as u64);
        let mut cfg = MwemConfig::paper(t, u, 1.0, 1e-3, opts.seed);
        cfg.log_every = 0;

        let classic = run_classic(&cfg, &q, &h, &mut NativeBackend);
        let t_classic = classic.avg_select_time.as_secs_f64() * 1e6;

        let mut times = std::collections::BTreeMap::new();
        for kind in [IndexKind::Ivf, IndexKind::Hnsw] {
            let out = run_fast(
                &FastMwemConfig::new(cfg.clone(), kind).with_shards(opts.shards),
                &q,
                &h,
                &mut NativeBackend,
            );
            times.insert(kind.to_string(), out.result.avg_select_time.as_secs_f64() * 1e6);
        }
        let (t_ivf, t_hnsw) = (times["ivf"], times["hnsw"]);
        csv.row_f64(&[
            m as f64,
            opts.shards as f64,
            t_classic,
            t_ivf,
            t_hnsw,
            t_classic / t_ivf,
            t_classic / t_hnsw,
        ])?;
        print_row(&[
            format!("{m}"),
            format!("{:.1}x", t_classic / t_ivf),
            format!("{:.1}x", t_classic / t_hnsw),
        ]);
    }
    csv.flush()?;
    Ok(())
}

/// Figure 2: per-iteration error difference MWEM − FastMWEM(flat) ≈ 0.
pub fn fig2_error_diff(opts: &EvalOpts) -> Result<()> {
    let u = opts.pick(3000usize, 512);
    let n = 500;
    let t = opts.pick(20_000usize, 1_000);
    let log_every = t / 20;
    let ms = opts.pick_vec(&[200usize, 500, 1000], &[100usize, 200]);

    let mut csv = CsvWriter::create(
        opts.csv_path("fig2_error_diff"),
        &["m", "iter", "err_classic", "err_fast_flat", "diff"],
    )?;
    println!("Fig 2: error difference MWEM vs Fast-MWEM(flat) (U={u}, T={t})");

    for &m in &ms {
        let (h, q) = workload(opts, u, n, m, 0xF2 ^ m as u64);
        let mut cfg = MwemConfig::paper(t, u, 1.0, 1e-3, opts.seed);
        cfg.log_every = log_every;

        let classic = run_classic(&cfg, &q, &h, &mut NativeBackend);
        let fast = run_fast(
            &FastMwemConfig::new(cfg, IndexKind::Flat),
            &q,
            &h,
            &mut NativeBackend,
        );

        let mut max_diff = 0.0f64;
        for (c, f) in classic.stats.iter().zip(fast.result.stats.iter()) {
            let diff = c.max_error_avg - f.max_error_avg;
            max_diff = max_diff.max(diff.abs());
            csv.row_f64(&[m as f64, c.iter as f64, c.max_error_avg, f.max_error_avg, diff])?;
        }
        print_row(&[format!("m={m}"), format!("max |err diff| = {max_diff:.4}")]);
    }
    csv.flush()?;
    Ok(())
}

/// Figure 3: error over iterations per index — all indices track each other.
pub fn fig3_error_over_iters(opts: &EvalOpts) -> Result<()> {
    let u = opts.pick(3000usize, 512);
    let n = 500;
    let m = opts.pick(1000usize, 200);
    let t = opts.pick(20_000usize, 1_000);
    let log_every = t / 20;

    let mut csv = CsvWriter::create(
        opts.csv_path("fig3_error_over_iters"),
        &["index", "iter", "max_error"],
    )?;
    println!("Fig 3: error over iterations per index (U={u}, m={m}, T={t})");

    let (h, q) = workload(opts, u, n, m, 0xF3);
    let mut cfg = MwemConfig::paper(t, u, 1.0, 1e-3, opts.seed);
    cfg.log_every = log_every;

    let classic = run_classic(&cfg, &q, &h, &mut NativeBackend);
    for s in &classic.stats {
        csv.row(&["classic".into(), s.iter.to_string(), format!("{}", s.max_error_avg)])?;
    }
    let mut finals = vec![("classic".to_string(), classic.stats.last().unwrap().max_error_avg)];

    for kind in [IndexKind::Flat, IndexKind::Ivf, IndexKind::Hnsw] {
        let out = run_fast(
            &FastMwemConfig::new(cfg.clone(), kind),
            &q,
            &h,
            &mut NativeBackend,
        );
        for s in &out.result.stats {
            csv.row(&[kind.to_string(), s.iter.to_string(), format!("{}", s.max_error_avg)])?;
        }
        finals.push((kind.to_string(), out.result.stats.last().unwrap().max_error_avg));
    }
    for (name, err) in finals {
        print_row(&[name, format!("final error {err:.4}")]);
    }
    csv.flush()?;
    Ok(())
}

/// Figure 4: per-iteration selection runtime vs m for all indices.
pub fn fig4_runtime_vs_m(opts: &EvalOpts) -> Result<()> {
    let u = opts.pick(3000usize, 512);
    let n = 500;
    let t = opts.pick(30usize, 10);
    let ms = opts.pick_vec(
        &[10_000usize, 20_000, 40_000, 70_000, 100_000],
        &[1_000usize, 2_000, 5_000, 10_000],
    );

    let mut csv = CsvWriter::create(
        opts.csv_path("fig4_runtime"),
        &[
            "m",
            "shards",
            "classic_us",
            "fast_flat_us",
            "ivf_us",
            "hnsw_us",
            "ivf_build_s",
            "hnsw_build_s",
        ],
    )?;
    println!(
        "Fig 4: per-iteration selection time vs m (U={u}, T={t}, shards={})",
        opts.shards
    );
    print_row(&[
        "m".into(),
        "classic".into(),
        "fast-flat".into(),
        "ivf".into(),
        "hnsw".into(),
    ]);

    for &m in &ms {
        let (h, q) = workload(opts, u, n, m, 0xF4 ^ m as u64);
        let mut cfg = MwemConfig::paper(t, u, 1.0, 1e-3, opts.seed);
        cfg.log_every = 0;

        let classic = run_classic(&cfg, &q, &h, &mut NativeBackend);
        let t_classic = classic.avg_select_time.as_secs_f64() * 1e6;

        let mut sel = std::collections::BTreeMap::new();
        let mut build = std::collections::BTreeMap::new();
        for kind in [IndexKind::Flat, IndexKind::Ivf, IndexKind::Hnsw] {
            let out = run_fast(
                &FastMwemConfig::new(cfg.clone(), kind).with_shards(opts.shards),
                &q,
                &h,
                &mut NativeBackend,
            );
            sel.insert(kind.to_string(), out.result.avg_select_time.as_secs_f64() * 1e6);
            build.insert(kind.to_string(), out.lazy.build_time.as_secs_f64());
        }
        csv.row_f64(&[
            m as f64,
            opts.shards as f64,
            t_classic,
            sel["flat"],
            sel["ivf"],
            sel["hnsw"],
            build["ivf"],
            build["hnsw"],
        ])?;
        print_row(&[
            format!("{m}"),
            format!("{t_classic:.0}us"),
            format!("{:.0}us", sel["flat"]),
            format!("{:.0}us", sel["ivf"]),
            format!("{:.0}us", sel["hnsw"]),
        ]);
    }
    csv.flush()?;
    Ok(())
}

/// Extension figure `shards` (DESIGN.md §5): sweep the shard count S on the
/// Fig. 1 workload. Reports per-S index build time (the parallel-build win),
/// per-iteration selection time and work (≈ S·√(m/S) total evaluations),
/// and the final error (unchanged — the decomposition is exact).
pub fn fig_shards_sweep(opts: &EvalOpts) -> Result<()> {
    let u = opts.pick(3000usize, 512);
    let n = 500;
    let m = opts.pick(50_000usize, 5_000);
    let t = opts.pick(200usize, 50);
    let shard_counts = opts.pick_vec(&[1usize, 2, 4, 8, 16], &[1usize, 2, 4]);

    let mut csv = CsvWriter::create(
        opts.csv_path("fig_shards"),
        &["shards", "build_s", "select_us", "work", "max_error"],
    )?;
    println!("Shards sweep: Fast-MWEM(hnsw) vs S (U={u}, m={m}, T={t})");
    print_row(&[
        "S".into(),
        "build".into(),
        "select/iter".into(),
        "work/iter".into(),
        "final error".into(),
    ]);

    let (h, q) = workload(opts, u, n, m, 0x5A);
    for &s in &shard_counts {
        let mut cfg = MwemConfig::paper(t, u, 1.0, 1e-3, opts.seed);
        cfg.log_every = 0;
        let out = run_fast(
            &FastMwemConfig::new(cfg, IndexKind::Hnsw).with_shards(s),
            &q,
            &h,
            &mut NativeBackend,
        );
        let build_s = out.lazy.build_time.as_secs_f64();
        let select_us = out.result.avg_select_time.as_secs_f64() * 1e6;
        let err = q.max_error(h.probs(), &out.result.p_avg);
        csv.row_f64(&[
            s as f64,
            build_s,
            select_us,
            out.result.avg_select_work,
            err,
        ])?;
        print_row(&[
            format!("{s}"),
            format!("{build_s:.2}s"),
            format!("{select_us:.0}us"),
            format!("{:.0}", out.result.avg_select_work),
            format!("{err:.4}"),
        ]);
    }
    csv.flush()?;
    Ok(())
}

/// Figure 6 (§I.1): the margin B and the tail sample count C = O(√m).
pub fn fig6_margin(opts: &EvalOpts) -> Result<()> {
    let u = opts.pick(3000usize, 512);
    let n = 500;
    let t = opts.pick(500usize, 100);
    let ms = opts.pick_vec(&[500usize, 2_000, 20_000], &[500usize, 2_000]);

    let mut csv = CsvWriter::create(
        opts.csv_path("fig6_margin"),
        &["m", "sqrt_m", "mean_C", "mean_C_over_m", "mean_B"],
    )?;
    println!("Fig 6: tail sample count C (T={t})");
    print_row(&["m".into(), "√m".into(), "E[C]".into(), "E[C]/m".into()]);

    for &m in &ms {
        let (h, q) = workload(opts, u, n, m, 0xF6 ^ m as u64);
        let mut cfg = MwemConfig::paper(t, u, 1.0, 1e-3, opts.seed);
        cfg.log_every = 0;
        let out = run_fast(
            &FastMwemConfig::new(cfg, IndexKind::Flat),
            &q,
            &h,
            &mut NativeBackend,
        );
        let mean_c = out.lazy.tail_counts.iter().sum::<usize>() as f64
            / out.lazy.tail_counts.len() as f64;
        let mean_b = out
            .lazy
            .margins
            .iter()
            .filter(|b| b.is_finite())
            .sum::<f64>()
            / out.lazy.margins.len() as f64;
        csv.row_f64(&[
            m as f64,
            (m as f64).sqrt(),
            mean_c,
            mean_c / m as f64,
            mean_b,
        ])?;
        print_row(&[
            format!("{m}"),
            format!("{:.0}", (m as f64).sqrt()),
            format!("{mean_c:.1}"),
            format!("{:.5}", mean_c / m as f64),
        ]);
    }
    csv.flush()?;
    Ok(())
}

/// Figure 7 (§I.2): final error vs number of samples n (m = 100, T = n²
/// capped), MWEM vs Fast-MWEM(flat).
pub fn fig7_error_vs_n(opts: &EvalOpts) -> Result<()> {
    let u = opts.pick(1024usize, 256);
    let m = 100;
    let ns = opts.pick_vec(&[30usize, 60, 100, 180, 300], &[30usize, 60, 100]);
    let t_cap = opts.pick(4_000usize, 800);

    let mut csv = CsvWriter::create(
        opts.csv_path("fig7_error_vs_n"),
        &["n", "t", "err_classic", "err_fast_flat"],
    )?;
    println!("Fig 7: final error vs n (U={u}, m={m}, T=min(n², {t_cap}))");
    print_row(&["n".into(), "classic".into(), "fast-flat".into()]);

    for &n in &ns {
        let t = (n * n).min(t_cap);
        let (h, q) = workload(opts, u, n, m, 0xF7 ^ n as u64);
        let mut cfg = MwemConfig::paper(t, u, 1.0, 1e-3, opts.seed);
        cfg.update = crate::mwem::UpdateRule::Hardt; // n-sensitive noise path
        cfg.log_every = 0;

        let classic = run_classic(&cfg, &q, &h, &mut NativeBackend);
        let e_classic = q.max_error(h.probs(), &classic.p_avg);
        let fast = run_fast(
            &FastMwemConfig::new(cfg, IndexKind::Flat),
            &q,
            &h,
            &mut NativeBackend,
        );
        let e_fast = q.max_error(h.probs(), &fast.result.p_avg);
        csv.row_f64(&[n as f64, t as f64, e_classic, e_fast])?;
        print_row(&[format!("{n}"), format!("{e_classic:.4}"), format!("{e_fast:.4}")]);
    }
    csv.flush()?;
    Ok(())
}
