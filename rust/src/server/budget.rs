//! Per-tenant privacy accountants with admission control (DESIGN.md §8).
//!
//! The serving analogue of the privacy-budget discipline in MWEM-style
//! release (Hardt–Ligett–McSherry) and privately-solved LPs (Hsu et al.):
//! every answered job spends ε that must be accounted *before*, not after,
//! execution. [`TenantBudget`] keeps one ledger per tenant and runs a
//! reserve → commit / refund protocol:
//!
//! * **admit** — at submission, atomically reserve the job's nominal ε
//!   against the tenant's cap. A job whose reservation would overshoot is
//!   denied before it ever enters the queue, so denied jobs spend zero ε.
//! * **commit** — when the job completes successfully, the reservation
//!   becomes spend.
//! * **refund** — when the job runs and fails, the reservation is
//!   atomically returned (and recorded as refunded), so failures never
//!   leak budget.
//! * **rescind** — when an admitted job never enters the queue (shed by
//!   backpressure or a closing server), the reservation is erased as if
//!   the job had never been admitted.
//!
//! Invariant per tenant: `spent ≤ admitted ≤ cap` at every instant.
//!
//! Arithmetic is exact: ε is tracked internally as integer **nano-ε**
//! (1e−9 ε units), not accumulated f64 sums. A long-lived daemon churns
//! through millions of reserve/refund cycles; f64 accumulation drifts by
//! an ulp per interleaved pair, so a tenant at exactly its cap could be
//! spuriously denied (or `admitted` could go microscopically negative
//! after refunds). With integers, 10k churn cycles leave the reservation
//! at exactly zero and an exact-cap job still admits. Budgets below one
//! nano-ε quantize to zero (documented; real jobs spend ≫ 1e−9 ε).

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Nano-ε per ε: the integer resolution of the ledgers.
const NANO_PER_EPS: f64 = 1e9;

/// Quantize an ε amount to integer nano-ε (round to nearest; negative
/// amounts clamp to zero — the ledger never goes backwards via inputs).
#[inline]
fn to_nano(eps: f64) -> u64 {
    (eps * NANO_PER_EPS).round().max(0.0) as u64
}

/// Convert integer nano-ε back to ε for reporting.
#[inline]
fn from_nano(nano: u64) -> f64 {
    nano as f64 / NANO_PER_EPS
}

/// One tenant's ledger snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TenantSpend {
    /// Tenant id.
    pub tenant: u64,
    /// Currently reserved ε (committed spend plus in-flight reservations).
    pub admitted: f64,
    /// ε committed by successfully completed jobs.
    pub spent: f64,
    /// ε returned by failed or queue-refused jobs.
    pub refunded: f64,
    /// Jobs whose reservation was accepted.
    pub admitted_jobs: u64,
    /// Jobs denied at admission (they spent zero ε).
    pub denied_jobs: u64,
}

/// Admission denial: the reservation would overshoot the tenant's cap.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionError {
    /// The denied tenant.
    pub tenant: u64,
    /// ε the job asked for.
    pub requested: f64,
    /// ε already reserved for this tenant.
    pub admitted: f64,
    /// The per-tenant cap.
    pub cap: f64,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "tenant {} admission denied: {} reserved + {} requested > cap {}",
            self.tenant, self.admitted, self.requested, self.cap
        )
    }
}

impl std::error::Error for AdmissionError {}

/// One tenant's internal ledger, in integer nano-ε (exact arithmetic).
#[derive(Clone, Copy, Debug, Default)]
struct Ledger {
    admitted: u64,
    spent: u64,
    refunded: u64,
    admitted_jobs: u64,
    denied_jobs: u64,
}

/// Registry of per-tenant privacy ledgers behind one lock; every transition
/// (reserve, commit, refund) is atomic with respect to concurrent
/// submitters and workers.
#[derive(Debug)]
pub struct TenantBudget {
    /// Per-tenant ε cap (`None` = unlimited: admission always passes, but
    /// spend is still metered per tenant).
    cap: Option<f64>,
    /// The cap quantized to nano-ε, the units the ledgers compare in.
    cap_nano: Option<u64>,
    ledgers: Mutex<BTreeMap<u64, Ledger>>,
}

impl TenantBudget {
    /// A budget registry where every tenant gets the same ε cap.
    pub fn new(cap: Option<f64>) -> Self {
        TenantBudget { cap, cap_nano: cap.map(to_nano), ledgers: Mutex::new(BTreeMap::new()) }
    }

    /// The uniform per-tenant cap, if any.
    pub fn cap(&self) -> Option<f64> {
        self.cap
    }

    /// Reserve `eps` for `tenant`, denying atomically if the reservation
    /// would exceed the cap. The comparison is exact integer arithmetic in
    /// nano-ε, so a tenant can spend exactly up to its cap no matter how
    /// many reserve/refund cycles preceded the attempt.
    pub fn admit(&self, tenant: u64, eps: f64) -> Result<(), AdmissionError> {
        let eps_n = to_nano(eps);
        let mut ledgers = self.ledgers.lock().unwrap();
        let ledger = ledgers.entry(tenant).or_default();
        if let Some(cap_n) = self.cap_nano {
            if ledger.admitted.saturating_add(eps_n) > cap_n {
                ledger.denied_jobs += 1;
                return Err(AdmissionError {
                    tenant,
                    requested: eps,
                    admitted: from_nano(ledger.admitted),
                    cap: self.cap.unwrap_or(f64::INFINITY),
                });
            }
        }
        ledger.admitted += eps_n;
        ledger.admitted_jobs += 1;
        Ok(())
    }

    /// Convert a reservation into committed spend (job succeeded).
    pub fn commit(&self, tenant: u64, eps: f64) {
        let mut ledgers = self.ledgers.lock().unwrap();
        if let Some(ledger) = ledgers.get_mut(&tenant) {
            ledger.spent += to_nano(eps);
        }
    }

    /// Return a reservation whose job ran and failed. The budget reopens
    /// for subsequent jobs and the ε is recorded in `refunded`.
    pub fn refund(&self, tenant: u64, eps: f64) {
        let eps_n = to_nano(eps);
        let mut ledgers = self.ledgers.lock().unwrap();
        if let Some(ledger) = ledgers.get_mut(&tenant) {
            ledger.admitted = ledger.admitted.saturating_sub(eps_n);
            ledger.refunded += eps_n;
        }
    }

    /// Roll back a reservation whose job never entered the queue (shed by
    /// backpressure, or refused by a closing server): the reservation is
    /// erased from the ledger entirely — `admitted`/`admitted_jobs` drop
    /// back and, unlike [`TenantBudget::refund`], nothing is recorded as
    /// refunded, so the ledger stays consistent with the `jobs_refunded`
    /// counter (which counts only jobs that ran and failed).
    pub fn rescind(&self, tenant: u64, eps: f64) {
        let mut ledgers = self.ledgers.lock().unwrap();
        if let Some(ledger) = ledgers.get_mut(&tenant) {
            ledger.admitted = ledger.admitted.saturating_sub(to_nano(eps));
            ledger.admitted_jobs = ledger.admitted_jobs.saturating_sub(1);
        }
    }

    /// Snapshot of every tenant's ledger, sorted by tenant id.
    pub fn snapshot(&self) -> Vec<TenantSpend> {
        self.ledgers
            .lock()
            .unwrap()
            .iter()
            .map(|(&tenant, l)| TenantSpend {
                tenant,
                admitted: from_nano(l.admitted),
                spent: from_nano(l.spent),
                refunded: from_nano(l.refunded),
                admitted_jobs: l.admitted_jobs,
                denied_jobs: l.denied_jobs,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_to_the_cap_then_denies() {
        let b = TenantBudget::new(Some(2.0));
        assert!(b.admit(1, 0.9).is_ok());
        assert!(b.admit(1, 0.9).is_ok());
        let err = b.admit(1, 0.3).unwrap_err();
        assert_eq!(err.tenant, 1);
        assert!((err.admitted - 1.8).abs() < 1e-12);
        // landing exactly on the cap is allowed
        assert!(b.admit(1, 0.2).is_ok());
        assert!(b.admit(1, 1e-6).is_err(), "cap exhausted");
        let s = &b.snapshot()[0];
        assert_eq!((s.admitted_jobs, s.denied_jobs), (3, 2));
    }

    #[test]
    fn tenants_are_independent() {
        let b = TenantBudget::new(Some(1.0));
        assert!(b.admit(1, 1.0).is_ok());
        assert!(b.admit(1, 0.5).is_err());
        assert!(b.admit(2, 1.0).is_ok(), "tenant 2 has its own cap");
        let snap = b.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].tenant, 1);
        assert_eq!(snap[1].tenant, 2);
    }

    #[test]
    fn refund_reopens_the_budget_and_denied_jobs_spend_zero() {
        let b = TenantBudget::new(Some(1.0));
        assert!(b.admit(7, 0.8).is_ok());
        assert!(b.admit(7, 0.8).is_err(), "would overshoot");
        b.refund(7, 0.8); // the first job failed
        assert!(b.admit(7, 0.9).is_ok(), "refund must reopen the budget");
        b.commit(7, 0.9);
        let s = &b.snapshot()[0];
        assert!((s.spent - 0.9).abs() < 1e-12, "only the committed job spends");
        assert!((s.refunded - 0.8).abs() < 1e-12);
        assert!((s.admitted - 0.9).abs() < 1e-12);
        assert!(s.spent <= s.admitted + 1e-12);
    }

    #[test]
    fn uncapped_budget_admits_everything_but_still_meters() {
        let b = TenantBudget::new(None);
        for _ in 0..50 {
            b.admit(3, 10.0).unwrap();
            b.commit(3, 10.0);
        }
        let s = &b.snapshot()[0];
        assert!((s.spent - 500.0).abs() < 1e-9);
        assert_eq!(s.admitted_jobs, 50);
    }

    #[test]
    fn rescind_erases_the_reservation_without_recording_a_refund() {
        let b = TenantBudget::new(Some(1.0));
        assert!(b.admit(4, 0.8).is_ok());
        b.rescind(4, 0.8); // queue refused the job: as if never admitted
        let s = &b.snapshot()[0];
        assert_eq!(s.admitted_jobs, 0);
        assert!((s.admitted - 0.0).abs() < 1e-12);
        assert!((s.refunded - 0.0).abs() < 1e-12, "sheds are not refunds");
        assert!(b.admit(4, 1.0).is_ok(), "full budget available again");
    }

    /// Regression: long-lived daemons churn reservations for days. With
    /// f64 accumulation the interleaved adds/subtracts drift by an ulp per
    /// cycle, so `admitted` ends microscopically nonzero and an exact-cap
    /// job is spuriously denied. With integer nano-ε the churn must leave
    /// the reservation at exactly zero and the full cap must still admit.
    #[test]
    fn reserve_refund_churn_leaves_zero_and_exact_cap_still_admits() {
        let cap = 2.0;
        let b = TenantBudget::new(Some(cap));
        // interleaved, unequal amounts — the worst case for f64 drift
        for i in 0..10_000u64 {
            let (e1, e2) = (0.1 + (i % 7) as f64 * 0.01, 0.2 + (i % 3) as f64 * 0.05);
            b.admit(1, e1).unwrap();
            b.admit(1, e2).unwrap();
            b.refund(1, e1);
            b.rescind(1, e2);
        }
        let s = &b.snapshot()[0];
        assert_eq!(s.admitted, 0.0, "churn must leave exactly zero reserved");
        // the full cap still fits in one job, exactly
        assert!(b.admit(1, cap).is_ok(), "exact-cap job must admit after churn");
        assert!(b.admit(1, 1e-6).is_err(), "cap is exactly exhausted");
        b.commit(1, cap);
        let s = &b.snapshot()[0];
        assert_eq!(s.spent, cap, "integer ledgers report exact spend");
    }

    /// Sub-nano-ε amounts quantize to zero (documented resolution floor).
    #[test]
    fn sub_nano_eps_quantizes_to_zero() {
        let b = TenantBudget::new(Some(1.0));
        for _ in 0..1_000 {
            b.admit(2, 1e-12).unwrap();
        }
        assert_eq!(b.snapshot()[0].admitted, 0.0);
        assert!(b.admit(2, 1.0).is_ok(), "full budget still available");
    }

    #[test]
    fn commit_refund_and_rescind_on_unknown_tenant_are_noops() {
        let b = TenantBudget::new(Some(1.0));
        b.commit(9, 1.0);
        b.refund(9, 1.0);
        b.rescind(9, 1.0);
        assert!(b.snapshot().is_empty());
    }
}
