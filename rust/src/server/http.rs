//! Minimal HTTP/1.1 framing for the wire front end (DESIGN.md §11).
//!
//! Only what the serving protocol needs, hand-rolled against `std` (the
//! offline build vendors no hyper): request-line + header parsing with
//! hard size caps, `Content-Length` request bodies, fixed-length
//! responses, and a [`ChunkedWriter`] for streaming release histograms
//! back without buffering the full payload. Every parse failure is a
//! typed [`HttpError`] carrying the status code the connection handler
//! should answer with — nothing here panics on wire bytes.

use std::io::{self, BufRead, Read, Write};

/// Hard caps on one request's framing. Oversize input fails with a typed
/// 4xx-bearing [`HttpError`], never unbounded buffering.
#[derive(Clone, Copy, Debug)]
pub struct HttpLimits {
    /// Total bytes of request line + headers (terminators included).
    pub max_header_bytes: usize,
    /// Maximum number of header fields.
    pub max_headers: usize,
    /// Maximum `Content-Length` body accepted.
    pub max_body_bytes: usize,
}

impl Default for HttpLimits {
    fn default() -> Self {
        HttpLimits { max_header_bytes: 8 * 1024, max_headers: 64, max_body_bytes: 1 << 20 }
    }
}

/// Why a request could not be read. [`HttpError::status`] gives the
/// response code to answer with (when a response is possible at all).
#[derive(Debug)]
pub enum HttpError {
    /// The peer closed the connection cleanly before (or while) sending a
    /// request — not an error worth answering.
    Eof,
    /// Structurally invalid request framing (answer 400).
    Malformed(String),
    /// A size cap was exceeded; carries the status to answer with
    /// (431 for header caps, 413 for the body cap).
    TooLarge {
        /// The HTTP status this violation maps to.
        status: u16,
        /// What exceeded which cap.
        msg: String,
    },
    /// Transport error (timeout, reset) — the connection is unusable.
    Io(io::Error),
}

impl HttpError {
    /// The HTTP status a handler should answer with, or `None` when the
    /// connection is beyond answering (EOF, transport error).
    pub fn status(&self) -> Option<u16> {
        match self {
            HttpError::Eof | HttpError::Io(_) => None,
            HttpError::Malformed(_) => Some(400),
            HttpError::TooLarge { status, .. } => Some(*status),
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Eof => write!(f, "connection closed"),
            HttpError::Malformed(m) => write!(f, "malformed request: {m}"),
            HttpError::TooLarge { status, msg } => write!(f, "request too large ({status}): {msg}"),
            HttpError::Io(e) => write!(f, "transport error: {e}"),
        }
    }
}

impl std::error::Error for HttpError {}

/// One parsed request: line, lowercased header names, and the full body
/// (request bodies are small job specs; only *responses* stream).
#[derive(Debug)]
pub struct Request {
    /// The method verb, as sent (`GET`, `POST`, ...).
    pub method: String,
    /// The request target (`/v1/jobs`).
    pub target: String,
    /// Header fields in arrival order; names lowercased, values trimmed.
    pub headers: Vec<(String, String)>,
    /// The request body (`Content-Length`-framed; empty when absent).
    pub body: Vec<u8>,
    /// Total wire bytes this request consumed (for the `bytes_in` meter).
    pub bytes_read: usize,
}

impl Request {
    /// First value of a header, by case-insensitive name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// Whether the connection should stay open after this exchange
    /// (HTTP/1.1 defaults to keep-alive unless `Connection: close`).
    pub fn keep_alive(&self) -> bool {
        !self
            .header("connection")
            .is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }
}

/// Read one `\n`-terminated line, capped at `cap` bytes. Returns the bytes
/// consumed; 0 means clean EOF before any byte.
fn read_line<R: BufRead>(r: &mut R, buf: &mut Vec<u8>, cap: usize) -> Result<usize, HttpError> {
    let mut limited = r.take(cap as u64 + 1);
    let n = limited.read_until(b'\n', buf).map_err(HttpError::Io)?;
    if n > cap {
        return Err(HttpError::TooLarge {
            status: 431,
            msg: format!("header line exceeds {cap} bytes"),
        });
    }
    if n > 0 && !buf.ends_with(b"\n") {
        return Err(HttpError::Eof); // stream ended mid-line
    }
    Ok(n)
}

fn trim_crlf(line: &[u8]) -> &[u8] {
    let line = line.strip_suffix(b"\n").unwrap_or(line);
    line.strip_suffix(b"\r").unwrap_or(line)
}

/// Read and parse one request from the stream under the given limits.
/// Blocks until a full request arrives (the caller decides when to start
/// by peeking the reader, so idle keep-alive time is spent *outside* this
/// call).
pub fn read_request<R: BufRead>(
    r: &mut R,
    limits: &HttpLimits,
) -> Result<Request, HttpError> {
    let mut line = Vec::new();
    let mut header_bytes = read_line(r, &mut line, limits.max_header_bytes)?;
    if header_bytes == 0 {
        return Err(HttpError::Eof);
    }
    let start = std::str::from_utf8(trim_crlf(&line))
        .map_err(|_| HttpError::Malformed("request line is not UTF-8".into()))?;
    let mut parts = start.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => {
            (m.to_string(), t.to_string(), v)
        }
        _ => {
            return Err(HttpError::Malformed(format!("bad request line {start:?}")));
        }
    };
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Malformed(format!("unsupported version {version:?}")));
    }

    let mut headers = Vec::new();
    loop {
        line.clear();
        let n = read_line(r, &mut line, limits.max_header_bytes)?;
        if n == 0 {
            return Err(HttpError::Eof); // stream ended inside the header block
        }
        header_bytes += n;
        if header_bytes > limits.max_header_bytes {
            return Err(HttpError::TooLarge {
                status: 431,
                msg: format!("header block exceeds {} bytes", limits.max_header_bytes),
            });
        }
        let t = trim_crlf(&line);
        if t.is_empty() {
            break;
        }
        if headers.len() >= limits.max_headers {
            return Err(HttpError::TooLarge {
                status: 431,
                msg: format!("more than {} header fields", limits.max_headers),
            });
        }
        let s = std::str::from_utf8(t)
            .map_err(|_| HttpError::Malformed("header is not UTF-8".into()))?;
        let (name, value) = s
            .split_once(':')
            .ok_or_else(|| HttpError::Malformed(format!("header without ':': {s:?}")))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    let req_header = |name: &str| {
        let name = name.to_ascii_lowercase();
        headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    };
    if req_header("transfer-encoding").is_some() {
        // request bodies are Content-Length only; chunked is a response
        // affordance here (DESIGN.md §11)
        return Err(HttpError::Malformed("chunked request bodies are not supported".into()));
    }
    let mut body = Vec::new();
    if let Some(cl) = req_header("content-length") {
        let len: usize = cl.parse().map_err(|_| {
            HttpError::Malformed(format!("bad content-length {cl:?}"))
        })?;
        if len > limits.max_body_bytes {
            return Err(HttpError::TooLarge {
                status: 413,
                msg: format!("body of {len} bytes exceeds the {} cap", limits.max_body_bytes),
            });
        }
        body = vec![0u8; len];
        r.read_exact(&mut body).map_err(HttpError::Io)?;
    }
    let bytes_read = header_bytes + body.len();
    Ok(Request { method, target, headers, body, bytes_read })
}

/// Reason phrase for the status codes this front end emits.
pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        401 => "Unauthorized",
        403 => "Forbidden",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Content Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Response",
    }
}

fn response_head(status: u16, extra: &[(&str, String)], framing: &str) -> String {
    let mut head = format!("HTTP/1.1 {status} {}\r\n", status_text(status));
    for (k, v) in extra {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str(framing);
    head.push_str("\r\n");
    head
}

/// Write a complete fixed-length response. Returns the bytes written (for
/// the `bytes_out` meter).
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    extra: &[(&str, String)],
    body: &[u8],
) -> io::Result<usize> {
    let head = response_head(status, extra, &format!("content-length: {}\r\n", body.len()));
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()?;
    Ok(head.len() + body.len())
}

/// A `Transfer-Encoding: chunked` response in progress: the head goes out
/// at [`ChunkedWriter::begin`], each [`ChunkedWriter::write_chunk`] frames
/// and flushes one piece, and [`ChunkedWriter::finish`] sends the terminal
/// frame — the peer sees bytes as they are produced, and the producer
/// never holds the full payload.
pub struct ChunkedWriter<'a, W: Write> {
    w: &'a mut W,
    bytes: usize,
}

impl<'a, W: Write> ChunkedWriter<'a, W> {
    /// Send the response head and start the chunked body.
    pub fn begin(
        w: &'a mut W,
        status: u16,
        extra: &[(&str, String)],
    ) -> io::Result<Self> {
        let head = response_head(status, extra, "transfer-encoding: chunked\r\n");
        w.write_all(head.as_bytes())?;
        Ok(ChunkedWriter { w, bytes: head.len() })
    }

    /// Frame and send one chunk (empty input is skipped — a zero-length
    /// chunk would terminate the body).
    pub fn write_chunk(&mut self, data: &[u8]) -> io::Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        let frame = format!("{:x}\r\n", data.len());
        self.w.write_all(frame.as_bytes())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.bytes += frame.len() + data.len() + 2;
        Ok(())
    }

    /// Send the terminal zero-chunk and flush. Returns total bytes written.
    pub fn finish(self) -> io::Result<usize> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()?;
        Ok(self.bytes + 5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &str) -> Result<Request, HttpError> {
        read_request(&mut BufReader::new(raw.as_bytes()), &HttpLimits::default())
    }

    #[test]
    fn parses_a_post_with_body() {
        let req = parse(
            "POST /v1/jobs HTTP/1.1\r\nHost: x\r\nAuthorization: Bearer tenant-0\r\n\
             Content-Length: 4\r\n\r\nbody",
        )
        .unwrap();
        assert_eq!((req.method.as_str(), req.target.as_str()), ("POST", "/v1/jobs"));
        assert_eq!(req.header("authorization"), Some("Bearer tenant-0"));
        assert_eq!(req.body, b"body");
        assert!(req.keep_alive(), "HTTP/1.1 defaults to keep-alive");
        assert_eq!(req.bytes_read, 90, "24 request line + 62 headers + 4 body");
    }

    #[test]
    fn connection_close_disables_keep_alive() {
        let req = parse("GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(!req.keep_alive());
    }

    #[test]
    fn eof_and_malformed_are_distinct() {
        assert!(matches!(parse(""), Err(HttpError::Eof)));
        assert!(matches!(parse("GET\r\n\r\n"), Err(HttpError::Malformed(_))));
        assert!(matches!(parse("GET / SPDY/3\r\n\r\n"), Err(HttpError::Malformed(_))));
        // headers cut off mid-block: the peer went away
        assert!(matches!(parse("GET / HTTP/1.1\r\nHost: x\r\n"), Err(HttpError::Eof)));
        assert_eq!(parse("GET /\r\n\r\n").unwrap_err().status(), Some(400));
    }

    #[test]
    fn size_caps_map_to_statuses() {
        let long_header = format!("GET / HTTP/1.1\r\nx: {}\r\n\r\n", "a".repeat(9000));
        assert_eq!(parse(&long_header).unwrap_err().status(), Some(431));
        let many_headers = format!(
            "GET / HTTP/1.1\r\n{}\r\n",
            (0..80).map(|i| format!("h{i}: v\r\n")).collect::<String>()
        );
        assert_eq!(parse(&many_headers).unwrap_err().status(), Some(431));
        let big_body = "POST / HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n";
        assert_eq!(parse(big_body).unwrap_err().status(), Some(413));
        let chunked_req = "POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        assert_eq!(parse(chunked_req).unwrap_err().status(), Some(400));
    }

    #[test]
    fn chunked_writer_frames_and_terminates() {
        let mut out = Vec::new();
        let mut cw =
            ChunkedWriter::begin(&mut out, 200, &[("x-job-id", "7".to_string())]).unwrap();
        cw.write_chunk(b"hello ").unwrap();
        cw.write_chunk(b"").unwrap(); // skipped, must not terminate
        cw.write_chunk(b"world").unwrap();
        let n = cw.finish().unwrap();
        let text = String::from_utf8(out).unwrap();
        assert_eq!(n, text.len(), "byte meter matches what hit the wire");
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("x-job-id: 7\r\n"));
        assert!(text.contains("transfer-encoding: chunked\r\n"));
        assert!(text.ends_with("6\r\nhello \r\n5\r\nworld\r\n0\r\n\r\n"));
    }

    #[test]
    fn write_response_sets_content_length() {
        let mut out = Vec::new();
        let n = write_response(&mut out, 429, &[("retry-after", "1".into())], b"busy\n").unwrap();
        let text = String::from_utf8(out).unwrap();
        assert_eq!(n, text.len());
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("retry-after: 1\r\n"));
        assert!(text.contains("content-length: 5\r\n"));
        assert!(text.ends_with("\r\n\r\nbusy\n"));
    }
}
