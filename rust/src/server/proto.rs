//! The wire protocol's payloads (DESIGN.md §11): job-spec request bodies
//! and streamed outcome response bodies.
//!
//! Requests are parsed straight off the wire with a [`JsonVisitor`] over
//! [`parse_events`] — one pass, no intermediate tree, every violation a
//! typed error. The spec is a **flat** JSON object; nested containers,
//! unknown fields and duplicate keys are rejected, and `tenant` cannot be
//! set in the body — it comes from the authenticated token, which is what
//! makes the ε ledger trustworthy at this boundary.
//!
//! Responses are emitted through [`emit_outcome`], a piecewise encoder
//! that both the chunked wire path and the buffering in-process path
//! ([`outcome_body_string`]) share — the wire soak asserts the two are
//! byte-identical for a fixed seed, so there is exactly one encoder and
//! one number formatter ([`fmt_f64`]).

use crate::coordinator::{
    JobOutcome, JobSpec, LpJobSpec, ReleaseJobSpec, WorkloadUpdateSpec,
};
use crate::lp::SelectionMode;
use crate::mips::IndexKind;
use crate::util::json::{
    fmt_f64, parse_events, DuplicateKeys, JsonError, JsonErrorKind, JsonLimits, JsonVisitor,
};
use crate::workloads::QueryClassKind;

/// Values released per chunk when streaming an outcome body.
const VALUES_PER_CHUNK: usize = 64;

/// Every field a job spec may carry, with the kinds it applies to — the
/// single source of truth for the unknown-field error message.
const FIELDS: &[(&str, &[&str])] = &[
    ("kind", &["release", "lp", "update"]),
    ("u", &["release", "update"]),
    ("m", &["release", "lp", "update"]),
    ("n", &["release", "update"]),
    ("t", &["release", "lp"]),
    ("d", &["lp"]),
    ("eps", &["release", "lp"]),
    ("delta", &["release", "lp"]),
    ("delta_inf", &["lp"]),
    ("index", &["release"]),
    ("class", &["release"]),
    ("mode", &["lp"]),
    ("shards", &["release", "lp"]),
    ("workload", &["release", "update"]),
    ("seed", &["release", "lp"]),
    ("insert", &["update"]),
    ("tombstone", &["update"]),
];

fn field_err(pos: usize, msg: impl Into<String>) -> JsonError {
    JsonError::at(JsonErrorKind::Visitor, pos, msg)
}

/// Folds the event stream of a flat job-spec object into typed fields.
#[derive(Default)]
struct SpecVisitor {
    in_object: bool,
    /// The member whose value is next (cleared once consumed).
    field: Option<String>,
    strings: Vec<(String, String, usize)>, // (field, value, pos)
    ints: Vec<(String, u64, usize)>,
    floats: Vec<(String, f64, usize)>,
}

impl SpecVisitor {
    fn take_field(&mut self, pos: usize) -> Result<String, JsonError> {
        match self.field.take() {
            Some(f) => Ok(f),
            None => Err(field_err(pos, "the job spec must be a JSON object")),
        }
    }
}

const INT_FIELDS: &[&str] = &[
    "u", "m", "n", "t", "d", "shards", "workload", "seed", "insert", "tombstone",
];
const FLOAT_FIELDS: &[&str] = &["eps", "delta", "delta_inf"];
const STRING_FIELDS: &[&str] = &["kind", "index", "class", "mode"];

impl JsonVisitor for SpecVisitor {
    fn begin_object(&mut self, pos: usize) -> Result<(), JsonError> {
        if self.in_object {
            return Err(field_err(
                pos,
                "the job spec is a flat object: nested objects are not allowed",
            ));
        }
        self.in_object = true;
        Ok(())
    }

    fn begin_array(&mut self, pos: usize) -> Result<(), JsonError> {
        Err(field_err(pos, "the job spec is a flat object: arrays are not allowed"))
    }

    fn key(&mut self, key: &str, pos: usize) -> Result<(), JsonError> {
        if key == "tenant" {
            return Err(field_err(
                pos,
                "field \"tenant\" is not settable: the tenant comes from the \
                 authenticated token",
            ));
        }
        if !FIELDS.iter().any(|(name, _)| *name == key) {
            let known: Vec<&str> = FIELDS.iter().map(|(name, _)| *name).collect();
            return Err(field_err(
                pos,
                format!("unknown field {key:?} (known fields: {})", known.join(", ")),
            ));
        }
        self.field = Some(key.to_string());
        Ok(())
    }

    fn null(&mut self, pos: usize) -> Result<(), JsonError> {
        let f = self.take_field(pos)?;
        Err(field_err(pos, format!("field {f:?} must not be null")))
    }

    fn boolean(&mut self, _b: bool, pos: usize) -> Result<(), JsonError> {
        let f = self.take_field(pos)?;
        Err(field_err(pos, format!("field {f:?} must not be a boolean")))
    }

    fn number(&mut self, n: f64, pos: usize) -> Result<(), JsonError> {
        let f = self.take_field(pos)?;
        if FLOAT_FIELDS.contains(&f.as_str()) {
            self.floats.push((f, n, pos));
            return Ok(());
        }
        if INT_FIELDS.contains(&f.as_str()) {
            if n.fract() != 0.0 || !(0.0..=u64::MAX as f64).contains(&n) {
                return Err(field_err(
                    pos,
                    format!("field {f:?} must be a non-negative integer, got {n}"),
                ));
            }
            self.ints.push((f, n as u64, pos));
            return Ok(());
        }
        Err(field_err(pos, format!("field {f:?} must be a string, not a number")))
    }

    fn string(&mut self, s: &str, pos: usize) -> Result<(), JsonError> {
        let f = self.take_field(pos)?;
        if STRING_FIELDS.contains(&f.as_str()) {
            self.strings.push((f, s.to_string(), pos));
            return Ok(());
        }
        Err(field_err(pos, format!("field {f:?} must be a number, not a string")))
    }
}

impl SpecVisitor {
    fn finish(self, tenant: u64) -> Result<JobSpec, JsonError> {
        if !self.in_object {
            return Err(field_err(0, "the job spec must be a JSON object"));
        }
        let str_of = |name: &str| {
            self.strings.iter().find(|(f, _, _)| f == name).map(|(_, v, p)| (v.as_str(), *p))
        };
        let int_of =
            |name: &str, dflt: u64| self.ints.iter().find(|(f, _, _)| f == name).map_or(dflt, |(_, v, _)| *v);
        let float_of = |name: &str, dflt: f64| {
            self.floats.iter().find(|(f, _, _)| f == name).map_or(dflt, |(_, v, _)| *v)
        };
        let Some((kind, _)) = str_of("kind") else {
            return Err(field_err(0, "missing required field \"kind\" (release|lp|update)"));
        };
        let kind = kind.to_string();

        // Every present field must apply to the requested kind — a field
        // the executor would silently ignore is a caller bug worth a 4xx.
        let present = self
            .strings
            .iter()
            .map(|(f, _, p)| (f.as_str(), *p))
            .chain(self.ints.iter().map(|(f, _, p)| (f.as_str(), *p)))
            .chain(self.floats.iter().map(|(f, _, p)| (f.as_str(), *p)));
        for (f, pos) in present {
            let applies = FIELDS
                .iter()
                .find(|(name, _)| *name == f)
                .is_some_and(|(_, kinds)| kinds.contains(&kind.as_str()));
            if !applies {
                return Err(field_err(
                    pos,
                    format!("field {f:?} does not apply to kind {kind:?}"),
                ));
            }
        }

        let shards = int_of("shards", 1).max(1) as usize;
        match kind.as_str() {
            "release" => {
                let index = match str_of("index") {
                    None => Some(IndexKind::Hnsw),
                    Some(("none", _)) => None,
                    Some((s, pos)) => {
                        Some(s.parse::<IndexKind>().map_err(|e| field_err(pos, e))?)
                    }
                };
                let class = match str_of("class") {
                    None => QueryClassKind::Linear,
                    Some((s, pos)) => {
                        s.parse::<QueryClassKind>().map_err(|e| field_err(pos, e))?
                    }
                };
                Ok(JobSpec::Release(ReleaseJobSpec {
                    u: int_of("u", 256) as usize,
                    m: int_of("m", 400) as usize,
                    n: int_of("n", 500) as usize,
                    t: int_of("t", 200) as usize,
                    eps: float_of("eps", 1.0),
                    delta: float_of("delta", 1e-3),
                    index,
                    shards,
                    class,
                    workload: int_of("workload", 0),
                    tenant,
                    seed: int_of("seed", 0),
                }))
            }
            "lp" => {
                let mode = match str_of("mode") {
                    Some(("exhaustive", _)) => SelectionMode::Exhaustive,
                    other => {
                        let kind = match other {
                            None => IndexKind::Hnsw,
                            Some((s, pos)) => {
                                s.parse::<IndexKind>().map_err(|e| field_err(pos, e))?
                            }
                        };
                        if shards > 1 {
                            SelectionMode::LazySharded(kind, shards)
                        } else {
                            SelectionMode::Lazy(kind)
                        }
                    }
                };
                Ok(JobSpec::Lp(LpJobSpec {
                    m: int_of("m", 2_000) as usize,
                    d: int_of("d", 16) as usize,
                    t: int_of("t", 200) as usize,
                    eps: float_of("eps", 1.0),
                    delta: float_of("delta", 1e-3),
                    delta_inf: float_of("delta_inf", 0.1),
                    mode,
                    tenant,
                    seed: int_of("seed", 0),
                }))
            }
            "update" => Ok(JobSpec::Update(WorkloadUpdateSpec {
                workload: int_of("workload", 0),
                u: int_of("u", 256) as usize,
                m: int_of("m", 400) as usize,
                n: int_of("n", 500) as usize,
                insert: int_of("insert", 4) as usize,
                tombstone: int_of("tombstone", 2) as usize,
                tenant,
            })),
            other => Err(field_err(
                0,
                format!("unknown kind {other:?} (expected release, lp or update)"),
            )),
        }
    }
}

/// The hardened limits every wire request body is parsed under: tighter
/// than [`JsonLimits::default`], with duplicate keys rejected — a body
/// that says `"seed": 1, "seed": 2` is ambiguous and must not be
/// half-honored.
pub fn wire_limits() -> JsonLimits {
    JsonLimits { max_depth: 4, max_number_len: 64, duplicate_keys: DuplicateKeys::Reject }
}

/// Parse a wire request body into a [`JobSpec`] for the authenticated
/// `tenant`, in one pass with no intermediate tree. Any violation —
/// malformed JSON, unknown/inapplicable fields, nesting, duplicate keys,
/// a body-supplied `tenant` — is a typed [`JsonError`] the front end maps
/// to a 4xx *before* anything touches the budget ledger.
pub fn parse_job_spec(body: &str, tenant: u64) -> Result<JobSpec, JsonError> {
    let mut v = SpecVisitor::default();
    parse_events(body, &wire_limits(), &mut v)?;
    v.finish(tenant)
}

/// Emit an outcome body in pieces, calling `sink` once per piece. The
/// `output` vector is released in [`VALUES_PER_CHUNK`]-value blocks, so a
/// chunked sink streams a large histogram without the encoder (or the
/// response path) ever materializing the whole body.
///
/// The body deliberately excludes wall-clock and job-id — those travel as
/// response headers — so the bytes depend only on the job's deterministic
/// result and the soak can assert wire == in-process per seed.
pub fn emit_outcome<E>(
    kind: &str,
    outcome: &JobOutcome,
    mut sink: impl FnMut(&str) -> Result<(), E>,
) -> Result<(), E> {
    sink(&format!(
        "{{\"kind\":\"{kind}\",\"quality\":{},\"eps_spent\":{},\"delta_spent\":{},\
         \"avg_select_work\":{},\"output\":",
        fmt_f64(outcome.quality),
        fmt_f64(outcome.eps_spent),
        fmt_f64(outcome.delta_spent),
        fmt_f64(outcome.avg_select_work),
    ))?;
    match &outcome.output {
        None => sink("null}")?,
        Some(values) => {
            sink("[")?;
            let mut piece = String::new();
            for (i, block) in values.chunks(VALUES_PER_CHUNK).enumerate() {
                piece.clear();
                for (j, v) in block.iter().enumerate() {
                    if i > 0 || j > 0 {
                        piece.push(',');
                    }
                    piece.push_str(&fmt_f64(f64::from(*v)));
                }
                sink(&piece)?;
            }
            sink("]}")?;
        }
    }
    Ok(())
}

/// Stream an outcome body through a chunked response, one wire chunk per
/// emitted piece — the response path never holds the whole payload.
pub fn write_outcome_chunked<W: std::io::Write>(
    kind: &str,
    outcome: &JobOutcome,
    cw: &mut super::http::ChunkedWriter<'_, W>,
) -> std::io::Result<()> {
    emit_outcome(kind, outcome, |piece| cw.write_chunk(piece.as_bytes()))
}

/// The outcome body as one buffered string — the in-process twin of the
/// chunked wire encoding (`repro job` prints this; the integration tests
/// and the soak compare wire bytes against it).
pub fn outcome_body_string(kind: &str, outcome: &JobOutcome) -> String {
    let mut s = String::new();
    let _ = emit_outcome::<std::convert::Infallible>(kind, outcome, |piece| {
        s.push_str(piece);
        Ok(())
    });
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn kind_of_err(body: &str) -> JsonErrorKind {
        parse_job_spec(body, 0).unwrap_err().kind
    }

    #[test]
    fn release_spec_parses_with_defaults_and_overrides() {
        let spec = parse_job_spec(r#"{"kind":"release"}"#, 3).unwrap();
        let JobSpec::Release(r) = spec else { panic!("expected release") };
        assert_eq!((r.u, r.m, r.n, r.t), (256, 400, 500, 200));
        assert_eq!((r.eps, r.delta), (1.0, 1e-3));
        assert_eq!(r.index, Some(IndexKind::Hnsw));
        assert_eq!(r.class, QueryClassKind::Linear, "linear is the default class");
        assert_eq!((r.shards, r.workload, r.seed), (1, 0, 0));
        assert_eq!(r.tenant, 3, "tenant comes from authentication");

        let spec = parse_job_spec(
            r#"{"kind":"release","u":128,"m":600,"t":40,"eps":0.5,"index":"flat",
                "shards":2,"workload":7,"seed":42}"#,
            1,
        )
        .unwrap();
        let JobSpec::Release(r) = spec else { panic!("expected release") };
        assert_eq!((r.u, r.m, r.t), (128, 600, 40));
        assert_eq!(r.eps, 0.5);
        assert_eq!(r.index, Some(IndexKind::Flat));
        assert_eq!((r.shards, r.workload, r.seed), (2, 7, 42));

        let spec = parse_job_spec(r#"{"kind":"release","index":"none"}"#, 0).unwrap();
        let JobSpec::Release(r) = spec else { panic!("expected release") };
        assert_eq!(r.index, None, "classic MWEM");
    }

    #[test]
    fn release_spec_parses_query_class() {
        for (s, want) in [
            ("convex-lsq", QueryClassKind::ConvexLsq),
            ("convex-logistic", QueryClassKind::ConvexLogistic),
            ("linear", QueryClassKind::Linear),
        ] {
            let body = format!(r#"{{"kind":"release","class":"{s}"}}"#);
            let spec = parse_job_spec(&body, 0).unwrap();
            let JobSpec::Release(r) = spec else { panic!("expected release") };
            assert_eq!(r.class, want, "class {s:?}");
        }
        // an unknown class and a class on a non-release kind are both 4xx
        let err = parse_job_spec(r#"{"kind":"release","class":"cubic"}"#, 0).unwrap_err();
        assert_eq!(err.kind, JsonErrorKind::Visitor);
        assert!(err.msg.contains("unknown query class"), "{}", err.msg);
        let err = parse_job_spec(r#"{"kind":"lp","class":"linear"}"#, 0).unwrap_err();
        assert_eq!(err.kind, JsonErrorKind::Visitor);
        assert!(err.msg.contains("does not apply"), "{}", err.msg);
    }

    #[test]
    fn lp_and_update_specs_parse() {
        let spec = parse_job_spec(r#"{"kind":"lp","m":800,"d":8,"mode":"exhaustive"}"#, 2)
            .unwrap();
        let JobSpec::Lp(l) = spec else { panic!("expected lp") };
        assert_eq!((l.m, l.d, l.t), (800, 8, 200));
        assert_eq!(l.mode, SelectionMode::Exhaustive);
        assert_eq!(l.delta_inf, 0.1);
        assert_eq!(l.tenant, 2);

        let spec = parse_job_spec(r#"{"kind":"lp","mode":"ivf","shards":3}"#, 0).unwrap();
        let JobSpec::Lp(l) = spec else { panic!("expected lp") };
        assert_eq!(l.mode, SelectionMode::LazySharded(IndexKind::Ivf, 3));

        let spec =
            parse_job_spec(r#"{"kind":"update","workload":5,"insert":3,"tombstone":1}"#, 4)
                .unwrap();
        let JobSpec::Update(u) = spec else { panic!("expected update") };
        assert_eq!((u.workload, u.insert, u.tombstone), (5, 3, 1));
        assert_eq!(u.tenant, 4);
    }

    #[test]
    fn adversarial_bodies_are_typed_errors_never_panics() {
        // malformed JSON surfaces the json layer's typed kinds
        assert_eq!(kind_of_err("{"), JsonErrorKind::Truncated);
        assert_eq!(kind_of_err(r#"{"kind":"release","eps":1e999}"#), JsonErrorKind::OversizedNumber);
        assert_eq!(
            kind_of_err(r#"{"kind":"release","seed":1,"seed":2}"#),
            JsonErrorKind::DuplicateKey
        );
        // protocol violations are Visitor-kind errors
        for body in [
            "5",                                    // not an object
            r#"{"kind":"release","nested":{}}"#,    // unknown + nested
            r#"{"kind":"release","u":[1]}"#,        // array value
            r#"{"kind":"teleport"}"#,               // unknown kind
            r#"{"u":256}"#,                         // missing kind
            r#"{"kind":"release","tenant":9}"#,     // tenant from body
            r#"{"kind":"release","u":1.5}"#,        // non-integer size
            r#"{"kind":"release","u":-4}"#,         // negative size
            r#"{"kind":"release","d":8}"#,          // lp-only field
            r#"{"kind":"lp","insert":1}"#,          // update-only field
            r#"{"kind":"update","eps":1.0}"#,       // eps on a zero-eps kind
            r#"{"kind":true}"#,                     // wrong type
            r#"{"kind":"release","u":null}"#,       // null value
        ] {
            let err = parse_job_spec(body, 0).unwrap_err();
            assert_eq!(err.kind, JsonErrorKind::Visitor, "body: {body} -> {err}");
        }
        // the message names the offender
        let err = parse_job_spec(r#"{"kind":"release","tenant":9}"#, 0).unwrap_err();
        assert!(err.msg.contains("authenticated"), "{}", err.msg);
    }

    #[test]
    fn outcome_bodies_stream_and_buffer_identically() {
        let outcome = JobOutcome {
            quality: 0.125,
            eps_spent: 1.0,
            delta_spent: 1e-3,
            avg_select_work: 40.0,
            total_time: Duration::from_millis(7),
            output: Some((0..200).map(|i| i as f32 / 3.0).collect()),
        };
        let buffered = outcome_body_string("release", &outcome);
        // piecewise emission concatenates to the same bytes
        let mut pieces: Vec<String> = Vec::new();
        emit_outcome::<std::convert::Infallible>("release", &outcome, |p| {
            pieces.push(p.to_string());
            Ok(())
        })
        .unwrap();
        assert!(pieces.len() > 3, "a 200-value output must stream in blocks");
        assert_eq!(pieces.concat(), buffered);
        // the body is valid JSON with the released vector intact
        let parsed = crate::util::json::Json::parse(&buffered).unwrap();
        assert_eq!(parsed.get("kind").unwrap().as_str(), Some("release"));
        assert_eq!(parsed.get("quality").unwrap().as_f64(), Some(0.125));
        assert_eq!(parsed.get("output").unwrap().as_arr().unwrap().len(), 200);
        // wall-clock never leaks into the body: same result, different
        // timing, identical bytes (the soak's determinism contract)
        let slower = JobOutcome { total_time: Duration::from_secs(9), ..outcome.clone() };
        assert_eq!(outcome_body_string("release", &slower), buffered);

        let none = JobOutcome { output: None, ..outcome };
        let body = outcome_body_string("update", &none);
        assert!(body.ends_with("\"output\":null}"), "{body}");
    }
}
