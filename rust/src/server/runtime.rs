//! The long-lived serving runtime: a bounded MPMC job queue feeding
//! persistent workers over the tiered warm-index cache, with per-tenant
//! budget admission and graceful drain (DESIGN.md §8).
//!
//! Lifecycle: [`Server::start`] spawns the worker threads once; any number
//! of submitter threads then call [`Server::submit`] concurrently. Each
//! submission is admission-controlled against its tenant's ε cap *before*
//! it enters the queue, and returns a [`JobTicket`] — the per-request
//! response path — that resolves to the job's [`JobResult`]. A failed job
//! atomically refunds its reservation. [`Server::drain`] closes the queue
//! (new work is refused), lets the workers finish everything in flight,
//! and returns the final [`Metrics`] with per-kind latency histograms and
//! per-tenant spend gauges.

use super::budget::{AdmissionError, TenantBudget, TenantSpend};
use super::queue::{BoundedQueue, PushError, QueuePolicy};
use crate::config::{CacheConfig, Config, PagerConfig, StoreConfig};
use crate::coordinator::pool::finalize_serving_metrics;
use crate::coordinator::{execute_with_cache, JobResult, JobSpec};
use crate::metrics::Metrics;
use crate::store::{HeapBudget, LeaseSettings, PagerSettings, TieredIndexCache};
use crate::workloads::WorkloadRegistry;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Sizing, backpressure and admission control for a [`Server`].
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Persistent worker threads.
    pub workers: usize,
    /// Bounded queue depth — jobs admitted but not yet picked up.
    pub queue_depth: usize,
    /// What `submit` does when the queue is at depth.
    pub policy: QueuePolicy,
    /// Per-tenant privacy cap (ε). Every tenant gets this budget; `None`
    /// disables admission control (spend is still metered per tenant).
    pub eps_per_tenant: Option<f64>,
    /// Warm-index cache capacity (DESIGN.md §6); 0 disables the L1 tier.
    pub cache_capacity: usize,
    /// Persistent artifact store directory (DESIGN.md §7); `None` keeps
    /// warm serving in-memory only.
    pub store_dir: Option<PathBuf>,
    /// Heap ceiling for L1-resident index data (DESIGN.md §12);
    /// mmap-borrowed rows count as zero against it.
    pub heap_budget: HeapBudget,
    /// How store artifacts are restored: zero-copy mmap paging vs heap
    /// decode (DESIGN.md §12).
    pub pager: PagerSettings,
    /// Build-lease protocol for N servers sharing one store dir
    /// (DESIGN.md §13): a shared miss builds once, peers wait-and-promote.
    pub lease: LeaseSettings,
    /// Manifest generation watch (DESIGN.md §13): adopt peer-committed
    /// workload updates before serving, keeping the
    /// `stale_generation_serves == 0` invariant across processes.
    pub watch: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 4,
            queue_depth: 64,
            policy: QueuePolicy::Block,
            eps_per_tenant: None,
            cache_capacity: 8,
            store_dir: None,
            heap_budget: HeapBudget::unlimited(),
            pager: PagerSettings::default(),
            lease: LeaseSettings::default(),
            watch: true,
        }
    }
}

impl ServerConfig {
    /// Read the `[server]` section, honoring the CLI shorthands
    /// `--workers`, `--queue-depth`, `--policy` and `--eps-per-tenant`
    /// (shorthands win over section values), plus the `[cache]` and
    /// `[store]` sections for the warm-serving tiers.
    ///
    /// ```text
    /// [server]
    /// workers = 4
    /// queue_depth = 64
    /// policy = "block"        # or "reject"
    /// eps_per_tenant = 8.0    # unset = unlimited
    /// ```
    pub fn from_config(cfg: &Config) -> anyhow::Result<Self> {
        let d = ServerConfig::default();
        let policy_str =
            cfg.str_or("policy", &cfg.str_or("server.policy", &d.policy.to_string()));
        let policy: QueuePolicy =
            policy_str.parse().map_err(|e: String| anyhow::anyhow!(e))?;
        let eps_per_tenant: Option<f64> = match cfg.get("eps-per-tenant")? {
            Some(v) => Some(v),
            None => cfg.get("server.eps_per_tenant")?,
        };
        let pager = PagerConfig::from_config(cfg)?;
        let store = StoreConfig::from_config(cfg)?;
        Ok(ServerConfig {
            workers: cfg.or("workers", cfg.or("server.workers", d.workers)?)?,
            queue_depth: cfg
                .or("queue-depth", cfg.or("server.queue_depth", d.queue_depth)?)?,
            policy,
            eps_per_tenant,
            cache_capacity: CacheConfig::from_config(cfg)?.capacity,
            store_dir: store.dir.as_deref().map(PathBuf::from),
            heap_budget: pager.heap_budget(),
            pager: pager.settings(),
            lease: store.lease_settings(),
            watch: store.watch,
        })
    }
}

/// Why [`Server::submit`] refused a job. Refused jobs never spend ε.
#[derive(Debug)]
pub enum SubmitError {
    /// The bounded queue is at depth under [`QueuePolicy::Reject`]. The
    /// admission reservation was refunded; the submitter should shed or
    /// retry later.
    QueueFull {
        /// The configured queue depth that was hit.
        depth: usize,
    },
    /// The server is draining — no new work is accepted.
    Draining,
    /// The tenant's ε cap would be exceeded; the job was denied before
    /// queueing and spent zero ε.
    Budget(AdmissionError),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { depth } => {
                write!(f, "queue full (depth {depth}): job rejected by backpressure")
            }
            SubmitError::Draining => write!(f, "server draining: new work refused"),
            SubmitError::Budget(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// The per-request response path: resolves to the job's [`JobResult`].
#[derive(Debug)]
pub struct JobTicket {
    /// Submission id: unique and increasing in submission order. A
    /// budget-admitted submission that the queue then refuses burns its
    /// id, so ids are not dense under [`QueuePolicy::Reject`].
    pub job_id: usize,
    rx: mpsc::Receiver<JobResult>,
}

impl JobTicket {
    /// Block until the job completes. If the server was torn down before
    /// the job ran (never happens under a graceful [`Server::drain`]),
    /// resolves to a failed result rather than hanging.
    pub fn wait(self) -> JobResult {
        let job_id = self.job_id;
        self.rx.recv().unwrap_or_else(|_| JobResult {
            job_id,
            kind: "dropped",
            outcome: Err(anyhow::anyhow!("server dropped the job before completion")),
        })
    }
}

/// One admitted job riding the queue to a worker.
struct Envelope {
    job_id: usize,
    tenant: u64,
    eps: f64,
    spec: JobSpec,
    enqueued: Instant,
    reply: mpsc::Sender<JobResult>,
}

/// A running serving runtime. `&self` methods are safe to call from any
/// number of threads (MPMC submission); drop order is governed by
/// [`Server::drain`].
pub struct Server {
    cfg: ServerConfig,
    queue: Arc<BoundedQueue<Envelope>>,
    budget: Arc<TenantBudget>,
    metrics: Arc<Mutex<Metrics>>,
    cache: Option<Arc<TieredIndexCache>>,
    registry: Arc<WorkloadRegistry>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicUsize,
}

impl Server {
    /// Spawn the persistent workers and start accepting jobs.
    ///
    /// Like [`crate::coordinator::Coordinator::start`], an unopenable
    /// `store_dir` degrades to in-memory-only warm serving with a warning
    /// — the store is an accelerator, never a startup dependency.
    pub fn start(cfg: ServerConfig) -> Self {
        let queue = Arc::new(BoundedQueue::new(cfg.queue_depth, cfg.policy));
        let budget = Arc::new(TenantBudget::new(cfg.eps_per_tenant));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let cache: Option<Arc<TieredIndexCache>> =
            if cfg.cache_capacity > 0 || cfg.store_dir.is_some() {
                let tiered = match &cfg.store_dir {
                    Some(dir) => TieredIndexCache::with_settings(
                        cfg.cache_capacity,
                        cfg.heap_budget,
                        dir,
                        cfg.pager,
                    )
                    .unwrap_or_else(|e| {
                        eprintln!(
                            "warning: cannot open artifact store {dir:?} ({e:#}); \
                             serving in-memory only"
                        );
                        TieredIndexCache::memory_only_with_budget(
                            cfg.cache_capacity,
                            cfg.heap_budget,
                        )
                    }),
                    None => TieredIndexCache::memory_only_with_budget(
                        cfg.cache_capacity,
                        cfg.heap_budget,
                    ),
                }
                .with_lease(cfg.lease)
                .with_watch(cfg.watch);
                Some(Arc::new(tiered))
            } else {
                None
            };

        // Dynamic-workload state (DESIGN.md §9): one registry shared by
        // every worker, seeded from the store's persisted delta chains so
        // a restarted daemon resumes at the generations it left off.
        let registry = Arc::new(WorkloadRegistry::new());
        if let Some(store) = cache.as_deref().and_then(TieredIndexCache::store) {
            registry.restore(store.delta_chains());
        }

        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let queue = Arc::clone(&queue);
                let budget = Arc::clone(&budget);
                let metrics = Arc::clone(&metrics);
                let cache = cache.clone();
                let registry = Arc::clone(&registry);
                std::thread::spawn(move || {
                    while let Some(env) = queue.pop() {
                        run_one(env, cache.as_deref(), &registry, &metrics, &budget);
                    }
                })
            })
            .collect();

        Server {
            cfg,
            queue,
            budget,
            metrics,
            cache,
            registry,
            workers,
            next_id: AtomicUsize::new(0),
        }
    }

    /// Submit a job from any thread. Admission order: the tenant's ε is
    /// reserved first (denied jobs never queue and spend zero ε), then the
    /// job enters the bounded queue under the backpressure policy; a
    /// queue-refused job rescinds its reservation before returning, as if
    /// it had never been admitted.
    pub fn submit(&self, spec: JobSpec) -> Result<JobTicket, SubmitError> {
        let tenant = spec.tenant();
        let eps = spec.eps();
        if let Err(e) = self.budget.admit(tenant, eps) {
            self.metrics.lock().unwrap().inc("jobs_denied_budget", 1);
            return Err(SubmitError::Budget(e));
        }
        let job_id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        let env =
            Envelope { job_id, tenant, eps, spec, enqueued: Instant::now(), reply };
        match self.queue.push(env) {
            Ok(()) => {
                self.metrics.lock().unwrap().inc("jobs_admitted", 1);
                Ok(JobTicket { job_id, rx })
            }
            Err(PushError::Full(_)) => {
                self.budget.rescind(tenant, eps);
                self.metrics.lock().unwrap().inc("jobs_rejected_queue", 1);
                Err(SubmitError::QueueFull { depth: self.queue.depth() })
            }
            Err(PushError::Closed(_)) => {
                self.budget.rescind(tenant, eps);
                Err(SubmitError::Draining)
            }
        }
    }

    /// Begin a graceful shutdown without blocking: the queue refuses new
    /// work from this point on; workers keep serving the backlog.
    /// Idempotent. [`Server::drain`] calls this implicitly.
    pub fn close(&self) {
        self.queue.close();
    }

    /// Graceful drain: refuse new work, let every in-flight and queued job
    /// complete, join the workers, and return the final metrics (per-kind
    /// latency histograms, queue-wait distribution, cache/store counters,
    /// and per-tenant spend gauges).
    pub fn drain(mut self) -> Metrics {
        self.queue.close();
        for w in std::mem::take(&mut self.workers) {
            let _ = w.join();
        }
        {
            let mut m = self.metrics.lock().unwrap();
            finalize_serving_metrics(&mut m, self.cache.as_deref());
            if let Some(cap) = self.budget.cap() {
                m.set_gauge("tenant_eps_cap", cap);
            }
            for t in self.budget.snapshot() {
                m.set_gauge(&format!("tenant_{}_eps_spent", t.tenant), t.spent);
                m.set_gauge(&format!("tenant_{}_eps_admitted", t.tenant), t.admitted);
                if t.refunded > 0.0 {
                    m.set_gauge(&format!("tenant_{}_eps_refunded", t.tenant), t.refunded);
                }
            }
        }
        let metrics = Arc::clone(&self.metrics);
        drop(self); // releases the server's own Arc clones (close is idempotent)
        Arc::try_unwrap(metrics)
            .map(|m| m.into_inner().unwrap())
            .unwrap_or_default()
    }

    /// Point-in-time copy of the live metrics (for status endpoints and
    /// tests; the authoritative final registry comes from [`Server::drain`]).
    pub fn metrics_snapshot(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Shared handle to the live metrics registry, so a front end (the
    /// wire listener, DESIGN.md §11) can meter into the same registry the
    /// workers use and [`Server::drain`] finalizes.
    pub(crate) fn metrics_handle(&self) -> Arc<Mutex<Metrics>> {
        Arc::clone(&self.metrics)
    }

    /// Snapshot of every tenant's privacy ledger.
    pub fn tenant_spend(&self) -> Vec<TenantSpend> {
        self.budget.snapshot()
    }

    /// Submissions that passed budget admission so far — including any
    /// later shed by a full or closing queue, so this is an upper bound
    /// on (not a count of) enqueued jobs; use the `jobs_admitted` counter
    /// for jobs that actually entered the queue.
    pub fn submitted(&self) -> usize {
        self.next_id.load(Ordering::Relaxed)
    }

    /// Jobs admitted but not yet picked up by a worker (racy; for
    /// monitoring).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// The tiered warm-index cache, when warm serving is enabled.
    pub fn tiered_cache(&self) -> Option<&TieredIndexCache> {
        self.cache.as_deref()
    }

    /// The dynamic-workload registry shared by this server's workers
    /// (DESIGN.md §9).
    pub fn registry(&self) -> &WorkloadRegistry {
        &self.registry
    }

    /// The resolved configuration this server runs under.
    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }
}

/// Dropping a server without [`Server::drain`] must not leak the
/// persistent workers: closing the queue wakes every idle worker (they
/// finish the backlog and exit on their own, detached — unlike `drain`,
/// which joins them and reports metrics).
impl Drop for Server {
    fn drop(&mut self) {
        self.queue.close();
    }
}

/// One worker's handling of one admitted job: execute over the shared
/// cache (panics are caught and converted into failed results so a bad job
/// can never kill a persistent worker), meter latency + cache counters,
/// settle the tenant's reservation (commit on success, refund on failure),
/// and resolve the submitter's ticket.
fn run_one(
    env: Envelope,
    cache: Option<&TieredIndexCache>,
    registry: &WorkloadRegistry,
    metrics: &Mutex<Metrics>,
    budget: &TenantBudget,
) {
    let Envelope { job_id, tenant, eps, spec, enqueued, reply } = env;
    let kind = spec.kind();
    let waited = enqueued.elapsed();
    let started = Instant::now();
    let outcome =
        catch_unwind(AssertUnwindSafe(|| execute_with_cache(&spec, cache, Some(registry))))
            .unwrap_or_else(|_| Err(anyhow::anyhow!("job panicked on the worker")));
    let store_on = cache.is_some_and(|c| c.store().is_some());
    {
        let mut m = metrics.lock().unwrap();
        m.inc("jobs_completed", 1);
        m.inc(&format!("jobs_{kind}"), 1);
        m.observe("queue_wait", waited);
        m.observe("job_duration", started.elapsed());
        m.observe(&format!("latency_{kind}"), started.elapsed());
        match &outcome {
            Ok((_, rep)) => rep.record_into(&mut m, store_on),
            Err(_) => m.inc("jobs_failed", 1),
        }
    }
    match &outcome {
        Ok(_) => budget.commit(tenant, eps),
        Err(_) => {
            budget.refund(tenant, eps);
            metrics.lock().unwrap().inc("jobs_refunded", 1);
        }
    }
    let outcome = outcome.map(|(o, _)| o);
    let _ = reply.send(JobResult { job_id, kind, outcome });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{LpJobSpec, WorkloadUpdateSpec};
    use crate::lp::SelectionMode;

    fn tiny_lp(tenant: u64, seed: u64, eps: f64) -> JobSpec {
        JobSpec::Lp(LpJobSpec {
            m: 50,
            d: 6,
            t: 10,
            eps,
            delta: 1e-3,
            delta_inf: 0.1,
            mode: SelectionMode::Exhaustive,
            tenant,
            seed,
        })
    }

    #[test]
    fn submit_runs_jobs_and_drain_reports() {
        let server = Server::start(ServerConfig {
            workers: 2,
            queue_depth: 8,
            cache_capacity: 0,
            ..ServerConfig::default()
        });
        let tickets: Vec<JobTicket> =
            (0..4).map(|i| server.submit(tiny_lp(i % 2, i, 0.5)).unwrap()).collect();
        assert_eq!(server.submitted(), 4);
        for t in tickets {
            let r = t.wait();
            assert_eq!(r.kind, "lp");
            assert!(r.outcome.is_ok());
        }
        let m = server.drain();
        assert_eq!(m.counter("jobs_completed"), 4);
        assert_eq!(m.counter("jobs_admitted"), 4);
        assert_eq!(m.counter("jobs_failed"), 0);
        assert_eq!(m.timing_summary("latency_lp").unwrap().count, 4);
        assert_eq!(m.timing_summary("queue_wait").unwrap().count, 4);
        assert_eq!(m.gauge("tenant_0_eps_spent"), Some(1.0));
        assert_eq!(m.gauge("tenant_1_eps_spent"), Some(1.0));
    }

    #[test]
    fn closed_server_refuses_new_work_but_finishes_backlog() {
        let server = Server::start(ServerConfig {
            workers: 1,
            queue_depth: 8,
            cache_capacity: 0,
            ..ServerConfig::default()
        });
        let t1 = server.submit(tiny_lp(0, 1, 0.5)).unwrap();
        server.close();
        match server.submit(tiny_lp(0, 2, 0.5)) {
            Err(SubmitError::Draining) => {}
            other => panic!("expected Draining, got {other:?}"),
        }
        assert!(t1.wait().outcome.is_ok(), "backlog completes after close");
        let m = server.drain();
        assert_eq!(m.counter("jobs_completed"), 1);
        // the refused job's reservation was refunded, so only 0.5 is spent
        assert_eq!(m.gauge("tenant_0_eps_spent"), Some(0.5));
    }

    /// Update jobs are tenant-budgeted like any other job but reserve
    /// zero ε, so a tenant at its cap can still evolve its workloads; the
    /// queue/drain semantics treat them like normal work.
    #[test]
    fn update_jobs_ride_the_queue_and_spend_zero_eps() {
        let server = Server::start(ServerConfig {
            workers: 1,
            queue_depth: 8,
            eps_per_tenant: Some(1.0),
            cache_capacity: 2,
            ..ServerConfig::default()
        });
        let t1 = server.submit(tiny_lp(0, 1, 1.0)).unwrap();
        assert!(server.submit(tiny_lp(0, 2, 0.5)).is_err(), "cap exhausted");
        let upd = server
            .submit(JobSpec::Update(WorkloadUpdateSpec {
                workload: 5,
                u: 32,
                m: 30,
                n: 100,
                insert: 1,
                tombstone: 0,
                tenant: 0,
            }))
            .unwrap();
        assert!(t1.wait().outcome.is_ok());
        let r = upd.wait();
        assert_eq!(r.kind, "update");
        let out = r.outcome.expect("update must run at a capped tenant");
        assert_eq!(out.eps_spent, 0.0);
        let m = server.drain();
        assert_eq!(m.counter("jobs_update"), 1);
        assert_eq!(m.gauge("tenant_0_eps_spent"), Some(1.0), "update spent nothing");
        assert_eq!(m.timing_summary("latency_update").unwrap().count, 1);
    }

    #[test]
    fn server_config_from_config_honors_shorthands() {
        let mut cfg = Config::parse(
            "[server]\nworkers = 2\nqueue_depth = 16\npolicy = \"reject\"\n\
             eps_per_tenant = 4.0\n",
        )
        .unwrap();
        let s = ServerConfig::from_config(&cfg).unwrap();
        assert_eq!((s.workers, s.queue_depth), (2, 16));
        assert_eq!(s.policy, QueuePolicy::Reject);
        assert_eq!(s.eps_per_tenant, Some(4.0));

        cfg.apply_overrides([
            "--workers=8",
            "--queue-depth=4",
            "--policy=block",
            "--eps-per-tenant=9.5",
        ])
        .unwrap();
        let s = ServerConfig::from_config(&cfg).unwrap();
        assert_eq!((s.workers, s.queue_depth), (8, 4));
        assert_eq!(s.policy, QueuePolicy::Block);
        assert_eq!(s.eps_per_tenant, Some(9.5));

        let d = ServerConfig::from_config(&Config::new()).unwrap();
        assert_eq!((d.workers, d.queue_depth), (4, 64));
        assert_eq!(d.policy, QueuePolicy::Block);
        assert_eq!(d.eps_per_tenant, None);
        assert_eq!(d.heap_budget, HeapBudget::unlimited());
        assert_eq!(d.pager, PagerSettings::default());

        // the [pager] section flows into the server's tier settings, with
        // the --heap-budget-mb shorthand winning over the section value
        let mut cfg =
            Config::parse("[pager]\nenabled = false\nheap_budget_mb = 2\n").unwrap();
        cfg.apply_overrides(["--heap-budget-mb=5"]).unwrap();
        let s = ServerConfig::from_config(&cfg).unwrap();
        assert!(!s.pager.enabled && s.pager.verify);
        assert_eq!(s.heap_budget.limit(), Some(5 << 20));

        // the [store] multi-process knobs flow into the server's lease
        // and watch settings (DESIGN.md §13); defaults keep both on
        assert_eq!(d.lease, LeaseSettings::default());
        assert!(d.watch);
        let cfg = Config::parse(
            "[store]\nlease_ttl_ms = 7000\nwatch = false\n",
        )
        .unwrap();
        let s = ServerConfig::from_config(&cfg).unwrap();
        assert_eq!(s.lease.ttl, std::time::Duration::from_millis(7000));
        assert!(s.lease.enabled && !s.watch);
    }
}
