//! The network front end (DESIGN.md §11): an HTTP/1.1 listener over the
//! serving runtime, turning sockets into [`Server::submit`] calls.
//!
//! Architecture: one nonblocking accept thread feeds accepted sockets
//! into a bounded [`BoundedQueue`] (Reject at `max_conns` — an overloaded
//! accept answers 503 immediately instead of queueing connections
//! invisibly), drained by a pool of connection workers. Each worker owns
//! one connection at a time: keep-alive request loop, per-request
//! routing, and a chunked streaming response for job outcomes. The
//! backpressure ladder maps queue/budget states to statuses:
//!
//! * spec parse failure → **400** (typed [`crate::util::json::JsonError`],
//!   zero ε touched)
//! * missing/unknown token → **401** (tenants authenticate; ε ledgers key
//!   off the token, never off the body)
//! * [`SubmitError::Budget`] → **403** (the cap is a privacy guarantee,
//!   not a transient state — no Retry-After)
//! * [`SubmitError::QueueFull`] under [`QueuePolicy::Reject`] → **429**
//!   with `Retry-After`
//! * a drained per-tenant token bucket ([`WireConfig::rate_limit`]) →
//!   **429** with the seconds until the next token as `Retry-After`,
//!   after authentication but before parsing or submission (zero ε
//!   touched, keep-alive survives). Buckets are keyed by tenant id and
//!   shared across connections, so a tenant cannot dodge the limiter by
//!   opening a fresh connection per request
//! * [`SubmitError::Draining`] / connection overflow → **503** with
//!   `Retry-After`
//!
//! Metrics flow into the *same* registry the workers use (so one drain
//! reports both): `conns_accepted`/`conns_open`, `bytes_in`/`bytes_out`,
//! `parse_errors`, per-status `http_<code>` counters and the
//! `wire_request` latency series.

use super::http::{read_request, write_response, ChunkedWriter, HttpLimits, Request};
use super::proto::{parse_job_spec, write_outcome_chunked};
use super::queue::{BoundedQueue, PushError, QueuePolicy};
use super::runtime::{Server, SubmitError};
use crate::config::Config;
use crate::metrics::Metrics;
use std::collections::{BTreeMap, HashMap};
use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long a connection worker sleeps in a socket read before rechecking
/// the shutdown flag — the upper bound on shutdown latency per idle
/// connection.
const IDLE_TICK: Duration = Duration::from_millis(250);

/// Listener sizing and authentication for a [`WireServer`].
#[derive(Clone, Debug)]
pub struct WireConfig {
    /// Address to bind (`host:port`; port 0 picks a free one — the bound
    /// address is printed and available via [`WireServer::local_addr`]).
    pub listen: String,
    /// Accepted-but-unserviced connection bound: the accept thread
    /// answers 503 beyond it instead of queueing invisibly.
    pub max_conns: usize,
    /// Connection worker threads — the bound on concurrently *serviced*
    /// connections.
    pub conn_workers: usize,
    /// Bearer-token → tenant-id map. Empty falls back to `tenants`
    /// development tokens.
    pub auth: Vec<(String, u64)>,
    /// With no explicit `auth`, issue dev tokens `tenant-0..tenant-N-1`.
    pub tenants: u64,
    /// `Retry-After` seconds on 429/503 responses.
    pub retry_after_secs: u64,
    /// Per-request body cap (bytes).
    pub max_body_bytes: usize,
    /// Per-tenant sustained request rate (requests/second; 0 turns the
    /// limiter off). Enforced as one token bucket per authenticated
    /// tenant, aggregated across every connection that tenant holds, so
    /// a chatty tenant cannot starve the workers — or dodge the limit —
    /// by fanning out over many connections.
    pub rate_limit: f64,
    /// Token-bucket capacity: requests one tenant may issue back-to-back
    /// (across all of its connections) before the sustained rate applies.
    pub rate_burst: u32,
}

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig {
            listen: "127.0.0.1:0".into(),
            max_conns: 32,
            conn_workers: 8,
            auth: Vec::new(),
            tenants: 4,
            retry_after_secs: 1,
            max_body_bytes: HttpLimits::default().max_body_bytes,
            rate_limit: 0.0,
            rate_burst: 8,
        }
    }
}

impl WireConfig {
    /// Read the `[wire]` section, honoring the CLI shorthands `--listen`,
    /// `--max-conns`, `--conn-workers`, `--tenants` and `--rate-limit`
    /// (shorthands win over section values).
    ///
    /// ```text
    /// [wire]
    /// listen = "127.0.0.1:8700"
    /// max_conns = 32
    /// conn_workers = 8
    /// auth = "s3cret:0,t0ken:1"   # token:tenant pairs; unset = dev tokens
    /// retry_after_secs = 1
    /// rate_limit = 0.0            # per-tenant requests/second (0 = off)
    /// rate_burst = 8              # back-to-back allowance per tenant
    /// ```
    pub fn from_config(cfg: &Config) -> anyhow::Result<Self> {
        let d = WireConfig::default();
        let auth_str = cfg.str_or("wire.auth", "");
        let mut auth = Vec::new();
        for pair in auth_str.split(',').filter(|p| !p.trim().is_empty()) {
            let (token, id) = pair.trim().split_once(':').ok_or_else(|| {
                anyhow::anyhow!("wire.auth entry {pair:?} is not token:tenant")
            })?;
            let id: u64 = id
                .parse()
                .map_err(|_| anyhow::anyhow!("wire.auth tenant {id:?} is not a number"))?;
            auth.push((token.to_string(), id));
        }
        Ok(WireConfig {
            listen: cfg.str_or("listen", &cfg.str_or("wire.listen", &d.listen)),
            max_conns: cfg.or("max-conns", cfg.or("wire.max_conns", d.max_conns)?)?,
            conn_workers: cfg
                .or("conn-workers", cfg.or("wire.conn_workers", d.conn_workers)?)?,
            auth,
            tenants: cfg.or("tenants", cfg.or("wire.tenants", d.tenants)?)?,
            retry_after_secs: cfg.or("wire.retry_after_secs", d.retry_after_secs)?,
            max_body_bytes: cfg.or("wire.max_body_bytes", d.max_body_bytes)?,
            rate_limit: cfg.or("rate-limit", cfg.or("wire.rate_limit", d.rate_limit)?)?,
            rate_burst: cfg.or("wire.rate_burst", d.rate_burst)?,
        })
    }

    /// The effective token → tenant map: explicit `auth` pairs, or the
    /// `tenant-0..tenant-N-1` development tokens.
    pub fn auth_map(&self) -> BTreeMap<String, u64> {
        if self.auth.is_empty() {
            (0..self.tenants.max(1)).map(|i| (format!("tenant-{i}"), i)).collect()
        } else {
            self.auth.iter().cloned().collect()
        }
    }
}

/// State shared by the accept thread and every connection worker.
struct WireShared {
    server: Server,
    /// Clone of the server's registry handle — dropped before the inner
    /// [`Server::drain`] so its `Arc::try_unwrap` still succeeds.
    metrics: Arc<Mutex<Metrics>>,
    auth: BTreeMap<String, u64>,
    conns: BoundedQueue<TcpStream>,
    shutdown: AtomicBool,
    conns_open: AtomicI64,
    shutdown_signal: (Mutex<bool>, Condvar),
    retry_after_secs: u64,
    limits: HttpLimits,
    rate_limit: f64,
    rate_burst: u32,
    /// One token bucket per authenticated tenant, lazily created on the
    /// tenant's first request and shared by all of its connections.
    buckets: Mutex<HashMap<u64, TokenBucket>>,
}

/// Per-tenant token bucket: `rate` tokens/second sustained, `burst`
/// capacity, one token per request. An empty bucket reports the seconds
/// (rounded up, at least 1) until the next token accrues — the value the
/// 429 response carries as `Retry-After`.
struct TokenBucket {
    tokens: f64,
    burst: f64,
    rate: f64,
    last: Instant,
}

impl TokenBucket {
    fn new(rate: f64, burst: u32) -> TokenBucket {
        let burst = f64::from(burst.max(1));
        TokenBucket { tokens: burst, burst, rate, last: Instant::now() }
    }

    fn admit(&mut self) -> Result<(), u64> {
        let now = Instant::now();
        self.tokens = (self.tokens + now.duration_since(self.last).as_secs_f64() * self.rate)
            .min(self.burst);
        self.last = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            Ok(())
        } else {
            Err(((1.0 - self.tokens) / self.rate).ceil().max(1.0) as u64)
        }
    }
}

impl WireShared {
    fn meter<R>(&self, f: impl FnOnce(&mut Metrics) -> R) -> R {
        f(&mut self.metrics.lock().unwrap())
    }

    /// Spend one token from `tenant`'s bucket (creating it at full burst
    /// on first sight). `Err` carries the `Retry-After` seconds. With the
    /// limiter off (`rate_limit <= 0`) every request is admitted and no
    /// bucket is allocated.
    fn admit_tenant(&self, tenant: u64) -> Result<(), u64> {
        if self.rate_limit <= 0.0 {
            return Ok(());
        }
        self.buckets
            .lock()
            .unwrap()
            .entry(tenant)
            .or_insert_with(|| TokenBucket::new(self.rate_limit, self.rate_burst))
            .admit()
    }

    fn count_status(&self, status: u16) {
        self.meter(|m| m.inc(&format!("http_{status}"), 1));
    }

    fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let (lock, cv) = &self.shutdown_signal;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }
}

/// The wire front end: owns the inner [`Server`], the accept thread and
/// the connection workers. Drive it with [`WireServer::wait_for_shutdown`]
/// + [`WireServer::drain`].
pub struct WireServer {
    shared: Arc<WireShared>,
    addr: SocketAddr,
    accept_thread: Option<JoinHandle<()>>,
    conn_threads: Vec<JoinHandle<()>>,
}

impl WireServer {
    /// Bind the listener and start serving `server` over it.
    pub fn start(server: Server, cfg: &WireConfig) -> anyhow::Result<WireServer> {
        let listener = TcpListener::bind(&cfg.listen)
            .map_err(|e| anyhow::anyhow!("binding {}: {e}", cfg.listen))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| anyhow::anyhow!("nonblocking listener: {e}"))?;
        let addr = listener.local_addr().map_err(|e| anyhow::anyhow!("local_addr: {e}"))?;

        let shared = Arc::new(WireShared {
            metrics: server.metrics_handle(),
            server,
            auth: cfg.auth_map(),
            conns: BoundedQueue::new(cfg.max_conns.max(1), QueuePolicy::Reject),
            shutdown: AtomicBool::new(false),
            conns_open: AtomicI64::new(0),
            shutdown_signal: (Mutex::new(false), Condvar::new()),
            retry_after_secs: cfg.retry_after_secs,
            limits: HttpLimits {
                max_body_bytes: cfg.max_body_bytes,
                ..HttpLimits::default()
            },
            rate_limit: cfg.rate_limit,
            rate_burst: cfg.rate_burst,
            buckets: Mutex::new(HashMap::new()),
        });

        let accept_thread = {
            let shared = Arc::clone(&shared);
            Some(std::thread::spawn(move || accept_loop(&listener, &shared)))
        };
        let conn_threads = (0..cfg.conn_workers.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || {
                    while let Some(stream) = shared.conns.pop() {
                        handle_connection(&shared, stream);
                    }
                })
            })
            .collect();

        Ok(WireServer { shared, addr, accept_thread, conn_threads })
    }

    /// The bound listen address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request shutdown from any thread — same effect as a wire
    /// `POST /v1/shutdown`. Idempotent; does not block.
    pub fn shutdown(&self) {
        self.shared.request_shutdown();
    }

    /// Block until shutdown is requested (wire or [`WireServer::shutdown`]).
    pub fn wait_for_shutdown(&self) {
        let (lock, cv) = &self.shared.shutdown_signal;
        let mut requested = lock.lock().unwrap();
        while !*requested {
            requested = cv.wait(requested).unwrap();
        }
    }

    /// Graceful teardown: stop accepting, let every serviced connection
    /// and every admitted job finish, then drain the inner server and
    /// return the combined metrics (wire counters and job histograms live
    /// in the same registry).
    pub fn drain(mut self) -> Metrics {
        self.shared.request_shutdown();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        self.shared.conns.close();
        for t in std::mem::take(&mut self.conn_threads) {
            let _ = t.join();
        }
        match Arc::try_unwrap(self.shared) {
            Ok(shared) => {
                let WireShared { server, metrics, conns_open, .. } = shared;
                debug_assert_eq!(conns_open.load(Ordering::Relaxed), 0);
                // the front end's registry clone must die before drain's
                // Arc::try_unwrap inside the inner server
                drop(metrics);
                server.drain()
            }
            // unreachable once every thread is joined; degrade to a
            // snapshot rather than panicking in teardown
            Err(shared) => {
                shared.server.close();
                shared.server.metrics_snapshot()
            }
        }
    }
}

/// Accept loop: nonblocking accepts with a shutdown-checking sleep, and
/// overload shedding when the connection queue is at `max_conns`.
fn accept_loop(listener: &TcpListener, shared: &WireShared) {
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nodelay(true);
                shared.meter(|m| m.inc("conns_accepted", 1));
                match shared.conns.push(stream) {
                    Ok(()) => {}
                    Err(PushError::Full(mut stream)) => {
                        // shed at the door: the client learns immediately
                        shared.count_status(503);
                        let _ = write_response(
                            &mut stream,
                            503,
                            &[
                                ("retry-after", shared.retry_after_secs.to_string()),
                                ("connection", "close".to_string()),
                            ],
                            b"connection limit reached\n",
                        );
                    }
                    Err(PushError::Closed(_)) => break,
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Serve one connection to completion: keep-alive request loop with an
/// idle tick that watches the shutdown flag.
fn handle_connection(shared: &WireShared, stream: TcpStream) {
    let open = shared.conns_open.fetch_add(1, Ordering::SeqCst) + 1;
    shared.meter(|m| m.set_gauge("conns_open", open as f64));
    serve_connection(shared, stream);
    let open = shared.conns_open.fetch_sub(1, Ordering::SeqCst) - 1;
    shared.meter(|m| m.set_gauge("conns_open", open as f64));
}

fn serve_connection(shared: &WireShared, stream: TcpStream) {
    if stream.set_read_timeout(Some(IDLE_TICK)).is_err() {
        return;
    }
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        // Idle phase: wait for the first byte of a request (or EOF), so
        // keep-alive idle time never counts against request parsing and
        // the shutdown flag is polled every tick.
        match reader.fill_buf() {
            Ok([]) => return, // peer closed
            Ok(_) => {}
            Err(e)
                if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
            {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(_) => return,
        }
        match read_request(&mut reader, &shared.limits) {
            Ok(req) => {
                shared.meter(|m| m.inc("bytes_in", req.bytes_read as u64));
                let keep_alive = req.keep_alive();
                if handle_request(shared, &req, &mut writer).is_err() {
                    return; // write side failed; connection unusable
                }
                if !keep_alive || shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(e) => {
                if let Some(status) = e.status() {
                    shared.count_status(status);
                    let _ = write_response(
                        &mut writer,
                        status,
                        &[("connection", "close".to_string())],
                        format!("{e}\n").as_bytes(),
                    );
                }
                return;
            }
        }
    }
}

/// Route one parsed request and write its response. `Err` means the
/// transport failed mid-response and the connection must be dropped.
fn handle_request(
    shared: &WireShared,
    req: &Request,
    w: &mut TcpStream,
) -> io::Result<()> {
    shared.meter(|m| m.inc("requests", 1));
    let started = Instant::now();
    let written = match (req.method.as_str(), req.target.as_str()) {
        ("GET", "/healthz") => respond(shared, w, 200, &[], b"ok\n")?,
        (_, "/healthz") => method_not_allowed(shared, w, "GET")?,
        (method, target) => {
            // everything else requires a tenant token
            let token = req
                .header("authorization")
                .and_then(|v| v.strip_prefix("Bearer "))
                .map(str::trim);
            let tenant = token.and_then(|t| shared.auth.get(t).copied());
            // Rate limit after authentication, before routing: a drained
            // tenant bucket sheds the request with 429 + the exact wait,
            // spends no ε, and keeps the connection alive for the retry.
            let admitted = tenant.map(|t| shared.admit_tenant(t).map(|()| t));
            match (method, target, admitted) {
                (_, _, None) => {
                    respond(shared, w, 401, &[], b"unknown or missing bearer token\n")?
                }
                (_, _, Some(Err(secs))) => {
                    shared.meter(|m| m.inc("rate_limited", 1));
                    respond(
                        shared,
                        w,
                        429,
                        &[("retry-after", secs.to_string())],
                        b"per-tenant rate limit exceeded; retry later\n",
                    )?
                }
                ("GET", "/v1/metrics", Some(Ok(_))) => {
                    let body = shared.server.metrics_snapshot().to_json().to_string();
                    respond(
                        shared,
                        w,
                        200,
                        &[("content-type", "application/json".to_string())],
                        body.as_bytes(),
                    )?
                }
                ("POST", "/v1/shutdown", Some(Ok(_))) => {
                    shared.request_shutdown();
                    respond(shared, w, 200, &[], b"draining\n")?
                }
                ("POST", "/v1/jobs", Some(Ok(tenant))) => {
                    handle_job(shared, req, w, tenant)?
                }
                (_, "/v1/jobs", Some(Ok(_))) => method_not_allowed(shared, w, "POST")?,
                (_, "/v1/metrics", Some(Ok(_))) => method_not_allowed(shared, w, "GET")?,
                (_, "/v1/shutdown", Some(Ok(_))) => method_not_allowed(shared, w, "POST")?,
                _ => respond(shared, w, 404, &[], b"unknown endpoint\n")?,
            }
        }
    };
    shared.meter(|m| {
        m.inc("bytes_out", written as u64);
        m.observe("wire_request", started.elapsed());
    });
    Ok(())
}

/// POST /v1/jobs: parse → submit → wait → stream. Every refusal maps to
/// the backpressure ladder in the module docs, and no refusal spends ε.
fn handle_job(
    shared: &WireShared,
    req: &Request,
    w: &mut TcpStream,
    tenant: u64,
) -> io::Result<usize> {
    let Ok(body) = std::str::from_utf8(&req.body) else {
        shared.meter(|m| m.inc("parse_errors", 1));
        return respond(shared, w, 400, &[], b"request body is not UTF-8\n");
    };
    let spec = match parse_job_spec(body, tenant) {
        Ok(spec) => spec,
        Err(e) => {
            shared.meter(|m| m.inc("parse_errors", 1));
            return respond(shared, w, 400, &[], format!("{e}\n").as_bytes());
        }
    };
    let ticket = match shared.server.submit(spec) {
        Ok(t) => t,
        Err(SubmitError::QueueFull { depth }) => {
            return respond(
                shared,
                w,
                429,
                &[("retry-after", shared.retry_after_secs.to_string())],
                format!("queue full (depth {depth}); retry later\n").as_bytes(),
            );
        }
        Err(SubmitError::Draining) => {
            return respond(
                shared,
                w,
                503,
                &[("retry-after", shared.retry_after_secs.to_string())],
                b"server draining\n",
            );
        }
        Err(SubmitError::Budget(e)) => {
            return respond(shared, w, 403, &[], format!("{e}\n").as_bytes());
        }
    };
    let job_id = ticket.job_id;
    let result = ticket.wait();
    match result.outcome {
        Err(e) => respond(
            shared,
            w,
            500,
            &[("x-job-id", job_id.to_string())],
            format!("job failed: {e:#}\n").as_bytes(),
        ),
        Ok(outcome) => {
            // Stream the outcome chunked: job id and wall-clock ride as
            // headers so the body stays byte-deterministic per seed.
            shared.count_status(200);
            let mut cw = ChunkedWriter::begin(
                w,
                200,
                &[
                    ("content-type", "application/json".to_string()),
                    ("x-job-id", job_id.to_string()),
                    (
                        "x-duration-us",
                        (outcome.total_time.as_micros() as u64).to_string(),
                    ),
                ],
            )?;
            write_outcome_chunked(result.kind, &outcome, &mut cw)?;
            cw.finish()
        }
    }
}

/// Fixed-length response + status metering. Returns bytes written.
fn respond(
    shared: &WireShared,
    w: &mut TcpStream,
    status: u16,
    extra: &[(&str, String)],
    body: &[u8],
) -> io::Result<usize> {
    shared.count_status(status);
    write_response(w, status, extra, body)
}

fn method_not_allowed(
    shared: &WireShared,
    w: &mut TcpStream,
    allow: &str,
) -> io::Result<usize> {
    respond(
        shared,
        w,
        405,
        &[("allow", allow.to_string())],
        format!("method not allowed (use {allow})\n").as_bytes(),
    )
}

/// What a [`WireClient`] request came back with.
#[derive(Debug)]
pub struct WireResponse {
    /// HTTP status code.
    pub status: u16,
    /// Response headers, names lowercased.
    pub headers: Vec<(String, String)>,
    /// The fully read body (chunked bodies are de-framed).
    pub body: Vec<u8>,
    /// Number of body chunks received (1 for `Content-Length` framing) —
    /// lets tests assert a response actually streamed.
    pub chunks: usize,
}

impl WireResponse {
    /// First value of a header, by case-insensitive name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// The body as UTF-8 (lossy).
    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// Minimal blocking HTTP/1.1 client for the wire protocol — one
/// keep-alive connection per instance. Shared by the integration tests,
/// the serving bench's wire axis, the example and the soak driver, so
/// every consumer speaks the protocol the same way.
pub struct WireClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl WireClient {
    /// Connect to a wire server.
    pub fn connect(addr: &str) -> io::Result<WireClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let read_half = stream.try_clone()?;
        Ok(WireClient { reader: BufReader::new(read_half), writer: stream })
    }

    /// Send one request and read the full response. `token` becomes a
    /// `Bearer` header when present; `body` implies `Content-Length`.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        token: Option<&str>,
        body: Option<&str>,
    ) -> io::Result<WireResponse> {
        let mut head = format!("{method} {path} HTTP/1.1\r\nhost: wire\r\n");
        if let Some(t) = token {
            head.push_str(&format!("authorization: Bearer {t}\r\n"));
        }
        if let Some(b) = body {
            head.push_str(&format!("content-length: {}\r\n", b.len()));
        }
        head.push_str("\r\n");
        self.writer.write_all(head.as_bytes())?;
        if let Some(b) = body {
            self.writer.write_all(b.as_bytes())?;
        }
        self.writer.flush()?;
        self.read_response()
    }

    /// `POST /v1/jobs` with a spec body.
    pub fn post_job(&mut self, token: &str, spec: &str) -> io::Result<WireResponse> {
        self.request("POST", "/v1/jobs", Some(token), Some(spec))
    }

    /// Authenticated GET.
    pub fn get(&mut self, path: &str, token: Option<&str>) -> io::Result<WireResponse> {
        self.request("GET", path, token, None)
    }

    fn read_line(&mut self) -> io::Result<String> {
        let mut line = Vec::new();
        self.reader.read_until(b'\n', &mut line)?;
        if line.is_empty() {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "connection closed mid-response",
            ));
        }
        while matches!(line.last(), Some(b'\n' | b'\r')) {
            line.pop();
        }
        String::from_utf8(line)
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "non-UTF-8 line"))
    }

    fn read_response(&mut self) -> io::Result<WireResponse> {
        use std::io::Read;
        let status_line = self.read_line()?;
        let status: u16 = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("bad status line {status_line:?}"),
                )
            })?;
        let mut headers = Vec::new();
        loop {
            let line = self.read_line()?;
            if line.is_empty() {
                break;
            }
            if let Some((k, v)) = line.split_once(':') {
                headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
            }
        }
        let header = |name: &str| {
            headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
        };
        let mut body = Vec::new();
        let mut chunks = 0usize;
        if header("transfer-encoding").is_some_and(|v| v.contains("chunked")) {
            loop {
                let size_line = self.read_line()?;
                let size = usize::from_str_radix(size_line.trim(), 16).map_err(|_| {
                    io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("bad chunk size {size_line:?}"),
                    )
                })?;
                if size == 0 {
                    self.read_line()?; // the terminal CRLF
                    break;
                }
                let start = body.len();
                body.resize(start + size, 0);
                self.reader.read_exact(&mut body[start..])?;
                let mut crlf = [0u8; 2];
                self.reader.read_exact(&mut crlf)?;
                chunks += 1;
            }
        } else if let Some(cl) = header("content-length") {
            let len: usize = cl.parse().map_err(|_| {
                io::Error::new(io::ErrorKind::InvalidData, "bad content-length")
            })?;
            body.resize(len, 0);
            self.reader.read_exact(&mut body)?;
            chunks = usize::from(len > 0);
        }
        Ok(WireResponse { status, headers, body, chunks })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::ServerConfig;

    fn tiny_server(cfg: ServerConfig) -> WireServer {
        let server = Server::start(cfg);
        WireServer::start(server, &WireConfig::default()).expect("bind loopback")
    }

    #[test]
    fn healthz_and_auth_do_not_require_jobs() {
        let wire = tiny_server(ServerConfig {
            workers: 1,
            cache_capacity: 0,
            ..ServerConfig::default()
        });
        let addr = wire.local_addr().to_string();
        let mut c = WireClient::connect(&addr).unwrap();
        let r = c.get("/healthz", None).unwrap();
        assert_eq!((r.status, r.body_str().as_str()), (200, "ok\n"));
        // same keep-alive connection: unauthenticated API call
        let r = c.get("/v1/metrics", None).unwrap();
        assert_eq!(r.status, 401);
        let r = c.get("/v1/metrics", Some("tenant-0")).unwrap();
        assert_eq!(r.status, 200);
        assert!(r.body_str().contains("counters"));
        let r = c.get("/nope", Some("tenant-0")).unwrap();
        assert_eq!(r.status, 404);
        let r = c.request("PUT", "/v1/jobs", Some("tenant-0"), None).unwrap();
        assert_eq!(r.status, 405);
        assert_eq!(r.header("allow"), Some("POST"));

        wire.shutdown();
        let m = wire.drain();
        assert_eq!(m.counter("conns_accepted"), 1);
        assert_eq!(m.counter("http_401"), 1);
        assert!(m.counter("bytes_in") > 0 && m.counter("bytes_out") > 0);
        assert_eq!(m.gauge("conns_open"), Some(0.0), "clean drain closes all conns");
    }

    #[test]
    fn wire_shutdown_endpoint_unblocks_wait() {
        let wire = tiny_server(ServerConfig {
            workers: 1,
            cache_capacity: 0,
            ..ServerConfig::default()
        });
        let addr = wire.local_addr().to_string();
        let waiter = {
            let mut c = WireClient::connect(&addr).unwrap();
            std::thread::spawn(move || c.request("POST", "/v1/shutdown", Some("tenant-1"), None))
        };
        wire.wait_for_shutdown();
        let r = waiter.join().unwrap().unwrap();
        assert_eq!((r.status, r.body_str().as_str()), (200, "draining\n"));
        wire.drain();
    }

    #[test]
    fn token_bucket_admits_burst_then_meters_with_wait_hint() {
        let mut b = TokenBucket::new(0.5, 2);
        assert!(b.admit().is_ok());
        assert!(b.admit().is_ok());
        let secs = b.admit().expect_err("bucket drained after the burst");
        assert!(secs >= 1, "Retry-After must be at least one second");
        // backdate the bucket by 4 seconds: 2 tokens accrue at 0.5/s
        let Some(earlier) = b.last.checked_sub(Duration::from_secs(4)) else { return };
        b.last = earlier;
        assert!(b.admit().is_ok());
        assert!(b.admit().is_ok());
        assert!(b.admit().is_err(), "refill is capped at the burst size");
    }

    #[test]
    fn wire_config_from_config_parses_auth_and_shorthands() {
        let mut cfg = Config::parse(
            "[wire]\nlisten = \"127.0.0.1:9999\"\nmax_conns = 7\n\
             auth = \"s3cret:0, t0ken:12\"\n",
        )
        .unwrap();
        let w = WireConfig::from_config(&cfg).unwrap();
        assert_eq!(w.listen, "127.0.0.1:9999");
        assert_eq!(w.max_conns, 7);
        assert_eq!(w.auth_map(), BTreeMap::from([("s3cret".into(), 0), ("t0ken".into(), 12)]));

        cfg.apply_overrides(["--listen=0.0.0.0:80", "--max-conns=3"]).unwrap();
        let w = WireConfig::from_config(&cfg).unwrap();
        assert_eq!((w.listen.as_str(), w.max_conns), ("0.0.0.0:80", 3));

        // rate-limit knobs: section values, with the --rate-limit shorthand
        let mut cfg =
            Config::parse("[wire]\nrate_limit = 2.5\nrate_burst = 3\n").unwrap();
        let w = WireConfig::from_config(&cfg).unwrap();
        assert_eq!((w.rate_limit, w.rate_burst), (2.5, 3));
        cfg.apply_overrides(["--rate-limit=0.5"]).unwrap();
        assert_eq!(WireConfig::from_config(&cfg).unwrap().rate_limit, 0.5);

        let d = WireConfig::from_config(&Config::new()).unwrap();
        assert_eq!(d.listen, "127.0.0.1:0");
        assert_eq!((d.rate_limit, d.rate_burst), (0.0, 8), "limiter defaults off");
        assert_eq!(d.auth_map().len(), 4, "dev tokens tenant-0..3");
        assert_eq!(d.auth_map().get("tenant-2"), Some(&2));

        assert!(WireConfig::from_config(
            &Config::parse("[wire]\nauth = \"no-colon\"\n").unwrap()
        )
        .is_err());
    }
}
