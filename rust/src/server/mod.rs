//! Long-lived concurrent serving runtime (DESIGN.md §8).
//!
//! Where [`crate::coordinator::Coordinator`] is a batch harness — submit a
//! known set of jobs, `finish()`, tear the pool down — this module is the
//! steady-state request path the ROADMAP's serving north-star asks for: a
//! bounded MPMC [`BoundedQueue`] with configurable backpressure
//! ([`QueuePolicy`]) feeding persistent workers that share the tiered
//! warm-index cache ([`crate::store::TieredIndexCache`], DESIGN.md §6–§7),
//! fronted by per-tenant privacy accountants ([`TenantBudget`]) that admit
//! or deny every job against its tenant's ε cap *before* it runs and
//! atomically refund reservations on failure. Submitters get a
//! [`JobTicket`] per accepted job; [`Server::drain`] shuts down gracefully
//! — in-flight jobs complete, new work is refused — and reports per-kind
//! latency histograms (p50/p95/p99) plus per-tenant spend.

pub mod budget;
pub mod queue;
pub mod runtime;

pub use budget::{AdmissionError, TenantBudget, TenantSpend};
pub use queue::{BoundedQueue, PushError, QueuePolicy};
pub use runtime::{JobTicket, Server, ServerConfig, SubmitError};
