//! Long-lived concurrent serving runtime (DESIGN.md §8).
//!
//! Where [`crate::coordinator::Coordinator`] is a batch harness — submit a
//! known set of jobs, `finish()`, tear the pool down — this module is the
//! steady-state request path the ROADMAP's serving north-star asks for: a
//! bounded MPMC [`BoundedQueue`] with configurable backpressure
//! ([`QueuePolicy`]) feeding persistent workers that share the tiered
//! warm-index cache ([`crate::store::TieredIndexCache`], DESIGN.md §6–§7),
//! fronted by per-tenant privacy accountants ([`TenantBudget`]) that admit
//! or deny every job against its tenant's ε cap *before* it runs and
//! atomically refund reservations on failure. Submitters get a
//! [`JobTicket`] per accepted job; [`Server::drain`] shuts down gracefully
//! — in-flight jobs complete, new work is refused — and reports per-kind
//! latency histograms (p50/p95/p99) plus per-tenant spend.
//!
//! The network face of this runtime (DESIGN.md §11) lives in three
//! layers: [`http`] (HTTP/1.1 framing with hard caps and chunked
//! streaming), [`proto`] (one-pass job-spec parsing and the shared
//! outcome encoder), and [`wire`] (the [`WireServer`] listener that turns
//! authenticated sockets into [`Server::submit`] calls, plus the
//! [`WireClient`] the tests, benches and soak driver all speak through).

pub mod budget;
pub mod http;
pub mod proto;
pub mod queue;
pub mod runtime;
pub mod wire;

pub use budget::{AdmissionError, TenantBudget, TenantSpend};
pub use http::{HttpError, HttpLimits};
pub use proto::{outcome_body_string, parse_job_spec};
pub use queue::{BoundedQueue, PushError, QueuePolicy};
pub use runtime::{JobTicket, Server, ServerConfig, SubmitError};
pub use wire::{WireClient, WireConfig, WireResponse, WireServer};
