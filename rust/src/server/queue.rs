//! Bounded MPMC job queue with configurable backpressure (DESIGN.md §8).
//!
//! The serving runtime's spine: any number of submitter threads `push`,
//! any number of persistent workers `pop`. The queue is bounded at a
//! configurable `depth`; what happens at the bound is the backpressure
//! [`QueuePolicy`] — block the submitter until a worker frees a slot, or
//! fail fast and hand the item straight back. `close()` flips the queue
//! into drain mode: new pushes are refused, pops keep serving whatever is
//! already queued, and once empty every blocked consumer wakes with
//! `None` — the graceful-shutdown contract of
//! [`crate::server::Server::drain`].

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Backpressure policy: what [`BoundedQueue::push`] does when the queue is
/// at depth.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QueuePolicy {
    /// Block the submitter until a worker frees a slot (or the queue
    /// closes). Lossless; submission rate is clamped to service rate.
    #[default]
    Block,
    /// Refuse immediately, returning the item to the submitter as
    /// [`PushError::Full`]. The submitter sees the overload and can shed,
    /// retry, or route elsewhere.
    Reject,
}

impl std::str::FromStr for QueuePolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "block" => Ok(QueuePolicy::Block),
            "reject" => Ok(QueuePolicy::Reject),
            other => Err(format!(
                "unknown queue policy {other:?} (expected \"block\" or \"reject\")"
            )),
        }
    }
}

impl std::fmt::Display for QueuePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            QueuePolicy::Block => "block",
            QueuePolicy::Reject => "reject",
        })
    }
}

/// Why a push did not enqueue. The rejected item rides back so the caller
/// can undo side effects (the server refunds the admission reservation).
#[derive(Debug)]
pub enum PushError<T> {
    /// Queue at depth under [`QueuePolicy::Reject`].
    Full(T),
    /// Queue closed (server draining) — no new work is accepted.
    Closed(T),
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded multi-producer multi-consumer queue (mutex + two condvars; the
/// offline build vendors no crossbeam — see DESIGN.md §3).
pub struct BoundedQueue<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    depth: usize,
    policy: QueuePolicy,
}

impl<T> BoundedQueue<T> {
    /// A queue bounded at `depth` items (clamped to ≥ 1) with the given
    /// backpressure policy.
    pub fn new(depth: usize, policy: QueuePolicy) -> Self {
        BoundedQueue {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            depth: depth.max(1),
            policy,
        }
    }

    /// The configured depth bound.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// The configured backpressure policy.
    pub fn policy(&self) -> QueuePolicy {
        self.policy
    }

    /// Items currently queued (racy by nature; for metrics and tests).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    /// True when nothing is queued right now.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue `item`, applying the backpressure policy at the depth
    /// bound. Fails with [`PushError::Closed`] once [`close`] has been
    /// called (including while blocked waiting for a slot).
    ///
    /// [`close`]: BoundedQueue::close
    pub fn push(&self, item: T) -> Result<(), PushError<T>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(PushError::Closed(item));
            }
            if st.items.len() < self.depth {
                st.items.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            match self.policy {
                QueuePolicy::Reject => return Err(PushError::Full(item)),
                QueuePolicy::Block => st = self.not_full.wait(st).unwrap(),
            }
        }
    }

    /// Dequeue the oldest item, blocking while the queue is empty and
    /// open. Returns `None` only when the queue is closed *and* drained —
    /// in-flight work is never dropped.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Close the queue: refuse all future pushes, wake every blocked
    /// submitter (they see [`PushError::Closed`]) and every idle worker
    /// (they drain the backlog, then see `None`). Idempotent.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_order_within_bound() {
        let q = BoundedQueue::new(4, QueuePolicy::Reject);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 4);
        for i in 0..4 {
            assert_eq!(q.pop(), Some(i));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn reject_policy_fails_fast_at_depth_and_recovers() {
        let q = BoundedQueue::new(2, QueuePolicy::Reject);
        q.push(1).unwrap();
        q.push(2).unwrap();
        match q.push(3) {
            Err(PushError::Full(item)) => assert_eq!(item, 3, "item rides back"),
            other => panic!("expected Full, got {other:?}"),
        }
        // a pop frees a slot; the next push lands
        assert_eq!(q.pop(), Some(1));
        q.push(3).unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn block_policy_waits_for_a_slot() {
        let q = Arc::new(BoundedQueue::new(1, QueuePolicy::Block));
        q.push(10).unwrap();
        let (tx, rx) = mpsc::channel();
        let qc = Arc::clone(&q);
        let pusher = std::thread::spawn(move || {
            qc.push(11).unwrap(); // blocks: queue is full
            tx.send(()).unwrap();
        });
        // the pusher must still be blocked after a grace period
        assert!(
            rx.recv_timeout(Duration::from_millis(50)).is_err(),
            "push through a full Block queue must not complete"
        );
        assert_eq!(q.pop(), Some(10));
        rx.recv_timeout(Duration::from_secs(5)).expect("pop must unblock the pusher");
        pusher.join().unwrap();
        assert_eq!(q.pop(), Some(11));
    }

    #[test]
    fn close_refuses_new_work_but_drains_backlog() {
        let q = BoundedQueue::new(4, QueuePolicy::Block);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        match q.push(3) {
            Err(PushError::Closed(item)) => assert_eq!(item, 3),
            other => panic!("expected Closed, got {other:?}"),
        }
        assert_eq!(q.pop(), Some(1), "backlog survives close");
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None, "drained + closed => None");
        assert_eq!(q.pop(), None, "None is sticky");
    }

    #[test]
    fn close_wakes_blocked_pusher_and_idle_popper() {
        let q = Arc::new(BoundedQueue::new(1, QueuePolicy::Block));
        q.push(1).unwrap();
        let qp = Arc::clone(&q);
        let pusher = std::thread::spawn(move || qp.push(2));
        let qe = Arc::new(BoundedQueue::<u32>::new(1, QueuePolicy::Block));
        let qec = Arc::clone(&qe);
        let popper = std::thread::spawn(move || qec.pop());
        std::thread::sleep(Duration::from_millis(30));
        q.close();
        qe.close();
        match pusher.join().unwrap() {
            Err(PushError::Closed(2)) => {}
            other => panic!("blocked pusher must see Closed, got {other:?}"),
        }
        assert_eq!(popper.join().unwrap(), None, "idle popper must wake with None");
    }

    #[test]
    fn many_producers_many_consumers_deliver_everything_once() {
        let q = Arc::new(BoundedQueue::new(8, QueuePolicy::Block));
        let n_producers = 4;
        let per_producer = 50u64;
        let producers: Vec<_> = (0..n_producers)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..per_producer {
                        q.push(p * 1_000 + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> =
            consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        let mut want: Vec<u64> = (0..n_producers)
            .flat_map(|p| (0..per_producer).map(move |i| p * 1_000 + i))
            .collect();
        want.sort_unstable();
        assert_eq!(all, want, "every item delivered exactly once");
    }

    #[test]
    fn depth_zero_clamps_to_one() {
        let q = BoundedQueue::new(0, QueuePolicy::Reject);
        assert_eq!(q.depth(), 1);
        q.push(1).unwrap();
        assert!(matches!(q.push(2), Err(PushError::Full(2))));
    }

    #[test]
    fn policy_parses_and_displays() {
        assert_eq!("block".parse::<QueuePolicy>().unwrap(), QueuePolicy::Block);
        assert_eq!("reject".parse::<QueuePolicy>().unwrap(), QueuePolicy::Reject);
        assert!("drop".parse::<QueuePolicy>().is_err());
        assert_eq!(QueuePolicy::Reject.to_string(), "reject");
        assert_eq!(QueuePolicy::default(), QueuePolicy::Block);
    }
}
