//! `repro` — the Fast-MWEM coordinator CLI.
//!
//! Subcommands:
//!   eval <fig1..fig9|all> [--quick] [--out=DIR] [--seed=N]
//!       regenerate a paper figure (CSV + stdout table)
//!   release [--m=..] [--u=..] [--n=..] [--t=..] [--index=flat|ivf|hnsw|none]
//!           [--eps=..] [--delta=..] [--xla] run one private release job
//!   lp [--m=..] [--d=..] [--t=..] [--mode=exhaustive|flat|ivf|hnsw]
//!       run one scalar-private LP job
//!   serve [--jobs=N] [--workers=N] [--eps-cap=..] [--store-dir=PATH]
//!       drive the thread-pool coordinator with a batch of jobs
//!   check-artifacts [--dir=artifacts]
//!       load + compile + smoke-run every AOT artifact
//!
//! Flags may also come from a config file: `--config=path.toml` (the
//! key=value / [section] subset, see config/mod.rs).

use anyhow::{bail, Context, Result};
use fast_mwem::config::{CacheConfig, Config, ShardingConfig, StoreConfig};
use fast_mwem::coordinator::{Coordinator, CoordinatorConfig, JobSpec, LpJobSpec, ReleaseJobSpec};
use fast_mwem::eval::{self, EvalOpts};
use fast_mwem::lp::{run_scalar, ScalarLpConfig, SelectionMode};
use fast_mwem::mips::IndexKind;
use fast_mwem::mwem::{run_classic, run_fast, FastMwemConfig, MwemConfig, NativeBackend};
use fast_mwem::runtime::{XlaBackend, XlaEngine};
use fast_mwem::util::rng::Rng;
use fast_mwem::workloads;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_flags(args: &[String]) -> Result<(Vec<String>, Config)> {
    let mut positional = Vec::new();
    let mut cfg = Config::new();
    for a in args {
        if let Some(rest) = a.strip_prefix("--") {
            if let Some((k, v)) = rest.split_once('=') {
                if k == "config" {
                    let file = Config::from_file(v)?;
                    for key in file.keys().map(str::to_string).collect::<Vec<_>>() {
                        if cfg.get_str(&key).is_none() {
                            cfg.set(&key, file.str_or(&key, ""));
                        }
                    }
                } else {
                    cfg.set(k, v);
                }
            } else {
                cfg.set(rest, "true"); // bare flag
            }
        } else {
            positional.push(a.clone());
        }
    }
    Ok((positional, cfg))
}

fn run(args: &[String]) -> Result<()> {
    let (pos, cfg) = parse_flags(args)?;
    let cmd = pos.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "eval" => cmd_eval(&pos, &cfg),
        "release" => cmd_release(&cfg),
        "lp" => cmd_lp(&cfg),
        "serve" => cmd_serve(&cfg),
        "check-artifacts" => cmd_check_artifacts(&cfg),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `repro help`"),
    }
}

const HELP: &str = "\
repro — Fast-MWEM reproduction CLI

USAGE:
  repro eval <fig1..fig9|shards|all> [--quick] [--out=DIR] [--seed=N] [--shards=S]
  repro release [--m=1000] [--u=1024] [--n=500] [--t=2000]
                [--index=hnsw|ivf|flat|none] [--eps=1.0] [--delta=1e-3]
                [--shards=S] [--xla]
  repro lp [--m=20000] [--d=20] [--t=2000] [--mode=hnsw|ivf|flat|exhaustive]
           [--shards=S]
  repro serve [--jobs=8] [--workers=4] [--eps-cap=N] [--shards=S]
              [--workloads=W] [--cache-capacity=C] [--store-dir=PATH]
  repro check-artifacts [--dir=artifacts]

Sharding (DESIGN.md §5): --shards=S (or a [sharding] config section) splits
the lazy EM across S per-shard indices, built in parallel on the pool.

Warm-index serving (DESIGN.md §6): the coordinator keeps up to C pre-built
k-MIPS indices resident (--cache-capacity=C, or a [cache] section;
0 disables). `serve` spreads its release jobs across W distinct workloads
(--workloads=W, default 2) so repeats hit the cache and skip index builds.

Persistent artifact store (DESIGN.md §7): --store-dir=PATH (or a [store]
config section) snapshots built indices to disk, so a restarted `serve`
against the same directory restores them (store_hit metric) instead of
rebuilding — warm serving that survives restarts.
";

fn cmd_eval(pos: &[String], cfg: &Config) -> Result<()> {
    let which = pos.get(1).map(String::as_str).unwrap_or("all");
    let opts = EvalOpts {
        quick: cfg.get_str("quick").is_some(),
        out_dir: cfg.str_or("out", "results").into(),
        seed: cfg.or("seed", 20260204u64)?,
        shards: ShardingConfig::from_config(cfg)?.shards,
    };
    eval::run(which, &opts)
}

fn cmd_release(cfg: &Config) -> Result<()> {
    let u: usize = cfg.or("u", 1024)?;
    let m: usize = cfg.or("m", 1000)?;
    let n: usize = cfg.or("n", 500)?;
    let t: usize = cfg.or("t", 2000)?;
    let eps: f64 = cfg.or("eps", 1.0)?;
    let delta: f64 = cfg.or("delta", 1e-3)?;
    let seed: u64 = cfg.or("seed", 1u64)?;
    let index = cfg.str_or("index", "hnsw");
    let use_xla = cfg.get_str("xla").is_some();
    let sharding = ShardingConfig::from_config(cfg)?;

    let mut rng = Rng::new(seed);
    let h = workloads::gaussian_histogram(&mut rng, u, n);
    let q = workloads::binary_queries(&mut rng, m, u);
    let mut mwem_cfg = MwemConfig::paper(t, u, eps, delta, seed ^ 7);
    mwem_cfg.log_every = (t / 10).max(1);

    if index == "none" && sharding.shards > 1 {
        println!("note: --shards only applies to Fast-MWEM; ignored with --index=none");
    }
    println!(
        "release: U={u} m={m} n={n} T={t} eps={eps} index={index} shards={} xla={use_xla}",
        if index == "none" { 1 } else { sharding.shards }
    );
    let p0 = vec![1.0 / u as f32; u];
    println!("initial max error: {:.4}", q.max_error(h.probs(), &p0));

    let mut native = NativeBackend;
    let mut xla_backend;
    let backend: &mut dyn fast_mwem::mwem::MwemBackend = if use_xla {
        let dir = cfg.str_or("artifacts", "artifacts");
        xla_backend = XlaBackend::load(dir).context("loading XLA artifacts")?;
        &mut xla_backend
    } else {
        &mut native
    };

    let (result, extra) = if index == "none" {
        (run_classic(&mwem_cfg, &q, &h, backend), None)
    } else {
        let kind: IndexKind = index.parse().map_err(|e: String| anyhow::anyhow!(e))?;
        let out = run_fast(
            &FastMwemConfig::new(mwem_cfg, kind).with_sharding(sharding),
            &q,
            &h,
            backend,
        );
        (out.result, Some(out.lazy))
    };

    for s in &result.stats {
        println!(
            "  iter {:>6}  max_error(avg) {:.4}  work {:>8}",
            s.iter, s.max_error_avg, s.selection_work
        );
    }
    println!("final max error (avg p̂): {:.4}", q.max_error(h.probs(), &result.p_avg));
    println!(
        "per-iter selection: {:.1}us, work {:.0} score-evals (m={m})",
        result.avg_select_time.as_secs_f64() * 1e6,
        result.avg_select_work,
    );
    if let Some(lazy) = extra {
        let mean_c: f64 =
            lazy.tail_counts.iter().sum::<usize>() as f64 / lazy.tail_counts.len().max(1) as f64;
        println!("index build {:.2}s, mean tail C {:.1}", lazy.build_time.as_secs_f64(), mean_c);
    }
    println!(
        "privacy spent: eps={:.3} delta={:.1e} (budget eps={eps} delta={delta:.1e})",
        result.privacy_spent.0, result.privacy_spent.1
    );
    Ok(())
}

fn cmd_lp(cfg: &Config) -> Result<()> {
    let m: usize = cfg.or("m", 20_000)?;
    let d: usize = cfg.or("d", 20)?;
    let t: usize = cfg.or("t", 2_000)?;
    let seed: u64 = cfg.or("seed", 1u64)?;
    let sharding = ShardingConfig::from_config(cfg)?;
    let mode = match cfg.str_or("mode", "hnsw").as_str() {
        "exhaustive" => {
            if sharding.shards > 1 {
                println!("note: --shards only applies to lazy modes; ignored with --mode=exhaustive");
            }
            SelectionMode::Exhaustive
        }
        other => {
            let kind = other.parse::<IndexKind>().map_err(|e| anyhow::anyhow!(e))?;
            if sharding.shards > 1 {
                SelectionMode::LazySharded(kind, sharding.shards)
            } else {
                SelectionMode::Lazy(kind)
            }
        }
    };
    let mut rng = Rng::new(seed);
    let lp = workloads::random_feasibility_lp(&mut rng, m, d, 0.6);
    let lp_cfg = ScalarLpConfig {
        t,
        eps: cfg.or("eps", 1.0)?,
        delta: cfg.or("delta", 1e-3)?,
        delta_inf: cfg.or("delta-inf", 0.1)?,
        mode,
        seed: seed ^ 3,
        log_every: (t / 10).max(1),
    };
    println!("lp: m={m} d={d} T={t} mode={mode}");
    let res = run_scalar(&lp_cfg, &lp);
    for s in &res.stats {
        println!(
            "  iter {:>6}  max_violation {:+.4}  violated {:.3}",
            s.iter, s.max_violation, s.violation_fraction
        );
    }
    println!(
        "final: max violation {:+.4}, per-iter select {:.1}us, build {:.2}s",
        lp.max_violation(&res.x),
        res.avg_select_time.as_secs_f64() * 1e6,
        res.index_build_time.as_secs_f64()
    );
    Ok(())
}

fn cmd_serve(cfg: &Config) -> Result<()> {
    let jobs: usize = cfg.or("jobs", 8)?;
    let workers: usize = cfg.or("workers", 4)?;
    let eps_cap: Option<f64> = cfg.get("eps-cap")?;
    let sharding = ShardingConfig::from_config(cfg)?;
    let cache = CacheConfig::from_config(cfg)?;
    let store = StoreConfig::from_config(cfg)?;
    let workload_count: usize = cfg.or("workloads", 2usize)?.max(1);
    println!(
        "serve: {jobs} jobs on {workers} workers (eps cap {eps_cap:?}, shards {}, \
         {workload_count} workloads, cache capacity {}, store {})",
        sharding.shards,
        cache.capacity,
        store.dir.as_deref().unwrap_or("off"),
    );

    let lp_mode = if sharding.shards > 1 {
        SelectionMode::LazySharded(IndexKind::Hnsw, sharding.shards)
    } else {
        SelectionMode::Lazy(IndexKind::Hnsw)
    };
    let mut coord = Coordinator::start(CoordinatorConfig {
        workers,
        eps_cap,
        cache_capacity: cache.capacity,
        store_dir: store.dir.map(std::path::PathBuf::from),
    });
    let mut accepted = 0usize;
    for i in 0..jobs {
        let spec = if i % 2 == 0 {
            JobSpec::Release(ReleaseJobSpec {
                u: 256,
                m: 400,
                n: 500,
                t: 200,
                eps: 1.0,
                delta: 1e-3,
                index: Some(IndexKind::Hnsw),
                shards: sharding.shards,
                // spread release jobs across a few repeated workloads so
                // the warm-index cache sees serving-shaped traffic
                workload: (i / 2 % workload_count) as u64,
                seed: i as u64,
            })
        } else {
            JobSpec::Lp(LpJobSpec {
                m: 2_000,
                d: 16,
                t: 200,
                eps: 1.0,
                delta: 1e-3,
                delta_inf: 0.1,
                mode: lp_mode,
                seed: i as u64,
            })
        };
        match coord.submit(spec) {
            Ok(_) => accepted += 1,
            Err(e) => println!("  job {i} rejected: {e}"),
        }
    }
    let (results, metrics) = coord.finish();
    for r in &results {
        match &r.outcome {
            Ok(o) => println!(
                "  job {:>3} [{}] quality {:.4}  eps {:.3}  {:.1}ms",
                r.job_id,
                r.kind,
                o.quality,
                o.eps_spent,
                o.total_time.as_secs_f64() * 1e3
            ),
            Err(e) => println!("  job {:>3} [{}] FAILED: {e}", r.job_id, r.kind),
        }
    }
    println!(
        "index cache: {} hits / {} misses, {} entries resident, ~{}ms build time saved",
        metrics.counter("index_cache_hit"),
        metrics.counter("index_cache_miss"),
        metrics.gauge("index_cache_entries").unwrap_or(0.0),
        metrics.counter("index_build_saved_ms"),
    );
    if metrics.gauge("store_artifacts").is_some() {
        println!(
            "artifact store: {} restores / {} cold builds, {} artifacts on disk, \
             {} bytes written, ~{}ms decoding",
            metrics.counter("store_hit"),
            metrics.counter("store_miss"),
            metrics.gauge("store_artifacts").unwrap_or(0.0),
            metrics.counter("store_bytes_written"),
            metrics.counter("store_promote_ms"),
        );
    }
    println!("accepted {accepted}/{jobs}; metrics: {}", metrics.to_json());
    Ok(())
}

fn cmd_check_artifacts(cfg: &Config) -> Result<()> {
    let dir = cfg.str_or("dir", "artifacts");
    let mut engine = XlaEngine::load(&dir)?;
    println!(
        "platform {}, manifest grid {:?}, {} artifacts",
        engine.platform(),
        engine.manifest().grid,
        engine.manifest().entries.len()
    );
    let names: Vec<String> = engine.manifest().entries.keys().cloned().collect();
    for name in names {
        let entry = engine.entry(&name)?.clone();
        // build inputs of the right shapes (i32 scalar for step's i_t)
        let mut bufs = Vec::new();
        for (i, spec) in entry.inputs.iter().enumerate() {
            if spec.dtype == "int32" {
                bufs.push(engine.buffer_scalar_i32(0)?);
            } else if spec.shape.is_empty() {
                bufs.push(engine.buffer_scalar_f32(0.0)?);
            } else {
                let data = vec![if i == 0 { 1.0f32 } else { 0.0 }; spec.elements()];
                bufs.push(engine.buffer_f32(&data, &spec.shape)?);
            }
        }
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let outs = engine.execute(&name, &refs)?;
        println!(
            "  {name}: OK ({} outputs, first len {})",
            outs.len(),
            outs.first().map(Vec::len).unwrap_or(0)
        );
    }
    Ok(())
}
