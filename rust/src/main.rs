//! `repro` — the Fast-MWEM coordinator CLI.
//!
//! Subcommands:
//!   eval <fig1..fig9|shards|convex|all> [--quick] [--out=DIR] [--seed=N]
//!       regenerate a paper figure (CSV + stdout table)
//!   release [--m=..] [--u=..] [--n=..] [--t=..] [--index=flat|ivf|hnsw|none]
//!           [--eps=..] [--delta=..] run one private release job
//!   lp [--m=..] [--d=..] [--t=..] [--mode=exhaustive|flat|ivf|hnsw]
//!       run one scalar-private LP job
//!   serve [--jobs=N] [--workers=N] [--eps-cap=..] [--store-dir=PATH]
//!       drive the thread-pool coordinator with a batch of jobs
//!   serve --daemon [--jobs=N] [--tenants=K] [--queue-depth=D]
//!         [--policy=block|reject] [--eps-per-tenant=E] [--metrics-out=P]
//!       run the long-lived serving runtime: concurrent submitters,
//!       bounded queue, per-tenant budget admission, graceful drain
//!   serve --daemon --listen=ADDR [--max-conns=N] [--conn-workers=N]
//!       expose the runtime over HTTP/1.1 (DESIGN.md §11): jobs arrive as
//!       wire requests instead of local submitter threads; runs until a
//!       `POST /v1/shutdown`, then drains gracefully
//!   job --body=JSON [--tenant=N]
//!       execute one wire-encoded job spec in-process and print the exact
//!       response body the wire would stream (the byte-identity oracle)
//!   bench-compare [--baseline=..] [--fresh=a.json,b.json] [--tolerance=..]
//!       perf-regression gate: compare fresh bench JSON against a baseline
//!
//! Every command honors `--kernels=scalar|native|avx2|neon` (or a
//! `[kernels]` config section): which SIMD dispatch arm the scoring
//! kernels run on (DESIGN.md §10).
//!
//! Flags may also come from a config file: `--config=path.toml` (the
//! key=value / [section] subset, see config/mod.rs).

use anyhow::{bail, Context, Result};
use fast_mwem::config::{
    CacheConfig, Config, DynamicConfig, KernelConfig, PagerConfig, ShardingConfig,
    StoreConfig, WorkloadConfig,
};
use fast_mwem::coordinator::{
    execute, execute_with_cache, Coordinator, CoordinatorConfig, JobSpec, LpJobSpec,
    ReleaseJobSpec, WorkloadUpdateSpec,
};
use fast_mwem::store::TieredIndexCache;
use fast_mwem::workloads::WorkloadRegistry;
use fast_mwem::eval::{self, EvalOpts};
use fast_mwem::lp::{run_scalar, ScalarLpConfig, SelectionMode};
use fast_mwem::metrics::Metrics;
use fast_mwem::mips::IndexKind;
use fast_mwem::mwem::{run_classic, run_fast, FastMwemConfig, MwemConfig};
use fast_mwem::runtime::{kernels, CpuBackend};
use fast_mwem::server::{
    outcome_body_string, parse_job_spec, Server, ServerConfig, SubmitError, WireConfig,
    WireServer,
};
use fast_mwem::util::json::Json;
use fast_mwem::util::rng::Rng;
use fast_mwem::workloads::{self, QueryClassKind};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_flags(args: &[String]) -> Result<(Vec<String>, Config)> {
    let mut positional = Vec::new();
    let mut cfg = Config::new();
    for a in args {
        if let Some(rest) = a.strip_prefix("--") {
            if let Some((k, v)) = rest.split_once('=') {
                if k == "config" {
                    let file = Config::from_file(v)?;
                    for key in file.keys().map(str::to_string).collect::<Vec<_>>() {
                        if cfg.get_str(&key).is_none() {
                            cfg.set(&key, file.str_or(&key, ""));
                        }
                    }
                } else {
                    cfg.set(k, v);
                }
            } else {
                cfg.set(rest, "true"); // bare flag
            }
        } else {
            positional.push(a.clone());
        }
    }
    Ok((positional, cfg))
}

fn run(args: &[String]) -> Result<()> {
    let (pos, cfg) = parse_flags(args)?;
    // Pin the kernel dispatch before any scoring work touches it — the
    // choice is process-wide and sticky (first resolution wins).
    KernelConfig::from_config(&cfg)?.apply()?;
    // Same for the quantized shortlist tier (DESIGN.md §12): ambient mode
    // is process-wide, set once before any index builds.
    PagerConfig::from_config(&cfg)?.apply_quant()?;
    let cmd = pos.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "eval" => cmd_eval(&pos, &cfg),
        "release" => cmd_release(&cfg),
        "lp" => cmd_lp(&cfg),
        "serve" => {
            if cfg.get_str("daemon").is_some() {
                cmd_serve_daemon(&cfg)
            } else {
                cmd_serve(&cfg)
            }
        }
        "update-workload" => cmd_update_workload(&cfg),
        "job" => cmd_job(&cfg),
        "bench-compare" => cmd_bench_compare(&cfg),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `repro help`"),
    }
}

const HELP: &str = "\
repro — Fast-MWEM reproduction CLI

USAGE:
  repro eval <fig1..fig9|shards|convex|all> [--quick] [--out=DIR] [--seed=N] [--shards=S]
  repro release [--m=1000] [--u=1024] [--n=500] [--t=2000]
                [--index=hnsw|ivf|flat|none] [--eps=1.0] [--delta=1e-3]
                [--shards=S] [--class=linear|convex-lsq|convex-logistic]
  repro lp [--m=20000] [--d=20] [--t=2000] [--mode=hnsw|ivf|flat|exhaustive]
           [--shards=S]
  repro serve [--jobs=8] [--workers=4] [--eps-cap=N] [--shards=S]
              [--workloads=W] [--class=NAME] [--cache-capacity=C]
              [--store-dir=PATH] [--heap-budget-mb=N] [--quant=off|int8|f16]
  repro serve --daemon [--jobs=24] [--tenants=3] [--workers=4]
              [--queue-depth=64] [--policy=block|reject]
              [--eps-per-tenant=E] [--workloads=W] [--cache-capacity=C]
              [--store-dir=PATH] [--metrics-out=PATH]
              [--update-every=N] [--update-insert=I] [--update-tombstone=T]
  repro serve --daemon --listen=127.0.0.1:8700 [--max-conns=32]
              [--conn-workers=8] [--tenants=4] [--metrics-out=PATH]
  repro job --body='{\"kind\":\"release\",\"seed\":7}' [--tenant=0]
  repro update-workload [--workload=0] [--m=400] [--u=256] [--n=500]
              [--insert=4] [--tombstone=2] [--store-dir=PATH]
  repro bench-compare [--baseline=BENCH_baseline.json]
              [--fresh=BENCH_hot_paths.json,BENCH_serving.json]
              [--tolerance=0.25]

Every command accepts --kernels=scalar|native|avx2|neon (or a [kernels]
config section): which SIMD dispatch arm the scoring kernels run on
(DESIGN.md §10). Default: the FAST_MWEM_KERNELS env var, then
auto-detection. The `kernel` metrics gauge reports the active arm.

Sharding (DESIGN.md §5): --shards=S (or a [sharding] config section) splits
the lazy EM across S per-shard indices, built in parallel on the pool.

Query classes (DESIGN.md §14): --class=NAME (or a [workload] config
section) selects the released query family: linear counting queries (the
default) or the low-sensitivity convex-loss releases convex-lsq /
convex-logistic, all driven through the same engine and lazy selection
oracle. The class travels in the wire spec (\"class\" field), enters the
workload fingerprint (so the store never serves one class's artifact for
another), and `repro eval convex` plots the convex error/work axis.

Warm-index serving (DESIGN.md §6): the coordinator keeps up to C pre-built
k-MIPS indices resident (--cache-capacity=C, or a [cache] section;
0 disables). `serve` spreads its release jobs across W distinct workloads
(--workloads=W, default 2) so repeats hit the cache and skip index builds.

Persistent artifact store (DESIGN.md §7): --store-dir=PATH (or a [store]
config section) snapshots built indices to disk, so a restarted `serve`
against the same directory restores them (store_hit metric) instead of
rebuilding — warm serving that survives restarts.

Zero-copy paging (DESIGN.md §12): store artifacts restore over a shared
memory mapping by default — row data pages in on demand and pins no heap,
so artifacts larger than RAM still serve. --heap-budget-mb=N (or a [pager]
config section: enabled, verify, heap_budget_mb, quant) caps the heap the
warm cache may pin; the store_mmap_restore / store_decode_restore counters
say which restore path promotions took. --quant=int8|f16 adds a quantized
shortlist tier: compact codes widen the candidate shortlist, exact rows
rescore it, and every select() draw stays bit-identical with every one of
these knobs on or off.

Serving runtime (DESIGN.md §8): `serve --daemon` (or a [server] config
section) runs the long-lived runtime instead of the one-shot batch pool:
one submitter thread per tenant pushes a mixed Release+Lp stream through a
bounded MPMC queue (--queue-depth, --policy) into persistent workers; every
job is admission-checked against its tenant's ε cap (--eps-per-tenant)
before it runs, failures refund, and the final drain reports per-kind
latency p50/p95/p99 plus per-tenant spend (--metrics-out dumps the JSON).

Wire front end (DESIGN.md §11): `serve --daemon --listen=ADDR` (or a
[wire] config section) exposes the runtime over HTTP/1.1 instead of local
submitter threads: tenants authenticate with bearer tokens (dev tokens
tenant-0..K-1, or [wire] auth = \"token:id,...\"), POST /v1/jobs submits a
flat JSON job spec, and the outcome streams back chunked. Backpressure
rides the status line: 429 + Retry-After when the queue rejects, 403 when
the ε cap denies, 503 while draining. `repro job --body=SPEC` runs the
same spec in-process and prints the byte-identical response body. The
daemon runs until `POST /v1/shutdown`, then drains gracefully.

Dynamic workloads (DESIGN.md §9): `update-workload` appends/retires query
rows of an evolving workload — zero-ε, data-independent — bumping its
generation; cached/persisted indices are *patched* forward on their next
lookup instead of rebuilt, and a stale generation is never served. In
`serve --daemon`, `--update-every=N` (or a [dynamic] config section) makes
every tenant submit one update per N jobs, mixing updates into the release
stream.

Multi-process serving (DESIGN.md §13): N daemons may share one
--store-dir. A shared cold miss takes a build *lease* (a lock file next
to the artifact) so exactly one process builds while peers wait and
promote the committed artifact (lease_acquired / lease_waited /
lease_takeovers counters); a crashed builder's lease expires after
[store] lease_ttl_ms and is taken over. A manifest *watch* (one stat per
miss) adopts peer-committed workload updates before serving, keeping
stale_generation_serves == 0 across processes (peer_invalidations
counter). Knobs in the [store] section: lease, lease_ttl_ms,
lease_poll_ms, lease_wait_ms, watch. examples/router.rs hash-partitions
tenants across such a daemon fleet.

Perf gate: `bench-compare` checks fresh bench JSON (machine-independent
warm-path ratios) against BENCH_baseline.json and exits nonzero on a
regression beyond the tolerance — the same gate CI runs per commit.
";

fn cmd_eval(pos: &[String], cfg: &Config) -> Result<()> {
    let which = pos.get(1).map(String::as_str).unwrap_or("all");
    let opts = EvalOpts {
        quick: cfg.get_str("quick").is_some(),
        out_dir: cfg.str_or("out", "results").into(),
        seed: cfg.or("seed", 20260204u64)?,
        shards: ShardingConfig::from_config(cfg)?.shards,
    };
    eval::run(which, &opts)
}

fn cmd_release(cfg: &Config) -> Result<()> {
    let u: usize = cfg.or("u", 1024)?;
    let m: usize = cfg.or("m", 1000)?;
    let n: usize = cfg.or("n", 500)?;
    let t: usize = cfg.or("t", 2000)?;
    let eps: f64 = cfg.or("eps", 1.0)?;
    let delta: f64 = cfg.or("delta", 1e-3)?;
    let seed: u64 = cfg.or("seed", 1u64)?;
    let index = cfg.str_or("index", "hnsw");
    let sharding = ShardingConfig::from_config(cfg)?;
    let class = WorkloadConfig::from_config(cfg)?.class;

    let mut rng = Rng::new(seed);
    let h = workloads::gaussian_histogram(&mut rng, u, n);
    let q = workloads::synthesize_queries(&mut rng, class, m, u);
    let mut mwem_cfg = MwemConfig::paper(t, u, eps, delta, seed ^ 7);
    mwem_cfg.log_every = (t / 10).max(1);

    if index == "none" && sharding.shards > 1 {
        println!("note: --shards only applies to Fast-MWEM; ignored with --index=none");
    }
    println!(
        "release: U={u} m={m} n={n} T={t} eps={eps} index={index} class={class} shards={} kernels={}",
        if index == "none" { 1 } else { sharding.shards },
        kernels::active().arm,
    );
    let p0 = vec![1.0 / u as f32; u];
    println!("initial max error: {:.4}", q.max_error(h.probs(), &p0));

    let mut cpu = CpuBackend::new();
    let backend: &mut dyn fast_mwem::mwem::MwemBackend = &mut cpu;

    let (result, extra) = if index == "none" {
        (run_classic(&mwem_cfg, &q, &h, backend), None)
    } else {
        let kind: IndexKind = index.parse().map_err(|e: String| anyhow::anyhow!(e))?;
        let out = run_fast(
            &FastMwemConfig::new(mwem_cfg, kind).with_sharding(sharding),
            &q,
            &h,
            backend,
        );
        (out.result, Some(out.lazy))
    };

    for s in &result.stats {
        println!(
            "  iter {:>6}  max_error(avg) {:.4}  work {:>8}",
            s.iter, s.max_error_avg, s.selection_work
        );
    }
    println!("final max error (avg p̂): {:.4}", q.max_error(h.probs(), &result.p_avg));
    println!(
        "per-iter selection: {:.1}us, work {:.0} score-evals (m={m})",
        result.avg_select_time.as_secs_f64() * 1e6,
        result.avg_select_work,
    );
    if let Some(lazy) = extra {
        let mean_c: f64 =
            lazy.tail_counts.iter().sum::<usize>() as f64 / lazy.tail_counts.len().max(1) as f64;
        println!("index build {:.2}s, mean tail C {:.1}", lazy.build_time.as_secs_f64(), mean_c);
    }
    println!(
        "privacy spent: eps={:.3} delta={:.1e} (budget eps={eps} delta={delta:.1e})",
        result.privacy_spent.0, result.privacy_spent.1
    );
    Ok(())
}

fn cmd_lp(cfg: &Config) -> Result<()> {
    let m: usize = cfg.or("m", 20_000)?;
    let d: usize = cfg.or("d", 20)?;
    let t: usize = cfg.or("t", 2_000)?;
    let seed: u64 = cfg.or("seed", 1u64)?;
    let sharding = ShardingConfig::from_config(cfg)?;
    let mode = match cfg.str_or("mode", "hnsw").as_str() {
        "exhaustive" => {
            if sharding.shards > 1 {
                println!("note: --shards only applies to lazy modes; ignored with --mode=exhaustive");
            }
            SelectionMode::Exhaustive
        }
        other => {
            let kind = other.parse::<IndexKind>().map_err(|e| anyhow::anyhow!(e))?;
            if sharding.shards > 1 {
                SelectionMode::LazySharded(kind, sharding.shards)
            } else {
                SelectionMode::Lazy(kind)
            }
        }
    };
    let mut rng = Rng::new(seed);
    let lp = workloads::random_feasibility_lp(&mut rng, m, d, 0.6);
    let lp_cfg = ScalarLpConfig {
        t,
        eps: cfg.or("eps", 1.0)?,
        delta: cfg.or("delta", 1e-3)?,
        delta_inf: cfg.or("delta-inf", 0.1)?,
        mode,
        seed: seed ^ 3,
        log_every: (t / 10).max(1),
    };
    println!("lp: m={m} d={d} T={t} mode={mode}");
    let res = run_scalar(&lp_cfg, &lp);
    for s in &res.stats {
        println!(
            "  iter {:>6}  max_violation {:+.4}  violated {:.3}",
            s.iter, s.max_violation, s.violation_fraction
        );
    }
    println!(
        "final: max violation {:+.4}, per-iter select {:.1}us, build {:.2}s",
        lp.max_violation(&res.x),
        res.avg_select_time.as_secs_f64() * 1e6,
        res.index_build_time.as_secs_f64()
    );
    Ok(())
}

fn cmd_serve(cfg: &Config) -> Result<()> {
    let jobs: usize = cfg.or("jobs", 8)?;
    let workers: usize = cfg.or("workers", 4)?;
    let eps_cap: Option<f64> = cfg.get("eps-cap")?;
    let sharding = ShardingConfig::from_config(cfg)?;
    let cache = CacheConfig::from_config(cfg)?;
    let store = StoreConfig::from_config(cfg)?;
    let pager = PagerConfig::from_config(cfg)?;
    let class = WorkloadConfig::from_config(cfg)?.class;
    let workload_count: usize = cfg.or("workloads", 2usize)?.max(1);
    println!(
        "serve: {jobs} jobs on {workers} workers (eps cap {eps_cap:?}, shards {}, \
         {workload_count} workloads (class {class}), cache capacity {}, store {}, \
         pager {}, heap budget {})",
        sharding.shards,
        cache.capacity,
        store.dir.as_deref().unwrap_or("off"),
        if pager.enabled { "mmap" } else { "decode" },
        match pager.heap_budget().limit() {
            Some(b) => format!("{}MiB", b >> 20),
            None => "unlimited".into(),
        },
    );

    let lp_mode = if sharding.shards > 1 {
        SelectionMode::LazySharded(IndexKind::Hnsw, sharding.shards)
    } else {
        SelectionMode::Lazy(IndexKind::Hnsw)
    };
    let mut coord = Coordinator::start(CoordinatorConfig {
        workers,
        eps_cap,
        cache_capacity: cache.capacity,
        store_dir: store.dir.as_deref().map(std::path::PathBuf::from),
        heap_budget: pager.heap_budget(),
        pager: pager.settings(),
        lease: store.lease_settings(),
        watch: store.watch,
    });
    let mut accepted = 0usize;
    for i in 0..jobs {
        let spec = if i % 2 == 0 {
            JobSpec::Release(ReleaseJobSpec {
                u: 256,
                m: 400,
                n: 500,
                t: 200,
                eps: 1.0,
                delta: 1e-3,
                index: Some(IndexKind::Hnsw),
                shards: sharding.shards,
                class,
                // spread release jobs across a few repeated workloads so
                // the warm-index cache sees serving-shaped traffic
                workload: (i / 2 % workload_count) as u64,
                tenant: 0, // batch mode: one global cap, no tenants
                seed: i as u64,
            })
        } else {
            JobSpec::Lp(LpJobSpec {
                m: 2_000,
                d: 16,
                t: 200,
                eps: 1.0,
                delta: 1e-3,
                delta_inf: 0.1,
                mode: lp_mode,
                tenant: 0,
                seed: i as u64,
            })
        };
        match coord.submit(spec) {
            Ok(_) => accepted += 1,
            Err(e) => println!("  job {i} rejected: {e}"),
        }
    }
    let (results, metrics) = coord.finish();
    for r in &results {
        match &r.outcome {
            Ok(o) => println!(
                "  job {:>3} [{}] quality {:.4}  eps {:.3}  {:.1}ms",
                r.job_id,
                r.kind,
                o.quality,
                o.eps_spent,
                o.total_time.as_secs_f64() * 1e3
            ),
            Err(e) => println!("  job {:>3} [{}] FAILED: {e}", r.job_id, r.kind),
        }
    }
    println!(
        "index cache: {} hits / {} misses ({} patched forward), {} entries resident, \
         ~{}ms build time saved",
        metrics.counter("index_cache_hit"),
        metrics.counter("index_cache_miss"),
        metrics.counter("index_cache_patched"),
        metrics.gauge("index_cache_entries").unwrap_or(0.0),
        metrics.counter("index_build_saved_ms"),
    );
    if metrics.gauge("store_artifacts").is_some() {
        println!(
            "artifact store: {} restores / {} cold builds ({} mmap-paged, {} decoded), \
             {} artifacts on disk, {} bytes written, ~{}ms promoting",
            metrics.counter("store_hit"),
            metrics.counter("store_miss"),
            metrics.counter("store_mmap_restore"),
            metrics.counter("store_decode_restore"),
            metrics.gauge("store_artifacts").unwrap_or(0.0),
            metrics.counter("store_bytes_written"),
            metrics.counter("store_promote_ms"),
        );
    }
    println!("accepted {accepted}/{jobs}; metrics: {}", metrics.to_json());
    Ok(())
}

/// Build the daemon's mixed per-tenant job stream: even slots are
/// repeated-workload Release jobs (so the warm-index cache sees
/// serving-shaped traffic), odd slots are Lp jobs — every tenant submits
/// both kinds. With `--update-every=N`, every N-th slot becomes a
/// `WorkloadUpdate` instead, so the release stream interleaves with
/// workload evolution and later releases answer the patched generations.
fn daemon_spec(
    tenant: u64,
    i: usize,
    shards: usize,
    workload_count: usize,
    class: QueryClassKind,
    lp_mode: SelectionMode,
    dynamic: DynamicConfig,
) -> JobSpec {
    if dynamic.update_every > 0 && i % dynamic.update_every == dynamic.update_every - 1 {
        return JobSpec::Update(WorkloadUpdateSpec {
            workload: (i / 2 % workload_count) as u64,
            u: 256,
            m: 400,
            n: 500,
            insert: dynamic.insert,
            tombstone: dynamic.tombstone,
            tenant,
        });
    }
    if i % 2 == 0 {
        JobSpec::Release(ReleaseJobSpec {
            u: 256,
            m: 400,
            n: 500,
            t: 200,
            eps: 1.0,
            delta: 1e-3,
            index: Some(IndexKind::Hnsw),
            shards,
            class,
            workload: (i / 2 % workload_count) as u64,
            tenant,
            seed: tenant * 10_000 + i as u64,
        })
    } else {
        JobSpec::Lp(LpJobSpec {
            m: 2_000,
            d: 16,
            t: 200,
            eps: 1.0,
            delta: 1e-3,
            delta_inf: 0.1,
            mode: lp_mode,
            tenant,
            seed: tenant * 10_000 + i as u64,
        })
    }
}

fn cmd_serve_daemon(cfg: &Config) -> Result<()> {
    // --listen (or a [wire] section) switches the daemon to the network
    // front end: jobs arrive over HTTP instead of from local submitters.
    if cfg.get_str("listen").is_some() || cfg.get_str("wire.listen").is_some() {
        return cmd_serve_wire(cfg);
    }
    let jobs: usize = cfg.or("jobs", 24)?;
    let tenants: u64 = cfg.or("tenants", 3u64)?.max(1);
    let sharding = ShardingConfig::from_config(cfg)?;
    let dynamic = DynamicConfig::from_config(cfg)?;
    let class = WorkloadConfig::from_config(cfg)?.class;
    let workload_count: usize = cfg.or("workloads", 2usize)?.max(1);
    let metrics_out = cfg.get_str("metrics-out").map(str::to_string);
    let server_cfg = ServerConfig::from_config(cfg)?;
    println!(
        "serve --daemon: {jobs} jobs from {tenants} tenants on {} workers \
         (queue depth {}, policy {}, eps/tenant {:?}, {workload_count} workloads, \
         cache capacity {}, store {})",
        server_cfg.workers,
        server_cfg.queue_depth,
        server_cfg.policy,
        server_cfg.eps_per_tenant,
        server_cfg.cache_capacity,
        server_cfg.store_dir.as_deref().map(|p| p.display().to_string()).unwrap_or_else(|| "off".into()),
    );

    let lp_mode = if sharding.shards > 1 {
        SelectionMode::LazySharded(IndexKind::Hnsw, sharding.shards)
    } else {
        SelectionMode::Lazy(IndexKind::Hnsw)
    };
    let server = Server::start(server_cfg);

    // One submitter thread per tenant — the MPMC submission path under
    // real concurrency, not a loop pretending to be one.
    let per_tenant: Vec<(u64, usize, usize, usize, usize)> = std::thread::scope(|s| {
        let server = &server;
        let handles: Vec<_> = (0..tenants)
            .map(|tenant| {
                s.spawn(move || {
                    let quota = jobs / tenants as usize
                        + usize::from((jobs % tenants as usize) > tenant as usize);
                    let mut tickets = Vec::new();
                    let (mut denied, mut shed) = (0usize, 0usize);
                    for i in 0..quota {
                        let spec = daemon_spec(
                            tenant,
                            i,
                            sharding.shards,
                            workload_count,
                            class,
                            lp_mode,
                            dynamic,
                        );
                        match server.submit(spec) {
                            Ok(t) => tickets.push(t),
                            Err(SubmitError::Budget(_)) => denied += 1,
                            Err(SubmitError::QueueFull { .. })
                            | Err(SubmitError::Draining) => shed += 1,
                        }
                    }
                    let (mut ok, mut failed) = (0usize, 0usize);
                    for t in tickets {
                        match t.wait().outcome {
                            Ok(_) => ok += 1,
                            Err(_) => failed += 1,
                        }
                    }
                    (tenant, ok, failed, denied, shed)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("submitter panicked")).collect()
    });

    let spends = server.tenant_spend();
    let metrics = server.drain();

    for (tenant, ok, failed, denied, shed) in &per_tenant {
        println!(
            "  tenant {tenant}: {ok} ok, {failed} failed, {denied} denied at \
             admission, {shed} shed by backpressure"
        );
    }
    for t in &spends {
        println!(
            "  tenant {} budget: spent eps {:.2}{}",
            t.tenant,
            t.spent,
            match metrics.gauge("tenant_eps_cap") {
                Some(cap) => format!(" of cap {cap:.2}"),
                None => " (uncapped)".to_string(),
            }
        );
    }
    print_latency_table(&metrics);
    if let Some(path) = metrics_out {
        std::fs::write(&path, metrics.to_json().to_string())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    println!("metrics: {}", metrics.to_json());
    Ok(())
}

/// The wire daemon (DESIGN.md §11): bind the HTTP front end over the
/// serving runtime and block until a `POST /v1/shutdown` arrives, then
/// drain gracefully and report wire counters next to the job histograms.
fn cmd_serve_wire(cfg: &Config) -> Result<()> {
    let server_cfg = ServerConfig::from_config(cfg)?;
    let wire_cfg = WireConfig::from_config(cfg)?;
    let metrics_out = cfg.get_str("metrics-out").map(str::to_string);
    println!(
        "serve --daemon: wire front end over {} workers (queue depth {}, \
         policy {}, eps/tenant {:?}, max conns {}, {} conn workers, \
         {} tenant tokens)",
        server_cfg.workers,
        server_cfg.queue_depth,
        server_cfg.policy,
        server_cfg.eps_per_tenant,
        wire_cfg.max_conns,
        wire_cfg.conn_workers,
        wire_cfg.auth_map().len(),
    );
    let server = Server::start(server_cfg);
    let wire = WireServer::start(server, &wire_cfg)?;
    // the soak driver greps this line for the bound address
    println!("wire: listening on {}", wire.local_addr());
    wire.wait_for_shutdown();
    println!("wire: shutdown requested, draining");
    let metrics = wire.drain();

    println!(
        "wire: {} conns, {} requests, {} bytes in / {} bytes out, \
         {} parse errors, {} shed (429), {} denied (403)",
        metrics.counter("conns_accepted"),
        metrics.counter("requests"),
        metrics.counter("bytes_in"),
        metrics.counter("bytes_out"),
        metrics.counter("parse_errors"),
        metrics.counter("http_429"),
        metrics.counter("http_403"),
    );
    if let Some(t) = metrics.timing_summary("wire_request") {
        println!(
            "  wire_request     n={:<4} p50 {:>8.2}ms  p95 {:>8.2}ms  p99 {:>8.2}ms  \
             max {:>8.2}ms",
            t.count,
            t.p50 * 1e3,
            t.p95 * 1e3,
            t.p99 * 1e3,
            t.max * 1e3
        );
    }
    print_latency_table(&metrics);
    if let Some(path) = metrics_out {
        std::fs::write(&path, metrics.to_json().to_string())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    println!("metrics: {}", metrics.to_json());
    Ok(())
}

/// Execute one wire-encoded job spec in-process and print the exact body
/// the wire front end would stream for it — the byte-identity oracle the
/// integration tests and the soak compare network responses against.
fn cmd_job(cfg: &Config) -> Result<()> {
    let Some(body) = cfg.get_str("body") else {
        bail!("job needs --body='{{\"kind\":\"release\",...}}' (a wire job spec)");
    };
    let tenant: u64 = cfg.or("tenant", 0u64)?;
    let spec = parse_job_spec(body, tenant).map_err(|e| anyhow::anyhow!("bad spec: {e}"))?;
    let outcome = execute(&spec)?;
    println!("{}", outcome_body_string(spec.kind(), &outcome));
    Ok(())
}

/// Per-kind latency and queue-wait summary (the serving runtime's
/// histogram headline).
fn print_latency_table(metrics: &Metrics) {
    let ms = |s: f64| s * 1e3;
    for series in ["latency_release", "latency_lp", "latency_update", "queue_wait"] {
        if let Some(t) = metrics.timing_summary(series) {
            println!(
                "  {series:<16} n={:<4} p50 {:>8.2}ms  p95 {:>8.2}ms  p99 {:>8.2}ms  \
                 max {:>8.2}ms",
                t.count,
                ms(t.p50),
                ms(t.p95),
                ms(t.p99),
                ms(t.max)
            );
        }
    }
}

/// Evolve a workload out of band (DESIGN.md §9): append/retire query rows,
/// bump the family generation, and persist the compact delta artifact so
/// serving processes pointed at the same `--store-dir` patch their indices
/// forward on the next lookup. The spec shape (`--m/--u/--n`) must match
/// the release jobs that answer this workload — they share the synthesized
/// base content, and the family fingerprint is derived from it.
fn cmd_update_workload(cfg: &Config) -> Result<()> {
    let workload: u64 = cfg.or("workload", 0u64)?;
    let u: usize = cfg.or("u", 256)?;
    let m: usize = cfg.or("m", 400)?;
    let n: usize = cfg.or("n", 500)?;
    let dynamic = DynamicConfig::from_config(cfg)?;
    let insert: usize = cfg.or("insert", dynamic.insert)?;
    let tombstone: usize = cfg.or("tombstone", dynamic.tombstone)?;
    let cache_cfg = CacheConfig::from_config(cfg)?;
    let store = StoreConfig::from_config(cfg)?;

    let cache = match &store.dir {
        Some(dir) => TieredIndexCache::with_store(cache_cfg.capacity, dir)
            .with_context(|| format!("opening artifact store {dir:?}"))?,
        None => {
            println!(
                "note: no --store-dir given — the update affects only this process; \
                 serving daemons pointed at a store directory will never see it"
            );
            TieredIndexCache::memory_only(cache_cfg.capacity)
        }
    }
    .with_lease(store.lease_settings())
    .with_watch(store.watch);
    let registry = WorkloadRegistry::new();
    if let Some(s) = cache.store() {
        registry.restore(s.delta_chains());
    }

    let spec = JobSpec::Update(WorkloadUpdateSpec {
        workload,
        u,
        m,
        n,
        insert,
        tombstone,
        tenant: 0,
    });
    let (outcome, _) = execute_with_cache(&spec, Some(&cache), Some(&registry))?;

    // re-derive the family fingerprint to report the new generation
    // (updates evolve linear-query families only, hence the Linear tag)
    let mut rng = Rng::new(workload);
    let _h = workloads::gaussian_histogram(&mut rng, u, n);
    let base = workloads::binary_queries(&mut rng, m, u);
    let fp = cache.fingerprint_for(workload, QueryClassKind::Linear.tag(), base.vectors());
    println!(
        "workload {workload} (family {fp:032x}) now at generation {}: \
         +{insert} rows, -{tombstone} rows in {:.1}ms",
        registry.generation(fp),
        outcome.total_time.as_secs_f64() * 1e3,
    );
    if let Some(s) = cache.store() {
        let st = s.stats();
        println!(
            "store {}: {} snapshots, {} delta artifacts",
            s.dir().display(),
            st.artifacts,
            st.deltas
        );
    }
    Ok(())
}

/// The perf-regression gate: compare fresh bench JSON artifacts against
/// the committed baseline. Baseline schema:
///
/// ```text
/// { "tolerance": 0.25,
///   "metrics": {
///     "<bench name>": {
///       "<dotted.path>": { "value": 0.6, "dir": "lower" | "higher" } } } }
/// ```
///
/// A `lower` metric fails when fresh > value·(1+tol); a `higher` metric
/// fails when fresh < value·(1−tol). A metric missing from the fresh run
/// fails too — silently dropping a tracked metric is itself a regression.
fn cmd_bench_compare(cfg: &Config) -> Result<()> {
    let baseline_path = cfg.str_or("baseline", "BENCH_baseline.json");
    let fresh_paths = cfg.str_or("fresh", "BENCH_hot_paths.json,BENCH_serving.json");
    let text = std::fs::read_to_string(&baseline_path)
        .with_context(|| format!("reading baseline {baseline_path}"))?;
    let baseline = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {baseline_path}: {e}"))?;
    let tol = match cfg.get::<f64>("tolerance")? {
        Some(t) => t,
        None => baseline.get("tolerance").and_then(Json::as_f64).unwrap_or(0.25),
    };

    let mut fresh_by_bench = std::collections::BTreeMap::new();
    for path in fresh_paths.split(',').filter(|p| !p.is_empty()) {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading fresh bench {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
        let name = j
            .get("bench")
            .and_then(Json::as_str)
            .with_context(|| format!("{path} has no \"bench\" name"))?
            .to_string();
        fresh_by_bench.insert(name, j);
    }

    let Some(Json::Obj(benches)) = baseline.get("metrics") else {
        bail!("{baseline_path} has no \"metrics\" object");
    };
    let mut failures = 0usize;
    for (bench, entries) in benches {
        let Json::Obj(entries) = entries else {
            bail!("baseline metrics.{bench} must be an object");
        };
        let Some(fresh) = fresh_by_bench.get(bench) else {
            println!("FAIL {bench}: baseline tracks this bench but no fresh file was given");
            failures += 1;
            continue;
        };
        for (key, spec) in entries {
            let value = spec
                .get("value")
                .and_then(Json::as_f64)
                .with_context(|| format!("baseline {bench}.{key} has no numeric value"))?;
            let dir = spec.get("dir").and_then(Json::as_str).unwrap_or("lower");
            let mut cur = Some(fresh);
            for part in key.split('.') {
                cur = cur.and_then(|c| c.get(part));
            }
            let Some(got) = cur.and_then(Json::as_f64) else {
                println!("FAIL {bench}.{key}: metric missing from the fresh run");
                failures += 1;
                continue;
            };
            let (ok, bound) = match dir {
                "lower" => (got <= value * (1.0 + tol), value * (1.0 + tol)),
                "higher" => (got >= value * (1.0 - tol), value * (1.0 - tol)),
                other => bail!("baseline {bench}.{key}: unknown dir {other:?}"),
            };
            println!(
                "{} {bench}.{key}: {got:.4} (baseline {value:.4}, {dir} is better, \
                 bound {bound:.4})",
                if ok { "PASS" } else { "FAIL" }
            );
            if !ok {
                failures += 1;
            }
        }
    }
    anyhow::ensure!(
        failures == 0,
        "{failures} perf-regression check(s) failed (tolerance {tol})"
    );
    println!("perf gate passed (tolerance {tol})");
    Ok(())
}

