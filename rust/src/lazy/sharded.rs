//! Sharded lazy exponential mechanism (DESIGN.md §5).
//!
//! [`super::LazyEm`] answers one EM draw over m candidates in Θ(√m)
//! expected time, but it builds and probes a single monolithic k-MIPS
//! index — index construction (and any rebuild) is serial, and every draw
//! is a single-threaded walk of one index. [`ShardedLazyEm`] removes that
//! bottleneck by partitioning the candidate set into S contiguous shards,
//! building one index per shard **in parallel** via the pool module's
//! scoped fan-out ([`crate::coordinator::pool::parallel_map`] — short-lived
//! scoped threads, not the [`crate::coordinator::Coordinator`]'s persistent
//! workers), and answering `select()` by drawing each shard's lazy Gumbel
//! max and taking the argmax across shards.
//!
//! The decomposition is *exact*, not approximate, by Gumbel max-stability:
//! a softmax sample over all m candidates is the argmax of the perturbed
//! scores `s_i + G_i`, and partitioning the candidates into disjoint
//! shards commutes with that argmax —
//!
//! ```text
//! argmax_{i ∈ [m]} (s_i + G_i)  =  argmax over shards of
//!                                  [ argmax_{i ∈ shard} (s_i + G_i) ].
//! ```
//!
//! Each shard draw is itself a lazy Gumbel draw ([`lazy_gumbel_max`]),
//! whose [`LazySample::value`] is exactly its shard's perturbed max, so the
//! outer combine is a plain `max` over S scalars. With per-shard
//! k = ⌈√(m/S)⌉ each shard does Θ(√(m/S)) expected work (the paper's bound
//! applied at shard size m/S); the S shard draws are independent and can
//! run on the pool, so expected wall-clock drops from Θ(√m) to Θ(√(m/S))
//! at S-way parallelism, and index build — the dominant preprocessing cost
//! for IVF/HNSW — parallelizes S ways with no cross-shard coupling.
//!
//! The indices themselves live in a [`ShardSet`] — the owned, `Arc`-shared
//! half of the mechanism — so one build can back many `ShardedLazyEm`
//! instances across jobs (the coordinator's warm-index cache, DESIGN.md §6).

use super::gumbel::{lazy_gumbel_max, LazySample};
use super::lazy_em::{retrieve_top_k_from, transform_ip};
use super::ScoreTransform;
use crate::coordinator::job::{execute_shard_search, ShardSearchJob};
use crate::coordinator::pool::parallel_map;
use crate::mips::snapshot::{self, malformed, SnapshotError, SnapshotReader, SnapshotWriter};
use crate::mips::{
    apply_delta_to_vectors, build_index, IndexKind, MipsIndex, PatchError, SnapshotCodec,
    VectorSet, WorkloadDelta,
};
use crate::runtime::kernels::dot;
use crate::util::rng::Rng;
use std::sync::Arc;

/// One contiguous slice of the candidate set with its own k-MIPS index.
struct ShardHandle {
    /// Global id of the shard's first candidate.
    offset: usize,
    /// Number of candidates in the shard.
    len: usize,
    /// Index over the shard's rows only (local ids `0..len`).
    index: Arc<dyn MipsIndex>,
}

/// The owned, shareable half of a [`ShardedLazyEm`]: S per-shard k-MIPS
/// indices plus their partition geometry, with no borrow of the candidate
/// vectors. Build once — the per-shard builds run in parallel on the pool —
/// then share the set behind an [`Arc`] across any number of mechanisms or
/// jobs. This is the unit the coordinator's warm-index cache
/// ([`crate::coordinator::IndexCache`]) keeps resident for sharded
/// workloads, the sharded sibling of a cached monolithic
/// `Arc<dyn MipsIndex>`.
///
/// ```
/// use fast_mwem::lazy::{ScoreTransform, ShardSet, ShardedLazyEm};
/// use fast_mwem::mips::{IndexKind, VectorSet};
/// use fast_mwem::util::rng::Rng;
/// use std::sync::Arc;
///
/// let mut rng = Rng::new(1);
/// let data: Vec<f32> = (0..64 * 4).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
/// let vs = VectorSet::new(data, 64, 4);
/// let set = Arc::new(ShardSet::build(IndexKind::Flat, &vs, 4, 7));
/// // two mechanisms sharing one build
/// let a = ShardedLazyEm::with_shard_set(Arc::clone(&set), &vs, ScoreTransform::Abs);
/// let b = ShardedLazyEm::with_shard_set(Arc::clone(&set), &vs, ScoreTransform::Abs);
/// assert_eq!(a.num_shards(), b.num_shards());
/// ```
pub struct ShardSet {
    shards: Vec<ShardHandle>,
    /// Total candidates covered (Σ shard lengths).
    m: usize,
    /// Dimension of the indexed vectors.
    d: usize,
    kind: IndexKind,
}

impl ShardSet {
    /// Partition `vectors` into `shards` contiguous shards and build one
    /// index of `kind` per shard, in parallel (one scoped build job per
    /// shard via [`parallel_map`]).
    ///
    /// `shards` is clamped to `[1, m]`; shard sizes differ by at most one.
    /// Panics if `vectors` is empty.
    pub fn build(kind: IndexKind, vectors: &VectorSet, shards: usize, seed: u64) -> Self {
        let m = vectors.len();
        assert!(m > 0, "ShardSet needs a non-empty vector set");
        let s = shards.clamp(1, m);
        let d = vectors.dim();

        let (base, rem) = (m / s, m % s);
        // independent, well-mixed build seed per shard via the tested
        // Rng::split primitive (derived up front, on the calling thread)
        let mut seed_rng = Rng::new(seed);
        let mut specs: Vec<(usize, usize, u64, VectorSet)> = Vec::with_capacity(s);
        let mut offset = 0usize;
        for i in 0..s {
            let len = base + usize::from(i < rem);
            let shard_seed = seed_rng.split(i as u64).next_u64();
            specs.push((offset, len, shard_seed, vectors.slice_rows(offset, len)));
            offset += len;
        }

        let shards_built: Vec<ShardHandle> =
            parallel_map(s, specs, |(offset, len, shard_seed, vs)| ShardHandle {
                offset,
                len,
                index: build_index(kind, vs, shard_seed),
            });

        ShardSet { shards: shards_built, m, d, kind }
    }

    /// Number of shards S.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total number of indexed candidates m.
    pub fn len(&self) -> usize {
        self.m
    }

    /// True when the set covers no candidates (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.m == 0
    }

    /// Dimension of the indexed vectors.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Which index implementation every shard uses.
    pub fn kind(&self) -> IndexKind {
        self.kind
    }

    /// `(offset, len)` of every shard, in candidate-id order.
    pub fn bounds(&self) -> Vec<(usize, usize)> {
        self.shards.iter().map(|s| (s.offset, s.len)).collect()
    }

    /// Heap bytes held across every shard's index (mmap-borrowed vector
    /// storage counts as zero — [`crate::mips::MipsIndex::heap_bytes`]).
    pub fn heap_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.index.heap_bytes()).sum()
    }

    /// Materialize every shard's live rows, concatenated in global
    /// candidate order — the vector set a fresh [`ShardSet::build`] at the
    /// current state would be given.
    pub fn live_vectors(&self) -> VectorSet {
        let mut out = VectorSet::zeros(0, self.d);
        for sh in &self.shards {
            out.append(&sh.index.live_vectors());
        }
        debug_assert_eq!(out.len(), self.m);
        out
    }

    /// Incremental maintenance with per-shard routing (DESIGN.md §9):
    /// tombstones are routed to the shard that owns each global id (and
    /// translated to shard-local ids), inserted rows are appended to the
    /// last shard (global insertions land at the end of the candidate
    /// range, so contiguity is preserved), and untouched shards reuse
    /// their `Arc` index with zero work. Shards that would go empty force
    /// a full rebuild over the effective rows — per-shard indices cannot
    /// be empty. Returns the patched set plus whether a full rebuild ran
    /// (per-shard amortized rebuilds do not count).
    pub fn patch(&self, delta: &WorkloadDelta, seed: u64) -> Result<(ShardSet, bool), PatchError> {
        delta.validate(self.m, self.d)?;
        let s = self.shards.len();

        // route tombstones to their owning shard, shard-local ids
        let mut local_tombs: Vec<Vec<u32>> = vec![Vec::new(); s];
        {
            let mut si = 0usize;
            for &e in &delta.tombstoned {
                let e = e as usize;
                while si + 1 < s && e >= self.shards[si].offset + self.shards[si].len {
                    si += 1;
                }
                let sh = &self.shards[si];
                debug_assert!(e >= sh.offset && e < sh.offset + sh.len);
                local_tombs[si].push((e - sh.offset) as u32);
            }
        }

        // per-shard indices cannot be empty: if any shard's live range
        // would vanish, rebuild the whole set over the effective rows
        let empties = (0..s).any(|i| {
            let ins = if i == s - 1 { delta.inserted.len() } else { 0 };
            local_tombs[i].len() == self.shards[i].len + ins
        });
        if empties {
            let vs = apply_delta_to_vectors(&self.live_vectors(), delta)?;
            return Ok((ShardSet::build(self.kind, &vs, s, seed), true));
        }

        let mut new_shards = Vec::with_capacity(s);
        let mut offset = 0usize;
        for (i, sh) in self.shards.iter().enumerate() {
            let inserted = if i == s - 1 {
                delta.inserted.clone()
            } else {
                VectorSet::zeros(0, self.d)
            };
            let local = WorkloadDelta { inserted, tombstoned: std::mem::take(&mut local_tombs[i]) };
            let (index, len) = if local.is_empty() {
                (Arc::clone(&sh.index), sh.len)
            } else {
                let shard_seed = seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                let patched = sh.index.patch(&local, shard_seed)?;
                let len = patched.index.len();
                (patched.index, len)
            };
            new_shards.push(ShardHandle { offset, len, index });
            offset += len;
        }
        Ok((
            ShardSet { shards: new_shards, m: offset, d: self.d, kind: self.kind },
            false,
        ))
    }
}

/// Snapshot payload: the shared index kind, the partition geometry and one
/// nested index snapshot per shard (each dispatched through
/// [`snapshot::encode_index`] / [`snapshot::decode_index`]). Decode
/// validates that the shards are contiguous, cover all m candidates, and
/// that every nested index matches its shard's geometry and the set's
/// kind — a corrupted artifact errors out instead of serving draws from a
/// mis-shapen partition.
impl SnapshotCodec for ShardSet {
    fn encode(&self, w: &mut SnapshotWriter<'_>) {
        w.u8(self.kind.tag());
        w.len(self.m);
        w.len(self.d);
        w.len(self.shards.len());
        for shard in &self.shards {
            w.len(shard.offset);
            w.len(shard.len);
            snapshot::encode_index(shard.index.as_ref(), w);
        }
    }

    fn decode(r: &mut SnapshotReader<'_>) -> Result<Self, SnapshotError> {
        let tag = r.u8()?;
        let kind = IndexKind::from_tag(tag)
            .ok_or_else(|| malformed(format!("unknown shard-set kind tag {tag}")))?;
        let m = r.u64_as_usize()?;
        let d = r.u64_as_usize()?;
        // each shard occupies >= 16 bytes (its offset + len prefix), so
        // the shard count is a guarded collection length
        let s = r.read_len(16)?;
        if m == 0 || s == 0 || s > m {
            return Err(malformed(format!("shard set geometry m={m} S={s} impossible")));
        }
        let mut shards = Vec::with_capacity(s);
        let mut next = 0usize;
        for i in 0..s {
            let offset = r.u64_as_usize()?;
            let len = r.u64_as_usize()?;
            if offset != next || len == 0 {
                return Err(malformed(format!(
                    "shard {i}: offset {offset} len {len} breaks contiguous cover at {next}"
                )));
            }
            let index = snapshot::decode_index(r)?;
            if index.kind() != kind || index.len() != len || index.dim() != d {
                return Err(malformed(format!(
                    "shard {i}: nested index {}({}, d={}) does not match shard \
                     {kind}({len}, d={d})",
                    index.kind(),
                    index.len(),
                    index.dim()
                )));
            }
            next = offset + len;
            shards.push(ShardHandle { offset, len, index });
        }
        if next != m {
            return Err(malformed(format!("shards cover {next} of {m} candidates")));
        }
        Ok(ShardSet { shards, m, d, kind })
    }
}

/// The exponential mechanism over S independently-indexed shards — exact
/// by Gumbel max-stability, parallel by construction.
///
/// ```
/// use fast_mwem::lazy::{ScoreTransform, ShardedLazyEm};
/// use fast_mwem::mips::{IndexKind, VectorSet};
/// use fast_mwem::util::rng::Rng;
///
/// let mut rng = Rng::new(1);
/// let data: Vec<f32> = (0..64 * 4).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
/// let vs = VectorSet::new(data, 64, 4);
/// let em = ShardedLazyEm::build(
///     IndexKind::Flat,
///     &vs,
///     4, // shards
///     ScoreTransform::Abs,
///     7, // seed
/// );
/// assert_eq!(em.num_shards(), 4);
/// let sample = em.select(&mut rng, &[0.1, -0.2, 0.3, 0.0], 1.0, 0.1);
/// assert!(sample.index < 64);
/// ```
pub struct ShardedLazyEm<'a> {
    /// The per-shard indices (owned or shared — see
    /// [`ShardedLazyEm::with_shard_set`]).
    set: Arc<ShardSet>,
    /// The full candidate set (borrowed, like [`super::LazyEm`]'s), for
    /// exact tail scoring by global row id — only the per-shard index
    /// copies are owned.
    vectors: &'a VectorSet,
    transform: ScoreTransform,
    /// Per-shard top-k size (default ⌈√(m/S)⌉, clamped to each shard).
    k: usize,
    margin_slack: f64,
    parallel_select: bool,
    workers: usize,
}

impl<'a> ShardedLazyEm<'a> {
    /// Partition `vectors` into `shards` contiguous shards and build one
    /// index of `kind` per shard, in parallel (one scoped build job per
    /// shard via [`parallel_map`]).
    ///
    /// `shards` is clamped to `[1, m]`; shard sizes differ by at most one.
    /// Panics if `vectors` is empty. Equivalent to [`ShardSet::build`]
    /// followed by [`ShardedLazyEm::with_shard_set`].
    pub fn build(
        kind: IndexKind,
        vectors: &'a VectorSet,
        shards: usize,
        transform: ScoreTransform,
        seed: u64,
    ) -> Self {
        Self::with_shard_set(
            Arc::new(ShardSet::build(kind, vectors, shards, seed)),
            vectors,
            transform,
        )
    }

    /// Wrap a pre-built (possibly cached and shared) [`ShardSet`] — the
    /// warm-serving entry point: repeated jobs on the same workload pass
    /// clones of one `Arc<ShardSet>` and skip index construction entirely.
    ///
    /// Panics unless the set's geometry matches `vectors` (same candidate
    /// count and dimension) — the set must have been built over the same
    /// vector content for draws to be meaningful.
    pub fn with_shard_set(
        set: Arc<ShardSet>,
        vectors: &'a VectorSet,
        transform: ScoreTransform,
    ) -> Self {
        assert_eq!(set.len(), vectors.len(), "shard set must cover the candidate set");
        assert_eq!(set.dim(), vectors.dim(), "shard set dimension mismatch");
        let (m, s) = (set.len(), set.num_shards());
        let k = ((m as f64 / s as f64).sqrt().ceil() as usize).max(1);
        ShardedLazyEm {
            set,
            vectors,
            transform,
            k,
            margin_slack: 0.0,
            parallel_select: false,
            workers: s,
        }
    }

    /// Override the per-shard top-k size (clamped to ≥ 1; further clamped
    /// to each shard's length at draw time).
    pub fn with_k(mut self, k: usize) -> Self {
        self.k = k.max(1);
        self
    }

    /// Set Algorithm 6's margin reduction `c` (applied within each shard).
    pub fn with_margin_slack(mut self, c: f64) -> Self {
        self.margin_slack = c;
        self
    }

    /// Run the S shard draws of each `select` on scoped threads instead of
    /// inline. Each draw pays an S-thread spawn/join, so this only wins
    /// once per-shard work (√(m/S) score evaluations) dominates thread
    /// dispatch — keep it off for small shards. The result is bit-identical
    /// either way because every shard consumes its own pre-split RNG stream.
    pub fn with_parallel_select(mut self, parallel: bool) -> Self {
        self.parallel_select = parallel;
        self
    }

    /// Cap the pool width used for parallel selection (default: one worker
    /// per shard).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Total number of candidates m.
    pub fn len(&self) -> usize {
        self.vectors.len()
    }

    /// True when the candidate set is empty (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.vectors.is_empty()
    }

    /// Number of shards S.
    pub fn num_shards(&self) -> usize {
        self.set.num_shards()
    }

    /// Per-shard top-k size.
    pub fn k(&self) -> usize {
        self.k
    }

    /// The underlying (shareable) shard set.
    pub fn shard_set(&self) -> &Arc<ShardSet> {
        &self.set
    }

    /// `(offset, len)` of every shard, in candidate-id order.
    pub fn shard_bounds(&self) -> Vec<(usize, usize)> {
        self.set.bounds()
    }

    /// One shard's lazy Gumbel draw: retrieve the shard-local top-k, take
    /// the lazy perturbed max over the shard, and translate the winner to
    /// its global candidate id. Called from
    /// [`crate::coordinator::job::execute_shard_search`].
    pub(crate) fn shard_draw(
        &self,
        shard_id: usize,
        mut rng: Rng,
        query: &[f32],
        scale: f64,
    ) -> LazySample {
        let shard = &self.set.shards[shard_id];
        let k = self.k.clamp(1, shard.len);
        let mut top = retrieve_top_k_from(shard.index.as_ref(), self.transform, k, query);
        for t in top.iter_mut() {
            t.1 *= scale;
        }
        let (offset, transform, vectors) = (shard.offset, self.transform, self.vectors);
        let mut local = lazy_gumbel_max(&mut rng, &top, shard.len, self.margin_slack, |i| {
            scale * transform_ip(transform, dot(vectors.row(offset + i), query) as f64)
        });
        local.index += offset;
        local
    }

    /// One ε₀-DP selection: sample i ∝ exp(ε₀·score_i/(2Δ)) — identical in
    /// distribution to [`super::LazyEm::select`] over the same candidates.
    pub fn select(
        &self,
        rng: &mut Rng,
        query: &[f32],
        eps0: f64,
        sensitivity: f64,
    ) -> LazySample {
        self.select_detailed(rng, query, eps0, sensitivity).0
    }

    /// Like [`ShardedLazyEm::select`], additionally returning every shard's
    /// own draw (diagnostics and the max-stability tests). The combined
    /// sample's `index`, `value` and `b` come from the winning shard;
    /// `work` and `tail_count` are summed across shards (total score
    /// evaluations charged to the draw — wall-clock divides by the pool
    /// width when parallel selection is on).
    pub fn select_detailed(
        &self,
        rng: &mut Rng,
        query: &[f32],
        eps0: f64,
        sensitivity: f64,
    ) -> (LazySample, Vec<LazySample>) {
        let scale = eps0 / (2.0 * sensitivity);
        // Pre-split one RNG stream per shard on the caller's thread: the
        // draw is deterministic in `rng` no matter how jobs are scheduled.
        let jobs: Vec<ShardSearchJob> = (0..self.num_shards())
            .map(|i| ShardSearchJob { shard_id: i, rng: rng.split(i as u64) })
            .collect();

        let draws: Vec<LazySample> = if self.parallel_select && self.num_shards() > 1 {
            parallel_map(self.workers, jobs, |job| {
                execute_shard_search(self, query, scale, job)
            })
        } else {
            jobs.into_iter()
                .map(|job| execute_shard_search(self, query, scale, job))
                .collect()
        };

        // Gumbel max-stability: the global sample is the shard draw with
        // the largest perturbed value.
        let mut combined = draws[0];
        for d in &draws[1..] {
            if d.value > combined.value {
                combined.index = d.index;
                combined.value = d.value;
                combined.b = d.b;
            }
            combined.tail_count += d.tail_count;
            combined.work += d.work;
        }
        (combined, draws)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lazy::{LazyEm, ScoreTransform};
    use crate::mips::FlatIndex;

    fn random_set(n: usize, d: usize, seed: u64) -> VectorSet {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
        VectorSet::new(data, n, d)
    }

    /// A pre-built, `Arc`-shared [`ShardSet`] is bit-identical to a fresh
    /// inline build with the same seed: warm (cached) serving changes
    /// nothing about the draw.
    #[test]
    fn shared_shard_set_draws_match_fresh_build() {
        let vs = random_set(60, 5, 21);
        let mut qrng = Rng::new(30);
        let q: Vec<f32> = (0..5).map(|_| qrng.uniform(-0.5, 0.5) as f32).collect();

        let set = Arc::new(ShardSet::build(IndexKind::Flat, &vs, 3, 22));
        assert_eq!(set.kind(), IndexKind::Flat);
        assert_eq!((set.len(), set.dim(), set.num_shards()), (60, 5, 3));
        let warm_a = ShardedLazyEm::with_shard_set(Arc::clone(&set), &vs, ScoreTransform::Abs);
        let warm_b = ShardedLazyEm::with_shard_set(Arc::clone(&set), &vs, ScoreTransform::Abs);
        let cold = ShardedLazyEm::build(IndexKind::Flat, &vs, 3, ScoreTransform::Abs, 22);

        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let mut r3 = Rng::new(5);
        for _ in 0..50 {
            let a = warm_a.select(&mut r1, &q, 1.0, 0.1);
            let b = warm_b.select(&mut r2, &q, 1.0, 0.1);
            let c = cold.select(&mut r3, &q, 1.0, 0.1);
            assert_eq!(a.index, c.index);
            assert_eq!(a.index, b.index);
            assert_eq!(a.work, c.work);
            assert!(a.value == c.value);
        }
    }

    #[test]
    fn shard_bounds_partition_the_candidates() {
        for (m, s) in [(10, 1), (10, 2), (10, 7), (10, 10), (10, 25), (64, 4)] {
            let vs = random_set(m, 3, 1);
            let em = ShardedLazyEm::build(IndexKind::Flat, &vs, s, ScoreTransform::Abs, 2);
            let bounds = em.shard_bounds();
            assert_eq!(em.num_shards(), s.min(m));
            let mut next = 0usize;
            for &(offset, len) in &bounds {
                assert_eq!(offset, next, "shards must be contiguous");
                assert!(len >= 1);
                next += len;
            }
            assert_eq!(next, m, "shards must cover all m candidates");
            // balanced: sizes differ by at most one
            let lens: Vec<usize> = bounds.iter().map(|&(_, l)| l).collect();
            let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(hi - lo <= 1, "unbalanced shards {lens:?}");
        }
    }

    /// The acceptance bar of this subsystem: for S ∈ {1, 2, 7} the sharded
    /// mechanism's selection distribution equals the exact softmax (and
    /// hence [`LazyEm`]'s — Theorem 3.3 plus max-stability).
    #[test]
    fn sharded_matches_exhaustive_em_distribution() {
        let m = 40;
        let d = 6;
        let vs = random_set(m, d, 1);
        let mut rng = Rng::new(2);
        let q: Vec<f32> = (0..d).map(|_| rng.uniform(-0.5, 0.5) as f32).collect();
        let (eps0, sens) = (1.0, 0.05);
        let scale = eps0 / (2.0 * sens);

        // target softmax over |<v_i, q>|
        let weights: Vec<f64> = (0..m)
            .map(|i| (scale * (dot(vs.row(i), &q) as f64).abs()).exp())
            .collect();
        let z: f64 = weights.iter().sum();

        for s in [1usize, 2, 7] {
            let em = ShardedLazyEm::build(IndexKind::Flat, &vs, s, ScoreTransform::Abs, 3);
            let trials = 120_000;
            let mut counts = vec![0usize; m];
            for _ in 0..trials {
                counts[em.select(&mut rng, &q, eps0, sens).index] += 1;
            }
            let mut max_err = 0.0f64;
            for i in 0..m {
                let want = weights[i] / z;
                let got = counts[i] as f64 / trials as f64;
                max_err = max_err.max((got - want).abs());
            }
            assert!(max_err < 0.013, "S={s}: max abs prob error {max_err}");
        }
    }

    /// Max-stability identity, checked exactly per draw: the combined
    /// sample IS the shard draw with the maximal perturbed value, its
    /// index lies inside the winning shard, and work/tails are summed.
    #[test]
    fn combine_is_exact_argmax_over_shard_values() {
        let m = 50;
        let d = 4;
        let vs = random_set(m, d, 5);
        let mut rng = Rng::new(6);
        let q: Vec<f32> = (0..d).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();

        for s in [1usize, 2, 7] {
            let em = ShardedLazyEm::build(IndexKind::Flat, &vs, s, ScoreTransform::Signed, 7);
            let bounds = em.shard_bounds();
            for _ in 0..200 {
                let (combined, draws) = em.select_detailed(&mut rng, &q, 2.0, 0.5);
                assert_eq!(draws.len(), em.num_shards());
                let best = draws
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.value.total_cmp(&b.1.value))
                    .unwrap();
                assert_eq!(combined.index, best.1.index);
                assert_eq!(combined.value, best.1.value);
                let (offset, len) = bounds[best.0];
                assert!(
                    combined.index >= offset && combined.index < offset + len,
                    "winner {} outside its shard [{offset}, {})",
                    combined.index,
                    offset + len
                );
                assert_eq!(
                    combined.work,
                    draws.iter().map(|d| d.work).sum::<usize>()
                );
                assert_eq!(
                    combined.tail_count,
                    draws.iter().map(|d| d.tail_count).sum::<usize>()
                );
                // every shard draw stays within its own candidate range
                for (i, dr) in draws.iter().enumerate() {
                    let (o, l) = bounds[i];
                    assert!(dr.index >= o && dr.index < o + l);
                }
            }
        }
    }

    /// At near-deterministic ε the sharded and monolithic mechanisms must
    /// agree exactly: both return the true argmax.
    #[test]
    fn sharded_and_monolithic_agree_at_high_eps() {
        let m = 100;
        let d = 8;
        let vs = random_set(m, d, 3);
        let q = vec![1.0f32; d];
        let best = (0..m)
            .max_by(|&a, &b| dot(vs.row(a), &q).total_cmp(&dot(vs.row(b), &q)))
            .unwrap();

        let flat = FlatIndex::new(vs.clone());
        let mono = LazyEm::new(&flat, &vs, ScoreTransform::Signed);
        let mut rng = Rng::new(4);
        for s in [1usize, 2, 7] {
            let em = ShardedLazyEm::build(IndexKind::Flat, &vs, s, ScoreTransform::Signed, 9);
            let mut agree = 0usize;
            for _ in 0..100 {
                let a = em.select(&mut rng, &q, 5_000.0, 1.0).index;
                let b = mono.select(&mut rng, &q, 5_000.0, 1.0).index;
                if a == best {
                    agree += 1;
                }
                assert_eq!(
                    a, b,
                    "S={s}: at ε→∞ both must return the argmax deterministically"
                );
            }
            assert!(agree > 95, "S={s}: hit rate {agree}/100");
        }
    }

    /// Parallel shard search returns exactly the sequential result (the
    /// RNG streams are pre-split, so scheduling cannot change the draw).
    #[test]
    fn parallel_select_is_deterministic() {
        let m = 200;
        let d = 6;
        let vs = random_set(m, d, 8);
        let q: Vec<f32> = (0..d).map(|i| (i as f32 * 0.3).sin()).collect();

        let seq = ShardedLazyEm::build(IndexKind::Flat, &vs, 4, ScoreTransform::Abs, 11)
            .with_parallel_select(false);
        let par = ShardedLazyEm::build(IndexKind::Flat, &vs, 4, ScoreTransform::Abs, 11)
            .with_parallel_select(true);

        let mut rng_a = Rng::new(12);
        let mut rng_b = Rng::new(12);
        for _ in 0..50 {
            let a = seq.select(&mut rng_a, &q, 1.0, 0.1);
            let b = par.select(&mut rng_b, &q, 1.0, 0.1);
            assert_eq!(a.index, b.index);
            assert_eq!(a.work, b.work);
            assert!((a.value - b.value).abs() == 0.0);
        }
    }

    /// Per-shard routing: a patched shard set covers exactly the effective
    /// rows (same partition invariants as a fresh build), untouched shards
    /// are reused by pointer, and flat-shard draws through the patched set
    /// are bit-identical to a set built fresh over the effective rows.
    #[test]
    fn patched_shard_set_matches_fresh_build_over_effective_rows() {
        let m = 60;
        let d = 5;
        let vs = random_set(m, d, 40);
        let set = ShardSet::build(IndexKind::Flat, &vs, 3, 41);

        let mut rng = Rng::new(42);
        let ins: Vec<f32> = (0..4 * d).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
        // tombstones span shard 0 (id 1) and shard 2 (ids 41, 59)
        let delta = WorkloadDelta::new(VectorSet::new(ins, 4, d), vec![1, 41, 59]);
        let effective = apply_delta_to_vectors(&vs, &delta).unwrap();

        let (patched, rebuilt) = set.patch(&delta, 43).unwrap();
        assert!(!rebuilt);
        assert_eq!(patched.len(), m - 3 + 4);
        assert_eq!(patched.num_shards(), 3);
        assert_eq!(patched.live_vectors().to_vec(), effective.to_vec());
        // partition invariants: contiguous cover of the effective rows
        let mut next = 0usize;
        for (offset, len) in patched.bounds() {
            assert_eq!(offset, next);
            assert!(len >= 1);
            next += len;
        }
        assert_eq!(next, patched.len());

        // flat shards: draws through the patched set are bit-identical to
        // a fresh build over the effective rows (flat patch is exact)
        let fresh = ShardSet::build(IndexKind::Flat, &effective, 3, 44);
        // shard sizes can differ (patched keeps survivor-based bounds), so
        // compare selection distributions via identical per-draw RNG only
        // when the bounds agree; otherwise compare against the softmax.
        let patched_em = ShardedLazyEm::with_shard_set(
            Arc::new(patched),
            &effective,
            ScoreTransform::Abs,
        );
        let fresh_em =
            ShardedLazyEm::with_shard_set(Arc::new(fresh), &effective, ScoreTransform::Abs);
        let q: Vec<f32> = (0..d).map(|_| rng.uniform(-0.5, 0.5) as f32).collect();
        let (eps0, sens) = (1.0, 0.05);
        let scale = eps0 / (2.0 * sens);
        let weights: Vec<f64> = (0..effective.len())
            .map(|i| (scale * (dot(effective.row(i), &q) as f64).abs()).exp())
            .collect();
        let z: f64 = weights.iter().sum();
        let trials = 60_000;
        let mut rng2 = Rng::new(45);
        let mut rng3 = Rng::new(46);
        let (mut c_patched, mut c_fresh) =
            (vec![0usize; effective.len()], vec![0usize; effective.len()]);
        for _ in 0..trials {
            c_patched[patched_em.select(&mut rng2, &q, eps0, sens).index] += 1;
            c_fresh[fresh_em.select(&mut rng3, &q, eps0, sens).index] += 1;
        }
        for i in 0..effective.len() {
            let want = weights[i] / z;
            for (label, counts) in [("patched", &c_patched), ("fresh", &c_fresh)] {
                let got = counts[i] as f64 / trials as f64;
                assert!(
                    (got - want).abs() < 0.02,
                    "{label} candidate {i}: {got:.4} vs {want:.4}"
                );
            }
        }

        // an untouched middle shard is shared by pointer, not rebuilt
        let delta_edge = WorkloadDelta::new(VectorSet::zeros(0, d), vec![0]);
        let (patched2, _) = set.patch(&delta_edge, 47).unwrap();
        let old_mid = set.bounds()[1];
        assert_eq!(patched2.bounds()[1], (old_mid.0 - 1, old_mid.1), "mid shard shifts left");
    }

    /// A delta that would empty a shard forces a full rebuild of the set.
    #[test]
    fn emptying_a_shard_forces_full_rebuild() {
        let vs = random_set(9, 4, 50);
        let set = ShardSet::build(IndexKind::Flat, &vs, 3, 51);
        // shard 0 covers ids 0..3: kill all three
        let delta = WorkloadDelta::new(VectorSet::zeros(0, 4), vec![0, 1, 2]);
        let (patched, rebuilt) = set.patch(&delta, 52).unwrap();
        assert!(rebuilt, "an emptied shard must force a full rebuild");
        assert_eq!(patched.len(), 6);
        let mut next = 0usize;
        for (offset, len) in patched.bounds() {
            assert_eq!(offset, next);
            assert!(len >= 1);
            next += len;
        }
        assert_eq!(next, 6);
    }

    /// Expected per-draw work obeys the sharded bound: about S·√(m/S) score
    /// evaluations in total (√(m/S) per shard), i.e. √(S·m) — not S·√m.
    #[test]
    fn total_work_tracks_sharded_bound() {
        let m = 4_096;
        let d = 8;
        let s = 4;
        let vs = random_set(m, d, 9);
        let em = ShardedLazyEm::build(IndexKind::Flat, &vs, s, ScoreTransform::Abs, 10);
        let mut rng = Rng::new(13);
        let q: Vec<f32> = (0..d).map(|_| rng.uniform(-0.1, 0.1) as f32).collect();
        let trials = 50;
        let mut total = 0usize;
        for _ in 0..trials {
            total += em.select(&mut rng, &q, 1.0, 1.0).work;
        }
        let avg = total as f64 / trials as f64;
        let bound = 6.0 * (s as f64) * (m as f64 / s as f64).sqrt();
        assert!(avg < bound, "avg work {avg} vs bound {bound}");
    }
}
