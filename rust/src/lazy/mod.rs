//! Lazy exponential mechanism — the paper's core contribution (§3.3–3.5).
//!
//! [`lazy_gumbel_max`] implements Algorithms 4/5/6 (Mussmann et al. 2017's
//! lazy Gumbel sampling plus the paper's approximate-top-k variants);
//! [`LazyEm`] wires it to a k-MIPS index so a single EM draw over m
//! candidates costs Θ(√m) expected time instead of Θ(m); and
//! [`ShardedLazyEm`] splits the candidates across S per-shard indices —
//! exact by Gumbel max-stability — so index construction and the per-draw
//! search parallelize on the coordinator pool (DESIGN.md §5).

pub mod gumbel;
pub mod lazy_em;
pub mod sharded;

pub use gumbel::{lazy_gumbel_max, LazySample};
pub use lazy_em::{LazyEm, ScoreTransform};
pub use sharded::{ShardSet, ShardedLazyEm};
