//! LazyEM: the exponential mechanism in Θ(√m) expected time (Algorithm 2's
//! `LazyEM` procedure), backed by any [`MipsIndex`].
//!
//! Scores must be inner products ⟨v_i, q⟩ of a static vector set against the
//! evolving query — exactly the structure of MWEM (scores |⟨q_i, h−p⟩|) and
//! of the private LP solvers (scores ⟨A_i∘b_i, x̃∘−1⟩ and ⟨y, N_j⟩).
//!
//! For absolute-value scores we do NOT double the dataset with complements
//! as the paper suggests (if q ∈ Q then 1−q ∈ Q): since both h and p are
//! distributions, ⟨1−q, h−p⟩ = −⟨q, h−p⟩, so querying the index with both
//! `d` and `−d` and merging by |·| retrieves the same top-k with half the
//! memory. This is documented as a substitution in DESIGN.md §3.

use super::gumbel::{lazy_gumbel_max, LazySample};
use crate::mips::{MipsIndex, VectorSet};
use crate::runtime::kernels::dot;
use crate::util::rng::Rng;

/// How raw inner products map to EM scores.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoreTransform {
    /// score_i = ⟨v_i, q⟩ (LP constraint selection).
    Signed,
    /// score_i = |⟨v_i, q⟩| (linear-query error selection).
    Abs,
}

/// Apply a [`ScoreTransform`] to a raw inner product.
#[inline]
pub(crate) fn transform_ip(transform: ScoreTransform, ip: f64) -> f64 {
    match transform {
        ScoreTransform::Signed => ip,
        ScoreTransform::Abs => ip.abs(),
    }
}

/// Retrieve the (approximate) top-k of `index` by transformed score.
///
/// For [`ScoreTransform::Abs`] the index is probed with both `query` and
/// `−query` and the hits merged by `max` — the complement trick of
/// DESIGN.md §3 (`|⟨v,q⟩| = max(⟨v,q⟩, ⟨v,−q⟩)`), shared by [`LazyEm`] and
/// the per-shard retrieval of [`super::ShardedLazyEm`].
pub(crate) fn retrieve_top_k_from(
    index: &dyn MipsIndex,
    transform: ScoreTransform,
    k: usize,
    query: &[f32],
) -> Vec<(usize, f64)> {
    match transform {
        ScoreTransform::Signed => index
            .top_k(query, k)
            .into_iter()
            .map(|nb| (nb.id as usize, nb.score as f64))
            .collect(),
        ScoreTransform::Abs => {
            // |⟨v,q⟩| = max(⟨v,q⟩, ⟨v,−q⟩): query both directions, merge.
            let neg: Vec<f32> = query.iter().map(|&x| -x).collect();
            let mut best: std::collections::HashMap<usize, f64> =
                std::collections::HashMap::with_capacity(2 * k);
            for nb in index.top_k(query, k).into_iter().chain(index.top_k(&neg, k)) {
                let e = best.entry(nb.id as usize).or_insert(f64::NEG_INFINITY);
                *e = e.max(nb.score as f64);
            }
            let mut v: Vec<(usize, f64)> = best.into_iter().collect();
            v.sort_by(|a, b| b.1.total_cmp(&a.1));
            v.truncate(k);
            v
        }
    }
}

/// The lazy exponential mechanism over a single monolithic k-MIPS index
/// (Algorithm 2's `LazyEM` procedure). Borrows the index and the raw
/// vectors; one instance serves any number of [`LazyEm::select`] draws.
pub struct LazyEm<'a> {
    index: &'a dyn MipsIndex,
    vectors: &'a VectorSet,
    transform: ScoreTransform,
    /// Top-k size; the paper uses k = √m.
    pub k: usize,
    /// Algorithm 6's margin reduction c (0 for Algorithms 4/5).
    pub margin_slack: f64,
}

impl<'a> LazyEm<'a> {
    /// Create a lazy EM over `index`, defaulting k to ⌈√m⌉.
    ///
    /// ```
    /// use fast_mwem::lazy::{LazyEm, ScoreTransform};
    /// use fast_mwem::mips::{FlatIndex, VectorSet};
    /// use fast_mwem::util::rng::Rng;
    ///
    /// // 4 candidate vectors in 2 dimensions
    /// let vs = VectorSet::new(vec![1.0, 0.0, 0.0, 1.0, -1.0, 0.0, 0.5, 0.5], 4, 2);
    /// let index = FlatIndex::new(vs.clone());
    /// let em = LazyEm::new(&index, &vs, ScoreTransform::Abs);
    /// assert_eq!(em.k, 2); // ⌈√4⌉
    ///
    /// // one ε₀-DP draw ∝ exp(ε₀·|⟨v_i, q⟩|/(2Δ))
    /// let mut rng = Rng::new(7);
    /// let sample = em.select(&mut rng, &[1.0, 0.0], 1.0, 0.1);
    /// assert!(sample.index < 4);
    /// ```
    pub fn new(
        index: &'a dyn MipsIndex,
        vectors: &'a VectorSet,
        transform: ScoreTransform,
    ) -> Self {
        let m = index.len();
        let k = ((m as f64).sqrt().ceil() as usize).clamp(1, m);
        LazyEm { index, vectors, transform, k, margin_slack: 0.0 }
    }

    /// Override the top-k size (clamped to `[1, m]`).
    pub fn with_k(mut self, k: usize) -> Self {
        self.k = k.clamp(1, self.index.len());
        self
    }

    /// Set Algorithm 6's margin reduction `c` (see [`lazy_gumbel_max`]).
    pub fn with_margin_slack(mut self, c: f64) -> Self {
        self.margin_slack = c;
        self
    }

    /// Raw (untransformed-scale) score of candidate i for `query`.
    #[inline]
    pub fn raw_score(&self, i: usize, query: &[f32]) -> f64 {
        transform_ip(self.transform, dot(self.vectors.row(i), query) as f64)
    }

    /// Retrieve the (approximate) top-k candidates by transformed score.
    pub fn retrieve_top_k(&self, query: &[f32]) -> Vec<(usize, f64)> {
        retrieve_top_k_from(self.index, self.transform, self.k, query)
    }

    /// One ε₀-DP selection: sample i ∝ exp(ε₀·score_i/(2Δ)) in Θ(√m)
    /// expected time.
    pub fn select(
        &self,
        rng: &mut Rng,
        query: &[f32],
        eps0: f64,
        sensitivity: f64,
    ) -> LazySample {
        let scale = eps0 / (2.0 * sensitivity);
        let mut top = self.retrieve_top_k(query);
        for t in top.iter_mut() {
            t.1 *= scale;
        }
        lazy_gumbel_max(rng, &top, self.index.len(), self.margin_slack, |i| {
            scale * self.raw_score(i, query)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mips::FlatIndex;

    fn random_set(n: usize, d: usize, seed: u64) -> VectorSet {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.uniform(0.0, 1.0) as f32).collect();
        VectorSet::new(data, n, d)
    }

    /// With a perfect (flat) index, LazyEM's output distribution is exactly
    /// the exponential mechanism's — Theorem 3.3's key claim.
    #[test]
    fn lazy_em_equals_exhaustive_em_distribution() {
        let m = 40;
        let d = 6;
        let vs = random_set(m, d, 1);
        let flat = FlatIndex::new(vs.clone());
        let em = LazyEm::new(&flat, &vs, ScoreTransform::Abs).with_k(7);

        let mut rng = Rng::new(2);
        let q: Vec<f32> = (0..d).map(|_| rng.uniform(-0.5, 0.5) as f32).collect();
        let (eps0, sens) = (1.0, 0.05);
        let scale = eps0 / (2.0 * sens);

        // target softmax over |<v_i, q>|
        let weights: Vec<f64> = (0..m)
            .map(|i| (scale * (dot(vs.row(i), &q) as f64).abs()).exp())
            .collect();
        let z: f64 = weights.iter().sum();

        let trials = 150_000;
        let mut counts = vec![0usize; m];
        for _ in 0..trials {
            counts[em.select(&mut rng, &q, eps0, sens).index] += 1;
        }
        let mut max_err = 0.0f64;
        for i in 0..m {
            let want = weights[i] / z;
            let got = counts[i] as f64 / trials as f64;
            max_err = max_err.max((got - want).abs());
        }
        assert!(max_err < 0.012, "max abs prob error {max_err}");
    }

    #[test]
    fn signed_transform_prefers_largest_ip() {
        let m = 100;
        let d = 8;
        let vs = random_set(m, d, 3);
        let flat = FlatIndex::new(vs.clone());
        let em = LazyEm::new(&flat, &vs, ScoreTransform::Signed);
        let mut rng = Rng::new(4);
        let q = vec![1.0f32; 8];
        // very high eps → near-deterministic argmax
        let best = (0..m)
            .max_by(|&a, &b| dot(vs.row(a), &q).total_cmp(&dot(vs.row(b), &q)))
            .unwrap();
        let mut hits = 0;
        for _ in 0..200 {
            if em.select(&mut rng, &q, 5_000.0, 1.0).index == best {
                hits += 1;
            }
        }
        assert!(hits > 190, "hits {hits}");
    }

    #[test]
    fn abs_transform_finds_negative_direction() {
        // one vector strongly anti-aligned with q must be retrievable by |.|
        let d = 4;
        let mut data = vec![0.1f32; 20 * d];
        data[5 * d..6 * d].copy_from_slice(&[-5.0, -5.0, -5.0, -5.0]);
        let vs = VectorSet::new(data, 20, d);
        let flat = FlatIndex::new(vs.clone());
        let em = LazyEm::new(&flat, &vs, ScoreTransform::Abs).with_k(4);
        let top = em.retrieve_top_k(&[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(top[0].0, 5, "anti-aligned vector must rank first");
        assert!((top[0].1 - 20.0).abs() < 1e-4);
    }

    #[test]
    fn work_is_sublinear() {
        let m = 4_096;
        let d = 8;
        let vs = random_set(m, d, 5);
        let flat = FlatIndex::new(vs.clone());
        let em = LazyEm::new(&flat, &vs, ScoreTransform::Abs);
        let mut rng = Rng::new(6);
        let q: Vec<f32> = (0..d).map(|_| rng.uniform(-0.1, 0.1) as f32).collect();
        let mut total_work = 0usize;
        let trials = 50;
        for _ in 0..trials {
            total_work += em.select(&mut rng, &q, 1.0, 1.0).work;
        }
        let avg = total_work as f64 / trials as f64;
        assert!(avg < 6.0 * (m as f64).sqrt(), "avg work {avg}");
    }
}
