//! Lazy Gumbel sampling (Algorithm 4) and its approximate-top-k variants
//! (Algorithm 5: runtime-preserving; Algorithm 6: privacy-preserving).
//!
//! Given the (approximate) top-k of n scores, sample from the softmax over
//! *all* n scores while only ever evaluating Θ(√n) of them:
//!
//! 1. perturb the k known scores with Gumbel(0,1) noise; let M be the max,
//!    L the smallest known score, B = M − L (− c for Algorithm 6);
//! 2. any unseen score is ≤ L (+ c), so it can only win if its Gumbel noise
//!    exceeds B — which happens with probability `1 − exp(−exp(−B))` ≈ e^−B;
//! 3. draw `C ~ Bin(n − k, tail)` — the number of tail winners — place them
//!    uniformly in [n] \ S, give each a truncated Gumbel (Lemma C.3), and
//!    return the overall argmax.
//!
//! With k = √n, E[C] = O(√n) (Theorem D.1), so the whole draw is Θ(√n)
//! expected score evaluations.

use crate::sampling::{binomial::binomial, subset::sample_distinct_excluding, truncated::gumbel_tail_prob, truncated_gumbel};
use crate::util::rng::Rng;

/// Outcome of one lazy Gumbel draw, with the diagnostics the paper plots
/// (Figure 6 studies `tail_count`; Figure 4 the total work).
#[derive(Clone, Copy, Debug)]
pub struct LazySample {
    /// The sampled candidate (index into [0, n)).
    pub index: usize,
    /// The winner's Gumbel-perturbed score, `max_i (score_i + G_i)`. By
    /// Gumbel max-stability this is what lets independent draws be combined
    /// with a plain max: the argmax across disjoint candidate sets of their
    /// per-set perturbed maxima is an exact softmax sample over the union —
    /// the identity [`crate::lazy::ShardedLazyEm`] is built on.
    pub value: f64,
    /// The margin B = M − L − margin_slack.
    pub b: f64,
    /// C — how many tail candidates needed scoring.
    pub tail_count: usize,
    /// Total score evaluations charged to this draw (k + C).
    pub work: usize,
}

/// One draw from `p_i ∝ exp(score_i)` over `n` candidates.
///
/// * `top`: the (approximate) top-k as `(candidate index, score)` pairs —
///   scores already scaled by ε₀/(2Δ) by the caller. Need not be sorted.
/// * `margin_slack`: the paper's `c` for Algorithm 6 (lower B by c to keep
///   exactness under a c-approximate top-k, at e^c extra samples); 0 for
///   Algorithms 4/5.
/// * `tail_score`: oracle for scaled scores of candidates outside `top`
///   (exact inner products in all our applications).
///
/// Panics if `top` is empty or contains out-of-range indices.
pub fn lazy_gumbel_max(
    rng: &mut Rng,
    top: &[(usize, f64)],
    n: usize,
    margin_slack: f64,
    mut tail_score: impl FnMut(usize) -> f64,
) -> LazySample {
    assert!(!top.is_empty(), "lazy_gumbel_max needs a non-empty top-k");

    // Sort the candidate ids once, up front: the sorted set doubles as the
    // tail-sampling exclusion list below, and the adjacent scan detects
    // duplicate ids *before* k is fixed — an approximate top-k that
    // returns the same id twice would otherwise inflate k, so the binomial
    // trial count n − k would disagree with the true tail-set size
    // n − |distinct(S)| and silently skew the sampling distribution
    // (Theorem 3.3's exactness argument needs the two to be equal).
    let mut excluded: Vec<usize> = top.iter().map(|&(i, _)| i).collect();
    excluded.sort_unstable();
    let had_dups = excluded.windows(2).any(|w| w[0] == w[1]);
    if had_dups {
        excluded.dedup();
    }

    // Rare slow path (exact retrieval never duplicates): collapse repeats
    // so each candidate keeps its first slot and best score and is
    // perturbed exactly once. O(k²) scan, pathological inputs only.
    let dedup_storage: Vec<(usize, f64)>;
    let top: &[(usize, f64)] = if had_dups {
        let mut d: Vec<(usize, f64)> = Vec::with_capacity(excluded.len());
        for &(idx, s) in top {
            match d.iter_mut().find(|e| e.0 == idx) {
                Some(e) => e.1 = e.1.max(s),
                None => d.push((idx, s)),
            }
        }
        dedup_storage = d;
        &dedup_storage
    } else {
        top
    };
    let k = top.len();
    debug_assert_eq!(k, excluded.len());

    // Gumbel-perturb the known scores; track max (M) and min raw score (L).
    let mut best_idx = top[0].0;
    let mut best_val = f64::NEG_INFINITY;
    let mut min_score = f64::INFINITY;
    for &(idx, s) in top {
        debug_assert!(idx < n);
        let v = s + rng.gumbel();
        if v > best_val {
            best_val = v;
            best_idx = idx;
        }
        if s < min_score {
            min_score = s;
        }
    }

    if k >= n {
        return LazySample {
            index: best_idx,
            value: best_val,
            b: f64::INFINITY,
            tail_count: 0,
            work: k,
        };
    }

    let b = best_val - min_score - margin_slack;
    let tail_p = gumbel_tail_prob(b);
    let c = binomial(rng, (n - k) as u64, tail_p) as usize;

    let mut tail_count = 0usize;
    if c > 0 {
        // `excluded` is the sorted, duplicate-free id set from above, so
        // the binomial trial count matches the tail-set size exactly.
        let tail = sample_distinct_excluding(rng, n, &excluded, c.min(n - k));
        tail_count = tail.len();
        for t in tail {
            let v = tail_score(t) + truncated_gumbel(rng, b);
            if v > best_val {
                best_val = v;
                best_idx = t;
            }
        }
    }

    LazySample { index: best_idx, value: best_val, b, tail_count, work: k + tail_count }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The heart of Theorem 3.3: with an exact top-k, lazy sampling draws
    /// from exactly the softmax distribution. χ²-style frequency check.
    #[test]
    fn matches_softmax_distribution_exact_topk() {
        let scores: Vec<f64> = vec![1.2, 0.3, -0.5, 2.0, 0.0, 1.0, -1.0, 0.8];
        let n = scores.len();
        let k = 3;
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));
        let top: Vec<(usize, f64)> = order[..k].iter().map(|&i| (i, scores[i])).collect();

        let weights: Vec<f64> = scores.iter().map(|&s| s.exp()).collect();
        let z: f64 = weights.iter().sum();

        let mut rng = Rng::new(42);
        let trials = 300_000;
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            let s = lazy_gumbel_max(&mut rng, &top, n, 0.0, |i| scores[i]);
            counts[s.index] += 1;
        }
        for i in 0..n {
            let want = weights[i] / z;
            let got = counts[i] as f64 / trials as f64;
            assert!(
                (got - want).abs() < 0.01,
                "candidate {i}: got {got:.4} want {want:.4}"
            );
        }
    }

    /// Regression: a duplicated candidate id in the (approximate) top-k
    /// must not skew the distribution. Before the dedup-before-k fix, a
    /// duplicate inflated k, shrank the Bin(n − k, ·) trial count below
    /// the true tail-set size, and double-perturbed one candidate — here
    /// the softmax frequencies must still match exactly.
    #[test]
    fn duplicated_topk_ids_do_not_skew_the_distribution() {
        let scores: Vec<f64> = vec![1.2, 0.3, -0.5, 2.0, 0.0, 1.0, -1.0, 0.8];
        let n = scores.len();
        // candidate 3 appears twice (once with a stale lower score), as a
        // sloppy approximate retriever might return it
        let top: Vec<(usize, f64)> =
            vec![(3, scores[3]), (0, scores[0]), (3, scores[3] - 0.2), (5, scores[5])];

        let weights: Vec<f64> = scores.iter().map(|&s| s.exp()).collect();
        let z: f64 = weights.iter().sum();

        let mut rng = Rng::new(77);
        let trials = 300_000;
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            let s = lazy_gumbel_max(&mut rng, &top, n, 0.0, |i| scores[i]);
            counts[s.index] += 1;
        }
        for i in 0..n {
            let want = weights[i] / z;
            let got = counts[i] as f64 / trials as f64;
            assert!(
                (got - want).abs() < 0.01,
                "candidate {i}: got {got:.4} want {want:.4}"
            );
        }
    }

    /// Expected tail work is O(√n) when k = √n (Theorem D.1).
    #[test]
    fn tail_work_is_sqrt_n() {
        let n = 10_000;
        let k = 100; // √n
        // uniform scores: worst case for the margin
        let scores = vec![0.0f64; n];
        let top: Vec<(usize, f64)> = (0..k).map(|i| (i, 0.0)).collect();
        let mut rng = Rng::new(7);
        let trials = 300;
        let mut total_work = 0usize;
        for _ in 0..trials {
            let s = lazy_gumbel_max(&mut rng, &top, n, 0.0, |i| scores[i]);
            total_work += s.work;
        }
        let avg = total_work as f64 / trials as f64;
        // E[C] ≤ n/k = √n = 100, so avg work ≤ k + n/k = 200 (+ slack)
        assert!(avg < 320.0, "avg work {avg}");
    }

    /// Algorithm 6: lowering the margin by c inflates tail sampling ~e^c.
    #[test]
    fn margin_slack_increases_tail_samples() {
        let n = 5_000;
        let k = 70;
        let top: Vec<(usize, f64)> = (0..k).map(|i| (i, 0.0)).collect();
        let mut rng = Rng::new(8);
        let avg = |rng: &mut Rng, slack: f64| {
            let trials = 200;
            let mut w = 0usize;
            for _ in 0..trials {
                w += lazy_gumbel_max(rng, &top, n, slack, |_| 0.0).tail_count;
            }
            w as f64 / trials as f64
        };
        let w0 = avg(&mut rng, 0.0);
        let w1 = avg(&mut rng, 1.0);
        let ratio = w1 / w0.max(1e-9);
        assert!(
            (ratio - std::f64::consts::E).abs() < 0.8,
            "ratio {ratio} (w0={w0}, w1={w1})"
        );
    }

    /// Max-stability: the winner's perturbed value `max_i (s_i + G_i)` is
    /// itself Gumbel(logsumexp(s)) distributed, so its mean must be
    /// `logsumexp(s) + γ`. This is the identity the sharded EM combines on.
    #[test]
    fn winning_value_is_gumbel_of_logsumexp() {
        let scores = vec![1.2f64, 0.3, -0.5, 2.0, 0.0, 1.0];
        let top: Vec<(usize, f64)> = scores.iter().cloned().enumerate().collect();
        let lse = crate::util::math::logsumexp(&scores);
        let mut rng = Rng::new(12);
        let trials = 200_000;
        let mut sum = 0.0;
        for _ in 0..trials {
            let s = lazy_gumbel_max(&mut rng, &top, scores.len(), 0.0, |_| unreachable!());
            sum += s.value;
        }
        let mean = sum / trials as f64;
        let gamma = 0.577_215_664_901_532_9;
        assert!(
            (mean - (lse + gamma)).abs() < 0.02,
            "mean {mean} vs logsumexp+γ {}",
            lse + gamma
        );
    }

    /// With k = n there is no tail; the draw degenerates to plain Gumbel-max.
    #[test]
    fn full_topk_has_no_tail() {
        let scores = vec![0.5f64, 1.5, -0.5];
        let top: Vec<(usize, f64)> = scores.iter().cloned().enumerate().collect();
        let mut rng = Rng::new(9);
        let s = lazy_gumbel_max(&mut rng, &top, 3, 0.0, |_| unreachable!());
        assert_eq!(s.tail_count, 0);
        assert!(s.index < 3);
    }

    /// Theorem F.4: with a c-approximate top-k (a candidate outside S
    /// exceeds the worst of S by c), every candidate's sampling probability
    /// stays within [e^{-c}·p_i, e^{c}·p_i] of the true softmax.
    #[test]
    fn approximate_topk_respects_f4_bounds() {
        let n = 50;
        let c = 0.5;
        // candidate 49 slightly beats the provided top-k but is excluded
        let scores: Vec<f64> = (0..n).map(|i| if i == 49 { c } else { 0.0 }).collect();
        let top: Vec<(usize, f64)> = (0..7).map(|i| (i, scores[i])).collect();

        let z: f64 = scores.iter().map(|&s| s.exp()).sum();
        let p_true = c.exp() / z;

        let mut rng = Rng::new(10);
        let mut wins = 0usize;
        let trials = 120_000;
        for _ in 0..trials {
            let s = lazy_gumbel_max(&mut rng, &top, n, 0.0, |i| scores[i]);
            if s.index == 49 {
                wins += 1;
            }
        }
        let got = wins as f64 / trials as f64;
        let (lo, hi) = ((-c).exp() * p_true, c.exp() * p_true);
        assert!(
            got >= lo * 0.9 && got <= hi * 1.1,
            "win rate {got} outside F.4 bounds [{lo}, {hi}]"
        );
    }

    /// Algorithm 6: lowering the margin by c restores exactness even with a
    /// c-approximate top-k (Theorem F.10).
    #[test]
    fn margin_slack_restores_exactness_under_approximation() {
        let n = 50;
        let c = 0.5;
        let scores: Vec<f64> = (0..n).map(|i| if i == 49 { c } else { 0.0 }).collect();
        let top: Vec<(usize, f64)> = (0..7).map(|i| (i, scores[i])).collect();

        let z: f64 = scores.iter().map(|&s| s.exp()).sum();
        let p_true = c.exp() / z;

        let mut rng = Rng::new(11);
        let mut wins = 0usize;
        let trials = 200_000;
        for _ in 0..trials {
            let s = lazy_gumbel_max(&mut rng, &top, n, c, |i| scores[i]);
            if s.index == 49 {
                wins += 1;
            }
        }
        let got = wins as f64 / trials as f64;
        assert!(
            (got - p_true).abs() < 0.15 * p_true + 0.003,
            "win rate {got} vs exact {p_true}"
        );
    }
}
