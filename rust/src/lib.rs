//! # fast-mwem
//!
//! A production-grade reproduction of **"Fast-MWEM: Private Data Release in
//! Sublinear Time"** (Haris, Choi, Laksanawisit, 2026) as an all-Rust
//! stack:
//!
//! * **Coordinator layer** — the MWEM / Fast-MWEM
//!   iteration loops, all privacy-critical randomness, the from-scratch
//!   k-MIPS indices (flat / IVF / HNSW), the lazy Gumbel exponential
//!   mechanism, private LP solvers, job coordination, config, CLI, metrics
//!   and the paper's full evaluation harness.
//! * **Layers 1–2 (runtime/kernels, in-crate)** — the dense hot-spot
//!   kernels (score matvecs, multiplicative-weight updates, k-means
//!   distances, the LP Bregman clip): runtime-dispatched `std::arch` SIMD
//!   (AVX2 on x86_64, NEON on aarch64) over a cache-aligned blocked
//!   vector layout, with the portable scalar reference in `util/math.rs`
//!   as the always-available arm every SIMD path is differentially
//!   tested against.
//!
//! Nothing but Rust runs anywhere: the kernel arm is selected once at
//! startup ([`runtime::kernels`]) and every scoring loop dispatches
//! through it.
//!
//! See `DESIGN.md` for the module inventory, the offline-build
//! substitutions (§3), the per-figure experiment index (§4), the
//! sharded-LazyEM design (§5), the warm-index serving cache (§6), the
//! persistent artifact store (§7), the long-lived serving runtime with
//! per-tenant budget admission (§8), the kernel layer (§10), the
//! HTTP/1.1 wire front end (§11) and the generic private-mechanism
//! engine with its query-class seam (§14); `EXPERIMENTS.md` records
//! paper-vs-measured results; `README.md` has the build/run quickstart.
//! A generated markdown API reference lives in `docs/api/`
//! (`./scripts/gen_api_docs.py`, drift-gated in CI).

#![warn(missing_docs)]

pub mod config;
pub mod coordinator;
pub mod dp;
pub mod eval;
pub mod lazy;
pub mod lp;
pub mod metrics;
pub mod mips;
pub mod mwem;
pub mod runtime;
pub mod sampling;
pub mod server;
pub mod store;
pub mod util;
pub mod workloads;

pub use util::rng::Rng;
