//! # fast-mwem
//!
//! A production-grade reproduction of **"Fast-MWEM: Private Data Release in
//! Sublinear Time"** (Haris, Choi, Laksanawisit, 2026) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: the MWEM / Fast-MWEM
//!   iteration loops, all privacy-critical randomness, the from-scratch
//!   k-MIPS indices (flat / IVF / HNSW), the lazy Gumbel exponential
//!   mechanism, private LP solvers, job coordination, config, CLI, metrics
//!   and the paper's full evaluation harness.
//! * **Layer 2 (python/compile/model.py, build time)** — JAX compute graphs
//!   for the dense hot-spots (score matvecs, multiplicative-weight updates),
//!   AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 1 (python/compile/kernels/, build time)** — Pallas kernels the
//!   L2 graphs are built from, validated against pure-jnp oracles.
//!
//! Python never runs on the request path: [`runtime::XlaEngine`] loads the
//! AOT artifacts through the PJRT C API (`xla` crate) once and executes them
//! from Rust.
//!
//! See `DESIGN.md` for the module inventory, the offline-build
//! substitutions (§3), the per-figure experiment index (§4), the
//! sharded-LazyEM design (§5), the warm-index serving cache (§6), the
//! persistent artifact store (§7) and the long-lived serving runtime with
//! per-tenant budget admission (§8);
//! `EXPERIMENTS.md` records paper-vs-measured results; `README.md` has the
//! build/run quickstart.

#![warn(missing_docs)]

pub mod config;
pub mod coordinator;
pub mod dp;
pub mod eval;
pub mod lazy;
pub mod lp;
pub mod metrics;
pub mod mips;
pub mod mwem;
pub mod runtime;
pub mod sampling;
pub mod server;
pub mod store;
pub mod util;
pub mod workloads;

pub use util::rng::Rng;
