//! Snapshot codec seam: byte-level encode/decode of built k-MIPS indices
//! (DESIGN.md §7).
//!
//! The persistent artifact store (`crate::store`) snapshots *built* indices
//! to disk so a coordinator restart does not throw away the Θ(m·d)+
//! preprocessing the warm-index cache amortizes. This module is the codec
//! half of that story: a [`SnapshotCodec`] trait each concrete index
//! implements next to its own fields (flat / IVF / HNSW in `mips`, the
//! sharded [`crate::lazy::ShardSet`] in `lazy`), plus the little-endian
//! byte reader/writer primitives they share. The envelope around a payload
//! — magic, format version, workload fingerprint, length, checksum — is
//! owned by `crate::store::format`; this layer encodes only the index
//! structure itself.
//!
//! The codec is hand-rolled (the offline build vendors no serde/bincode —
//! DESIGN.md §3) and **defensive on the read side**: every length is
//! validated against the remaining buffer before allocation, every id
//! against its range, so a truncated or corrupted artifact surfaces as a
//! [`SnapshotError`] — never a panic — and the store falls back to a
//! rebuild.
//!
//! Derived structure (the augmented-space norms of
//! [`super::AugmentedSpace`], for example) is *recomputed* from the stored
//! vectors rather than serialized: the recomputation is deterministic over
//! identical f32 bit patterns, so a restored index is bit-identical to a
//! fresh build over the same content, and the snapshot stays minimal.

use super::{IndexKind, MipsIndex, VectorSet};
use std::fmt;
use std::sync::Arc;

/// Why a snapshot payload could not be decoded.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SnapshotError {
    /// The buffer ended before the structure did.
    Truncated {
        /// Bytes the decoder needed.
        need: usize,
        /// Bytes the buffer still had.
        have: usize,
    },
    /// The bytes decoded but describe an impossible structure.
    Malformed(String),
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::Truncated { need, have } => {
                write!(f, "snapshot truncated: need {need} bytes, have {have}")
            }
            SnapshotError::Malformed(msg) => write!(f, "malformed snapshot: {msg}"),
        }
    }
}

impl std::error::Error for SnapshotError {}

/// Shorthand for a malformed-structure error.
pub(crate) fn malformed(msg: impl Into<String>) -> SnapshotError {
    SnapshotError::Malformed(msg.into())
}

// ---------------------------------------------------------------------------
// little-endian write primitives (append-only, infallible)
// ---------------------------------------------------------------------------

/// Append a `u8`.
pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

/// Append a `u32`, little-endian.
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a `u64`, little-endian.
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a `u128`, little-endian.
pub fn put_u128(out: &mut Vec<u8>, v: u128) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a `usize` as a `u64` (the on-disk format is width-independent).
pub fn put_len(out: &mut Vec<u8>, v: usize) {
    put_u64(out, v as u64);
}

/// Append an `f32` slice as raw little-endian bit patterns, length-prefixed.
pub fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    put_len(out, vs.len());
    for &v in vs {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

/// Append a `u32` slice little-endian, length-prefixed.
pub fn put_u32s(out: &mut Vec<u8>, vs: &[u32]) {
    put_len(out, vs.len());
    for &v in vs {
        put_u32(out, v);
    }
}

// ---------------------------------------------------------------------------
// checked read cursor
// ---------------------------------------------------------------------------

/// A bounds-checked read cursor over a snapshot buffer. Every accessor
/// returns [`SnapshotError::Truncated`] instead of panicking when the
/// buffer runs short.
pub struct SnapshotReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> SnapshotReader<'a> {
    /// Wrap a buffer for reading from its start.
    pub fn new(bytes: &'a [u8]) -> Self {
        SnapshotReader { bytes, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }

    /// True when every byte has been consumed.
    pub fn is_exhausted(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], SnapshotError> {
        if self.remaining() < n {
            return Err(SnapshotError::Truncated { need: n, have: self.remaining() });
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a `u8`.
    pub fn u8(&mut self) -> Result<u8, SnapshotError> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian `u32`.
    pub fn u32(&mut self) -> Result<u32, SnapshotError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a little-endian `u64`.
    pub fn u64(&mut self) -> Result<u64, SnapshotError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a little-endian `u128`.
    pub fn u128(&mut self) -> Result<u128, SnapshotError> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }

    /// Read a `u64` scalar as `usize` (plain values — offsets, parameters,
    /// counts that are only *validated*, never allocated from). Before
    /// sizing an allocation, use [`SnapshotReader::read_len`] instead.
    pub fn u64_as_usize(&mut self) -> Result<usize, SnapshotError> {
        let v = self.u64()?;
        if v > usize::MAX as u64 {
            return Err(malformed(format!("scalar {v} exceeds usize")));
        }
        Ok(v as usize)
    }

    /// Read a collection-length prefix (u64 on disk), validating that at
    /// least `min_bytes_per_item × len` bytes remain — so a corrupted
    /// length cannot trigger a huge allocation. `min_bytes_per_item` is
    /// the smallest on-disk footprint one item can have in the bytes that
    /// follow (clamped to ≥ 1).
    pub fn read_len(&mut self, min_bytes_per_item: usize) -> Result<usize, SnapshotError> {
        let n = self.u64()?;
        let need = (n as usize).saturating_mul(min_bytes_per_item.max(1));
        if n > usize::MAX as u64 || need > self.remaining() {
            return Err(SnapshotError::Truncated { need, have: self.remaining() });
        }
        Ok(n as usize)
    }

    /// Read a length-prefixed `f32` vector (raw bit patterns).
    pub fn f32s(&mut self) -> Result<Vec<f32>, SnapshotError> {
        let n = self.read_len(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    /// Read a length-prefixed `u32` vector.
    pub fn u32s(&mut self) -> Result<Vec<u32>, SnapshotError> {
        let n = self.read_len(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

// ---------------------------------------------------------------------------
// the codec seam
// ---------------------------------------------------------------------------

/// Byte-level snapshot codec for a built search structure. Implemented by
/// each concrete index next to its private fields ([`super::FlatIndex`],
/// [`super::IvfIndex`], [`super::HnswIndex`]) and by
/// [`crate::lazy::ShardSet`]; the store serializes through this seam so no
/// index internals leak into the on-disk format module.
///
/// Contract: `decode(&mut r)` over bytes produced by `encode` must
/// reconstruct a structure whose search results are **bit-identical** to
/// the encoded one's. Decoders must validate every length and id — a
/// corrupted buffer returns an error, never panics and never fabricates a
/// plausible-but-wrong structure.
pub trait SnapshotCodec: Sized {
    /// Append this structure's snapshot payload to `out`.
    fn encode(&self, out: &mut Vec<u8>);

    /// Reconstruct a structure from `r`, validating as it reads.
    fn decode(r: &mut SnapshotReader<'_>) -> Result<Self, SnapshotError>;
}

/// Encode a [`VectorSet`] (shape + raw f32 bit patterns). Only the logical
/// n·d values are written, row by row — the blocked layout's padding never
/// reaches disk, so these bytes are identical across layout changes.
pub fn put_vectors(out: &mut Vec<u8>, vs: &VectorSet) {
    put_len(out, vs.len());
    put_len(out, vs.dim());
    put_len(out, vs.len() * vs.dim());
    for row in vs.rows() {
        for &v in row {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }
}

/// Decode a [`VectorSet`], validating `data.len() == n × d`.
pub fn read_vectors(r: &mut SnapshotReader<'_>) -> Result<VectorSet, SnapshotError> {
    let n = r.u64_as_usize()?;
    let d = r.u64_as_usize()?;
    let data = r.f32s()?;
    if n.checked_mul(d) != Some(data.len()) {
        return Err(malformed(format!(
            "vector set shape {n}×{d} does not match {} stored values",
            data.len()
        )));
    }
    Ok(VectorSet::new(data, n, d))
}

/// Encode any built index behind the [`MipsIndex`] trait: a one-byte
/// [`IndexKind`] tag followed by the concrete codec's payload
/// ([`MipsIndex::write_snapshot`] dispatches to it).
pub fn encode_index(index: &dyn MipsIndex, out: &mut Vec<u8>) {
    put_u8(out, index.kind().tag());
    index.write_snapshot(out);
}

/// Decode an index encoded by [`encode_index`]: read the kind tag, then
/// the matching concrete payload.
pub fn decode_index(r: &mut SnapshotReader<'_>) -> Result<Arc<dyn MipsIndex>, SnapshotError> {
    let tag = r.u8()?;
    let kind = IndexKind::from_tag(tag)
        .ok_or_else(|| malformed(format!("unknown index kind tag {tag}")))?;
    Ok(match kind {
        IndexKind::Flat => Arc::new(super::FlatIndex::decode(r)?),
        IndexKind::Ivf => Arc::new(super::IvfIndex::decode(r)?),
        IndexKind::Hnsw => Arc::new(super::HnswIndex::decode(r)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mips::build_index;
    use crate::util::rng::Rng;

    fn random_set(n: usize, d: usize, seed: u64) -> VectorSet {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        VectorSet::new(data, n, d)
    }

    #[test]
    fn primitives_round_trip() {
        let mut buf = Vec::new();
        put_u8(&mut buf, 7);
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, u64::MAX - 1);
        put_u128(&mut buf, 1u128 << 100);
        put_f32s(&mut buf, &[1.5, -0.0, f32::MIN_POSITIVE]);
        put_u32s(&mut buf, &[0, 42, u32::MAX]);

        let mut r = SnapshotReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.u128().unwrap(), 1u128 << 100);
        let fs = r.f32s().unwrap();
        assert_eq!(fs.len(), 3);
        assert_eq!(fs[0].to_bits(), 1.5f32.to_bits());
        assert_eq!(fs[1].to_bits(), (-0.0f32).to_bits(), "signed zero preserved");
        assert_eq!(r.u32s().unwrap(), vec![0, 42, u32::MAX]);
        assert!(r.is_exhausted());
    }

    #[test]
    fn reader_rejects_truncation_without_panicking() {
        let mut buf = Vec::new();
        put_u64(&mut buf, 5);
        let mut r = SnapshotReader::new(&buf[..3]);
        assert!(matches!(r.u64(), Err(SnapshotError::Truncated { .. })));

        // absurd length prefix must not allocate
        let mut buf = Vec::new();
        put_u64(&mut buf, u64::MAX / 2);
        let mut r = SnapshotReader::new(&buf);
        assert!(r.f32s().is_err());
    }

    #[test]
    fn vectors_round_trip_and_validate_shape() {
        let vs = random_set(7, 3, 1);
        let mut buf = Vec::new();
        put_vectors(&mut buf, &vs);
        let back = read_vectors(&mut SnapshotReader::new(&buf)).unwrap();
        assert_eq!((back.len(), back.dim()), (7, 3));
        for (a, b) in vs.to_vec().iter().zip(back.to_vec().iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // the encoding equals the pre-blocked-layout flat encoding:
        // n, d, then one length-prefixed n·d f32 run
        let mut flat = Vec::new();
        put_len(&mut flat, vs.len());
        put_len(&mut flat, vs.dim());
        put_f32s(&mut flat, &vs.to_vec());
        assert_eq!(buf, flat, "padding must not leak into snapshot bytes");

        // inconsistent shape vs data length is malformed, not a panic
        let mut bad = Vec::new();
        put_len(&mut bad, 4);
        put_len(&mut bad, 3);
        put_f32s(&mut bad, &[0.0; 5]);
        assert!(read_vectors(&mut SnapshotReader::new(&bad)).is_err());
    }

    #[test]
    fn dyn_index_round_trips_through_kind_tag() {
        let vs = random_set(300, 8, 2);
        for kind in [IndexKind::Flat, IndexKind::Ivf, IndexKind::Hnsw] {
            let built = build_index(kind, vs.clone(), 9);
            let mut buf = Vec::new();
            encode_index(built.as_ref(), &mut buf);
            let mut r = SnapshotReader::new(&buf);
            let restored = decode_index(&mut r).unwrap();
            assert!(r.is_exhausted(), "{kind}: trailing bytes");
            assert_eq!(restored.kind(), kind);
            assert_eq!((restored.len(), restored.dim()), (300, 8));

            let mut qrng = Rng::new(3);
            for _ in 0..10 {
                let q: Vec<f32> =
                    (0..8).map(|_| qrng.uniform(-1.0, 1.0) as f32).collect();
                let a = built.top_k(&q, 12);
                let b = restored.top_k(&q, 12);
                assert_eq!(a.len(), b.len(), "{kind}");
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.id, y.id, "{kind}: ids must match exactly");
                    assert_eq!(
                        x.score.to_bits(),
                        y.score.to_bits(),
                        "{kind}: scores must be bit-identical"
                    );
                }
            }
        }
    }

    #[test]
    fn unknown_kind_tag_is_rejected() {
        let mut buf = Vec::new();
        put_u8(&mut buf, 250);
        assert!(decode_index(&mut SnapshotReader::new(&buf)).is_err());
    }
}
